"""Cocktail ensembling baseline (paper Table 1 comparison)."""
import numpy as np

from repro.core.adapter import ControllerConfig, InfAdapterController
from repro.core.cocktail import (CocktailController, majority_vote_accuracy,
                                 solve_cocktail)
from repro.core.forecaster import MovingMaxForecaster
from repro.core.profiles import paper_resnet_profiles
from repro.data.traces import paper_nonbursty_trace
from repro.sim.runner import run_experiment

PROFILES = paper_resnet_profiles(noise=0.0)


def test_majority_vote_bounds():
    # independent 3x 80% voters: 89.6%; with rho=1 -> best single
    assert abs(majority_vote_accuracy([80, 80, 80], rho=0.0) - 89.6) < 0.1
    assert majority_vote_accuracy([80, 80, 80], rho=1.0) == 80.0
    assert majority_vote_accuracy([75.0], rho=0.5) == 75.0
    mid = majority_vote_accuracy([80, 80, 80], rho=0.6)
    assert 80.0 < mid < 89.6


def test_cocktail_every_member_sized_for_full_load():
    a = solve_cocktail(PROFILES, 50.0, 30, 750.0)
    assert a.feasible
    for m, n in a.units.items():
        assert PROFILES[m].throughput(n) >= 50.0


def test_cocktail_cost_inefficiency_vs_infadapter():
    """The paper's §6 argument: ensembling sends all requests to all models,
    so at comparable accuracy Cocktail pays more resources than InfAdapter."""
    trace = paper_nonbursty_trace(seconds=600)
    cfg = ControllerConfig(budget=40, beta=0.05, gamma=0.2)
    inf = InfAdapterController(PROFILES, MovingMaxForecaster(), cfg)
    r_inf = run_experiment("inf", inf, PROFILES, trace,
                           warm_start={"resnet18": 8}, reference_accuracy=78.31)
    co = CocktailController(PROFILES, MovingMaxForecaster(), cfg)
    r_co = run_experiment("cocktail", co, PROFILES, trace,
                          warm_start={"resnet18": 8}, reference_accuracy=78.31)
    assert (r_co.summary["avg_cost_units"]
            > r_inf.summary["avg_cost_units"] * 1.1)
    # ensembles can beat the best single model's accuracy (negative loss ok)
    assert r_co.summary["avg_accuracy"] > 70.0
