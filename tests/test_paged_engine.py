"""Paged KV-cache serving engine: output parity, pool lifecycle, capacity.

Covers the DESIGN.md §Paged KV cache engine contract:
  * paged continuous batching emits the same greedy tokens as the dense
    discipline (same jitted model, different cache layout),
  * right-sized prefill admits without padding the batch to max_batch,
  * pages allocated at admission are freed at retirement (no leak across a
    full workload, including drain-on-variant-switch),
  * a small pool gates admission to memory-true capacity — requests queue
    rather than over-commit, and everything still completes,
  * pool occupancy is surfaced through summarize()/kv_pool_stats().
"""
import time

import numpy as np
import pytest

from conftest import MAX_NEW, tiny_engine, tiny_requests
from repro.serving.api import Request
from repro.serving.engine import PagedVariantBackend

_reqs = tiny_requests


def _engine(kv_cache="paged", **kw):
    return tiny_engine(kv_cache=kv_cache, **kw)


def test_paged_matches_dense_outputs():
    """Same prompts -> same greedy tokens under both KV disciplines."""
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 128, 8) for _ in range(5)]
    outs = {}
    for kv in ("dense", "paged"):
        eng = _engine(kv_cache=kv)
        eng.apply_allocation(0.0, {"small": 1})
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, tokens=p, max_new=MAX_NEW,
                               arrival=time.time()), "small")
        eng.drain(0.0)
        assert len(eng.done) == len(prompts)
        outs[kv] = {r.rid: r.output for r in eng.done}
    for i in range(len(prompts)):
        np.testing.assert_array_equal(outs["dense"][i], outs["paged"][i])


def test_paged_pallas_matches_dense_outputs():
    """The Pallas paged_flash_decode path agrees with the jnp dense path
    end-to-end (interpret mode on CPU)."""
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, 128, 8) for _ in range(2)]
    outs = {}
    for kv, pallas in (("dense", False), ("paged", True)):
        eng = _engine(kv_cache=kv, use_pallas=pallas, max_new=4)
        eng.apply_allocation(0.0, {"small": 1})
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, tokens=p, max_new=4,
                               arrival=time.time()), "small")
        eng.drain(0.0)
        outs[kv] = {r.rid: r.output for r in eng.done}
    for i in range(len(prompts)):
        np.testing.assert_array_equal(outs["dense"][i], outs["paged"][i])


def test_pages_freed_at_retirement_no_leak():
    eng = _engine()
    eng.apply_allocation(0.0, {"small": 1})
    b = eng.backends["small"]
    assert isinstance(b, PagedVariantBackend)
    rng = np.random.default_rng(3)
    for r in _reqs(7, rng):
        assert eng.submit(r, "small")
    peak = 0
    for _ in range(200):
        eng.step(0.0)
        used = b.pool.used_pages
        assert used <= b.pool.usable_pages
        # live slots and owned pages agree at every tick
        assert used == b.active_slots * b.pages_per_slot
        peak = max(peak, used)
        if len(eng.done) == 7:
            break
    assert len(eng.done) == 7
    assert peak > 0                       # the pool actually carried load
    assert b.pool.used_pages == 0         # every page returned
    assert b.pool.free_pages == b.pool.usable_pages


def test_small_pool_gates_admission_to_memory_capacity():
    """A pool holding one sequence admits one slot at a time even though the
    batch has two — memory-true capacity — and still serves everyone."""
    pps = -(-(8 + MAX_NEW) // 4)          # pages_per_slot at these params
    eng2 = _engine(kv_pool_pages=pps + 1)  # one sequence + the trash page
    eng2.apply_allocation(0.0, {"small": 1})
    b2 = eng2.backends["small"]
    assert b2.pages_per_slot == pps
    rng = np.random.default_rng(4)
    for r in _reqs(4, rng):
        assert eng2.submit(r, "small")
    max_active = 0
    for _ in range(400):
        eng2.step(0.0)
        assert b2.active_slots <= 1       # page-gated below the slot count
        max_active = max(max_active, b2.active_slots)
        if len(eng2.done) == 4:
            break
    assert len(eng2.done) == 4
    assert max_active == 1
    assert b2.pool.used_pages == 0


def test_occupancy_surfaced_mid_flight():
    eng = _engine()
    eng.apply_allocation(0.0, {"small": 1})
    rng = np.random.default_rng(5)
    for r in _reqs(2, rng, max_new=MAX_NEW):
        eng.submit(r, "small")
    eng.step(0.0)                         # both admitted, still decoding
    stats = eng.kv_pool_stats()
    b = eng.backends["small"]
    assert stats is not None
    assert stats["used_pages"] == 2 * b.pages_per_slot
    assert 0.0 < stats["occupancy"] <= 1.0
    s = eng.summarize(1e9, 70.0)
    if s:                                 # some requests may have finished
        assert "kv_pool_occupancy" in s
    eng.drain(0.0)
    assert eng.kv_pool_stats()["occupancy"] == 0.0
    assert eng.summarize(1e9, 70.0)["kv_pool_occupancy"] == 0.0
    # dense engines report no pool
    dense = _engine(kv_cache="dense")
    dense.apply_allocation(0.0, {"small": 1})
    assert dense.kv_pool_stats() is None


def test_profiler_builds_paged_backend_on_paged_engine():
    """EngineProfiler's throwaway backend must carry the engine's KV
    discipline: profiling a paged engine measures paged admission/decode
    semantics (memory-true capacity), not the dense ring."""
    from repro.profiling.measure import EngineProfiler
    eng = _engine()                       # paged, nothing loaded yet
    prof = EngineProfiler(eng, points=(1, 2), requests_per_point=4, warmup=1)
    assert isinstance(prof._backend("small"), PagedVariantBackend)
    m = prof.profile_variant("small", points=(1, 2), requests_per_point=4)
    assert m.profile.th_slope > 0 or m.profile.th_intercept > 0


def test_variant_switch_drains_paged_slots_and_frees_pages():
    eng = _engine(n_variants=2)
    eng.apply_allocation(0.0, {"small": 1})
    rng = np.random.default_rng(6)
    for r in _reqs(4, rng):
        eng.submit(r, "small")
    eng.step(0.0)                           # 2 in flight on "small", 2 queued
    b_small = eng.backends["small"]
    assert eng.in_flight() == 2
    eng.apply_allocation(1.0, {"big": 1})   # create-then-remove switch
    assert b_small.pool.used_pages == 0     # drained slots returned pages
    eng.drain(1.0)
    assert len(eng.done) == 4
    assert sum(1 for r in eng.done if r.accuracy == 75.0) == 2
    assert eng.backends["big"].pool.used_pages == 0


# ---------------------------------------------------------------------------
# prefix-sharing greedy-parity matrix (DESIGN.md §Prefix sharing)
# ---------------------------------------------------------------------------

_SHARED_PROMPT_LEN = 16
# budget must outlive several decode chunks: sharing needs the seed request
# still resident (pages live, prefix published) when the others admit
_SHARED_MAX_NEW = 6


def _shared_prefix_workload(pallas, page, gqa, sched, sharing):
    """Serve a shared-prefix workload and return {rid: tokens}, hit count.

    Five 16-token prompts over one 8-token system prefix, three of them
    byte-identical (the full-prompt match that exercises the CoW boundary
    at page size 16). Request 0 is admitted one tick early so the rest
    overlap a live, published prefix — sharing only happens between
    overlapping requests (index entries die with their pages)."""
    eng = tiny_engine(max_batch=3, prompt_len=_SHARED_PROMPT_LEN,
                      max_new=_SHARED_MAX_NEW, kv_cache="paged",
                      kv_page_size=page, kv_prefix_sharing=sharing,
                      scheduler=sched, use_pallas=pallas,
                      variant_overrides={"num_kv_heads": 2 if gqa else 4})
    eng.apply_allocation(0.0, {"small": 1})
    rng = np.random.default_rng(9)
    pre = rng.integers(0, 128, 8)
    p0 = np.concatenate([pre, rng.integers(0, 128, 8)])
    prompts = [p0, np.concatenate([pre, rng.integers(0, 128, 8)]), p0,
               np.concatenate([pre, rng.integers(0, 128, 8)]), p0]
    eng.submit(Request(rid=0, tokens=prompts[0], max_new=_SHARED_MAX_NEW,
                       arrival=time.time()), "small")
    eng.step(0.0)
    for i in range(1, len(prompts)):
        eng.submit(Request(rid=i, tokens=prompts[i], max_new=_SHARED_MAX_NEW,
                           arrival=time.time()), "small")
    eng.drain(0.0)
    assert len(eng.done) == len(prompts)
    b = eng.backends["small"]
    b.pool.assert_invariants()
    assert b.pool.used_pages == 0          # every page returned, shared too
    return ({r.rid: np.asarray(r.output) for r in eng.done},
            b.pool.prefix_hits)


_PARITY_REF = {}                           # (gqa, sched) -> sharing-off tokens


@pytest.mark.parametrize("sched", ["fifo", "chunked"])
@pytest.mark.parametrize("gqa", [True, False])
@pytest.mark.parametrize("page", [8, 16])
@pytest.mark.parametrize("pallas", [False, True])
def test_prefix_sharing_parity_matrix(pallas, page, gqa, sched):
    """Shared-prefix admission is bitwise-identical to sharing disabled
    across {jnp, Pallas} x {page 8/16} x {GQA on/off} x {chunked/monolithic
    prefill}. The sharing-off reference is computed once per model/schedule
    (jnp, page 8) — the repo's existing parity suites establish that greedy
    tokens do not move across kernel or page-size choices, so every cell
    here also re-checks that invariance."""
    on, hits = _shared_prefix_workload(pallas, page, gqa, sched, True)
    assert hits > 0                        # parity must not hold vacuously
    key = (gqa, sched)
    if key not in _PARITY_REF:
        _PARITY_REF[key] = _shared_prefix_workload(False, 8, gqa, sched,
                                                   False)[0]
    off = _PARITY_REF[key]
    assert sorted(on) == sorted(off)
    for rid in on:
        np.testing.assert_array_equal(on[rid], off[rid])
