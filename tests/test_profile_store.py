"""Profile store: JSON round-trip fidelity, provenance rules, versioning."""
import json

import pytest

from repro.core.profiles import (fit_throughput, measured_resnet_points,
                                 paper_resnet_profiles, VariantProfile)
from repro.profiling.store import (PROVENANCES, SCHEMA_VERSION, ProfileStore)


def _profile(name="v0"):
    return VariantProfile(name=name, accuracy=71.3, rt=3.25,
                          th_slope=12.125, th_intercept=1.75,
                          lat_base_ms=25.5, lat_k_ms=110.0, max_units=32)


def test_roundtrip_identical(tmp_path):
    """save -> load reproduces bit-identical VariantProfile dataclasses."""
    store = ProfileStore(str(tmp_path / "s.json"))
    fit = fit_throughput(measured_resnet_points("resnet18", noise=0.02))
    store.register(_profile(), "measured", fit=fit, meta={"note": "t"})
    store.register(_profile("v1"), "roofline")
    path = store.save()
    loaded = ProfileStore.load(path)
    assert loaded.names() == ["v0", "v1"]
    assert loaded.get("v0") == _profile()          # exact dataclass equality
    assert loaded.get("v1") == _profile("v1")
    e = loaded.entry("v0")
    assert e.provenance == "measured"
    assert e.meta == {"note": "t"}
    assert e.updated_at == store.entry("v0").updated_at
    assert e.fit.slope == fit.slope and e.fit.r_squared == fit.r_squared
    assert e.fit.points == fit.points
    # a second round-trip is a fixed point
    p2 = loaded.save(str(tmp_path / "s2.json"))
    assert ProfileStore.load(p2).get("v0") == _profile()


def test_provenance_validation_and_supersede():
    store = ProfileStore()
    with pytest.raises(ValueError):
        store.register(_profile(), "guessed")
    assert set(PROVENANCES) == {"measured", "roofline", "paper-calibrated"}
    store.register(_profile(), "paper-calibrated")
    e = store.register(_profile(), "measured")     # re-measurement overwrites
    assert e.meta["superseded"] == "paper-calibrated"
    assert store.entry("v0").provenance == "measured"


def test_schema_version_enforced(tmp_path):
    store = ProfileStore(str(tmp_path / "s.json"))
    store.register(_profile(), "measured")
    path = store.save()
    doc = json.load(open(path))
    assert doc["schema_version"] == SCHEMA_VERSION
    doc["schema_version"] = SCHEMA_VERSION + 1
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="schema_version"):
        ProfileStore.load(str(bad))


def test_paper_profiles_register(tmp_path):
    """paper_resnet_profiles registers into a store under paper-calibrated
    provenance, and the store round-trips the whole family."""
    store = ProfileStore(str(tmp_path / "resnet.json"))
    profs = paper_resnet_profiles(noise=0.01, seed=0, store=store)
    assert len(store) == 5
    loaded = ProfileStore.load(store.save())
    for name, p in profs.items():
        assert loaded.get(name) == p
        assert loaded.entry(name).provenance == "paper-calibrated"
        assert loaded.entry(name).fit is not None
