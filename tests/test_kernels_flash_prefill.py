"""Shape/dtype sweep of the flash prefill kernel vs the pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [
    # B, S,  H, KV, hd, window
    (2, 64, 4, 2, 64, 0),
    (1, 100, 8, 1, 64, 0),     # MQA + non-block-multiple seq (padding path)
    (2, 128, 4, 4, 32, 32),    # MHA + sliding window
    (1, 256, 6, 2, 128, 64),
    (1, 96, 8, 8, 256, 0),     # gemma-style head_dim=256
    (3, 48, 2, 1, 64, 16),
]


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KV,hd,window", SHAPES)
def test_flash_prefill_matches_oracle(B, S, H, KV, hd, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, S, H, hd), dtype)
    k = _rand(ks[1], (B, S, KV, hd), dtype)
    v = _rand(ks[2], (B, S, KV, hd), dtype)
    out = ops.flash_prefill(q, k, v, window=window)
    want = ref.ref_flash_prefill(q, k, v, window=window)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_flash_prefill_softcap():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (1, 64, 4, 64), jnp.float32)
    k = _rand(ks[1], (1, 64, 2, 64), jnp.float32)
    v = _rand(ks[2], (1, 64, 2, 64), jnp.float32)
    out = ops.flash_prefill(q, k, v, softcap=20.0)
    want = ref.ref_flash_prefill(q, k, v, softcap=20.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-4)


def test_flash_prefill_is_causal():
    """Changing future tokens must not change past outputs."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = _rand(ks[0], (1, 64, 4, 64), jnp.float32)
    k = _rand(ks[1], (1, 64, 2, 64), jnp.float32)
    v = _rand(ks[2], (1, 64, 2, 64), jnp.float32)
    out1 = ops.flash_prefill(q, k, v)
    k2 = k.at[:, 40:].set(9.0)
    v2 = v.at[:, 40:].set(-9.0)
    out2 = ops.flash_prefill(q, k2, v2)
    np.testing.assert_allclose(np.asarray(out1[:, :40]), np.asarray(out2[:, :40]),
                               atol=1e-5)
