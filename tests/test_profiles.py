"""Profiles: paper-calibrated ResNet relations + TPU roofline profiles."""
import numpy as np

from repro.configs import get_config
from repro.core.profiles import (fit_throughput, measured_resnet_points,
                                 paper_resnet_profiles, roofline_profile,
                                 roofline_decode_tokens_per_s,
                                 variant_ladder_profiles)


def test_paper_relations_hold():
    p = paper_resnet_profiles(noise=0.0)
    # Fig.1: R18@8 ~ R50@20 (within 10%)
    assert abs(p["resnet18"].throughput(8) - p["resnet50"].throughput(20)) \
        / p["resnet50"].throughput(20) < 0.10
    # Fig.2 feasibility: {R50:2, R101:6, R152:6} sustains 75 RPS
    cap = (p["resnet50"].throughput(2) + p["resnet101"].throughput(6)
           + p["resnet152"].throughput(6))
    assert cap >= 75.0
    # MS's best single variant at B=14 for 75 RPS is R50
    assert p["resnet50"].throughput(14) >= 75.0
    assert p["resnet101"].throughput(14) < 75.0
    assert p["resnet152"].throughput(14) < 75.0


def test_latency_model_monotone():
    p = paper_resnet_profiles(noise=0.0)["resnet152"]
    lats = [p.p99_ms(n) for n in range(1, 20)]
    assert all(a >= b for a, b in zip(lats, lats[1:]))
    assert p.min_feasible_units(750.0) is not None
    assert p.p99_ms(p.min_feasible_units(750.0)) <= 750.0


def test_regression_fit():
    fit = fit_throughput(measured_resnet_points("resnet18", noise=0.0))
    assert fit.r_squared > 0.999
    assert abs(fit.slope - 13.0) < 0.2


def test_regression_fit_r2_bounded_under_noise():
    """R² stays a valid confidence signal in [0, 1] at any noise level."""
    for name in ("resnet18", "resnet50", "resnet152"):
        for noise in (0.0, 0.02, 0.1, 0.5):
            for seed in range(5):
                fit = fit_throughput(
                    measured_resnet_points(name, noise=noise, seed=seed))
                assert 0.0 <= fit.r_squared <= 1.0
    # and it degrades monotonically-ish: heavy noise can't look perfect
    noisy = [fit_throughput(measured_resnet_points("resnet18", noise=0.5,
                                                   seed=s)).r_squared
             for s in range(8)]
    assert min(noisy) < 0.999


def test_regression_fit_slope_recovery():
    """Clean data recovers every family's calibrated (slope, intercept);
    mild measurement noise keeps the slope within a sane band."""
    from repro.core.profiles import _RESNET_TRUTH
    for name, (a, b, *_rest) in _RESNET_TRUTH.items():
        fit = fit_throughput(measured_resnet_points(name, noise=0.0))
        assert abs(fit.slope - a) < 1e-6
        assert abs(fit.intercept - b) < 1e-6
        assert fit.points == measured_resnet_points(name, noise=0.0)
        noisy = fit_throughput(measured_resnet_points(name, noise=0.02, seed=3))
        assert abs(noisy.slope - a) / a < 0.25


def test_roofline_profile_monotone_in_chips():
    cfg = get_config("tinyllama-1.1b")
    prof = roofline_profile(cfg, accuracy=70.0)
    assert prof.throughput(8) > prof.throughput(1)
    assert prof.rt > 0


def test_roofline_batching_helps_decode():
    """TPU adaptation: decode throughput grows with batch (bandwidth-bound)."""
    cfg = get_config("tinyllama-1.1b")
    t1 = roofline_decode_tokens_per_s(cfg, 1, batch=1)
    t32 = roofline_decode_tokens_per_s(cfg, 1, batch=32)
    assert t32 > 4 * t1


def test_variant_ladder_accuracy_monotone():
    from repro.profiling.store import ProfileStore
    cfg = get_config("yi-6b")
    store = ProfileStore()
    ladder = variant_ladder_profiles(cfg, store=store)
    assert all(store.entry(n).provenance == "roofline" for n in ladder)
    profs = sorted(ladder.values(), key=lambda p: p.accuracy)
    # deeper (more params) -> more accurate, slower
    assert profs[0].th_slope >= profs[-1].th_slope * 0.9
    assert len({p.accuracy for p in profs}) == len(profs)
