"""Trace generator: the paper's workload shapes."""
import numpy as np

from repro.data.traces import (arrivals_from_rate, paper_bursty_trace,
                               paper_nonbursty_trace, synthetic_twitter_trace)


def test_bursty_shape_matches_paper_fig5():
    t = paper_bursty_trace(base=40, spike=95, noise=0.0)
    assert len(t) == 1200
    assert abs(t[:550].mean() - 40) < 2          # steady
    assert t[650:780].max() > 90                 # spike
    assert t[990:1000].mean() < t[700] * 0.5     # decayed
    assert abs(t[1190] - 40) < 5                 # recovered


def test_nonbursty_gentle():
    t = paper_nonbursty_trace(noise=0.0)
    assert t.max() / t.min() < 2.5


def test_synthetic_statistics():
    t = synthetic_twitter_trace(seconds=7200, seed=3)
    assert t.min() > 0
    hour_means = t.reshape(2, 3600).mean(axis=1)
    assert (np.abs(np.diff(hour_means)) / hour_means[0] < 1.0).all()


def test_arrivals_poisson_rate():
    rate = np.full(200, 50.0, np.float32)
    arr = arrivals_from_rate(rate, seed=0)
    assert abs(len(arr) / 200 - 50.0) < 3.0
    assert (np.diff(arr) >= 0).all()
