"""Solver correctness: Eq. 1 semantics, exact==bruteforce, paper's Fig. 2."""
import numpy as np
import pytest

from repro.core.objective import assign_quotas, evaluate, loading_cost
from repro.core.profiles import VariantProfile, fit_throughput, paper_resnet_profiles
from repro.core.solver import (solve_bruteforce, solve_exact, solve_greedy,
                               solve_single_variant)

PROFILES = paper_resnet_profiles(noise=0.0)


def test_regression_fit_r_squared_matches_paper():
    """Paper Fig. 6: R^2 ~= 0.996 / 0.994 for ResNet18/50 profiles."""
    from repro.core.profiles import measured_resnet_points
    for name in ("resnet18", "resnet50"):
        fit = fit_throughput(measured_resnet_points(name, noise=0.01))
        assert fit.r_squared > 0.99


def test_fig2_budget14_selects_multivariant_set():
    """At B=14, λ=75: InfAdapter picks a multi-variant set including
    ResNet152; MS+'s best single variant is ResNet50 (paper Fig. 2)."""
    a = solve_exact(PROFILES, 75.0, 14, 750.0, beta=0.05, gamma=0.01)
    active = a.active_variants()
    assert len(active) >= 2
    assert "resnet152" in active
    ms = solve_single_variant(PROFILES, 75.0, 14, 750.0, beta=0.05, gamma=0.01)
    assert ms.active_variants() == {"resnet50"}
    assert a.aa > ms.aa  # InfAdapter's whole point


def test_exact_matches_bruteforce():
    for lam, budget in [(30, 8), (75, 14), (50, 10), (120, 20)]:
        e = solve_exact(PROFILES, lam, budget, 750.0, beta=0.05, gamma=0.01)
        b = solve_bruteforce(PROFILES, lam, budget, 750.0, beta=0.05, gamma=0.01)
        assert abs(e.objective - b.objective) < 0.15, (lam, budget)


def test_constraints_respected():
    for lam, budget in [(40, 12), (90, 20)]:
        for solver in (solve_exact, solve_greedy, solve_single_variant):
            a = solver(PROFILES, lam, budget, 750.0)
            assert a.total_units() <= budget
            for m, n in a.units.items():
                if n > 0:
                    assert PROFILES[m].p99_ms(n) <= 750.0
            if a.feasible:
                cap = sum(PROFILES[m].throughput(n)
                          for m, n in a.units.items() if n > 0)
                assert cap + 1e-6 >= lam
            for m, q in a.quotas.items():
                assert q <= PROFILES[m].throughput(a.units[m]) + 1e-6


def test_quota_waterfill_prefers_accuracy():
    units = {"resnet18": 4, "resnet152": 10}
    q = assign_quotas(PROFILES, units, 30.0)
    # resnet152 (more accurate) takes as much as its capacity allows
    assert q["resnet152"] == pytest.approx(
        min(PROFILES["resnet152"].throughput(10), 30.0))


def test_loading_cost_is_max_rt_of_cold_variants():
    lc = loading_cost(PROFILES, ["resnet18", "resnet152"], {"resnet18"})
    assert lc == PROFILES["resnet152"].rt
    assert loading_cost(PROFILES, ["resnet18"], {"resnet18"}) == 0.0


def test_infeasible_falls_back_to_best_effort():
    a = solve_exact(PROFILES, 10_000.0, 4, 750.0)
    assert not a.feasible
    assert a.total_units() >= 1  # still provisions something


def test_beta_tradeoff_direction():
    """Appendix: larger β/α prioritizes cost over accuracy."""
    lo = solve_exact(PROFILES, 60.0, 20, 750.0, beta=0.0125)
    hi = solve_exact(PROFILES, 60.0, 20, 750.0, beta=0.2)
    assert lo.aa >= hi.aa
    assert lo.rc >= hi.rc
