"""The paper's own ResNet variant family (InfAdapter backends)."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.resnet import RESNET_SPECS, apply_resnet, init_resnet


@pytest.mark.parametrize("name", ["resnet18", "resnet34", "resnet50"])
def test_resnet_forward(name):
    p = init_resnet(jax.random.PRNGKey(0), name, num_classes=10)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    y = jax.jit(lambda p, x: apply_resnet(p, name, x))(p, x)
    assert y.shape == (2, 10)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_accuracy_ladder_monotone():
    accs = [RESNET_SPECS[n][2] for n in
            ["resnet18", "resnet34", "resnet50", "resnet101", "resnet152"]]
    assert accs == sorted(accs)
