"""Prefill+decode must reproduce teacher-forcing logits for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, smoke_variant
from repro.models.model import build_model

S = 12


def _batches(cfg, B=2):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    toks = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.frontend == "vision_patches":
        batch["patch_embeds"] = jax.random.normal(
            ks[1], (B, cfg.num_frontend_tokens, 1024))
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(ks[2], (B, cfg.enc_seq, 80))
    return batch, toks


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_teacher_forcing(arch):
    cfg = smoke_variant(get_config(arch))
    m = build_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    batch, toks = _batches(cfg)
    B = toks.shape[0]
    full, _ = jax.jit(lambda p, b: m.apply(p, b, train=False))(p, batch)
    off = full.shape[1] - S  # multimodal prefix offset
    pre = dict(batch)
    pre["tokens"] = toks[:, :S - 3]
    lg, cache = jax.jit(lambda p, b: m.prefill(p, b, max_len=off + S))(p, pre)
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(full[:, off + S - 4], np.float32),
                               atol=2e-3, rtol=1e-3)
    step = jax.jit(m.decode_step)
    for i in range(3):
        lg, cache = step(p, cache, toks[:, S - 3 + i])
        np.testing.assert_allclose(
            np.asarray(lg, np.float32),
            np.asarray(full[:, off + S - 3 + i], np.float32),
            atol=2e-3, rtol=1e-3)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-130m", "hymba-1.5b"])
def test_pallas_path_matches_jnp_path(arch):
    cfg = smoke_variant(get_config(arch))
    from repro.models.model import LM
    m_ref, m_pl = LM(cfg), LM(cfg.replace(use_pallas=True))
    p = m_ref.init(jax.random.PRNGKey(0))
    batch, toks = _batches(cfg)
    lr, _ = m_ref.apply(p, batch, train=False)
    lp, _ = m_pl.apply(p, batch, train=False)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lp), atol=2e-4,
                               rtol=1e-4)


def test_sliding_window_cache_matches_full_for_long_decode():
    """A windowed ring cache must equal a full cache once window >= history."""
    cfg = smoke_variant(get_config("tinyllama-1.1b"))
    from repro.models.model import LM
    m_full = LM(cfg)
    m_win = LM(cfg.replace(sliding_window=64))  # window larger than test seq
    p = m_full.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 10), 0, cfg.vocab_size)
    lg_f, c_f = m_full.prefill(p, {"tokens": toks}, max_len=16)
    lg_w, c_w = m_win.prefill(p, {"tokens": toks}, max_len=16)
    np.testing.assert_allclose(np.asarray(lg_f), np.asarray(lg_w), atol=1e-4)
    for i in range(3):
        lg_f, c_f = m_full.decode_step(p, c_f, toks[:, i])
        lg_w, c_w = m_win.decode_step(p, c_w, toks[:, i])
        np.testing.assert_allclose(np.asarray(lg_f), np.asarray(lg_w), atol=1e-4)
