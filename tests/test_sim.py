"""Discrete-event cluster sim: queueing physics + reconfiguration semantics."""
import numpy as np

from repro.core.profiles import paper_resnet_profiles
from repro.sim.cluster import Backend, SimCluster

PROFILES = paper_resnet_profiles(noise=0.0)


def test_backend_capacity_matches_profile():
    p = PROFILES["resnet50"]
    b = Backend(p, units=8, ready_at=0.0)
    # serve at the profiled rate for 10s: latencies stay bounded
    lat = []
    th = p.throughput(8)
    for i in range(int(th * 10)):
        t = i / th
        done = b.serve(t)
        lat.append(done - t)
    assert np.percentile(np.array(lat) * 1000, 99) < p.p99_ms(8) * 1.5


def test_backend_overload_queues():
    p = PROFILES["resnet50"]
    b = Backend(p, units=2, ready_at=0.0)
    th = p.throughput(2)
    lat = []
    for i in range(int(th * 3)):
        t = i / (th * 2.0)  # 2x overload
        lat.append(b.serve(t) - t)
    assert lat[-1] > lat[0]  # queue grows


def test_new_variant_waits_for_readiness():
    c = SimCluster(PROFILES)
    c.apply_allocation(0.0, {"resnet152": 4})
    assert c.backends["resnet152"].ready_at == PROFILES["resnet152"].rt
    c.dispatch(1.0, "resnet152")
    r = c.requests[-1]
    assert r.completion >= PROFILES["resnet152"].rt


def test_zero_downtime_switch():
    """Old variant keeps serving until the replacement is ready."""
    c = SimCluster(PROFILES)
    c.apply_allocation(0.0, {"resnet18": 4})
    c.backends["resnet18"].ready_at = 0.0
    c.apply_allocation(100.0, {"resnet50": 6})
    # resnet18 must retire only once resnet50 is ready
    assert c.backends["resnet18"].retire_at >= 100.0 + PROFILES["resnet50"].rt - 1e-9
    c.dispatch(101.0, "resnet50")      # still warming -> served by resnet18
    assert c.requests[-1].backend == "resnet18"
    t_ready = 100.0 + PROFILES["resnet50"].rt + 0.1
    c.dispatch(t_ready, "resnet50")
    assert c.requests[-1].backend == "resnet50"


def test_resize_preserves_queue_and_readiness():
    c = SimCluster(PROFILES)
    c.apply_allocation(0.0, {"resnet50": 4})
    b0 = c.backends["resnet50"]
    c.apply_allocation(50.0, {"resnet50": 8})
    b1 = c.backends["resnet50"]
    assert b1.units == 8
    assert b1.ready_at == b0.ready_at  # resize never un-warms


def test_summary_metrics():
    c = SimCluster(PROFILES)
    c.apply_allocation(-PROFILES["resnet18"].rt, {"resnet18": 8})
    rng = np.random.default_rng(0)
    t = 0.0
    for _ in range(500):
        t += rng.exponential(1 / 50.0)
        c.dispatch(t, "resnet18")
    s = c.summarize(750.0, 78.31)
    assert s["n_requests"] == 500
    assert s["violation_rate"] < 0.05
    assert abs(s["avg_accuracy"] - 69.76) < 1e-6
