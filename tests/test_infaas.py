"""INFaaS-style model-less baseline (paper Table 1)."""
import numpy as np

from repro.core.adapter import ControllerConfig, InfAdapterController
from repro.core.forecaster import MovingMaxForecaster
from repro.core.infaas import INFaaSController
from repro.core.profiles import paper_resnet_profiles
from repro.data.traces import paper_nonbursty_trace
from repro.sim.runner import run_experiment

PROFILES = paper_resnet_profiles(noise=0.0)


def test_infaas_picks_cheapest_meeting_requirements():
    cfg = ControllerConfig(budget=20)
    c = INFaaSController(PROFILES, cfg, min_accuracy=75.0)
    elig = c._eligible()
    assert "resnet18" not in elig and "resnet34" not in elig  # below 75%
    assert elig[0] == "resnet50"  # cheapest per-RPS among eligible


def test_infaas_cost_aware_but_not_accuracy_maximizing():
    """Table 1: INFaaS optimizes cost ✓ but not accuracy ✗ — at equal budget
    InfAdapter ends with strictly better average accuracy."""
    trace = paper_nonbursty_trace(seconds=600)
    cfg = ControllerConfig(budget=20, beta=0.05, gamma=0.2)
    inf = InfAdapterController(PROFILES, MovingMaxForecaster(), cfg)
    r_inf = run_experiment("inf", inf, PROFILES, trace,
                           warm_start={"resnet18": 8}, reference_accuracy=78.31)
    infa = INFaaSController(PROFILES, cfg, min_accuracy=76.0)
    r_ia = run_experiment("infaas", infa, PROFILES, trace,
                          warm_start={"resnet50": 8}, reference_accuracy=78.31)
    assert r_ia.summary["violation_rate"] < 0.05       # it does meet the SLO
    assert (r_inf.summary["avg_accuracy"]
            > r_ia.summary["avg_accuracy"] + 0.3)      # but never maximizes
    assert r_ia.summary["avg_cost_units"] <= r_inf.summary["avg_cost_units"]


def test_infaas_spillover_when_primary_caps_out():
    import dataclasses
    profiles = dict(PROFILES)
    profiles["resnet50"] = dataclasses.replace(PROFILES["resnet50"],
                                               max_units=6)
    cfg = ControllerConfig(budget=20)
    c = INFaaSController(profiles, cfg, min_accuracy=76.0)

    class FakeCluster:
        def apply_allocation(self, t, units): self.units = dict(units)
        def loaded_variants(self, t): return set()
    cl = FakeCluster()
    c.monitor.record(-1, 120); c.monitor.advance_to(0)
    c.step(0.0, cl)
    active = [m for m, n in cl.units.items() if n > 0]
    assert cl.units["resnet50"] == 6          # primary capped at max_units
    assert len(active) >= 2                   # spilled to next-cheapest


def test_infaas_budget_saturation_under_overload():
    cfg = ControllerConfig(budget=8)
    c = INFaaSController(PROFILES, cfg, min_accuracy=76.0)

    class FakeCluster:
        def apply_allocation(self, t, units): self.units = dict(units)
        def loaded_variants(self, t): return set()
    cl = FakeCluster()
    c.monitor.record(-1, 500); c.monitor.advance_to(0)
    c.step(0.0, cl)
    assert sum(cl.units.values()) == 8        # uses the whole budget
