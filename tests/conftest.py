"""Shared test scaffolding.

Bootstraps ``src/`` onto ``sys.path`` (no install needed; smoke tests must
see ONE device — the 512-device XLA flag is set only inside
launch/dryrun.py), then provides the **tiny smoke geometry** used by the
engine-level test modules (test_paged_engine, test_scheduler,
test_cluster_engine carried three slightly-divergent copies of the same
constants before this conftest became the single source of truth), and
registers the hypothesis profiles the CI workflow selects via
``HYPOTHESIS_PROFILE``.
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest

# ----------------------------------------------------------- tiny geometry
# One smoke-sized serving setup: big enough to exercise paging/scheduling
# (2 slots, multi-page sequences), small enough that every jit warms in
# seconds on CPU.
VOCAB = 128
PROMPT_LEN = 8
MAX_NEW = 6


def tiny_variants(n=1, d_model=64, **overrides):
    """1–2 tiny tinyllama-derived variants: "small" (2 layers, 70.0 acc)
    and optionally "big" (3 layers, 75.0 acc). ``overrides`` are extra
    ``ModelConfig.replace`` fields (e.g. ``num_kv_heads`` for GQA
    matrices)."""
    from repro.configs import get_config, smoke_variant
    base = smoke_variant(get_config("tinyllama-1.1b")).replace(
        d_model=d_model, d_ff=128, vocab_size=VOCAB, **overrides)
    out = {"small": (base.replace(num_layers=2, name="small"), 70.0)}
    if n > 1:
        out["big"] = (base.replace(num_layers=3, name="big"), 75.0)
    return out


def tiny_requests(n, rng, max_new=MAX_NEW, prompt_len=PROMPT_LEN):
    """``n`` random-prompt requests at the tiny geometry."""
    from repro.serving.api import Request
    return [Request(rid=i, tokens=rng.integers(0, VOCAB, prompt_len),
                    max_new=max_new, arrival=time.time())
            for i in range(n)]


def tiny_engine(n_variants=1, nodes=None, variant_overrides=None, **kw):
    """``InProcessServingEngine`` at the tiny geometry; every parameter
    remains overridable. ``nodes=`` switches on the replica fabric (the
    cluster tests' spread placement default applies only then);
    ``variant_overrides`` are ModelConfig fields forwarded to
    ``tiny_variants``."""
    from repro.serving.engine import InProcessServingEngine
    kw.setdefault("max_batch", 2)
    kw.setdefault("prompt_len", PROMPT_LEN)
    kw.setdefault("max_new", MAX_NEW)
    kw.setdefault("decode_chunk", 2)
    kw.setdefault("kv_page_size", 4)
    if nodes is not None:
        kw.setdefault("placement", "spread")
        kw.setdefault("replica_size", 1)
        kw["nodes"] = nodes
    variants = tiny_variants(n_variants, **(variant_overrides or {}))
    return InProcessServingEngine(variants, **kw)


# Fixture forms for tests that prefer injection over imports; the plain
# functions above stay importable for module-level use.
@pytest.fixture
def make_tiny_variants():
    return tiny_variants


@pytest.fixture
def make_tiny_requests():
    return tiny_requests


@pytest.fixture
def make_tiny_engine():
    return tiny_engine


# ------------------------------------------------------ hypothesis profiles
# "ci" (selected by the workflow via HYPOTHESIS_PROFILE=ci): fixed seed
# (derandomize) and the raised example count the acceptance gate requires;
# "dev" keeps local runs fast. hypothesis itself is optional outside CI —
# the property tests fall back to seeded loops when it is absent.
try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "ci", max_examples=500, derandomize=True, deadline=None,
        suppress_health_check=list(HealthCheck))
    settings.register_profile("dev", max_examples=25, deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ImportError:
    pass
