import os
import sys

# Make src/ importable without install; smoke tests must see ONE device
# (the 512-device XLA flag is set only inside launch/dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
