"""Weighted round-robin: quota-proportional dispatch (paper's dispatcher)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't fail collection
from hypothesis import given, settings, strategies as st

from repro.core.dispatcher import WeightedRoundRobinDispatcher


def test_proportions_match_quotas():
    d = WeightedRoundRobinDispatcher()
    d.set_weights({"a": 30.0, "b": 60.0, "c": 10.0})
    for _ in range(1000):
        d.next_backend()
    shares = d.realized_shares()
    assert abs(shares["a"] - 0.3) < 0.02
    assert abs(shares["b"] - 0.6) < 0.02
    assert abs(shares["c"] - 0.1) < 0.02


def test_smoothness_no_bursts():
    """Smooth WRR: within any window of total-weight requests, each backend
    gets floor/ceil of its proportional share (no starvation bursts)."""
    d = WeightedRoundRobinDispatcher()
    d.set_weights({"a": 2.0, "b": 1.0})
    seq = [d.next_backend() for _ in range(30)]
    for i in range(0, 30, 3):
        win = seq[i:i + 3]
        assert win.count("a") == 2 and win.count("b") == 1


@given(weights=st.dictionaries(
    st.sampled_from(["a", "b", "c", "d"]),
    st.floats(0.5, 100.0), min_size=1, max_size=4))
@settings(max_examples=30, deadline=None)
def test_share_convergence_property(weights):
    d = WeightedRoundRobinDispatcher()
    d.set_weights(weights)
    n = 2000
    for _ in range(n):
        d.next_backend()
    total = sum(weights.values())
    for m, w in weights.items():
        assert abs(d.realized_shares().get(m, 0.0) - w / total) < 0.05


@given(weights=st.dictionaries(
    st.sampled_from(["a", "b", "c", "d"]),
    st.floats(0.5, 100.0), min_size=1, max_size=4),
    warmup=st.integers(1, 500))
@settings(max_examples=30, deadline=None)
def test_shares_converge_to_quotas_after_reset(weights, warmup):
    """reset() zeroes the counters so realized_shares reflects only the
    current run — and convergence-to-quota still holds afterwards."""
    d = WeightedRoundRobinDispatcher()
    d.set_weights(weights)
    for _ in range(warmup):              # pollute counters with a "previous run"
        d.next_backend()
    d.reset()
    assert d.realized_shares() == {}
    assert all(c == 0 for c in d.dispatched.values())
    n = 2000
    for _ in range(n):
        d.next_backend()
    assert sum(d.dispatched.values()) == n   # counts the post-reset run only
    total = sum(weights.values())
    for m, w in weights.items():
        assert abs(d.realized_shares().get(m, 0.0) - w / total) < 0.05


def test_weight_update_mid_stream():
    d = WeightedRoundRobinDispatcher()
    d.set_weights({"a": 1.0})
    assert d.next_backend() == "a"
    d.set_weights({"b": 1.0})
    assert d.next_backend() == "b"
    assert d.next_backend() == "b"
