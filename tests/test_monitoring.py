"""RateMonitor: per-second bucketing, idle-gap flushing, windowed rates."""
import numpy as np

from repro.core.monitoring import RateMonitor


def test_bucket_flush_across_idle_gap():
    """advance_to across an idle gap must emit one zero bucket per silent
    second, so windowed history reflects the lull instead of compacting it."""
    mon = RateMonitor()
    mon.record(0.2, 3)
    mon.record(0.9, 2)
    mon.advance_to(10.5)              # seconds 1..9 were silent
    h = mon.history(600)
    assert len(h) == 10               # buckets 0..9 closed; bucket 10 pending
    assert h[0] == 5.0
    assert np.all(h[1:] == 0.0)
    # arrivals after the gap land in the right bucket
    mon.record(10.7, 4)
    mon.advance_to(12.0)
    h = mon.history(600)
    assert len(h) == 12
    assert h[10] == 4.0 and h[11] == 0.0


def test_advance_is_idempotent_and_keeps_current_bucket():
    mon = RateMonitor()
    mon.record(0.0, 1)
    mon.record(5.0, 2)                # flushes 0..4
    mon.advance_to(5.9)               # same bucket: no new history
    mon.advance_to(5.99)
    assert len(mon.history(600)) == 5
    mon.advance_to(6.0)               # closes bucket 5 with its 2 arrivals
    h = mon.history(600)
    assert len(h) == 6 and h[5] == 2.0


def test_current_rate_windows():
    mon = RateMonitor()
    for t in range(10):
        mon.record(float(t), 6)
    mon.advance_to(10.0)
    assert mon.current_rate(window=5) == 6.0
    assert mon.current_rate(window=10) == 6.0
    mon.advance_to(20.0)              # 10 idle seconds dilute the window
    assert mon.current_rate(window=5) == 0.0
