"""Hypothesis property tests over the scheduling layer's invariants
(DESIGN.md §Scheduling): EDF never starves a request beyond a bounded wait
under random arrival orders, and preemption/resume never loses or
duplicates generated tokens (dense and paged, pool leak-free at every
tick). Deterministic seeded versions of the same invariants run in
tests/test_scheduler.py when hypothesis is unavailable."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't fail collection
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, smoke_variant
from repro.serving.api import Request
from repro.serving.engine import InProcessServingEngine
from repro.serving.sched import MAX_PREEMPTIONS

VOCAB = 128
MAX_NEW = 6
_RNG = np.random.default_rng(11)
PROMPTS = [_RNG.integers(0, VOCAB, 8) for _ in range(6)]


def _variants():
    base = smoke_variant(get_config("tinyllama-1.1b")).replace(
        d_model=64, d_ff=128, vocab_size=VOCAB)
    return {"small": (base.replace(num_layers=2, name="small"), 70.0)}


def _engine(**kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("prompt_len", 8)
    kw.setdefault("max_new", MAX_NEW)
    kw.setdefault("decode_chunk", 2)
    kw.setdefault("kv_page_size", 4)
    kw.setdefault("prefill_chunk", 4)
    eng = InProcessServingEngine(_variants(), **kw)
    eng.apply_allocation(0.0, {"small": 1})
    return eng


def _req(rid, prompt, slo_ms=0.0, arrival=0.0, max_new=MAX_NEW):
    return Request(rid=rid, tokens=prompt, max_new=max_new, arrival=arrival,
                   slo_ms=slo_ms)


@pytest.fixture(scope="module")
def edf_engine():
    return _engine(scheduler="edf")


@settings(max_examples=10, deadline=None)
@given(order=st.permutations(range(6)),
       slos=st.lists(st.sampled_from([20.0, 100.0, 1000.0, 1e6]),
                     min_size=6, max_size=6))
def test_edf_bounded_wait_no_starvation(edf_engine, order, slos):
    """Every request completes exactly once within a tick bound — EDF with
    expired-last ordering cannot starve any arrival order/deadline mix."""
    eng = edf_engine
    eng.done.clear()
    for j, i in enumerate(order):
        assert eng.submit(_req(i, PROMPTS[i], slo_ms=slos[j],
                               arrival=float(j)), "small")
    for _ in range(60):    # 6 reqs, 2 slots, 6 tokens in chunks of 2: << 60
        eng.step(1e6)
        if len(eng.done) == 6:
            break
    assert sorted(r.rid for r in eng.done) == list(range(6))
    assert all(r.output is not None and len(r.output) == MAX_NEW
               for r in eng.done)


@pytest.fixture(scope="module", params=["dense", "paged"])
def preempt_setup(request):
    ref_eng = _engine(kv_cache=request.param, max_new=10)
    for i, p in enumerate(PROMPTS):
        ref_eng.submit(_req(i, p, max_new=10), "small")
    ref_eng.drain(0.0)
    ref = {r.rid: np.asarray(r.output) for r in ref_eng.done}
    eng = _engine(kv_cache=request.param, scheduler="edf",
                  preemption="requeue", max_new=10, clock=lambda: 0.0)
    return eng, ref


@settings(max_examples=8, deadline=None)
@given(ids=st.permutations(range(6)),
       n_hopeless=st.integers(min_value=1, max_value=2))
def test_preemption_resume_never_loses_tokens(preempt_setup, ids,
                                              n_hopeless):
    """Hopeless requests grab the slots, feasible ones arrive behind them:
    whatever the preemption pattern, final tokens equal the unpressured
    reference, preemption count stays bounded, and the paged pool's owned
    pages always equal live slots × pages_per_slot."""
    eng, ref = preempt_setup
    eng.done.clear()
    b = eng.backends["small"]
    now = 100.0    # "hopeless" deadlines (arrival + 1ms) have passed by now
    for i in ids[:n_hopeless]:
        assert eng.submit(_req(i, PROMPTS[i], slo_ms=1.0, max_new=10,
                               arrival=0.0), "small")
    eng.step(now)                            # hopeless admitted to slots
    for i in ids[n_hopeless:]:
        assert eng.submit(_req(i, PROMPTS[i], slo_ms=1e9, max_new=10,
                               arrival=0.0), "small")
    for _ in range(200):
        eng.step(now)
        if hasattr(b, "pool"):
            assert b.pool.used_pages == b.active_slots * b.pages_per_slot
        if len(eng.done) == 6:
            break
    assert sorted(r.rid for r in eng.done) == list(range(6))
    for r in eng.done:
        assert r.preemptions <= MAX_PREEMPTIONS
        np.testing.assert_array_equal(ref[r.rid], np.asarray(r.output))
    if hasattr(b, "pool"):
        assert b.pool.used_pages == 0
