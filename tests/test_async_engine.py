"""Async two-phase dispatch/commit tick loop (DESIGN.md §Async tick loop).

The engine with ``async_tick=True`` dispatches tick t's jitted exec and
only then commits tick t-1's un-synced token arrays, hiding the D2H read
and per-slot bookkeeping behind device compute. The contract under test:

* **Greedy parity** — async outputs are bitwise identical to the sync
  default, and the done-sets match, across the KV-discipline x scheduler
  x preemption matrix. Greedy decoding is deterministic, so even where
  the one-tick commit lag shifts an admission or preemption *decision*
  by a tick (headroom lags), every request's token sequence must land
  byte-for-byte where the sync engine puts it.
* **Commit-lag mechanics** — a pending exec exists between ticks,
  ``flush_pending`` commits it on demand (the driver's fault/shutdown
  path), drain terminates, and nothing leaks: no pending exec, no
  finished-but-uncommitted zombie slot, no bound slot, no pool page.
* **Random schedules** (hypothesis when available, seeded loop
  otherwise) — arbitrary arrival gaps and lengths complete fully with
  monotone per-request span timelines under the one-tick commit lag.
"""
import numpy as np
import pytest

from conftest import MAX_NEW, PROMPT_LEN, VOCAB, tiny_engine

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _assert_clean(eng):
    """Post-drain invariants: the pipeline left nothing behind."""
    for b in eng.backends.values():
        assert b._pending is None, "un-committed exec after drain"
        assert not b._uncommitted_done, "zombie slots after drain"
        assert all(r is None for r in b.slot_req), "bound slot after drain"
        pool = getattr(b, "pool", None)
        if pool is not None:
            assert pool.used_pages == 0, "leaked pool pages after drain"


def _serve(async_tick, *, kv_cache="dense", sharing=False, scheduler="fifo",
           preemption="none", n=8, seed=0, max_ticks=600):
    """One staggered workload on a virtual clock; returns rid -> output.

    The virtual clock makes deadline math identical across the sync and
    async runs — tick *count*, not wall time, drives every decision."""
    from repro.serving.api import Request

    t = [0.0]
    kw = dict(kv_cache=kv_cache, scheduler=scheduler, preemption=preemption,
              async_tick=async_tick, clock=lambda: t[0])
    if sharing:
        kw["kv_prefix_sharing"] = True
    eng = tiny_engine(**kw)
    eng.apply_allocation(0.0, {"small": 1})
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, VOCAB, PROMPT_LEN // 2)
    for i in range(n):
        if sharing and i % 2:   # half the prompts reuse a common prefix
            toks = np.concatenate(
                [shared, rng.integers(0, VOCAB, PROMPT_LEN - len(shared))])
        else:
            toks = rng.integers(0, VOCAB, PROMPT_LEN)
        # tight deadlines on even rids so chunked-EDF preemption actually
        # fires while odd rids stay feasible waiters (no-op when
        # preemption="none": fifo never selects victims)
        slo = (30.0 if i % 2 == 0 else 5000.0) if preemption != "none" else 0.0
        eng.submit(Request(rid=i, tokens=toks,
                           max_new=int(rng.integers(2, MAX_NEW + 1)),
                           arrival=t[0], slo_ms=slo), None)
        eng.step(t[0])
        t[0] += 0.05
    for _ in range(max_ticks):
        if not eng.backlog(t[0]) and not eng.in_flight():
            break
        eng.step(t[0])
        t[0] += 0.05
    else:
        pytest.fail("drain did not terminate under commit lag")
    _assert_clean(eng)
    return {r.rid: np.asarray(r.output) for r in eng.done}


MATRIX = [
    # kv_cache, sharing, scheduler, preemption
    ("dense", False, "fifo", "none"),
    ("paged", False, "fifo", "none"),
    ("paged", True, "fifo", "none"),
    ("dense", False, "chunked", "none"),
    ("paged", False, "chunked", "none"),
    ("paged", True, "chunked", "none"),
    ("dense", False, "chunked", "requeue"),
    ("paged", True, "chunked", "requeue"),
]


@pytest.mark.parametrize("kv_cache,sharing,scheduler,preemption", MATRIX)
def test_async_greedy_parity(kv_cache, sharing, scheduler, preemption):
    kw = dict(kv_cache=kv_cache, sharing=sharing, scheduler=scheduler,
              preemption=preemption)
    sync = _serve(False, **kw)
    asyn = _serve(True, **kw)
    assert set(asyn) == set(sync), "done-sets differ"
    for rid, out in sync.items():
        assert np.array_equal(asyn[rid], out), \
            f"async output diverged from sync for rid={rid}"


def test_async_requires_continuous_mode():
    with pytest.raises(AssertionError):
        tiny_engine(mode="pump", async_tick=True)


def test_pending_exec_lives_between_ticks_and_flush_commits():
    from repro.serving.api import Request

    prompt = np.random.default_rng(3).integers(0, VOCAB, PROMPT_LEN)

    def serve_one(async_tick, probe=False):
        t = [0.0]
        eng = tiny_engine(async_tick=async_tick, clock=lambda: t[0])
        eng.apply_allocation(0.0, {"small": 1})
        b = eng.backends["small"]
        eng.submit(Request(rid=0, tokens=prompt.copy(), max_new=MAX_NEW,
                           arrival=0.0), None)
        eng.step(t[0])                   # admit + dispatch (commit q empty)
        if probe:
            assert b._pending is not None, \
                "no in-flight exec after an active tick"
            # commit on demand (the driver's fault/shutdown path) — and
            # flushing mid-run must not disturb the token stream
            eng.flush_pending(t[0])
            assert b._pending is None
        t[0] += 0.05
        for _ in range(200):
            if not eng.backlog(t[0]) and not eng.in_flight():
                break
            eng.step(t[0])
            t[0] += 0.05
        _assert_clean(eng)
        return np.asarray(eng.done[0].output)

    assert np.array_equal(serve_one(True, probe=True), serve_one(False))


def test_zombie_slot_blocks_admission_for_one_tick_only():
    """A request finished by count at dispatch holds its slot until the
    commit one tick later — admission headroom lags exactly one tick,
    never more, and the waiter still completes."""
    from repro.serving.api import Request

    t = [0.0]
    eng = tiny_engine(async_tick=True, max_batch=1, clock=lambda: t[0])
    eng.apply_allocation(0.0, {"small": 1})
    rng = np.random.default_rng(5)
    for i in range(2):                   # 1 slot, 2 requests: forced queueing
        eng.submit(Request(rid=i, tokens=rng.integers(0, VOCAB, PROMPT_LEN),
                           max_new=2, arrival=0.0), None)
    for _ in range(200):
        if not eng.backlog(t[0]) and not eng.in_flight():
            break
        eng.step(t[0])
        t[0] += 0.05
    _assert_clean(eng)
    assert sorted(r.rid for r in eng.done) == [0, 1]
    assert all(len(r.output) == 2 for r in eng.done)   # generated tokens


# ---------------------------------------------------------- property harness
# One shared async engine (jit warm-up once) serves every example; each
# example drains fully and re-checks the leak invariants, so examples are
# independent. rids are globally unique so traced timelines never mix.
_SHARED = {}


def _shared_async_engine():
    if not _SHARED:
        t = [0.0]
        eng = tiny_engine(kv_cache="paged", scheduler="chunked",
                          async_tick=True, trace=True, clock=lambda: t[0])
        eng.apply_allocation(0.0, {"small": 1})
        _SHARED.update(eng=eng, t=t, rid=iter(range(10 ** 9)))
    return _SHARED


def _check_schedule(sched):
    """Submit per (gap_ticks, max_new) schedule, drain, and assert: every
    request completes with the right length, spans are monotone in time,
    and nothing (slot/page/pending) leaks."""
    from repro.serving.api import Request

    s = _shared_async_engine()
    eng, t = s["eng"], s["t"]
    rng = np.random.default_rng(11)
    rids = []
    for gap, max_new in sched:
        for _ in range(gap):
            eng.step(t[0])
            t[0] += 0.05
        rid = next(s["rid"])
        rids.append((rid, max_new))
        eng.submit(Request(rid=rid, tokens=rng.integers(0, VOCAB, PROMPT_LEN),
                           max_new=max_new, arrival=t[0]), None)
    for _ in range(600):
        if not eng.backlog(t[0]) and not eng.in_flight():
            break
        eng.step(t[0])
        t[0] += 0.05
    else:
        pytest.fail("drain did not terminate under commit lag")
    _assert_clean(eng)
    done = {r.rid: r for r in eng.done}
    for rid, max_new in rids:
        assert rid in done, f"rid={rid} never completed"
        assert len(done[rid].output) == max_new   # generated tokens only
        ts = [ev.t for ev in eng.tracer.events.get(rid, ())]
        assert ts == sorted(ts), \
            f"span times not monotone for rid={rid}: {ts}"
        assert eng.tracer.events[rid][-1].name == "complete"


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 2), st.integers(1, MAX_NEW)),
                    min_size=1, max_size=6))
    def test_async_random_arrival_schedules(sched):
        _check_schedule(sched)
else:
    def test_async_random_arrival_schedules():
        rng = np.random.default_rng(0)
        for _ in range(25):
            sched = [(int(rng.integers(0, 3)),
                      int(rng.integers(1, MAX_NEW + 1)))
                     for _ in range(int(rng.integers(1, 7)))]
            _check_schedule(sched)
