"""Optimizer + training substrate."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.train.optimizer import AdamConfig, adam_init, adam_update, global_norm


def test_adam_converges_quadratic():
    cfg = AdamConfig(lr=0.1, warmup_steps=0, schedule="constant", grad_clip=0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adam_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adam_update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clip():
    cfg = AdamConfig(lr=0.0, grad_clip=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(3)}
    state = adam_init(params)
    _, _, m = adam_update(cfg, {"w": jnp.full(3, 100.0)}, state, params)
    assert float(m["grad_norm"]) > 100.0  # reported pre-clip


def test_warmup_schedule():
    cfg = AdamConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    params = {"w": jnp.ones(2)}
    state = adam_init(params)
    _, state, m1 = adam_update(cfg, {"w": jnp.ones(2)}, state, params)
    assert float(m1["lr"]) < 1e-3 * 0.2  # still warming up


def test_microbatched_train_step_matches_full_batch():
    """Gradient accumulation must equal the full-batch gradient step."""
    from repro.configs import get_config, smoke_variant
    from repro.launch.steps import make_train_step
    from repro.models.model import build_model
    cfg = smoke_variant(get_config("tinyllama-1.1b")).replace(
        num_layers=2, d_model=64, d_ff=128, vocab_size=64, remat=False)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = adam_init(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 64)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    p1, _, m1 = make_train_step(cfg, microbatches=1)(params, opt, batch)
    p2, _, m2 = make_train_step(cfg, microbatches=2)(params, opt, batch)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree_util.tree_leaves(p1),
                              jax.tree_util.tree_leaves(p2)))
    assert err < 1e-5
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4


def test_data_pipeline_learnable():
    from repro.data.tokens import SyntheticTokenPipeline
    pipe = SyntheticTokenPipeline(vocab=64, seq_len=32, batch=4, branching=4)
    b = pipe.next_batch()
    assert b["tokens"].shape == (4, 32)
    assert b["labels"].shape == (4, 32)
    # labels are the next tokens
    assert bool(jnp.all(b["tokens"][:, 1:] == b["labels"][:, :-1]))
