"""Checkpointing subsystem: round-trip, latest, prune, structure validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.models.model import build_model
from repro.train import checkpoint as ckpt
from repro.train.optimizer import adam_init


def _params():
    cfg = smoke_variant(get_config("tinyllama-1.1b")).replace(
        num_layers=2, d_model=32, d_ff=64, vocab_size=64)
    m = build_model(cfg)
    return m.init(jax.random.PRNGKey(0))


def test_roundtrip(tmp_path):
    params = _params()
    opt = adam_init(params)
    state = {"params": params, "opt": opt}
    ckpt.save(str(tmp_path), 100, state, metadata={"loss": 1.5})
    restored, meta = ckpt.restore(str(tmp_path), state)
    assert meta["loss"] == 1.5
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_prune(tmp_path):
    params = {"w": jnp.ones(3)}
    for s in (1, 5, 9, 12):
        ckpt.save(str(tmp_path), s, params)
    assert ckpt.latest_step(str(tmp_path)) == 12
    ckpt.prune(str(tmp_path), keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 12
    restored, _ = ckpt.restore(str(tmp_path), params, step=9)


def test_structure_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"w": jnp.ones(3)})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), {"w": jnp.ones(3), "b": jnp.ones(2)})


def test_missing_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path / "none"), {"w": jnp.ones(1)})
