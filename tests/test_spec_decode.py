"""Speculative decoding on the variant ladder: parity, rollback, migration.

Covers the DESIGN.md §Speculative decoding contract:
  * ``verify_chunk`` scores a (B, k+1) proposed slice in one call and its
    per-position argmax equals teacher-forcing greedy at every offset,
  * engine-level speculative greedy output is BITWISE identical to
    target-only decoding across the full {dense, paged, paged+sharing} x
    {fifo, chunked} x {sync, async_tick} matrix (acceptance only commits
    the longest agreeing prefix + the verifier's own bonus token, so the
    committed stream is the target model's stream by induction),
  * rejected drafts never leak: the paged pool balances to zero after
    drain in every paged combo,
  * per-slot acceptance telemetry reaches the obs registry, and a
    correlated drafter (same weights as the verifier) accepts everything,
  * cross-variant preemptive migration resumes a preempted request on a
    cheaper variant with every generated token preserved.

(The rollback-never-leaks hypothesis rule lives in test_paged_prefix.py
next to the rest of the poisoned-mirror pool harness.)
"""
import time

import jax
import numpy as np
import pytest

from conftest import MAX_NEW, PROMPT_LEN, VOCAB, tiny_variants
from repro.serving.api import Request
from repro.serving.engine import InProcessServingEngine
from repro.serving.sched import migration_target

N_REQ = 5


def _reqs(n=N_REQ, seed=0, max_new=MAX_NEW):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, tokens=rng.integers(0, VOCAB, PROMPT_LEN),
                    max_new=max_new, arrival=time.time()) for i in range(n)]


def _run(speculative, kv_cache="dense", sharing=False, scheduler="fifo",
         async_tick=False, spec_k=2, variants=None, target="big"):
    kw = dict(max_batch=2, prompt_len=PROMPT_LEN, max_new=MAX_NEW,
              decode_chunk=2, kv_cache=kv_cache, kv_page_size=4,
              kv_prefix_sharing=sharing, scheduler=scheduler,
              async_tick=async_tick)
    if speculative:
        kw.update(speculative=speculative, spec_k=spec_k)
    eng = InProcessServingEngine(variants or tiny_variants(2), **kw)
    eng.apply_allocation(0.0, {target: 1})
    for r in _reqs():
        assert eng.submit(r, target)
    eng.drain(0.0)
    assert len(eng.done) == N_REQ
    return {r.rid: np.asarray(r.output) for r in eng.done}, eng


# Target-only greedy output is invariant across KV layout / scheduler /
# tick mode (test_async_engine pins that parity), so ONE dense reference
# serves the whole speculative matrix — and transitively asserts the
# invariance again through the speculative path.
_REF = {}


def _reference():
    if not _REF:
        out, _ = _run(None)
        _REF.update(out)
    return _REF


MATRIX = [(kv, sh, sc, at)
          for (kv, sh) in (("dense", False), ("paged", False),
                           ("paged", True))
          for sc in ("fifo", "chunked")
          for at in (False, True)]


@pytest.mark.parametrize("kv_cache,sharing,scheduler,async_tick", MATRIX)
def test_spec_greedy_parity(kv_cache, sharing, scheduler, async_tick):
    """Speculative == target-only, bitwise, across the full matrix."""
    ref = _reference()
    got, eng = _run("small:big", kv_cache, sharing, scheduler, async_tick)
    for rid in ref:
        np.testing.assert_array_equal(ref[rid], got[rid])
    # rollback never leaks: every page returns to the pool (or parks on
    # the retained tier, which free_pages already counts)
    if kv_cache == "paged":
        st = eng.kv_pool_stats()
        assert st["used_pages"] == 0
        assert st["retained_pages"] >= 0
    s = eng.summarize(60_000, 75.0)
    assert s["spec_tokens_per_step"] >= 1.0     # bonus token floor
    assert eng.metrics.value("spec.rounds") > 0
    assert eng.metrics.value("spec.committed_tokens") == N_REQ * (MAX_NEW - 1)


def test_correlated_drafter_accepts_everything():
    """A drafter with the verifier's own weights agrees at every position,
    so acceptance is 1.0 and tokens/verifier-step hits the k+1 sequence
    budget allows — the speedup headroom the bench gates on."""
    variants = tiny_variants(2)
    cfg, _ = variants["big"]
    variants["twin"] = (cfg.replace(name="twin"), 60.0)
    out, eng = _run("twin:big", variants=variants)
    for rid, toks in _reference().items():
        np.testing.assert_array_equal(toks, out[rid])
    s = eng.summarize(60_000, 75.0)
    assert s["spec_accept_rate"] == 1.0
    # MAX_NEW-1 post-prefill tokens in ceil((MAX_NEW-1)/(k+1)) rounds
    assert s["spec_tokens_per_step"] == pytest.approx(2.5)


def test_verify_chunk_matches_teacher_forcing():
    """pred[:, j] == greedy argmax after consuming tokens[:, :j+1] — the
    one-call verify is exactly k+1 steps of target-only decoding."""
    from repro.configs import get_config, smoke_variant
    from repro.models.model import build_model
    cfg = smoke_variant(get_config("tinyllama-1.1b")).replace(
        d_model=64, d_ff=128, vocab_size=VOCAB, num_layers=2)
    m = build_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    S, S0, k = 12, 8, 3
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0, VOCAB)
    full, _ = jax.jit(lambda p, b: m.apply(p, b, train=False))(
        p, {"tokens": toks})
    _, cache = jax.jit(lambda p, b: m.prefill(p, b, max_len=S))(
        p, {"tokens": toks[:, :S0]})
    start = np.full((2,), S0, np.int32)
    nv = np.full((2,), k + 1, np.int32)
    pred, _ = jax.jit(m.verify_chunk)(p, cache, toks[:, S0:S0 + k + 1],
                                      start, nv)
    want = np.argmax(np.asarray(full[:, S0:S0 + k + 1]), axis=-1)
    np.testing.assert_array_equal(np.asarray(pred), want)


def test_migration_target_policy():
    class B:                                    # accuracy-only stand-in
        def __init__(self, acc):
            self.accuracy = acc
    backends = {"s": B(70.0), "m": B(72.0), "b": B(75.0)}
    # cheapest strictly-cheaper backend wins
    assert migration_target("b", backends, {}) == "s"
    assert migration_target("m", backends, {}) == "s"
    # nothing cheaper loaded -> stay home (plain requeue semantics)
    assert migration_target("s", backends, {}) is None
    # accuracy ties break toward the shorter queue
    backends["s2"] = B(70.0)
    queues = {"s": [1, 2, 3], "s2": [1]}
    assert migration_target("b", backends, queues) == "s2"


def test_cross_variant_migration_resume_parity():
    """preemption="migrate": a deadline-hopeless resident resumes on the
    cheaper variant with its generated prefix preserved verbatim, finishes
    its full budget there, and reports the cheaper accuracy; feasible
    requests keep the expensive tier."""
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, VOCAB, PROMPT_LEN) for _ in range(6)]
    eng = InProcessServingEngine(tiny_variants(2), max_batch=2,
                                 prompt_len=PROMPT_LEN, max_new=10,
                                 decode_chunk=2, scheduler="edf",
                                 preemption="migrate", clock=lambda: 0.0)
    eng.apply_allocation(0.0, {"small": 1, "big": 1})
    now = 100.0            # hopeless = arrival + slo long past
    hopeless = [Request(rid=i, tokens=prompts[i], max_new=10, arrival=0.0,
                        slo_ms=1.0) for i in range(2)]
    for r in hopeless:
        assert eng.submit(r, "big")
    eng.step(now)                               # admit the hopeless pair
    for i in range(2, 6):
        assert eng.submit(Request(rid=i, tokens=prompts[i], max_new=10,
                                  arrival=0.0, slo_ms=1e9), "big")
    resumed = {}
    for _ in range(300):
        eng.step(now)
        for r in hopeless:                      # snapshot at preemption
            if r.resume_tokens is not None and r.rid not in resumed:
                resumed[r.rid] = list(r.resume_tokens)
        if len(eng.done) == 6:
            break
    assert sorted(r.rid for r in eng.done) == list(range(6))
    assert eng.metrics.value("requests.migrated") >= 1
    by_rid = {r.rid: r for r in eng.done}
    migrated = [r for r in eng.done if r.accuracy == 70.0]
    assert migrated and all(r.rid in (0, 1) for r in migrated)
    for r in migrated:
        assert len(r.output) == 10              # full budget, on-variant
        pre = resumed[r.rid]
        assert pre                               # tokens existed to preserve
        np.testing.assert_array_equal(np.asarray(r.output)[:len(pre)],
                                      np.asarray(pre))
    for i in range(2, 6):                       # feasible stayed expensive
        assert by_rid[i].accuracy == 75.0
