"""Continuous-batching engine: slot lifecycle, pump-equivalence, backpressure.

Covers the DESIGN.md §Continuous batching contract:
  * slot admission/retirement invariants (never more in flight than slots,
    slots are reused, every submitted request completes exactly once),
  * output equivalence with the legacy pump path on identical prompts
    (same jitted model functions -> same greedy tokens),
  * backlog() reports true admission-queue depth under queued load,
  * bounded queues reject (backpressure) instead of growing without bound,
  * create-then-remove drains in-flight work and requeues waiting requests.
"""
import time

import numpy as np

from repro.configs import get_config, smoke_variant
from repro.serving.api import ClusterAPI, Request, ServingAPI
from repro.serving.engine import InProcessServingEngine

MAX_NEW = 6


def _variants(n=1):
    base = smoke_variant(get_config("tinyllama-1.1b")).replace(
        d_model=64, d_ff=128, vocab_size=128)
    out = {"small": (base.replace(num_layers=2, name="small"), 70.0)}
    if n > 1:
        out["big"] = (base.replace(num_layers=3, name="big"), 75.0)
    return out


def _reqs(n, rng, max_new=MAX_NEW, prompt_len=8):
    return [Request(rid=i, tokens=rng.integers(0, 128, prompt_len),
                    max_new=max_new, arrival=time.time()) for i in range(n)]


def _engine(mode="continuous", **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("prompt_len", 8)
    kw.setdefault("max_new", MAX_NEW)
    kw.setdefault("decode_chunk", 2)
    return InProcessServingEngine(_variants(), mode=mode, **kw)


def test_slot_admission_and_retirement_invariants():
    eng = _engine()
    eng.apply_allocation(0.0, {"small": 1})
    rng = np.random.default_rng(0)
    n = 7                                   # > 3x slot count
    for r in _reqs(n, rng):
        assert eng.submit(r, "small")
    b = eng.backends["small"]
    seen = set()
    for _ in range(200):
        assert 0 <= b.active_slots <= b.max_batch
        # active slots and free slots partition the batch
        assert b.active_slots + len(b.free_slots) == b.max_batch
        eng.step(0.0)
        for r in eng.done:
            seen.add(r.rid)
        if len(eng.done) == n:
            break
    assert len(eng.done) == n               # everyone completes...
    assert seen == set(range(n))            # ...exactly once (no dup/loss)
    assert eng.in_flight() == 0 and eng.backlog(0.0) == 0
    for r in eng.done:
        assert r.output.shape == (MAX_NEW,)
        assert r.accuracy == 70.0


def test_continuous_matches_pump_outputs():
    """Same prompts -> same greedy tokens on both execution paths."""
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 128, 8) for _ in range(5)]
    outs = {}
    for mode in ("pump", "continuous"):
        eng = _engine(mode=mode)
        eng.apply_allocation(0.0, {"small": 1})
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, tokens=p, max_new=MAX_NEW,
                               arrival=time.time()), "small")
        assert eng.pump(0.0) == len(prompts)
        outs[mode] = {r.rid: r.output for r in eng.done}
    for i in range(len(prompts)):
        np.testing.assert_array_equal(outs["pump"][i], outs["continuous"][i])


def test_backlog_reports_queue_depth():
    eng = _engine()
    eng.apply_allocation(0.0, {"small": 1})
    rng = np.random.default_rng(2)
    for r in _reqs(6, rng):
        eng.submit(r, "small")
    assert eng.backlog(0.0) == 6.0          # nothing admitted yet
    eng.step(0.0)                           # admits max_batch=2 into slots
    assert eng.backlog(0.0) == 4.0
    assert eng.in_flight() == 2
    eng.drain(0.0)
    assert eng.backlog(0.0) == 0.0 and eng.in_flight() == 0


def test_backpressure_rejects_when_queue_full():
    eng = _engine(queue_cap=3)
    eng.apply_allocation(0.0, {"small": 1})
    rng = np.random.default_rng(3)
    results = [eng.submit(r, "small") for r in _reqs(5, rng)]
    assert results == [True, True, True, False, False]
    assert eng.rejected == 2
    assert eng.backlog(0.0) == 3.0
    s_before = eng.drain(0.0)
    assert s_before == 3                    # only admitted requests serve
    assert eng.summarize(60_000, 75.0)["rejected"] == 2


def test_variant_switch_drains_and_requeues():
    eng = InProcessServingEngine(_variants(2), max_batch=2, prompt_len=8,
                                 max_new=MAX_NEW, decode_chunk=2)
    eng.apply_allocation(0.0, {"small": 1})
    rng = np.random.default_rng(4)
    for r in _reqs(4, rng):
        eng.submit(r, "small")
    eng.step(0.0)                           # 2 in flight on "small", 2 queued
    assert eng.in_flight() == 2
    eng.apply_allocation(1.0, {"big": 1})   # create-then-remove switch
    # in-flight work on the retiring variant completed at its accuracy
    assert sum(1 for r in eng.done if r.accuracy == 70.0) >= 2
    # waiting requests were requeued onto the survivor, none lost
    assert eng.backlog(1.0) == 2.0
    eng.drain(1.0)
    assert len(eng.done) == 4
    assert sum(1 for r in eng.done if r.accuracy == 75.0) == 2


def test_engine_and_sim_implement_shared_protocols():
    from repro.core.profiles import paper_resnet_profiles
    from repro.sim.cluster import SimCluster
    eng = _engine()
    sim = SimCluster(paper_resnet_profiles())
    for obj in (eng, sim):
        assert isinstance(obj, ClusterAPI)
        assert isinstance(obj, ServingAPI)
