"""Online-observability tests (DESIGN.md §Observability, "Online tier").

Unit coverage for the rolling-window instruments (bucket expiry, backward
stamps, window queries), the SLO burn-rate monitor (multi-window AND rule,
cooldown, min-requests guard), the flight recorder (bounded rings, rate
limiting, schema-valid dumps), and the ``attach_measured`` edge cases.
Integration coverage: engine and sim feed identical windowed metric names
and identical burn-rate alert semantics (parity); a controller wired with
``burn_alerts=`` re-solves on an injected slowdown BEFORE the next
interval tick; the dispatch profiler lands the host/device split on
sampled TickRecords; tracer drop counters stay zero on normal runs.
"""
import json
import math
import os

import numpy as np
import pytest

from conftest import MAX_NEW, PROMPT_LEN, VOCAB, tiny_engine

from repro.obs import (Alert, BurnRateRule, CollectingSink, DecisionAudit,
                       FlightRecorder, FlightTrigger, MetricWindows,
                       NULL_WINDOWS, Observability, SLOMonitor,
                       dispatch_floor_summary, slo_class_key)
from repro.obs.export import (assert_zero, summarize_file,
                              validate_metrics_file, validate_trace_file,
                              write_metrics_jsonl)
from repro.obs.slo import bad_metric, good_metric
from repro.obs.windows import WindowedCounter, WindowedHistogram


# ------------------------------------------------------------- windows
def test_windowed_counter_totals_and_expiry():
    c = WindowedCounter("x", window_s=10.0, n_buckets=10)  # 1 s buckets
    c.inc(0.5)
    c.inc(1.5, 2)
    c.inc(2.5)
    assert c.total(2.5) == 4.0
    assert c.total(2.5, window_s=1.0) == 1.0      # newest bucket only
    assert c.total(2.5, window_s=2.0) == 3.0
    # advancing 10 s expires everything; rate follows
    assert c.total(12.6) == 0.0
    assert c.rate(12.6) == 0.0


def test_windowed_counter_backward_stamp_clamps_and_negative_raises():
    c = WindowedCounter("x", window_s=10.0, n_buckets=10)
    c.inc(5.0)
    c.inc(1.0)          # behind the newest bucket: clamps into it
    assert c.total(5.0, window_s=1.0) == 2.0
    with pytest.raises(ValueError):
        c.inc(6.0, -1)


def test_windowed_counter_large_clock_jump_resets_ring():
    c = WindowedCounter("x", window_s=10.0, n_buckets=10)
    for t in range(10):
        c.inc(float(t))
    assert c.total(9.0) == 10.0
    c.inc(1e6)          # jump far past the ring: only the new bucket lives
    assert c.total(1e6) == 1.0


def test_windowed_histogram_stats_and_expiry():
    h = WindowedHistogram("lat", window_s=10.0, n_buckets=10)
    for i, v in enumerate([5.0, 7.0, 10.0, 12.0]):
        h.observe(float(i), v)
    assert h.count(3.0) == 4
    assert h.mean(3.0) == pytest.approx(8.5)
    assert h.percentile(3.0, 50) == pytest.approx(8.5)
    assert h.count(3.0, window_s=1.0) == 1        # newest bucket
    assert h.count(30.0) == 0
    assert math.isnan(h.mean(30.0))
    assert math.isnan(h.percentile(30.0, 99))


def test_windowed_histogram_sample_cap_keeps_exact_count():
    h = WindowedHistogram("lat", window_s=10.0, n_buckets=10, cap=4)
    for _ in range(20):
        h.observe(0.5, 1.0)
    assert h.count(0.5) == 20                      # count/sum stay exact
    assert h.mean(0.5) == pytest.approx(1.0)


def test_metric_windows_map_and_null():
    w = MetricWindows(window_s=10.0, n_buckets=10)
    assert w.on
    w.inc("a", 1.0, 2)
    w.observe("b", 1.0, 3.0)
    assert w.names() == ["a", "b"]
    assert w.counter("a").total(1.0) == 2.0
    assert w.rate("a", 1.0, window_s=10.0) == pytest.approx(0.2)
    assert w.rate("b", 1.0) == 0.0                 # histogram: no rate
    assert not NULL_WINDOWS.on
    NULL_WINDOWS.inc("a", 0.0)                     # no-op, no state
    assert NULL_WINDOWS.names() == []


def test_window_snapshot_rows_validate(tmp_path):
    w = MetricWindows(window_s=10.0, n_buckets=10)
    w.inc("req", 1.0, 3)
    w.observe("lat", 1.0, 9.0)
    rows = w.snapshot(1.0)
    assert {r["kind"] for r in rows} == {"window_counter",
                                         "window_histogram"}
    p = tmp_path / "m.jsonl"
    with open(p, "w") as f:
        for r in rows:
            f.write(json.dumps(r) + "\n")
    assert validate_metrics_file(str(p)) == 2


# ----------------------------------------------------------------- slo
def test_slo_class_key_formats():
    assert slo_class_key(750.0) == "750"
    assert slo_class_key(1500.5) == "1500.5"
    assert slo_class_key(0.0) == "none"
    assert slo_class_key(-1.0) == "none"
    assert good_metric("750") == "slo.class.750.good"
    assert bad_metric("none") == "slo.class.none.bad"


def _fed_windows(goods, bads, cls="750"):
    """Windows with (t, n) good/bad feeds for one class."""
    w = MetricWindows(window_s=60.0, n_buckets=60)
    for t, n in goods:
        w.inc(good_metric(cls), t, n)
    for t, n in bads:
        w.inc(bad_metric(cls), t, n)
    return w


def test_burn_rate_monitor_fires_on_both_windows():
    w = _fed_windows(goods=[], bads=[(t, 2) for t in range(0, 31)])
    sink = CollectingSink()
    mon = SLOMonitor(w, budget=0.05,
                     rules=(BurnRateRule(fast_s=5.0, slow_s=30.0),),
                     sinks=(sink,), min_requests=5)
    fired = mon.check(30.0)
    assert len(fired) == 1
    a = fired[0]
    assert a.slo_class == "750" and a.kind == "burn_rate"
    assert a.burn_fast == pytest.approx(20.0)      # all-bad / 0.05 budget
    assert a.burn_slow == pytest.approx(20.0)
    assert sink.pending() == 1
    assert sink.pop_pending() == [a] and sink.pending() == 0
    assert sink.alerts == [a]                      # history survives pop


def test_burn_rate_needs_slow_window_too():
    # bad only in the last 3 s: the 5 s fast window burns, the 30 s slow
    # window is still mostly good -> no alert (one-bucket blip filter)
    w = _fed_windows(goods=[(t, 10) for t in range(0, 27)],
                     bads=[(t, 2) for t in (27, 28, 29)])
    mon = SLOMonitor(w, budget=0.05,
                     rules=(BurnRateRule(fast_s=3.0, slow_s=30.0),))
    assert mon.check(29.5) == []


def test_burn_rate_min_requests_silences_noise():
    w = _fed_windows(goods=[], bads=[(0.5, 2)])    # 2 < min_requests
    mon = SLOMonitor(w, budget=0.05,
                     rules=(BurnRateRule(fast_s=5.0, slow_s=30.0),),
                     min_requests=5)
    assert mon.burn_rate("750", 1.0, 5.0) is None
    assert mon.check(1.0) == []


def test_burn_rate_cooldown_rearms():
    w = _fed_windows(goods=[], bads=[(float(t), 2) for t in range(0, 60)])
    mon = SLOMonitor(w, budget=0.05,
                     rules=(BurnRateRule(fast_s=5.0, slow_s=30.0),),
                     cooldown_s=10.0)
    assert len(mon.check(30.0)) == 1
    assert mon.check(35.0) == []                   # inside cooldown
    assert len(mon.check(41.0)) == 1               # re-armed
    assert len(mon.alerts) == 2


def test_monitor_disabled_windows_noop():
    mon = SLOMonitor(NULL_WINDOWS)
    assert mon.check(0.0) == []


# ----------------------------------------------------- controller reaction
def _mini_controller(burn_alerts=None, reactive=False):
    from repro.core.adapter import ControllerConfig, InfAdapterController
    from repro.core.forecaster import MovingMaxForecaster
    from repro.core.profiles import paper_resnet_profiles
    cfg = ControllerConfig(interval_s=30.0, budget=8, slo_ms=750.0,
                           reactive=reactive)
    profiles = paper_resnet_profiles()
    ctrl = InfAdapterController(profiles, MovingMaxForecaster(window=10),
                                cfg, burn_alerts=burn_alerts)
    return ctrl, profiles


def test_maybe_react_resolves_on_burn_alert_without_reactive():
    from repro.sim.cluster import SimCluster
    sink = CollectingSink()
    ctrl, profiles = _mini_controller(burn_alerts=sink, reactive=False)
    sim = SimCluster(profiles)
    ctrl.monitor.record(0.0, 5)
    ctrl.step(0.0, sim)
    assert ctrl.maybe_react(3.0, sim) is None      # no alert pending
    sink.emit(Alert(t=3.0, slo_class="750", rule="fast5s/slow30s",
                    burn_fast=20.0, burn_slow=20.0, budget=0.05))
    d = ctrl.maybe_react(3.0, sim)
    assert d is not None and d.t == 3.0
    assert ctrl.audit.entries[-1].reason == "burn_rate"
    assert sink.pending() == 0                     # alert consumed
    # next interval step reverts to the normal reason
    ctrl.step(30.0, sim)
    assert ctrl.audit.entries[-1].reason == "interval"


def test_maybe_react_without_sink_keeps_legacy_gate():
    from repro.sim.cluster import SimCluster
    ctrl, profiles = _mini_controller(burn_alerts=None, reactive=False)
    sim = SimCluster(profiles)
    ctrl.monitor.record(0.0, 5)
    ctrl.step(0.0, sim)
    assert ctrl.maybe_react(3.0, sim) is None      # not reactive, no sink


def test_sim_burn_alert_resolves_before_next_interval():
    """End-to-end on the virtual clock: a replica slowdown makes requests
    miss their SLO, the monitor trips mid-interval, and the controller
    re-solves (reason burn_rate) BEFORE the next 30 s interval tick."""
    from repro.cluster import make_nodes
    from repro.cluster.faults import FaultSchedule, replica_slowdown
    from repro.sim.cluster import SimCluster
    from repro.sim.runner import run_experiment

    sink = CollectingSink()
    ctrl, profiles = _mini_controller(burn_alerts=sink, reactive=False)
    obs = Observability(windows=True)
    sim = SimCluster(profiles, nodes=make_nodes(2, 8), replica_size=1,
                     obs=obs)
    mon = SLOMonitor(obs.windows, budget=0.05,
                     rules=(BurnRateRule(fast_s=5.0, slow_s=15.0),),
                     sinks=(sink,), cooldown_s=60.0, min_requests=3)
    rate = np.full(60, 8.0)
    faults = FaultSchedule([])      # slowdown applied after warm-up below
    result = None

    # inject the slowdown on every replica shortly after t=10
    class SlowAt(FaultSchedule):
        def __init__(self):
            super().__init__([])
            self.done = False

        def next_t(self):
            return 10.0 if not self.done else float("inf")

        def apply_due(self, t, cluster):
            if self.done or t < 10.0:
                return []
            self.done = True
            evs = []
            for rid in list(cluster.fabric.replicas):
                e = replica_slowdown(10.0, rid, 50.0)
                cluster.inject_fault(10.0, e)
                evs.append(e)
            return evs

    result = run_experiment("burn", ctrl, profiles, rate, slo_ms=750.0,
                            interval_s=30.0, seed=0, cluster=sim,
                            warm_start={list(profiles)[0]: 1},
                            faults=SlowAt(), slo_monitor=mon)
    assert result is not None
    assert len(mon.alerts) >= 1
    burn = [e for e in ctrl.audit.entries if e.reason == "burn_rate"]
    assert burn, "no burn_rate re-solve recorded"
    assert 10.0 < burn[0].t < 30.0      # reacted before the interval tick


# ------------------------------------------------------ engine/sim parity
def _run_windowed_engine(slo_ms, **kw):
    from repro.serving.api import Request
    clk = [0.0]
    obs = Observability(trace=True, windows=True)
    eng = tiny_engine(clock=lambda: clk[0], obs=obs, queue_cap=64, **kw)
    name = next(iter(eng.variant_defs))
    eng.apply_allocation(0.0, {name: 1})
    rng = np.random.default_rng(1)
    for i in range(6):
        eng.submit(Request(rid=i, tokens=rng.integers(0, VOCAB, PROMPT_LEN),
                           max_new=MAX_NEW, arrival=clk[0], slo_ms=slo_ms),
                   None)
        eng.step(clk[0])
        clk[0] += 0.01
    for _ in range(500):
        if not (eng.backlog(clk[0]) or eng.in_flight()):
            break
        eng.step(clk[0])
        clk[0] += 0.01
    assert len(eng.done) == 6
    return eng, clk[0]


def _run_windowed_sim(slo_ms):
    from repro.core.profiles import paper_resnet_profiles
    from repro.serving.api import Request
    from repro.sim.cluster import SimCluster
    profiles = paper_resnet_profiles()
    obs = Observability(windows=True)
    sim = SimCluster(profiles, obs=obs)
    name = next(iter(profiles))
    sim.apply_allocation(-100.0, {name: 2})
    for i in range(20):
        sim.submit(Request(rid=i, tokens=np.zeros(0, np.int64), max_new=1,
                           arrival=float(i) * 0.05, slo_ms=slo_ms), name)
    sim.drain(2.0)
    return sim, 2.0


WINDOW_CORE = {"requests.submitted", "requests.completed",
               "request.latency_ms"}


def test_engine_and_sim_emit_same_windowed_names():
    slo = 750.0
    eng, t_e = _run_windowed_engine(slo)
    sim, t_s = _run_windowed_sim(slo)
    cls = slo_class_key(slo)
    for w, t in ((eng.windows, t_e), (sim.windows, t_s)):
        names = set(w.names())
        assert WINDOW_CORE <= names
        # every completion lands in exactly one per-class counter
        good = w.counter(good_metric(cls)).total(t)
        bad = w.counter(bad_metric(cls)).total(t)
        assert good + bad == w.counter("requests.completed").total(t) > 0
    # same vocabulary modulo the engine's extra goodput window
    e_names = {n for n in eng.windows.names()
               if n in WINDOW_CORE or n.startswith("slo.class.")}
    s_names = {n for n in sim.windows.names()
               if n in WINDOW_CORE or n.startswith("slo.class.")}
    assert e_names == s_names


def test_engine_and_sim_burn_alert_parity():
    """An impossible SLO turns every completion bad on BOTH backends; the
    same monitor configuration fires the same alert on each."""
    slo = 1e-6
    eng, t_e = _run_windowed_engine(slo)
    sim, t_s = _run_windowed_sim(slo)
    for w, t in ((eng.windows, t_e), (sim.windows, t_s)):
        mon = SLOMonitor(w, budget=0.05,
                         rules=(BurnRateRule(fast_s=5.0, slow_s=30.0),),
                         min_requests=3)
        fired = mon.check(t)
        assert len(fired) == 1
        assert fired[0].slo_class == slo_class_key(slo)
        assert fired[0].burn_fast == pytest.approx(20.0)


# -------------------------------------------------------- flight recorder
def test_flight_recorder_dump_roundtrip(tmp_path):
    eng, t = _run_windowed_engine(750.0)
    fr = FlightRecorder(out_dir=str(tmp_path), min_interval_s=0.0)
    for evs in eng.tracer.events.values():
        for e in evs:
            fr.push_event(e)
    for rec in eng.tracer.ticks:
        fr.push_tick(rec)
    fr.snap_metrics(t, eng.metrics)
    path = fr.trigger("unit_test", t, extra={"note": "roundtrip"})
    assert path is not None and os.path.basename(path) == \
        "FLIGHT_unit_test.json"
    n = validate_trace_file(path)
    assert n > 0
    with open(path) as f:
        obj = json.load(f)
    assert obj["otherData"]["flight_reason"] == "unit_test"
    assert obj["otherData"]["note"] == "roundtrip"
    # counter deltas render as Chrome "C" events on pid 3
    assert any(e.get("ph") == "C" and e.get("pid") == 3
               for e in obj["traceEvents"])


def test_flight_recorder_rings_are_bounded():
    fr = FlightRecorder(max_spans=4, max_ticks=2, max_metric_snaps=2)
    from repro.obs.trace import SpanEvent, TickRecord
    for i in range(10):
        fr.push_event(SpanEvent(rid=i, name="queued", t=float(i)))
    assert len(fr.spans) == 4
    assert fr.spans[0].rid == 6                    # oldest evicted
    for i in range(5):
        fr.push_tick(TickRecord(t=float(i), backend="b", kind="decode"))
    assert len(fr.ticks) == 2 and fr.ticks[0].t == 3.0


def test_flight_recorder_rate_limit_and_max_dumps(tmp_path):
    fr = FlightRecorder(out_dir=str(tmp_path), min_interval_s=5.0,
                        max_dumps=3)
    assert fr.trigger("a", 0.0) is not None
    assert fr.trigger("a", 2.0) is None            # inside min_interval
    assert fr.trigger("b", 2.0) is not None        # per-reason limit
    p3 = fr.trigger("a", 7.0)
    assert p3 is not None and p3.endswith("FLIGHT_a_2.json")
    assert fr.trigger("c", 100.0) is None          # max_dumps exhausted
    assert len(fr.dumps) == 3


def test_flight_trigger_sanitizes_reason(tmp_path):
    fr = FlightRecorder(out_dir=str(tmp_path), min_interval_s=0.0)
    p = fr.trigger("burn rate: 750/ms!", 0.0)
    assert os.path.basename(p) == "FLIGHT_burn_rate_750_ms.json"


def test_fault_injection_triggers_flight_dump(tmp_path):
    from repro.cluster import make_nodes
    from repro.cluster.faults import replica_slowdown
    from repro.core.profiles import paper_resnet_profiles
    from repro.sim.cluster import SimCluster
    fr = FlightRecorder(out_dir=str(tmp_path), min_interval_s=0.0)
    obs = Observability(windows=True, flight=fr)
    assert obs.tracer.on                           # flight implies trace
    profiles = paper_resnet_profiles()
    sim = SimCluster(profiles, nodes=make_nodes(1, 4), replica_size=1,
                     obs=obs)
    sim.apply_allocation(-100.0, {list(profiles)[0]: 1})
    rid = next(iter(sim.fabric.replicas))
    sim.inject_fault(1.0, replica_slowdown(1.0, rid, 4.0))
    assert len(fr.dumps) == 1
    assert "fault_replica_slowdown" in fr.dumps[0]
    assert validate_trace_file(fr.dumps[0]) > 0


def test_alert_sink_flight_trigger(tmp_path):
    fr = FlightRecorder(out_dir=str(tmp_path), min_interval_s=0.0)
    FlightTrigger(fr).emit(Alert(t=1.0, slo_class="750",
                                 rule="fast5s/slow30s", burn_fast=4.0,
                                 burn_slow=3.0, budget=0.05))
    assert len(fr.dumps) == 1
    assert os.path.basename(fr.dumps[0]) == "FLIGHT_burn_rate_750.json"
    with open(fr.dumps[0]) as f:
        assert json.load(f)["otherData"]["burn_fast"] == 4.0


# ------------------------------------------------------ dispatch profiler
def test_dispatch_profiler_samples_every_nth_tick():
    eng, _ = _run_windowed_engine(750.0, profile_dispatch=2)
    recs = eng.tracer.ticks
    sampled = [r for r in recs if math.isfinite(r.dispatch_ms)]
    unsampled = [r for r in recs if not math.isfinite(r.dispatch_ms)]
    assert sampled and unsampled            # every 2nd tick fenced
    for r in sampled:
        assert r.dispatch_ms >= 0 and r.device_ms >= 0
        assert r.host_sync_ms >= 0
        assert (r.dispatch_ms + r.device_ms + r.host_sync_ms
                <= r.exec_ms + 1e-6)
    summary = dispatch_floor_summary(recs)
    assert summary
    for d in summary.values():
        assert d["n_sampled"] >= 1
        assert 0.0 <= d["dispatch_frac"] <= 1.0
        assert 0.0 <= d["host_sync_frac"] <= 1.0


def test_dispatch_profiler_off_leaves_nan():
    eng, _ = _run_windowed_engine(750.0)           # profile_dispatch=0
    assert eng.tracer.ticks
    assert all(math.isnan(r.dispatch_ms) for r in eng.tracer.ticks)
    assert dispatch_floor_summary(eng.tracer.ticks) == {}


# ----------------------------------------------------------- drop counters
def test_tracer_drop_counters_zero_on_normal_run(tmp_path):
    eng, _ = _run_windowed_engine(750.0)
    assert eng.metrics.value("obs.spans_dropped") == 0.0
    assert eng.metrics.value("obs.ticks_dropped") == 0.0
    p = tmp_path / "m.jsonl"
    write_metrics_jsonl(str(p), eng.metrics)
    assert_zero(str(p), "obs.spans_dropped")       # the CI smoke assertion
    assert_zero(str(p), "obs.ticks_dropped")


def test_tracer_drop_counter_increments_past_cap():
    obs = Observability(trace=True, max_events=2)
    tr = obs.tracer
    for i in range(5):
        tr.event(0, "queued", float(i))
    assert obs.metrics.value("obs.spans_dropped") == 3.0


def test_dropped_spans_still_reach_flight_ring(tmp_path):
    """The recorder keeps the recent past even after the tracer's own
    buffer filled — its feed runs before the cap check."""
    fr = FlightRecorder(out_dir=str(tmp_path))
    obs = Observability(trace=True, max_events=2, flight=fr)
    for i in range(6):
        obs.tracer.event(0, "queued", float(i))
    assert obs.metrics.value("obs.spans_dropped") == 4.0
    assert len(fr.spans) == 6


# ------------------------------------------------------- attach_measured
def _audit_with(times):
    a = DecisionAudit()
    for t in times:
        a.record(t, "C", {"lam": 1.0},
                 {"units": {"m": 1}, "predicted": {"p99_ms": 100.0,
                                                   "goodput": 0.9}})
    return a


def test_attach_measured_zero_decisions():
    a = DecisionAudit()
    assert a.attach_measured([1.0], [50.0], [True]) == 0


def test_attach_measured_zero_requests():
    a = _audit_with([0.0])
    assert a.attach_measured([], [], []) == 0
    assert a.entries[0].measured is None


def test_attach_measured_single_decision_takes_all_and_warmup():
    a = _audit_with([10.0])
    n = a.attach_measured([1.0, 11.0, 20.0], [50.0, 60.0, 70.0],
                          [True, True, False])
    assert n == 1
    m = a.entries[0].measured
    assert m["n_requests"] == 3                    # warm-up credited too
    assert m["goodput"] == pytest.approx(2 / 3)


def test_attach_measured_out_of_order_decisions_sorted():
    # recorded out of t-order: bucketing sorts by t (documented), so the
    # t=0 entry takes [0, 10) and the t=10 entry takes [10, inf)
    a = _audit_with([10.0, 0.0])
    n = a.attach_measured([1.0, 12.0], [50.0, 60.0], [True, False])
    assert n == 2
    by_t = {e.t: e.measured for e in a.entries}
    assert by_t[0.0]["n_requests"] == 1
    assert by_t[0.0]["p50_ms"] == pytest.approx(50.0)
    assert by_t[10.0]["n_requests"] == 1
    assert by_t[10.0]["p50_ms"] == pytest.approx(60.0)


def test_attach_measured_empty_window_marked_not_counted():
    a = _audit_with([0.0, 10.0])
    n = a.attach_measured([1.0], [50.0], [True])
    assert n == 1
    assert a.entries[1].measured == {"n_requests": 0}


# ------------------------------------------------------------- summarize
def test_export_summarize_metrics_and_audit(tmp_path):
    eng, _ = _run_windowed_engine(750.0)
    mp = tmp_path / "m.jsonl"
    write_metrics_jsonl(str(mp), eng.metrics)
    out = summarize_file(str(mp))
    assert "requests.completed" in out and "p99" in out
    a = _audit_with([0.0, 30.0])
    a.attach_measured([1.0, 31.0], [50.0, 60.0], [True, True])
    ap = tmp_path / "a.jsonl"
    a.to_jsonl(str(ap))
    out = summarize_file(str(ap))
    assert "interval" in out and "m:1" in out
    with pytest.raises(ValueError):
        summarize_file(str(tmp_path / "missing.jsonl")) \
            if (tmp_path / "missing.jsonl").exists() else \
            (_ for _ in ()).throw(ValueError("missing"))


def test_export_cli_assert_zero(tmp_path, capsys):
    from repro.obs.export import main
    eng, _ = _run_windowed_engine(750.0)
    mp = tmp_path / "m.jsonl"
    write_metrics_jsonl(str(mp), eng.metrics)
    assert main(["--validate-metrics", str(mp),
                 "--assert-zero", "obs.spans_dropped",
                 "--assert-zero", "obs.ticks_dropped",
                 "--summarize", str(mp)]) == 0
    assert main(["--validate-metrics", str(mp),
                 "--assert-zero", "requests.completed"]) == 1
    assert main(["--assert-zero", "obs.spans_dropped"]) == 1
