"""In-process JAX serving engine: real prefill/decode micro-batching."""
import time

import numpy as np

from repro.configs import get_config, smoke_variant
from repro.serving.engine import InProcessServingEngine, Request


def _variants():
    base = smoke_variant(get_config("tinyllama-1.1b")).replace(
        d_model=64, d_ff=128, vocab_size=128)
    return {
        "small": (base.replace(num_layers=2, name="small"), 70.0),
        "big": (base.replace(num_layers=3, name="big"), 75.0),
    }


def test_engine_serves_and_switches():
    eng = InProcessServingEngine(_variants(), max_batch=4, prompt_len=8)
    eng.apply_allocation(0.0, {"small": 1})
    assert eng.loaded_variants(0.0) == {"small"}
    rng = np.random.default_rng(0)
    for i in range(6):
        eng.submit(Request(rid=i, tokens=rng.integers(0, 128, 8),
                           max_new=4, arrival=time.time()), "small")
    served = eng.pump(0.0)
    assert served == 6
    assert all(r.output.shape == (4,) for r in eng.done)
    assert all(r.accuracy == 70.0 for r in eng.done)
    # switch variants (create-then-remove)
    eng.apply_allocation(1.0, {"big": 2})
    assert eng.loaded_variants(1.0) == {"big"}
    eng.submit(Request(rid=99, tokens=rng.integers(0, 128, 8),
                       max_new=2, arrival=time.time()), "big")
    eng.pump(1.0)
    assert eng.done[-1].accuracy == 75.0
    s = eng.summarize(slo_ms=60_000, best_accuracy=75.0)
    assert s["n_requests"] == 7
    assert s["violation_rate"] == 0.0


def test_engine_readiness_measured():
    eng = InProcessServingEngine(_variants(), max_batch=2, prompt_len=8)
    eng.apply_allocation(0.0, {"small": 1})
    assert eng.backends["small"].readiness_s > 0.0
