"""SLO-aware scheduling layer (DESIGN.md §Scheduling).

Covers the scheduler contract across both backends:
  * greedy-token parity: every kv_cache ∈ {dense, paged} × scheduler ∈
    {fifo, edf, chunked} serves the same tokens as the seed FIFO path
    (scheduling reorders and interleaves; it must never change outputs),
  * EDF admission favors tight deadlines; property: no request starves
    beyond a bounded number of ticks under random arrival orders/SLOs,
  * preemption/resume preserves every generated token exactly (dense and
    paged, pool leak-free at every tick), bounded by MAX_PREEMPTIONS,
  * the chunked scheduler interleaves prefill with decode so resident
    sequences progress while a long prompt is still prefilling,
  * the wall-clock serving loop stamps arrival/service/completion from ONE
    clock (regression: no cross-domain latencies),
  * the DES mirrors the discipline: scheduler="edf" assigns pending work
    deadline-first at each server-free instant.
"""
import numpy as np
import pytest

from conftest import MAX_NEW, VOCAB, tiny_engine
from repro.serving.api import Request, summarize_requests
from repro.serving.driver import ElapsedClock, run_serving_loop, trace_load
from repro.serving.sched import (MAX_PREEMPTIONS, ChunkedScheduler,
                                 EDFScheduler, FIFOScheduler, make_scheduler)


def _engine(**kw):
    kw.setdefault("prefill_chunk", 4)
    eng = tiny_engine(**kw)
    eng.apply_allocation(0.0, {"small": 1})
    return eng


def _req(rid, prompt, slo_ms=0.0, arrival=0.0, max_new=MAX_NEW):
    return Request(rid=rid, tokens=prompt, max_new=max_new, arrival=arrival,
                   slo_ms=slo_ms)


_RNG = np.random.default_rng(11)
PROMPTS = [_RNG.integers(0, VOCAB, 8) for _ in range(6)]
SLOS = [200.0, 50.0, 1000.0, 30.0, 500.0, 80.0]


# ---------------------------------------------------------------- policies
def test_make_scheduler_specs():
    assert isinstance(make_scheduler("fifo"), FIFOScheduler)
    assert isinstance(make_scheduler("edf"), EDFScheduler)
    ch = make_scheduler("chunked")
    assert isinstance(ch, ChunkedScheduler) and ch.chunked
    assert make_scheduler("chunked-fifo").name == "chunked-fifo"
    assert make_scheduler(ch) is ch          # pass-through
    with pytest.raises(ValueError):
        make_scheduler("lifo")


def test_edf_order_feasible_first_then_expired():
    s = EDFScheduler()
    now = 10.0
    feas_late = _req(0, PROMPTS[0], slo_ms=90_000.0, arrival=5.0)
    feas_soon = _req(1, PROMPTS[1], slo_ms=6_000.0, arrival=9.0)
    expired = _req(2, PROMPTS[2], slo_ms=1_000.0, arrival=1.0)
    ordered = s.order([feas_late, expired, feas_soon], now)
    assert [r.rid for r in ordered] == [1, 0, 2]   # expired sorts last


def test_fifo_order_is_identity_and_never_preempts():
    s = FIFOScheduler()
    reqs = [_req(i, PROMPTS[i], slo_ms=SLOS[i]) for i in range(4)]
    assert s.order(reqs, 99.0) == reqs
    assert s.select_victims(reqs, reqs, 99.0, 0) == []


def test_edf_victims_bounded_and_only_hopeless():
    s = EDFScheduler()
    now = 100.0
    hopeless = _req(0, PROMPTS[0], slo_ms=1_000.0, arrival=0.0)
    capped = _req(1, PROMPTS[1], slo_ms=1_000.0, arrival=0.0)
    capped.preemptions = MAX_PREEMPTIONS
    feasible = _req(2, PROMPTS[2], slo_ms=1e9, arrival=0.0)
    waiting = [_req(3, PROMPTS[3], slo_ms=1e9, arrival=90.0)]
    victims = s.select_victims([hopeless, capped, feasible], waiting, now, 0)
    assert victims == [hopeless]             # not the capped, not the feasible
    assert s.select_victims([hopeless], waiting, now, 1) == []  # slot is free


# ------------------------------------------------------- parity (engine)
@pytest.mark.parametrize("kv_cache", ["dense", "paged"])
def test_scheduler_matrix_greedy_parity(kv_cache):
    """kv × scheduler all serve the seed FIFO path's exact greedy tokens."""
    outs = {}
    for sched in ("fifo", "edf", "chunked"):
        eng = _engine(kv_cache=kv_cache, scheduler=sched)
        for i, p in enumerate(PROMPTS):
            assert eng.submit(_req(i, p, slo_ms=SLOS[i]), "small")
        eng.drain(0.0)
        assert len(eng.done) == len(PROMPTS)
        assert all(r.output.shape == (MAX_NEW,) for r in eng.done)
        outs[sched] = {r.rid: np.asarray(r.output) for r in eng.done}
    for sched in ("edf", "chunked"):
        for i in range(len(PROMPTS)):
            np.testing.assert_array_equal(outs["fifo"][i], outs[sched][i])


def test_chunked_paged_pallas_parity():
    """The Pallas prefill-continuation route (flash/paged decode kernels'
    cached-prefix masking, interpret mode on CPU) matches the jnp path."""
    outs = {}
    for pallas in (False, True):
        eng = _engine(kv_cache="paged", scheduler="chunked",
                      use_pallas=pallas, max_new=4)
        for i, p in enumerate(PROMPTS[:2]):
            eng.submit(_req(i, p, max_new=4), "small")
        eng.drain(0.0)
        outs[pallas] = {r.rid: np.asarray(r.output) for r in eng.done}
    for i in range(2):
        np.testing.assert_array_equal(outs[False][i], outs[True][i])


def test_edf_admits_tight_deadline_first():
    """Under a backlog, the tight-SLO request leaves the queue before
    looser ones that arrived earlier."""
    eng = _engine(scheduler="edf", clock=lambda: 50.0)
    for i in range(4):
        eng.submit(_req(i, PROMPTS[i], slo_ms=1e6, arrival=float(i)), "small")
    tight = _req(9, PROMPTS[4], slo_ms=60_000.0, arrival=4.0)
    eng.submit(tight, "small")
    eng.step(50.0)                           # admits 2 of 5 queued
    admitted = {r.rid for r in eng.backends["small"].slot_req
                if r is not None} | {r.rid for r in eng.done}
    assert 9 in admitted


def test_chunked_interleaves_decode_with_long_prefill():
    """While a long prompt prefills chunk-by-chunk, the resident sequence
    keeps emitting tokens — no head-of-line blocking inside the backend."""
    eng = _engine(scheduler="chunked", prompt_len=32, prefill_chunk=4,
                  max_new=24, decode_chunk=1)
    b = eng.backends["small"]
    rng = np.random.default_rng(3)
    # rid0 prefills 32 tokens in 8 chunks; rid1 arrives 4 ticks later, so
    # once rid0 decodes, rid1 is still prefilling for several ticks
    eng.submit(_req(0, rng.integers(0, VOCAB, 32), max_new=24), "small")
    for _ in range(4):
        eng.step(0.0)
    assert b._prefilling                     # rid0 still mid-prefill
    eng.submit(_req(1, rng.integers(0, VOCAB, 32), max_new=24), "small")
    grown = []
    for _ in range(20):
        decoding = [s for s, r in enumerate(b.slot_req)
                    if r is not None and s not in b._prefilling
                    and b.slot_remaining[s] > 1]
        if decoding and b._prefilling:       # overlap window: decode + prefill
            before = [len(b.slot_tokens[s]) for s in decoding]
            eng.step(0.0)
            after = [len(b.slot_tokens[s]) for s in decoding]
            grown.append(all(a > bo for a, bo in zip(after, before)))
        else:
            eng.step(0.0)
        if not b._prefilling and len({r.rid for r in eng.done}
                                     | {r.rid for r in b.slot_req
                                        if r is not None}) == 2:
            break
    eng.drain(0.0)
    assert len(eng.done) == 2
    assert grown and all(grown)              # decode progressed during prefill


# ------------------------------------- scheduling invariants (deterministic
# seeded sweeps here; the hypothesis-driven versions live in
# tests/test_scheduler_property.py, skipped when hypothesis is absent)
def test_edf_bounded_wait_no_starvation_seeded():
    """Random arrival orders and deadlines: every request completes within
    a tick bound, exactly once — EDF (with expired-last ordering) never
    starves anyone indefinitely."""
    eng = _engine(scheduler="edf")
    rng = np.random.default_rng(7)
    for trial in range(4):
        eng.done.clear()
        order = rng.permutation(6)
        slos = rng.choice([20.0, 100.0, 1000.0, 1e6], size=6)
        for j, i in enumerate(order):
            assert eng.submit(_req(int(i), PROMPTS[i], slo_ms=float(slos[j]),
                                   arrival=float(j)), "small")
        # 6 requests, 2 slots, MAX_NEW tokens in chunks of 2: << 60 ticks
        for _ in range(60):
            eng.step(1e6)
            if len(eng.done) == 6:
                break
        assert sorted(r.rid for r in eng.done) == list(range(6))
        assert all(r.output is not None and len(r.output) == MAX_NEW
                   for r in eng.done)


@pytest.mark.parametrize("kv_cache", ["dense", "paged"])
def test_preemption_resume_never_loses_tokens_seeded(kv_cache):
    """Random mixes of hopeless/feasible deadlines in random order, with
    preemption on: every request's final tokens equal the unpressured
    reference (nothing lost, nothing duplicated), preemptions stay bounded,
    and the paged pool never leaks at any tick."""
    ref_eng = _engine(kv_cache=kv_cache, max_new=10)
    for i, p in enumerate(PROMPTS):
        ref_eng.submit(_req(i, p, max_new=10), "small")
    ref_eng.drain(0.0)
    ref = {r.rid: np.asarray(r.output) for r in ref_eng.done}

    eng = _engine(kv_cache=kv_cache, scheduler="edf", preemption="requeue",
                  max_new=10, clock=lambda: 0.0)
    b = eng.backends["small"]
    rng = np.random.default_rng(13)
    now = 100.0    # every "hopeless" deadline (arrival+slo < now) has passed
    preempted_any = False
    for trial in range(3):
        eng.done.clear()
        # hopeless requests grab the slots first; feasible ones then arrive
        # and the scheduler must preempt to serve them
        ids = rng.permutation(6)
        hopeless, feasible = ids[:2], ids[2:]
        for i in hopeless:
            assert eng.submit(_req(int(i), PROMPTS[i], slo_ms=1.0,
                                   max_new=10, arrival=0.0), "small")
        eng.step(now)                        # admit the hopeless pair
        for i in feasible:
            assert eng.submit(_req(int(i), PROMPTS[i], slo_ms=1e9,
                                   max_new=10, arrival=0.0), "small")
        for _ in range(200):
            eng.step(now)
            if hasattr(b, "pool"):
                assert b.pool.used_pages == b.active_slots * b.pages_per_slot
            if len(eng.done) == 6:
                break
        assert sorted(r.rid for r in eng.done) == list(range(6))
        for r in eng.done:
            assert r.preemptions <= MAX_PREEMPTIONS
            preempted_any |= r.preemptions > 0
            np.testing.assert_array_equal(ref[r.rid], np.asarray(r.output))
        if hasattr(b, "pool"):
            assert b.pool.used_pages == 0
    assert preempted_any          # the invariants were actually exercised


def test_preemption_drop_completes_early_with_partial_output():
    eng = _engine(scheduler="edf", preemption="drop", max_new=10,
                  clock=lambda: 0.0)
    eng.submit(_req(0, PROMPTS[0], slo_ms=1.0, max_new=10), "small")
    eng.submit(_req(1, PROMPTS[1], slo_ms=1.0, max_new=10), "small")
    eng.step(100.0)                          # admit both (slots free)
    for i in range(2, 6):
        eng.submit(_req(i, PROMPTS[i], slo_ms=1e9, max_new=10,
                        arrival=100.0), "small")
    for _ in range(100):
        eng.step(100.0)
        if len(eng.done) == 6:
            break
    done = {r.rid: r for r in eng.done}
    dropped = [r for r in eng.done if r.dropped]
    assert dropped and all(r.rid in (0, 1) for r in dropped)
    assert all(len(done[i].output) == 10 and not done[i].dropped
               for i in range(2, 6))
    s = eng.summarize(slo_ms=1e12, best_accuracy=70.0)
    assert s["goodput"] < 1.0                # drops can't count as goodput


# ------------------------------------------------------------ one clock
def test_serving_loop_single_clock_sane_latencies():
    """Regression (clock-domain mismatch): the wall-clock loop stamps
    arrival from the same clock the engine stamps service/completion, so
    latencies are non-negative and bounded by the run length."""
    from repro.core.adapter import ControllerConfig, InfAdapterController
    from repro.core.forecaster import MovingMaxForecaster
    from repro.core.profiles import VariantProfile

    seconds = 2.0
    profiles = {"small": VariantProfile(
        name="small", accuracy=70.0, rt=0.1, th_slope=30.0, th_intercept=5.0,
        lat_base_ms=30.0, lat_k_ms=10.0)}
    eng = _engine(max_batch=4, max_new=4, scheduler="chunked",
                  clock=ElapsedClock())       # pre-warm: the measured loop
    # below must spend its seconds serving, not compiling
    ctrl = InfAdapterController(
        profiles, MovingMaxForecaster(),
        ControllerConfig(interval_s=1.0, budget=2, slo_ms=5_000.0))
    n = run_serving_loop(eng, ctrl, seconds=seconds, interval=1.0,
                         load_fn=lambda now: 6.0, tick_sleep=0.01,
                         slo_ms=5_000.0, log=None)
    assert n > 0 and eng.done
    for r in eng.done:
        assert 0.0 <= r.arrival <= seconds + 1.0       # elapsed domain
        assert 0.0 <= r.latency_ms <= (seconds + 10.0) * 1000.0
        assert r.queue_wait_ms >= 0.0
        assert r.service_ms >= 0.0
        assert r.completion >= r.service_start >= 0.0


def test_trace_load_indexing():
    arr = np.array([1.0, 2.0, 3.0])
    f = trace_load(arr, scale=2.0)
    assert f(0.0) == 2.0 and f(1.9) == 4.0
    assert f(10.0) == 6.0                    # holds last second
    assert trace_load(arr, repeat=True)(4.2) == 2.0


# ------------------------------------------------------------------ metric
def test_goodput_per_request_slo_and_drops():
    lat = [100.0, 400.0, 100.0, 100.0]
    s = summarize_requests([0, 1, 2, 3], lat, [70] * 4, slo_ms=200.0,
                           best_accuracy=70.0,
                           slo_list_ms=[0.0, 500.0, 50.0, 300.0],
                           dropped=[False, False, False, True])
    # r0: global 200 ok; r1: own 500 ok; r2: own 50 missed; r3: dropped
    assert s["goodput"] == pytest.approx(0.5)
    assert s["violation_rate"] == pytest.approx(0.25)   # global-SLO metric
    s2 = summarize_requests([0], [100.0], [70], slo_ms=200.0,
                            best_accuracy=70.0)
    assert s2["goodput"] == 1.0              # degenerates to 1 - viol rate


# --------------------------------------------------------------- DES mirror
def test_sim_edf_assigns_deadline_first():
    from repro.core.profiles import paper_resnet_profiles
    from repro.sim.cluster import SimCluster
    profiles = {"resnet18": paper_resnet_profiles()["resnet18"]}
    waits = {}
    for sched in ("fifo", "edf"):
        c = SimCluster(profiles, scheduler=sched)
        c.apply_allocation(0.0, {"resnet18": 1})
        c.mark_warm(t=0.0)
        for i in range(30):
            c.dispatch(0.001 * i, "resnet18", slo_ms=60_000.0)
        c.dispatch(0.05, "resnet18", slo_ms=100.0)     # tight straggler
        c.drain(1e9)
        s = c.summarize(60_000.0, 72.0, window_s=0)
        assert s["n_requests"] == 31
        tight = [r for r in c.requests if r.slo_ms == 100.0][0]
        waits[sched] = tight.latency_ms
    assert waits["edf"] < waits["fifo"] * 0.5          # jumped the queue


def test_sim_edf_no_lookahead_and_conservation():
    """EDF assignment may not peek at requests that had not arrived by the
    server-free instant, and every submission is served exactly once."""
    from repro.core.profiles import paper_resnet_profiles
    from repro.sim.cluster import SimCluster
    profiles = {"resnet18": paper_resnet_profiles()["resnet18"]}
    c = SimCluster(profiles, scheduler="edf")
    c.apply_allocation(0.0, {"resnet18": 1})
    c.mark_warm(t=0.0)
    c.dispatch(0.0, "resnet18", slo_ms=60_000.0)       # served immediately
    served_first = c.requests[-1] if c.requests else None
    c.dispatch(100.0, "resnet18", slo_ms=1.0)          # arrives much later
    c.drain(1e9)
    assert len(c.requests) == 2
    # the first request was not delayed waiting for the tighter future one
    first = min(c.requests, key=lambda r: r.arrival)
    assert first.service_start < 1.0
    assert served_first is None or served_first.arrival == 0.0


def test_sim_edf_serves_expired_deadlines_last():
    """DES parity with the engine's expired-last EDF: a request whose
    deadline already passed must not absorb a server ahead of
    still-feasible waiters (one violation must not become two)."""
    from repro.core.profiles import paper_resnet_profiles
    from repro.sim.cluster import SimCluster
    profiles = {"resnet18": paper_resnet_profiles()["resnet18"]}
    c = SimCluster(profiles, scheduler="edf")
    c.apply_allocation(0.0, {"resnet18": 1})
    c.mark_warm(t=0.0)
    # saturate so a queue forms, then add one long-expired request and a
    # batch of feasible ones — all pending at the same instant
    for i in range(40):
        c.dispatch(0.0, "resnet18", slo_ms=60_000.0)
    c.dispatch(0.01, "resnet18", slo_ms=0.001)     # deadline already gone
    for i in range(10):
        c.dispatch(0.02, "resnet18", slo_ms=60_000.0)
    c.drain(1e9)
    expired = [r for r in c.requests if r.slo_ms == 0.001][0]
    feasible_after = [r for r in c.requests
                      if r.slo_ms == 60_000.0 and r.arrival == 0.02]
    assert all(r.service_start <= expired.service_start
               for r in feasible_after)


def test_profiler_arrivals_share_engine_clock():
    """Regression (review finding): EngineProfiler stamps arrivals from the
    backend's own clock, so profiling an ElapsedClock engine yields sane,
    non-negative queue waits instead of epoch-minus-elapsed garbage."""
    from repro.profiling.measure import EngineProfiler
    eng = tiny_engine(max_new=4, clock=ElapsedClock())
    prof = EngineProfiler(eng, points=(1, 2), requests_per_point=4, warmup=1)
    m = prof.profile_variant("small", points=(1, 2), requests_per_point=4)
    for p in m.points:
        assert 0.0 <= p.mean_queue_ms < 60_000.0
        assert 0.0 <= p.mean_service_ms < 60_000.0


def test_sim_experiment_end_to_end_with_edf():
    """run_experiment drives a scheduler-mirrored cluster unchanged and the
    summary carries goodput."""
    from repro.core.adapter import ControllerConfig, InfAdapterController
    from repro.core.forecaster import MovingMaxForecaster
    from repro.core.profiles import paper_resnet_profiles
    from repro.sim.cluster import SimCluster
    from repro.sim.runner import run_experiment
    profiles = paper_resnet_profiles()
    trace = np.full(60, 30.0, np.float32)
    cfg = ControllerConfig(budget=20, beta=0.05, gamma=0.2)
    ctrl = InfAdapterController(profiles, MovingMaxForecaster(), cfg)
    res = run_experiment("edf-sim", ctrl, profiles, trace,
                         cluster=SimCluster(profiles, scheduler="edf"),
                         warm_start={"resnet18": 8})
    assert res.summary["n_requests"] > 1000
    assert 0.0 <= res.summary["goodput"] <= 1.0
