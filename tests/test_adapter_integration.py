"""End-to-end control-loop integration on the simulator (paper's evaluation
harness at reduced scale) + the headline directional claims."""
import numpy as np
import pytest

from repro.core.adapter import (ControllerConfig, InfAdapterController,
                                MSPlusController, VPAPlusController)
from repro.core.forecaster import MovingMaxForecaster
from repro.core.profiles import paper_resnet_profiles
from repro.data.traces import paper_bursty_trace, paper_nonbursty_trace
from repro.sim.runner import run_experiment

PROFILES = paper_resnet_profiles(noise=0.0)
REF = 78.31


def _run(controller_cls, trace, profiles=None, variant=None, **cfg_kw):
    cfg = ControllerConfig(budget=20, beta=0.05, gamma=0.2, **cfg_kw)
    if controller_cls is VPAPlusController:
        c = VPAPlusController(PROFILES[variant], cfg)
        profs = {variant: PROFILES[variant]}
        warm = {variant: 8}
    else:
        c = controller_cls(PROFILES, MovingMaxForecaster(), cfg)
        profs = PROFILES
        warm = {"resnet18": 8}
    return run_experiment(controller_cls.__name__, c, profs, trace,
                          warm_start=warm, reference_accuracy=REF)


@pytest.fixture(scope="module")
def bursty_results():
    trace = paper_bursty_trace(seconds=900)
    return {
        "inf": _run(InfAdapterController, trace),
        "ms": _run(MSPlusController, trace),
        "vpa152": _run(VPAPlusController, trace, variant="resnet152"),
        "vpa18": _run(VPAPlusController, trace, variant="resnet18"),
    }


def test_infadapter_reduces_violations_vs_heavy_vpa(bursty_results):
    """Headline claim: SLO violations reduced (up to 65%) vs VPA."""
    inf = bursty_results["inf"].summary["violation_rate"]
    vpa = bursty_results["vpa152"].summary["violation_rate"]
    assert inf < vpa * 0.35


def test_infadapter_less_accuracy_loss_than_ms(bursty_results):
    assert (bursty_results["inf"].summary["accuracy_loss"]
            < bursty_results["ms"].summary["accuracy_loss"])


def test_vpa18_cheap_but_inaccurate(bursty_results):
    s = bursty_results["vpa18"].summary
    assert s["avg_cost_units"] < bursty_results["inf"].summary["avg_cost_units"]
    assert s["accuracy_loss"] > 8.0


def test_nonbursty_all_meet_slo():
    trace = paper_nonbursty_trace(seconds=600)
    r = _run(InfAdapterController, trace)
    assert r.summary["violation_rate"] < 0.01


def test_reactive_extension_strictly_better():
    """Beyond-paper: reactive+queue-aware cuts violations at equal cost."""
    trace = paper_bursty_trace(seconds=900)
    faithful = _run(InfAdapterController, trace)
    reactive = _run(InfAdapterController, trace, reactive=True,
                    queue_aware=True)
    assert (reactive.summary["violation_rate"]
            <= faithful.summary["violation_rate"])
    assert (reactive.summary["avg_cost_units"]
            <= faithful.summary["avg_cost_units"] * 1.15)
