"""Shape/dtype sweep of the flash decode kernel vs the pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [
    # B, C(cache), H, KV, hd
    (2, 64, 4, 2, 64),
    (3, 100, 8, 1, 32),    # MQA, non-block-multiple cache (padding path)
    (2, 512, 4, 4, 128),
    (1, 1024, 8, 2, 64),
    (2, 96, 8, 8, 256),
]


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,C,H,KV,hd", SHAPES)
def test_flash_decode_matches_oracle(B, C, H, KV, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = _rand(ks[0], (B, 1, H, hd), dtype)
    k = _rand(ks[1], (B, C, KV, hd), dtype)
    v = _rand(ks[2], (B, C, KV, hd), dtype)
    bias = jnp.where(jax.random.bernoulli(ks[3], 0.8, (B, C)), 0.0, -1e9)
    out = ops.flash_decode(q, k, v, bias)
    want = ref.ref_flash_decode(q, k, v, bias)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_flash_decode_ragged_tail_block():
    """C % bk != 0 is handled inside the kernel wrapper (pad-and-mask tail
    block), so arbitrary context lengths work with any block size."""
    from repro.kernels import flash_decode as fd
    B, C, H, KV, hd = 2, 100, 4, 2, 32
    G = H // KV
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    q = _rand(ks[0], (B, KV, G, hd), jnp.float32)
    k = _rand(ks[1], (B, KV, C, hd), jnp.float32)
    v = _rand(ks[2], (B, KV, C, hd), jnp.float32)
    bias = jnp.where(jax.random.bernoulli(ks[3], 0.8, (B, C)), 0.0, -1e9)
    for bk in (32, 64, 512):              # 100 % bk != 0 for each
        out = fd.flash_decode_bkhd(q, k, v, bias, bk=bk)
        want = ref.ref_flash_decode(
            q.reshape(B, 1, H, hd), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), bias)
        np.testing.assert_allclose(
            np.asarray(out).reshape(B, 1, H, hd), np.asarray(want),
            atol=1e-4, rtol=1e-4)


def test_interpret_mode_auto_detected():
    """interpret=None resolves from the backend (interpret off-TPU) — the
    kernels are callable with no explicit interpret flag anywhere."""
    from repro.kernels.flash_decode import resolve_interpret
    assert resolve_interpret(None) == (jax.default_backend() != "tpu")
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False


def test_flash_decode_respects_bias_mask():
    """Masked cache slots must not affect the output: compare against shrunken cache."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (1, 1, 4, 64), jnp.float32)
    k = _rand(ks[1], (1, 64, 2, 64), jnp.float32)
    v = _rand(ks[2], (1, 64, 2, 64), jnp.float32)
    bias = jnp.zeros((1, 64)).at[:, 32:].set(-1e9)
    out_masked = ops.flash_decode(q, k, v, bias)
    out_small = ops.flash_decode(q, k[:, :32], v[:, :32], jnp.zeros((1, 32)))
    np.testing.assert_allclose(np.asarray(out_masked), np.asarray(out_small),
                               atol=1e-5)
