"""Roofline analysis utilities: HLO collective parsing, analytic models."""
import numpy as np

from repro.analysis import roofline as rl
from repro.configs import get_config, get_shape


def test_collective_bytes_parsing():
    hlo = """
  %ag = f32[16,1024]{1,0} all-gather(f32[1,1024]{1,0} %x), dimensions={0}
  %ar = bf16[512]{0} all-reduce(bf16[512]{0} %y), to_apply=%add
  %a2a = f32[8,64]{1,0} all-to-all(f32[8,64]{1,0} %z), dimensions={0}
"""
    total, per_kind = rl.collective_bytes(hlo)
    assert per_kind["all-gather"] == 16 * 1024 * 4
    assert per_kind["all-reduce"] == 512 * 2 * 2      # counted twice
    assert per_kind["all-to-all"] == 8 * 64 * 4
    assert total == sum(per_kind.values())


def test_collective_bytes_async_pairs_not_double_counted():
    hlo = """
  %s = f32[1024]{0} all-reduce-start(f32[1024]{0} %x), to_apply=%add
  %d = f32[1024]{0} all-reduce-done(f32[1024]{0} %s)
"""
    total, _ = rl.collective_bytes(hlo)
    assert total == 1024 * 4 * 2  # one AR (x2), not two


def test_analyze_dominant_term():
    cost = {"flops": 197e12 * 0.001, "bytes accessed": 819e9 * 0.005}
    rep = rl.analyze("a", "s", "16x16", 256, cost, "", 1e15)
    assert rep.dominant == "memory"
    assert abs(rep.compute_s - 0.001) < 1e-6
    assert abs(rep.memory_s - 0.005) < 1e-6


def test_model_flops_conventions():
    cfg = get_config("tinyllama-1.1b")
    tr = rl.model_flops(cfg, get_shape("train_4k"))
    de = rl.model_flops(cfg, get_shape("decode_32k"))
    n = cfg.active_param_count()
    assert abs(tr - 6 * n * 256 * 4096) / tr < 1e-6
    assert abs(de - 2 * n * 128) / de < 1e-6


def test_moe_active_flops_less_than_total():
    cfg = get_config("qwen3-moe-235b-a22b")
    assert cfg.active_param_count() < 0.2 * cfg.param_count()


def test_analytic_hbm_decreases_with_microbatching():
    cfg = get_config("deepseek-67b")
    shape = get_shape("train_4k")
    kw = dict(param_bytes_global=cfg.param_count() * 2.0, model_shard=16,
              batch_shard=16, fsdp_shard=16, train=True)
    m1 = rl.analytic_hbm_bytes(cfg, shape, microbatches=1, **kw)
    m16 = rl.analytic_hbm_bytes(cfg, shape, microbatches=16, **kw)
    assert m16 < m1 / 4


def test_scan_corrections_zero_for_decode():
    cfg = get_config("tinyllama-1.1b")
    f, b, _ = rl.scan_corrections(cfg, get_shape("decode_32k"),
                                  batch_shard=16, model_shard=16,
                                  heads_sharded=True)
    assert f == 0.0 and b == 0.0
