"""Replica sharding on the real engine: multiple VariantBackend instances
per variant behind the fabric, two-level routing, and node-crash recovery
with retry semantics — all through the shared ServingAPI."""
import time

import numpy as np

from conftest import tiny_engine, tiny_requests
from repro.cluster import make_nodes, node_crash, replica_slowdown
from repro.serving.api import ClusterAPI, ServingAPI

_reqs = tiny_requests


def _engine(n_variants=1, n_nodes=2, node_cap=2, **kw):
    return tiny_engine(n_variants=n_variants,
                       nodes=make_nodes(n_nodes, node_cap), **kw)


def test_allocation_materializes_as_engine_replicas():
    eng = _engine()
    assert isinstance(eng, ClusterAPI) and isinstance(eng, ServingAPI)
    eng.apply_allocation(0.0, {"small": 2})
    assert sorted(eng.backends) == ["small#0", "small#1"]
    # spread placement: one replica per node
    assert {r.node_id for r in eng.fabric.replicas.values()} == \
        {"node0", "node1"}
    assert eng.loaded_variants(0.0) == {"small"}
    rng = np.random.default_rng(0)
    for r in _reqs(8, rng):
        assert eng.submit(r, "small")
    eng.drain(0.0)
    assert len(eng.done) == 8
    assert {r.rid for r in eng.done} == set(range(8))     # exactly once
    served_by = {r.backend for r in eng.done}
    assert served_by == {"small#0", "small#1"}            # both replicas used
    assert eng.in_flight() == 0 and eng.backlog(0.0) == 0


def test_two_level_routing_respects_variant_choice():
    eng = _engine(n_variants=2, n_nodes=2, node_cap=2)
    eng.apply_allocation(0.0, {"small": 2, "big": 2})
    rng = np.random.default_rng(1)
    reqs = _reqs(6, rng)
    for r in reqs[:3]:
        eng.submit(r, "small")
    for r in reqs[3:]:
        eng.submit(r, "big")
    eng.step(0.0)                    # work spread across all four replicas
    # crash retry keeps variant affinity: orphans of small#x must land on
    # the surviving small replica, not spill onto big (and vice versa)
    now = time.time()
    eng.inject_fault(now, node_crash(now, "node0"))
    eng.drain(0.0)
    accs = {r.rid: r.accuracy for r in eng.done}
    assert all(accs[i] == 70.0 for i in range(3))     # small replicas only
    assert all(accs[i] == 75.0 for i in range(3, 6))  # big replicas only


def test_replica_reconfig_scale_down_drains():
    eng = _engine()
    eng.apply_allocation(0.0, {"small": 2})
    rng = np.random.default_rng(2)
    for r in _reqs(4, rng):
        eng.submit(r, "small")
    eng.step(0.0)                    # both replicas now hold work
    eng.apply_allocation(1.0, {"small": 1})
    assert len(eng.backends) == 1
    assert eng.fabric.provisioned_units() == 1
    eng.drain(1.0)
    assert len(eng.done) == 4        # drained + requeued, nothing lost


def test_node_crash_retries_on_survivor():
    eng = _engine(queue_cap=64)
    eng.apply_allocation(0.0, {"small": 2})
    rng = np.random.default_rng(3)
    for r in _reqs(10, rng):
        assert eng.submit(r, "small")
    eng.step(0.0)                    # work in flight on both replicas
    now = time.time()
    eng.inject_fault(now, node_crash(now, "node0"))
    assert sorted(eng.backends) == ["small#1"]
    assert eng.fabric.capacity_factor(now) == 0.5
    eng.drain(0.0)
    # retry semantics: every accepted request completes exactly once
    assert {r.rid for r in eng.done} == set(range(10))
    assert eng.rejected == 0
    # controller-driven re-placement restores capacity on the live node set
    eng.apply_allocation(now + 1.0, {"small": 2})
    assert eng.fabric.capacity_factor(now + 1.0) == 1.0
    assert all(r.node_id == "node1"
               for r in eng.fabric.replicas.values())


def test_replica_slowdown_fault_stretches_decode():
    eng = _engine()
    eng.apply_allocation(0.0, {"small": 2})
    eng.inject_fault(0.0, replica_slowdown(0.0, "small#0", 3.0))
    assert eng.backends["small#0"].slow_factor == 3.0
    assert eng.backends["small#1"].slow_factor == 1.0
    rng = np.random.default_rng(4)
    for r in _reqs(4, rng):
        eng.submit(r, "small")
    eng.drain(0.0)
    assert len(eng.done) == 4        # still correct, just slower
