"""Activation-sharding context: inert without a mesh; pins under one."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.context import (activation_sharding, batch_shard_size,
                                    constrain, constrain_batch)


def _make_mesh(sizes, names):
    """jax.make_mesh across versions: axis_types only exists on newer jax."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(sizes, names,
                             axis_types=(jax.sharding.AxisType.Auto,) * len(sizes))
    return jax.make_mesh(sizes, names)


def test_noop_without_context():
    x = jnp.ones((8, 4))
    assert constrain_batch(x) is x
    assert batch_shard_size() == 1
    y = constrain(x, "batch", None)
    assert y is x


def test_model_outputs_identical_with_singleton_mesh():
    """With a 1x1 mesh the constraints exist but results are unchanged."""
    from repro.configs import get_config, smoke_variant
    from repro.models.model import LM
    cfg = smoke_variant(get_config("granite-moe-3b-a800m"))
    m = LM(cfg)
    p = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    base, _ = m.apply(p, {"tokens": toks}, train=False)
    mesh = _make_mesh((1, 1), ("data", "model"))
    with activation_sharding(mesh, ("data",)):
        pinned, _ = m.apply(p, {"tokens": toks}, train=False)
    np.testing.assert_allclose(np.asarray(base), np.asarray(pinned),
                               atol=1e-5)


def test_indivisible_dims_left_alone():
    mesh = _make_mesh((1, 1), ("data", "model"))
    with activation_sharding(mesh, ("data",)):
        x = jnp.ones((7, 3))   # 7 % 1 == 0 -> constraint fine with 1 shard
        y = constrain_batch(x)
        assert y.shape == x.shape
