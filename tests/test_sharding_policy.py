"""Sharding policy: divisibility-aware specs + fallbacks (no devices needed —
AbstractMesh carries only the axis geometry)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.steps import cache_shapes, params_shapes
from repro.configs.shapes import get_shape
from repro.sharding.policy import cache_specs, param_specs

def _abstract_mesh(sizes, names):
    """AbstractMesh across jax versions: 0.4.x takes ((name, size), ...);
    newer releases take (sizes, names)."""
    try:
        return jax.sharding.AbstractMesh(sizes, names)
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(names, sizes)))


MESH = _abstract_mesh((16, 16), ("data", "model"))
POD_MESH = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _find(specs, path_fragment):
    found = {}

    def visit(path, sp):
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        if path_fragment in name:
            found[name] = sp
    jax.tree_util.tree_map_with_path(visit, specs)
    return found


def test_dense_tp_sharding_tinyllama():
    cfg = get_config("tinyllama-1.1b")      # 32 heads, kv=4, d_ff 5632
    specs, report = param_specs(cfg, params_shapes(cfg), MESH)
    wq = list(_find(specs, "attn/wq").values())[0]
    assert wq == P(None, None, "model")     # heads 32 % 16 == 0
    wk = list(_find(specs, "attn/wk").values())[0]
    assert wk == P(None, None, None)        # kv=4 !% 16 -> replicated
    wi = list(_find(specs, "ffn/wi").values())[0]
    assert wi == P(None, None, "model")     # d_ff 5632 % 16 == 0
    emb = list(_find(specs, "embed/table").values())[0]
    assert emb == P("model", None)          # padded vocab % 16 == 0
    assert any("wk" in f for f in report.fallbacks)


def test_gemma_heads_fallback():
    cfg = get_config("gemma-2b")            # 8 heads < 16
    specs, report = param_specs(cfg, params_shapes(cfg), MESH)
    wq = list(_find(specs, "attn/wq").values())[0]
    assert wq == P(None, None, None)
    wi = list(_find(specs, "ffn/wi").values())[0]
    assert wi == P(None, None, "model")     # FFN carries the TP instead


def test_moe_expert_parallel_vs_dff_fallback():
    qwen = get_config("qwen3-moe-235b-a22b")    # 128 experts % 16 == 0
    specs, _ = param_specs(qwen, params_shapes(qwen), MESH)
    wi = list(_find(specs, "ffn/wi").values())[0]
    assert wi == P(None, "model", None, None)   # expert-parallel
    gran = get_config("granite-moe-3b-a800m")   # 40 experts !% 16
    specs, report = param_specs(gran, params_shapes(gran), MESH)
    wi = list(_find(specs, "ffn/wi").values())[0]
    assert wi == P(None, None, None, "model")   # d_ff fallback (512 % 16)
    assert any("E=40" in f for f in report.fallbacks)


def test_fsdp_adds_data_axis():
    cfg = get_config("yi-6b")
    specs, _ = param_specs(cfg, params_shapes(cfg), MESH, fsdp=True)
    wq = list(_find(specs, "attn/wq").values())[0]
    assert "data" in wq and "model" in wq


def test_every_arch_every_leaf_gets_a_spec():
    from repro.configs import ALL_ARCHS
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        params = params_shapes(cfg)
        specs, _ = param_specs(cfg, params, MESH, fsdp=True)
        def check(p, sp):
            assert isinstance(sp, P)
            assert len(sp) <= len(p.shape)
            for ax, dim in zip(sp, p.shape):
                if ax is not None:
                    size = 16
                    assert dim % size == 0, (arch, p.shape, sp)
        jax.tree_util.tree_map(check, params, specs)


def test_cache_specs_shard_batch_and_sequence():
    cfg = get_config("tinyllama-1.1b")
    shape = get_shape("decode_32k")
    cache = cache_shapes(cfg, shape)
    specs = cache_specs(cfg, cache, MESH, shape.global_batch)
    assert specs["k"] == P(None, ("data",), None, "model", None)
    # long_500k: batch 1 -> replicated batch
    shape_l = get_shape("long_500k")
    from repro.configs.shapes import adapt_config_for_shape
    cfg_l, _ = adapt_config_for_shape(cfg, shape_l)
    cache = cache_shapes(cfg_l, shape_l)
    specs = cache_specs(cfg_l, cache, MESH, 1)
    assert specs["k"][1] is None


def test_multipod_batch_axes():
    cfg = get_config("tinyllama-1.1b")
    from repro.sharding.policy import batch_specs
    from repro.launch.steps import batch_specs_for
    shape = get_shape("train_4k")
    b = batch_specs(cfg, batch_specs_for(cfg, shape), POD_MESH, 256)
    assert b["tokens"] == P(("pod", "data"), None)
