"""LSTM forecaster (paper config) + baselines."""
import numpy as np

from repro.core.forecaster import (EnsembleMaxForecaster, LSTMForecaster,
                                   MovingMaxForecaster, forecast_mae,
                                   lstm_apply, lstm_init,
                                   train_lstm_forecaster)
from repro.data.traces import synthetic_twitter_trace


def test_lstm_paper_architecture():
    """25-unit LSTM + 1-unit dense (paper §5)."""
    p = lstm_init(np.random.default_rng(0).bit_generator.seed_seq and
                  __import__("jax").random.PRNGKey(0), hidden=25)
    assert p["wh"].shape == (25, 100)
    assert p["dense_w"].shape == (25, 1)
    import jax.numpy as jnp
    out = lstm_apply(p, jnp.ones((3, 50, 1)))
    assert out.shape == (3,)


def test_lstm_learns_constant_trace():
    trace = np.full(4000, 30.0, np.float32)
    fc, losses = train_lstm_forecaster(trace, steps=80, batch=16)
    assert losses[-1] < losses[0]
    pred = fc.predict(trace[:2000])
    assert 15.0 < pred < 45.0


def test_lstm_beats_moving_max_on_diurnal():
    trace = synthetic_twitter_trace(seconds=3 * 3600, seed=5)
    fc, _ = train_lstm_forecaster(trace[:2 * 3600], steps=150, batch=32)
    test = trace[2 * 3600:]
    lstm = forecast_mae(fc, test, stride=400)
    mm = forecast_mae(MovingMaxForecaster(), test, stride=400)
    assert lstm["mae"] < mm["mae"]


def test_moving_max_headroom():
    fc = MovingMaxForecaster(window=10, headroom=1.2)
    assert fc.predict(np.array([10.0, 20.0, 15.0])) == 24.0


def test_ensemble_takes_max():
    a = MovingMaxForecaster(window=5, headroom=1.0)
    b = MovingMaxForecaster(window=5, headroom=2.0)
    e = EnsembleMaxForecaster(members=(a, b))
    assert e.predict(np.array([10.0])) == 20.0
