"""MoE grouped dispatch vs the dense dropless oracle + router invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.models import moe as moe_mod


def _setup(E=4, k=2, D=32, F=64, B=2, S=8):
    cfg = smoke_variant(get_config("qwen3-moe-235b-a22b")).replace(
        d_model=D, d_ff=F, num_experts=E, experts_per_token=k)
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D))
    return cfg, p, x


def test_grouped_dispatch_matches_dense_oracle():
    cfg, p, x = _setup()
    # generous capacity -> dropless -> must match the dense oracle exactly
    y, metrics = moe_mod.apply_moe(cfg, p, x, capacity_factor=8.0)
    want = moe_mod.apply_moe_dense_oracle(cfg, p, x)
    assert float(metrics["drop_fraction"]) == 0.0
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-4,
                               rtol=1e-4)


@pytest.mark.parametrize("E,k", [(4, 1), (4, 2), (8, 3)])
def test_moe_shapes_and_finiteness(E, k):
    cfg, p, x = _setup(E=E, k=k)
    y, metrics = moe_mod.apply_moe(cfg, p, x)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(metrics["aux_loss"]) >= 1.0 - 1e-3  # >= 1 by Cauchy-Schwarz


def test_capacity_drops_bounded():
    cfg, p, x = _setup(B=2, S=32)
    y, metrics = moe_mod.apply_moe(cfg, p, x, capacity_factor=1.0)
    assert 0.0 <= float(metrics["drop_fraction"]) < 0.5


def test_aux_loss_uniform_router_is_one():
    """A perfectly uniform router gives aux loss ~= 1 (its minimum)."""
    cfg, p, x = _setup(E=4, k=2, B=4, S=64)
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])  # uniform logits
    _, metrics = moe_mod.apply_moe(cfg, p, x)
    assert abs(float(metrics["aux_loss"]) - 1.0) < 0.05
