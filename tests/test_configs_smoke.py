"""Per-architecture smoke tests: reduced variant, one forward + one train step
on CPU, asserting output shapes and absence of NaNs. (Deliverable f.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, smoke_variant
from repro.models.model import build_model


def _batch(cfg, B=2, S=16):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    toks = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.frontend == "vision_patches":
        batch["patch_embeds"] = jax.random.normal(
            ks[1], (B, cfg.num_frontend_tokens, 1024))
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(ks[2], (B, cfg.enc_seq, 80))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_shapes(arch):
    cfg = smoke_variant(get_config(arch))
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.num_experts <= 4
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = jax.jit(lambda p, b: m.apply(p, b, train=False))(params, batch)
    B, S = batch["tokens"].shape
    prefix = cfg.num_frontend_tokens if cfg.frontend == "vision_patches" else 0
    assert logits.shape == (B, S + prefix, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    cfg = smoke_variant(get_config(arch))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    def loss_fn(p):
        return m.loss(p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    # gradient sanity: finite and not identically zero
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)
    total = sum(float(jnp.sum(jnp.abs(g))) for g in flat)
    assert total > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_step(arch):
    cfg = smoke_variant(get_config(arch))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    pre = {k: v for k, v in batch.items() if k != "labels"}
    prefix = cfg.num_frontend_tokens if cfg.frontend == "vision_patches" else 0
    logits, cache = jax.jit(
        lambda p, b: m.prefill(p, b, max_len=batch["tokens"].shape[1] + prefix + 4)
    )(params, pre)
    assert bool(jnp.all(jnp.isfinite(logits)))
    logits2, cache = jax.jit(m.decode_step)(
        params, cache, jnp.zeros((batch["tokens"].shape[0],), jnp.int32))
    assert logits2.shape == (batch["tokens"].shape[0], cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits2)))
