"""Markdown table generation from dry-run JSON reports."""
import json
import os

from repro.analysis.report import dryrun_table, inject, roofline_table

ROW = {
    "arch": "yi-6b", "shape": "decode_32k", "mesh": "16x16", "chips": 256,
    "compute_s": 0.001, "memory_s": 0.005, "collective_s": 0.0005,
    "dominant": "memory", "usefulness": 0.4, "notes": "",
    "compile_s": 3.0, "hbm_estimate_bytes": 2e9, "fits_v5e_16gb": True,
    "sharding_fallbacks": ["x"], "skipped": False,
}


def test_tables_render():
    rows = [ROW, dict(ROW, mesh="2x16x16"),
            {"arch": "whisper-tiny", "shape": "long_500k", "skipped": True,
             "reason": "enc-dec"}]
    t1 = dryrun_table(rows)
    assert "yi-6b" in t1 and "SKIP" in t1 and "fits" in t1
    t2 = roofline_table(rows)
    assert "**memory**" in t2 and "0.005" in t2


def test_inject_idempotent(tmp_path):
    md = tmp_path / "x.md"
    md.write_text("before\n<!-- T -->\nafter")
    inject(str(md), "T", "TABLE1")
    inject(str(md), "T", "TABLE2")
    text = md.read_text()
    assert "TABLE2" in text and "TABLE1" not in text
    assert text.count("<!-- T -->") == 1
