"""Hypothesis property tests over the solver's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't fail collection
from hypothesis import given, settings, strategies as st

from repro.core.objective import evaluate
from repro.core.profiles import VariantProfile
from repro.core.solver import solve_bruteforce, solve_exact, solve_greedy


@st.composite
def profile_sets(draw):
    n = draw(st.integers(2, 4))
    out = {}
    for i in range(n):
        slope = draw(st.floats(1.0, 15.0))
        intercept = draw(st.floats(0.0, 20.0))
        acc = draw(st.floats(50.0, 99.0))
        lat_base = draw(st.floats(10.0, 300.0))
        lat_k = draw(st.floats(50.0, 600.0))
        out[f"m{i}"] = VariantProfile(
            name=f"m{i}", accuracy=acc, rt=draw(st.floats(1.0, 20.0)),
            th_slope=slope, th_intercept=intercept,
            lat_base_ms=lat_base, lat_k_ms=lat_k)
    return out


@given(profiles=profile_sets(), lam=st.floats(1.0, 120.0),
       budget=st.integers(2, 10))
@settings(max_examples=40, deadline=None)
def test_solver_never_violates_constraints(profiles, lam, budget):
    a = solve_exact(profiles, lam, budget, 750.0)
    assert a.total_units() <= budget
    for m, n in a.units.items():
        if n > 0:
            assert profiles[m].p99_ms(n) <= 750.0 + 1e-6
    for m, q in a.quotas.items():
        assert q <= profiles[m].throughput(a.units[m]) + 1e-6
    assert sum(a.quotas.values()) <= lam + 1e-6


@given(profiles=profile_sets(), lam=st.floats(5.0, 60.0),
       budget=st.integers(2, 8))
@settings(max_examples=25, deadline=None)
def test_exact_at_least_greedy(profiles, lam, budget):
    """The exact DP must never be beaten by the greedy heuristic."""
    e = solve_exact(profiles, lam, budget, 750.0)
    g = solve_greedy(profiles, lam, budget, 750.0)
    if e.feasible and g.feasible:
        assert e.objective >= g.objective - 0.25  # DP load-discretization slack


@given(profiles=profile_sets(), lam=st.floats(5.0, 60.0),
       budget=st.integers(2, 6))
@settings(max_examples=20, deadline=None)
def test_exact_matches_bruteforce_property(profiles, lam, budget):
    e = solve_exact(profiles, lam, budget, 750.0)
    b = solve_bruteforce(profiles, lam, budget, 750.0)
    assert e.feasible == b.feasible
    if b.feasible:
        assert e.objective >= b.objective - 0.3


@given(profiles=profile_sets(), lam=st.floats(5.0, 80.0),
       budget=st.integers(4, 12), beta=st.floats(0.01, 0.3))
@settings(max_examples=25, deadline=None)
def test_objective_monotone_in_budget(profiles, lam, budget, beta):
    """More budget can never hurt the optimal objective."""
    a1 = solve_exact(profiles, lam, budget, 750.0, beta=beta)
    a2 = solve_exact(profiles, lam, budget + 2, 750.0, beta=beta)
    if a1.feasible:
        assert a2.feasible
        assert a2.objective >= a1.objective - 1e-6
