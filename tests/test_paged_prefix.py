"""Stateful property harness for prefix-sharing ``PagedKVCache``.

Refcounted pages + copy-on-write are a classic source of *silent*
corruption: an aliased write poisons someone else's attention, a missed
decrement leaks pages, a stale index entry maps a sharer onto reused
memory. This harness drives random admit / decode / fork / preempt /
resume / retire / speculate sequences against the pool plus a host-side
simulation of the device page arrays (each written position stores a known
token value), and after **every** step asserts the DESIGN.md §Prefix
sharing invariants (plus the retained-tier partition and the speculative
rollback-never-leaks property — rejected draft positions rewind without a
single page moving):

  * refcount conservation — sum of refcounts == slot->page mappings, and
    every usable page is either free or refcounted by the slots mapping it
    (no leaks, no double frees),
  * CoW isolation — gathering any live slot's pages yields exactly the
    token values that slot wrote or shared; writes on behalf of one
    request never mutate another's gathered K/V (released pages are
    poisoned to catch dangling references),
  * prefix-index entries always point at live pages (bidirectionally).

hypothesis (RuleBasedStateMachine) drives the schedule when installed —
the CI profile runs it at 500 examples with a fixed seed (see
tests/conftest.py) — and a seeded random driver keeps the same core
exercised without it.

The harness also carries a ``repro.obs.Tracer`` on a step-counter clock:
every action stamps span events (admitted / cow_bind / preempt / resume /
complete) for the logical request it touches, and the per-step check
asserts the lifecycle invariants — timestamps monotone per request, no
events after a terminal one, resume only ever following a preempt —
under exactly the adversarial preempt/resume interleavings hypothesis
finds.
"""
import numpy as np

from repro.models.attention import PagedKVCache
from repro.obs import Tracer
from repro.obs import trace as ev

PS = 4                                   # page size (tokens)
MAX_PROMPT_BLOCKS = 3
MAX_DECODE = 4
PAGES_PER_SLOT = -(-(MAX_PROMPT_BLOCKS * PS + MAX_DECODE) // PS)
TOTAL_PAGES = 3 * PAGES_PER_SLOT + 3     # ~3 concurrent slots + slack
POISON = -1

# canonical prompt blocks: a tiny alphabet makes chain matches (and the
# full-prefix CoW case) common instead of astronomically rare
_PATTERNS = [np.arange(i * 10, i * 10 + PS, dtype=np.int64)
             for i in range(3)]


class _HarnessCore:
    """The model under test plus its host-side mirror.

    ``kv[page, offset]`` simulates the device K/V pool: a written position
    holds the token value whose K/V it would carry (token values are unique
    per (slot, position) for generated tokens, so any aliased write shows
    up in a gather check)."""

    def __init__(self):
        self.pool = PagedKVCache(TOTAL_PAGES, PS)
        self.kv = np.full((TOTAL_PAGES, PS), POISON, np.int64)
        self.live = {}          # slot -> {"seq", "prompt_len", "table", "rid"}
        self.preempted = []     # [(seq, prompt_len, rid)] awaiting resume
        self.next_slot = 0
        self.capacity = PAGES_PER_SLOT * PS
        # span stream on a step-counter clock: one logical request (rid)
        # survives preempt/resume across slots; check() asserts lifecycle
        # and monotonicity invariants over what the tracer recorded
        self.tracer = Tracer(enabled=True)
        self.t = 0
        self.next_rid = 0

    def _stamp(self, rid, name, **attrs):
        self.t += 1
        self.tracer.event(rid, name, float(self.t), **attrs)

    # ------------------------------------------------------------- actions
    def admit(self, prompt, gen=(), rid=None):
        """Admit ``prompt`` (+ ``gen`` for a resume) the way the engine
        does: plan against the index, map shared blocks by reference, CoW
        the fully-matched boundary block, write only the tail, publish the
        prompt blocks once fully written. Returns the slot or None when
        the pool refuses (nothing may have changed)."""
        seq = np.concatenate([np.asarray(prompt, np.int64),
                              np.asarray(gen, np.int64)])
        assert 1 <= len(seq) <= self.capacity
        plan = self.pool.prefix_plan(prompt, count=False)
        slot = self.next_slot
        fresh = self.pool.alloc(
            slot, PAGES_PER_SLOT - len(plan.shared), shared=plan.shared,
            protect=() if plan.cow_src is None else (plan.cow_src,))
        if fresh is None:
            return None
        # a fresh page's previous contents are dead the moment it is handed
        # out (it may have been reclaimed off the retained tier) — model the
        # reuse by poisoning before this request writes
        for pg in fresh:
            self.kv[pg] = POISON
        self.next_slot += 1
        resuming = rid is not None
        if rid is None:
            rid = self.next_rid
            self.next_rid += 1
        self._stamp(rid, ev.RESUME if resuming else ev.ADMITTED, slot=slot)
        table = list(plan.shared) + fresh
        if plan.cow_src is not None:
            self.kv[fresh[0]] = self.kv[plan.cow_src]
            self._stamp(rid, ev.COW_BIND, slot=slot)
        for pos in range(plan.tail_start, len(seq)):
            self.kv[table[pos // PS], pos % PS] = seq[pos]
        self.pool.publish_prefix(slot, prompt)
        self.live[slot] = {"seq": seq, "prompt_len": len(prompt),
                           "table": table, "rid": rid}
        return slot

    def decode(self, slot):
        """Append one generated token (value unique to (slot, position))."""
        rec = self.live[slot]
        pos = len(rec["seq"])
        if pos >= self.capacity:
            return
        tok = 10_000 + slot * 100 + pos
        self.kv[rec["table"][pos // PS], pos % PS] = tok
        rec["seq"] = np.append(rec["seq"], tok)

    def speculate(self, slot, k, accept):
        """Draft/verify/rollback (DESIGN.md §Speculative decoding): append
        ``k`` draft tokens the way decode does, then reject all but
        ``accept`` of them — ``pool.rollback`` validates the rewind and the
        slot's sequence truncates back. The rejected positions' K/V stays
        physically in the slot's pages (masked by position on device), so
        the next append simply overwrites; no page ever moves."""
        rec = self.live[slot]
        base = len(rec["seq"])
        k = min(k, self.capacity - base)
        if k == 0:
            return
        for _ in range(k):
            self.decode(slot)
        new_len = base + min(accept, k)
        self.pool.rollback(slot, new_len)
        rec["seq"] = rec["seq"][:new_len]

    def fork(self, slot):
        """Admit a fresh request with a live slot's exact prompt — the
        full-chain match that exercises the CoW boundary case."""
        rec = self.live[slot]
        return self.admit(rec["seq"][:rec["prompt_len"]])

    def release(self, slot, keep: bool):
        """Retire (or preempt, ``keep=True``) a slot: refcounts drop and
        every page actually released is poisoned — if anyone still gathers
        through it, the next check sees POISON."""
        rec = self.live.pop(slot)
        released = self.pool.free(slot)
        for pg in released:
            assert pg not in {p for r in self.live.values()
                              for p in r["table"]}
            # pages parked on the retained tier keep their K/V live (a
            # future identical prompt may revive them); only pages actually
            # returned for reuse are poisoned
            if pg not in self.pool._retained:
                self.kv[pg] = POISON
        self._stamp(rec["rid"], ev.PREEMPT if keep else ev.COMPLETE,
                    slot=slot)
        if keep:
            self.preempted.append((rec["seq"], rec["prompt_len"],
                                   rec["rid"]))

    def resume(self):
        """Re-admit a preempted request: prompt + preserved tokens rebuild
        through the same sharing path (plan over the prompt only)."""
        seq, plen, rid = self.preempted.pop()
        if self.admit(seq[:plen], seq[plen:], rid=rid) is None:
            self.preempted.append((seq, plen, rid))

    # -------------------------------------------------------------- checks
    def check(self):
        self.pool.assert_invariants()
        for slot, rec in self.live.items():
            assert self.pool.owned(slot) == rec["table"]
            got = np.array([self.kv[rec["table"][p // PS], p % PS]
                            for p in range(len(rec["seq"]))])
            np.testing.assert_array_equal(got, rec["seq"])
        self._check_spans()

    def _check_spans(self):
        """Lifecycle invariants over the recorded span stream: per-request
        timestamps strictly increase (one clock, step counter), streams
        open with ADMITTED, nothing follows a terminal event, and every
        RESUME pairs with exactly one preceding PREEMPT."""
        assert self.tracer.dropped_events == 0
        for rid, evs in self.tracer.events.items():
            ts = [e.t for e in evs]
            assert ts == sorted(ts) and len(set(ts)) == len(ts), (rid, evs)
            names = [e.name for e in evs]
            assert names[0] == ev.ADMITTED, (rid, names)
            for name in names[:-1]:
                assert name not in ev.TERMINAL_EVENTS, (rid, names)
            preempted_now = False
            for name in names:
                if name == ev.PREEMPT:
                    assert not preempted_now, (rid, names)
                    preempted_now = True
                elif name == ev.RESUME:
                    assert preempted_now, (rid, names)
                    preempted_now = False


def _make_prompt(pattern_ids, tail_seed):
    blocks = [_PATTERNS[i] for i in pattern_ids]
    prompt = np.concatenate(blocks) if blocks else _PATTERNS[0]
    if tail_seed >= 0:       # ragged tail: unpublishable partial block
        rng = np.random.default_rng(tail_seed)
        prompt = np.concatenate(
            [prompt, rng.integers(0, 100, 1 + tail_seed % (PS - 1))])
    return prompt[:MAX_PROMPT_BLOCKS * PS]


def _drive(core, rng, steps):
    """Seeded random schedule over the core (the non-hypothesis driver)."""
    for _ in range(steps):
        op = rng.integers(0, 7)
        slots = sorted(core.live)
        if op == 0 or not slots:
            ids = list(rng.integers(0, len(_PATTERNS),
                                    1 + rng.integers(0, MAX_PROMPT_BLOCKS)))
            core.admit(_make_prompt(ids, int(rng.integers(-1, 40))))
        elif op == 1:
            core.fork(slots[rng.integers(0, len(slots))])
        elif op == 2:
            core.decode(slots[rng.integers(0, len(slots))])
        elif op == 3:
            core.release(slots[rng.integers(0, len(slots))], keep=True)
        elif op == 4 and core.preempted:
            core.resume()
        elif op == 5:
            core.speculate(slots[rng.integers(0, len(slots))],
                           int(rng.integers(1, MAX_DECODE + 1)),
                           int(rng.integers(0, MAX_DECODE + 1)))
        else:
            core.release(slots[rng.integers(0, len(slots))], keep=False)
        core.check()


def test_prefix_pool_seeded_schedules():
    """Deterministic fallback sweep (always runs, hypothesis or not)."""
    for seed in range(4):
        core = _HarnessCore()
        _drive(core, np.random.default_rng(seed), 300)
        for slot in sorted(core.live):
            core.release(slot, keep=False)
            core.check()
        assert core.pool.free_pages == core.pool.usable_pages


try:
    from hypothesis import strategies as st
    from hypothesis.stateful import (RuleBasedStateMachine, invariant,
                                     precondition, rule)
    _HAVE_HYPOTHESIS = True
except ImportError:                      # optional outside CI — the seeded
    _HAVE_HYPOTHESIS = False             # sweep above still ran

if not _HAVE_HYPOTHESIS:
    class RuleBasedStateMachine:         # placeholder so the class parses
        TestCase = None

    def _noop(*a, **k):
        return lambda f: f
    rule = invariant = precondition = _noop

    class st:                            # never called without hypothesis
        @staticmethod
        def integers(*a, **k):
            return None

        @staticmethod
        def lists(*a, **k):
            return None


class PrefixPoolMachine(RuleBasedStateMachine):
    """hypothesis drives the same core through arbitrary interleavings;
    every rule ends with the full invariant check (the @invariant below
    re-runs it between rules)."""

    def __init__(self):
        super().__init__()
        self.core = _HarnessCore()

    @rule(ids=st.lists(st.integers(0, len(_PATTERNS) - 1), min_size=1,
                       max_size=MAX_PROMPT_BLOCKS),
          tail=st.integers(-1, 40))
    def admit(self, ids, tail):
        self.core.admit(_make_prompt(ids, tail))

    @precondition(lambda self: self.core.live)
    @rule(k=st.integers(0, 7))
    def fork(self, k):
        slots = sorted(self.core.live)
        self.core.fork(slots[k % len(slots)])

    @precondition(lambda self: self.core.live)
    @rule(k=st.integers(0, 7))
    def decode(self, k):
        slots = sorted(self.core.live)
        self.core.decode(slots[k % len(slots)])

    @precondition(lambda self: self.core.live)
    @rule(k=st.integers(0, 7), draft=st.integers(1, MAX_DECODE),
          accept=st.integers(0, MAX_DECODE))
    def speculate(self, k, draft, accept):
        slots = sorted(self.core.live)
        self.core.speculate(slots[k % len(slots)], draft, accept)

    @precondition(lambda self: self.core.live)
    @rule(k=st.integers(0, 7))
    def preempt(self, k):
        slots = sorted(self.core.live)
        self.core.release(slots[k % len(slots)], keep=True)

    @precondition(lambda self: self.core.preempted)
    @rule()
    def resume(self):
        self.core.resume()

    @precondition(lambda self: self.core.live)
    @rule(k=st.integers(0, 7))
    def retire(self, k):
        slots = sorted(self.core.live)
        self.core.release(slots[k % len(slots)], keep=False)

    @invariant()
    def pool_consistent(self):
        self.core.check()

    def teardown(self):
        for slot in sorted(self.core.live):
            self.core.release(slot, keep=False)
        self.core.check()
        assert self.core.pool.free_pages == self.core.pool.usable_pages


if _HAVE_HYPOTHESIS:
    TestPrefixPoolStateful = PrefixPoolMachine.TestCase
