"""Paged KV-cache kernel + pool bookkeeping.

Covers the DESIGN.md §Paged KV cache contract at the kernel layer:
  * ``paged_flash_decode`` matches the gather-based oracle (and, through it,
    dense ``ref_flash_decode``) across ragged lengths × page sizes × GQA
    group counts and dtypes,
  * length-0 rows are numerically inert (zeros, no NaN),
  * table entries beyond a row's live pages are never read,
  * ``PagedKVCache`` alloc/free never leaks or double-frees pages under
    random admission/retirement sequences (hypothesis property test);
    freeing a never-admitted slot raises instead of masking a caller bug,
  * refcounted prefix sharing keeps shared pages live until the last
    holder retires (poisoned-page regression; the full sharing lifecycle
    is state-machine-tested in tests/test_paged_prefix.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models.attention import PagedKVCache

# B, page_size, n_pages, H, KV, hd
SHAPES = [
    (2, 8, 4, 4, 2, 64),
    (3, 16, 3, 8, 1, 32),    # MQA
    (2, 32, 2, 4, 4, 128),   # no grouping
    (1, 8, 7, 8, 2, 64),     # odd page count
]


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _pool(key, B, KV, n_pages, ps, hd, dtype, extra=3):
    """Random pool + disjoint per-row tables + ragged lengths."""
    P = B * n_pages + 1 + extra              # + trash page + spare pages
    ks = jax.random.split(key, 4)
    kp = _rand(ks[0], (KV, P, ps, hd), dtype)
    vp = _rand(ks[1], (KV, P, ps, hd), dtype)
    perm = jax.random.permutation(ks[2], P - 1) + 1     # never the trash page
    tables = perm[:B * n_pages].reshape(B, n_pages).astype(jnp.int32)
    C = n_pages * ps
    lengths = jax.random.randint(ks[3], (B,), 1, C + 1).astype(jnp.int32)
    return kp, vp, tables, lengths


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,ps,n_pages,H,KV,hd", SHAPES)
def test_paged_decode_matches_oracle(B, ps, n_pages, H, KV, hd, dtype):
    G = H // KV
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    q = _rand(ks[0], (B, KV, G, hd), dtype)
    kp, vp, tables, lengths = _pool(ks[1], B, KV, n_pages, ps, hd, dtype)
    out = ops.paged_flash_decode(q, kp, vp, tables, lengths)
    want = ref.ref_paged_decode(q, kp, vp, tables, lengths)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_paged_decode_matches_dense_flash_decode():
    """Gathering a row's pages into a dense cache and masking by length must
    give the dense kernel's answer — paged is a layout change, not a math
    change."""
    B, ps, n_pages, H, KV, hd = 2, 8, 4, 4, 2, 64
    G = H // KV
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    q = _rand(ks[0], (B, KV, G, hd), jnp.float32)
    kp, vp, tables, lengths = _pool(ks[1], B, KV, n_pages, ps, hd, jnp.float32)
    out = ops.paged_flash_decode(q, kp, vp, tables, lengths)

    C = n_pages * ps
    kd = jnp.moveaxis(kp[:, tables], 1, 0).reshape(B, KV, C, hd)
    vd = jnp.moveaxis(vp[:, tables], 1, 0).reshape(B, KV, C, hd)
    bias = jnp.where(jnp.arange(C)[None] < lengths[:, None], 0.0, -1e9)
    want = ops.flash_decode_bkchd(q, kd, vd, bias)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_paged_decode_softcap():
    B, ps, n_pages, H, KV, hd = 2, 8, 3, 4, 2, 32
    G = H // KV
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    q = _rand(ks[0], (B, KV, G, hd), jnp.float32)
    kp, vp, tables, lengths = _pool(ks[1], B, KV, n_pages, ps, hd, jnp.float32)
    out = ops.paged_flash_decode(q, kp, vp, tables, lengths, softcap=5.0)
    want = ref.ref_paged_decode(q, kp, vp, tables, lengths, softcap=5.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_paged_decode_dead_rows_are_inert():
    """length == 0 rows (freed slots) produce exact zeros, never NaN."""
    B, ps, n_pages, H, KV, hd = 3, 8, 2, 4, 2, 32
    G = H // KV
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    q = _rand(ks[0], (B, KV, G, hd), jnp.float32)
    kp, vp, tables, _ = _pool(ks[1], B, KV, n_pages, ps, hd, jnp.float32)
    lengths = jnp.array([0, 5, 0], jnp.int32)
    out = np.asarray(ops.paged_flash_decode(q, kp, vp, tables, lengths))
    assert np.all(np.isfinite(out))
    assert np.all(out[0] == 0.0) and np.all(out[2] == 0.0)
    assert np.any(out[1] != 0.0)


def test_paged_decode_ignores_unreachable_pages():
    """Table entries beyond a row's live pages must not affect its output —
    point them at a poisoned page and compare."""
    B, ps, n_pages, H, KV, hd = 1, 8, 4, 4, 2, 32
    G = H // KV
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    q = _rand(ks[0], (B, KV, G, hd), jnp.float32)
    kp, vp, tables, _ = _pool(ks[1], B, KV, n_pages, ps, hd, jnp.float32)
    lengths = jnp.array([ps + 3], jnp.int32)          # live pages: 2 of 4
    poison = kp.shape[1] - 1
    kp = kp.at[:, poison].set(1e4)
    vp = vp.at[:, poison].set(1e4)
    base = ops.paged_flash_decode(q, kp, vp, tables, lengths)
    hot = tables.at[:, 2:].set(poison)
    out = ops.paged_flash_decode(q, kp, vp, hot, lengths)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(out))


def test_shared_pages_survive_sharer_retirement_poisoned():
    """Refcounted sharing at the kernel boundary (the shared-page mirror of
    the unreachable-page test above): two rows map the same prefix pages;
    when one retires, ``free`` must release only its private pages. Poison
    everything it released — simulating reuse by a later admission — and
    the survivor's decode output must not move. A pool that released
    shared pages at first retirement would hand the survivor garbage."""
    B, ps, n_pages, H, KV, hd = 2, 8, 4, 4, 2, 32
    G = H // KV
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = _rand(ks[0], (B, KV, G, hd), jnp.float32)

    pool = PagedKVCache(total_pages=2 * n_pages + 1, page_size=ps)
    a = pool.alloc(0, n_pages)
    shared = a[:2]                            # row 1 maps row 0's prefix
    b = pool.alloc(1, n_pages - len(shared), shared=shared)
    P = pool.total_pages
    kp = _rand(ks[1], (KV, P, ps, hd), jnp.float32)
    vp = _rand(ks[2], (KV, P, ps, hd), jnp.float32)
    tables = jnp.asarray([a, shared + b], jnp.int32)
    lengths = jnp.full((B,), n_pages * ps, jnp.int32)
    base = ops.paged_flash_decode(q, kp, vp, tables, lengths)

    released = pool.free(0)
    assert sorted(released) == sorted(a[2:])  # shared pages stayed live
    assert all(pg in pool.owned(1) for pg in shared)
    pool.assert_invariants()
    hot = jnp.asarray(released)
    kp = kp.at[:, hot].set(1e4)
    vp = vp.at[:, hot].set(1e4)
    out = ops.paged_flash_decode(q, kp, vp, tables, lengths)
    np.testing.assert_array_equal(np.asarray(base[1]), np.asarray(out[1]))
    assert sorted(pool.free(1)) == sorted(set(shared) | set(b))
    pool.assert_invariants()


# ---------------------------------------------------------------------------
# pool bookkeeping: alloc/free safety
# ---------------------------------------------------------------------------

def test_pool_alloc_free_basics():
    pool = PagedKVCache(total_pages=9, page_size=4)
    assert pool.usable_pages == 8 and pool.free_pages == 8
    assert pool.pages_needed(0) == 0 and pool.pages_needed(1) == 1
    assert pool.pages_needed(4) == 1 and pool.pages_needed(5) == 2
    a = pool.alloc(0, 3)
    b = pool.alloc(1, 5)
    assert PagedKVCache.TRASH_PAGE not in a + b
    assert len(set(a) | set(b)) == 8 and pool.free_pages == 0
    assert pool.alloc(2, 1) is None           # all-or-nothing: pool exhausted
    assert pool.occupancy == 1.0
    with pytest.raises(ValueError):
        pool.alloc(0, 1)                      # slot 0 already owns pages
    pool.free(0)
    assert pool.free_pages == 3 and sorted(pool.free(1)) == sorted(b)
    with pytest.raises(ValueError, match="owns no pages"):
        pool.free(5)                          # never admitted: a caller bug
    with pytest.raises(ValueError, match="owns no pages"):
        pool.free(0)                          # double free: same error class
    assert pool.occupancy == 0.0
    pool.assert_invariants()


def test_pool_random_admission_retirement_never_leaks():
    """Random interleaving of admissions and retirements preserves the pool
    invariants (free + owned partition the usable pages; no double grants).
    Hypothesis drives the schedule when available; a seeded fallback sweep
    keeps the property exercised without it."""
    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:                        # optional dep
        _pool_schedule_property(list(np.random.default_rng(0)
                                     .integers(0, 10_000, 200)))
        return

    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.integers(0, 10_000), min_size=1, max_size=120))
    def prop(ops_seed):
        _pool_schedule_property(ops_seed)

    prop()


def _pool_schedule_property(ops_seed):
    pool = PagedKVCache(total_pages=17, page_size=4)
    live = {}                                  # slot -> pages
    next_slot = 0
    for op in ops_seed:
        if op % 2 == 0 or not live:            # admit
            n = 1 + (op // 2) % 4
            free_before = pool.free_pages
            got = pool.alloc(next_slot, n)
            if got is None:
                assert n > free_before         # refuses only when short
            else:
                assert len(got) == n
                live[next_slot] = got
                next_slot += 1
        else:                                  # retire a random live slot
            slot = sorted(live)[(op // 2) % len(live)]
            freed = pool.free(slot)
            assert sorted(freed) == sorted(live.pop(slot))
        owned = [p for pages in live.values() for p in pages]
        # invariant: owned pages are unique, disjoint from free, and
        # partition the usable pool with the free list
        assert len(owned) == len(set(owned))
        assert PagedKVCache.TRASH_PAGE not in owned
        assert len(owned) + pool.free_pages == pool.usable_pages
        assert pool.used_pages == len(owned)
    for slot in list(live):
        pool.free(slot)
    assert pool.free_pages == pool.usable_pages
