"""SSD scan kernel + chunked jnp implementation vs the naive recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models.ssd import ssd_chunked

SHAPES = [
    # b, s, h, p, n, chunk
    (2, 128, 4, 32, 16, 64),
    (1, 256, 8, 64, 32, 128),
    (2, 64, 2, 16, 8, 32),
    (1, 64, 24, 64, 128, 64),   # mamba2-130m-like head geometry
]


def _inputs(key, b, s, h, p, n):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.abs(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    return x, dt, A, B, C


@pytest.mark.parametrize("b,s,h,p,n,chunk", SHAPES)
def test_ssd_kernel_matches_naive(b, s, h, p, n, chunk):
    x, dt, A, B, C = _inputs(jax.random.PRNGKey(0), b, s, h, p, n)
    init = jax.random.normal(jax.random.PRNGKey(9), (b, h, p, n)) * 0.1
    y, fs = ops.ssd_scan(x, dt, A, B, C, chunk=chunk, initial_state=init)
    yr, fsr = ref.ref_ssd(x, dt, A, B, C, initial_state=init)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=5e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(fs), np.asarray(fsr), atol=5e-3, rtol=1e-3)


@pytest.mark.parametrize("b,s,h,p,n,chunk", SHAPES[:2])
def test_ssd_chunked_jnp_matches_naive(b, s, h, p, n, chunk):
    x, dt, A, B, C = _inputs(jax.random.PRNGKey(1), b, s, h, p, n)
    y, fs = ssd_chunked(x, dt, A, B, C, chunk)
    yr, fsr = ref.ref_ssd(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=5e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(fs), np.asarray(fsr), atol=5e-3, rtol=1e-3)


def test_ssd_bf16_inputs():
    b, s, h, p, n = 1, 128, 4, 32, 16
    x, dt, A, B, C = _inputs(jax.random.PRNGKey(2), b, s, h, p, n)
    y32, _ = ops.ssd_scan(x, dt, A, B, C, chunk=64)
    yb, _ = ops.ssd_scan(x.astype(jnp.bfloat16), dt, A, B, C, chunk=64)
    np.testing.assert_allclose(np.asarray(yb, np.float32), np.asarray(y32),
                               atol=0.15, rtol=0.1)


def test_ssd_state_chaining():
    """Scanning two halves with carried state == scanning the whole sequence."""
    b, s, h, p, n = 1, 128, 2, 16, 8
    x, dt, A, B, C = _inputs(jax.random.PRNGKey(3), b, s, h, p, n)
    y_full, fs_full = ops.ssd_scan(x, dt, A, B, C, chunk=32)
    y1, fs1 = ops.ssd_scan(x[:, :64], dt[:, :64], A, B[:, :64], C[:, :64], chunk=32)
    y2, fs2 = ops.ssd_scan(x[:, 64:], dt[:, 64:], A, B[:, 64:], C[:, 64:],
                           chunk=32, initial_state=fs1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_full), atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(fs2), np.asarray(fs_full), atol=1e-3,
                               rtol=1e-3)
