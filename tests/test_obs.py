"""Observability layer tests (DESIGN.md §Observability).

Unit coverage for the metrics registry (instrument semantics, reservoir
histograms, disabled no-ops), the tracer + Chrome trace_event exporter
(round-trip through the schema validator, malformed traces rejected), and
the decision audit (window bucketing, regret signs). Integration coverage
drives the real engine on one virtual clock and asserts the registry, the
span streams, and ``summarize``/``kv_pool_stats`` agree with each other;
a sim run checks both backends emit the same metric names; an overhead
guard bounds the cost of disabled-mode hooks.
"""
import json

import numpy as np
import pytest

from conftest import MAX_NEW, PROMPT_LEN, VOCAB, tiny_engine, tiny_variants

from repro.obs import (DecisionAudit, MetricsRegistry, NULL_REGISTRY,
                       NullInstrument, Observability, Tracer,
                       attach_from_requests, predict_outputs,
                       to_chrome_trace, validate_chrome_trace)
from repro.obs import trace as ev
from repro.obs.export import validate_metrics_file, write_metrics_jsonl


# --------------------------------------------------------------- registry
def test_counter_gauge_semantics():
    m = MetricsRegistry()
    m.inc("a.total")
    m.inc("a.total", 4)
    assert m.value("a.total") == 5.0
    with pytest.raises(ValueError):
        m.counter("a.total").inc(-1)         # counters are monotone
    m.set("a.gauge", 3.5)
    m.set("a.gauge", 2.0)                    # gauges overwrite
    assert m.value("a.gauge") == 2.0
    assert m.value("missing", default=-1.0) == -1.0
    with pytest.raises(TypeError):
        m.gauge("a.total")                   # kind mismatch is an error


def test_histogram_percentiles_match_numpy():
    m = MetricsRegistry()
    rng = np.random.default_rng(0)
    xs = rng.exponential(10.0, 500)
    h = m.histogram("lat")
    for x in xs:
        h.observe(x)
    # 500 < reservoir cap: percentiles are exact
    for p in (50, 95, 99):
        assert h.percentile(p) == pytest.approx(np.percentile(xs, p))
    assert h.count == 500 and h.mean == pytest.approx(xs.mean())
    snap = h.snapshot()
    assert snap["kind"] == "histogram" and "p99" in snap


def test_histogram_reservoir_bounded():
    m = MetricsRegistry(reservoir=64)
    h = m.histogram("big")
    for x in range(10_000):
        h.observe(float(x))
    assert h.count == 10_000
    assert len(h._res) <= 64
    # algorithm R keeps a uniform sample: median far from either extreme
    assert 1_000 < h.percentile(50) < 9_000


def test_disabled_registry_is_noop():
    m = MetricsRegistry(enabled=False)
    c = m.counter("x")
    assert isinstance(c, NullInstrument)
    assert m.counter("y") is c               # one shared null instrument
    m.inc("x", 5)
    m.observe("h", 1.0)
    m.set("g", 2.0)
    assert m.snapshot() == [] and m.value("x") == 0.0
    assert NULL_REGISTRY.counter("z") is c


def test_registry_dump_and_reset(tmp_path):
    m = MetricsRegistry()
    m.inc("requests.completed", 3)
    m.observe("request.latency_ms", 12.0)
    path = str(tmp_path / "m.jsonl")
    n = write_metrics_jsonl(path, m, extra=[{"name": "run", "kind": "meta"}])
    assert n == 3 and validate_metrics_file(path) == 3
    m.reset()
    assert m.names() == []
    with pytest.raises(ValueError):          # empty dump fails validation
        write_metrics_jsonl(str(tmp_path / "e.jsonl"), m)
        validate_metrics_file(str(tmp_path / "e.jsonl"))


# ----------------------------------------------------------------- tracer
def _toy_tracer():
    tr = Tracer(enabled=True)
    tr.event(1, ev.QUEUED, 0.0)
    tr.event(1, ev.ADMITTED, 1.0, slot=0)
    tr.event(1, ev.PREFILL_COMPLETE, 2.0)
    tr.event(1, ev.COMPLETE, 5.0, latency_ms=5000.0)
    tr.event(2, ev.QUEUED, 0.5)
    tr.event(2, ev.ADMITTED, 1.5, slot=1)
    tr.event(2, ev.PREEMPT, 2.5, action="requeue")
    tr.event(2, ev.RESUME, 3.5, slot=0)
    tr.event(2, ev.PREFILL_COMPLETE, 4.0)
    tr.event(2, ev.DROP, 6.0)
    from repro.obs import TickRecord
    for i in range(3):
        tr.tick(TickRecord(backend="b0", t=float(i), kind="decode",
                           preempt_ms=0.0, admit_ms=0.1, exec_ms=1.0,
                           active=2, prefilling=0, queued=1, admitted=1,
                           preempted=0, completed=0))
    return tr


def test_chrome_trace_round_trip():
    tr = _toy_tracer()
    obj = to_chrome_trace(tr, label="t")
    n = validate_chrome_trace(obj)           # schema-valid by construction
    assert n == len(obj["traceEvents"]) > 0
    text = json.dumps(obj)                   # JSON round-trip preserves it
    assert validate_chrome_trace(json.loads(text)) == n
    # request lanes (pid 1) carry phase slices; tick lane (pid 2) X events
    pids = {e["pid"] for e in obj["traceEvents"] if e["ph"] != "M"}
    assert pids == {1, 2}
    slices = [e for e in obj["traceEvents"]
              if e["ph"] == "X" and e["pid"] == 1]
    assert any(e["name"] == "preempted" for e in slices)
    for e in slices:
        assert e["dur"] >= 0


def test_validate_rejects_malformed():
    good = to_chrome_trace(_toy_tracer(), label="t")
    for mangle in (
        lambda o: o.pop("traceEvents"),
        lambda o: o["traceEvents"][0].pop("ph"),
        lambda o: o["traceEvents"][0].update(ph="Z"),
        lambda o: next(e for e in o["traceEvents"]
                       if e["ph"] == "X").update(dur=-1.0),
        lambda o: next(e for e in o["traceEvents"]
                       if e["ph"] == "X").pop("dur"),
    ):
        obj = json.loads(json.dumps(good))
        mangle(obj)
        with pytest.raises(ValueError):
            validate_chrome_trace(obj)


def test_tracer_caps_drop_counted():
    tr = Tracer(enabled=True, max_events=10)
    for i in range(25):
        tr.event(i, ev.QUEUED, float(i))
    assert tr.n_events == 10 and tr.dropped_events == 15
    s = tr.summary()
    assert s["events"] == 10 and s["dropped_events"] == 15


# ------------------------------------------------------------------ audit
class _Prof:
    def __init__(self, p99, th):
        self._p99, self._th = p99, th

    def p99_ms(self, n):
        return self._p99

    def throughput(self, n):
        return self._th * n


class _Alloc:
    def __init__(self, units, quotas):
        self.units, self.quotas = units, quotas


def test_predict_outputs():
    profiles = {"fast": _Prof(100.0, 10.0), "slow": _Prof(900.0, 5.0)}
    alloc = _Alloc({"fast": 2, "slow": 1}, {"fast": 15.0, "slow": 5.0})
    pred = predict_outputs(profiles, alloc, lam=20.0, slo_ms=500.0)
    assert pred["p99_ms"] == pytest.approx(0.75 * 100 + 0.25 * 900)
    assert pred["p99_max_ms"] == 900.0
    assert pred["capacity_rps"] == pytest.approx(25.0)
    assert pred["goodput"] == pytest.approx(0.75)   # slow violates the SLO
    empty = predict_outputs(profiles, _Alloc({}, {}), 10.0, 500.0)
    assert empty["goodput"] == 0.0 and np.isnan(empty["p99_ms"])


def test_audit_window_bucketing_and_regret(tmp_path):
    audit = DecisionAudit()
    audit.record(0.0, "c", {"lam": 5.0},
                 {"predicted": {"p99_ms": 100.0, "goodput": 1.0}})
    audit.record(10.0, "c", {"lam": 9.0},
                 {"predicted": {"p99_ms": 200.0, "goodput": 0.5}},
                 reason="reactive")
    # warm-up (-1) and [0,10) land on decision 0; [10,inf) on decision 1
    arrivals = [-1.0, 1.0, 5.0, 12.0, 15.0]
    lats = [50.0, 150.0, 150.0, 300.0, 100.0]
    ok = [True, True, False, False, True]
    assert audit.attach_measured(arrivals, lats, ok) == 2
    m0, m1 = audit.entries[0].measured, audit.entries[1].measured
    assert m0["n_requests"] == 3 and m1["n_requests"] == 2
    assert m0["goodput"] == pytest.approx(2 / 3)
    # regret signs: measured p99 over prediction → positive p99 regret;
    # goodput under prediction → positive goodput regret (optimism)
    r1 = audit.entries[1].regret
    assert r1["p99_ms"] == pytest.approx(m1["p99_ms"] - 200.0)
    assert r1["goodput"] == pytest.approx(0.5 - 0.5)
    path = str(tmp_path / "a.jsonl")
    assert audit.to_jsonl(path) == 2
    rows = [json.loads(l) for l in open(path)]
    assert rows[1]["reason"] == "reactive" and "regret" in rows[1]
    s = audit.summary()
    assert s["n_decisions"] == 2 and s["n_measured"] == 2


def test_attach_from_requests_duck_typing():
    class R:
        def __init__(self, arrival, completion, slo_ms=0.0,
                     service_start=1.0, dropped=False):
            self.arrival, self.completion = arrival, completion
            self.slo_ms, self.service_start = slo_ms, service_start
            self.dropped = dropped

    audit = DecisionAudit()
    audit.record(0.0, "c", {}, {"predicted": {"p99_ms": 1.0,
                                              "goodput": 1.0}})
    reqs = [R(0.0, 0.1, slo_ms=200.0),            # ok (100ms <= 200ms)
            R(1.0, 2.0, slo_ms=200.0),            # SLO miss
            R(2.0, 2.1, dropped=True),            # dropped
            R(3.0, 3.05, service_start=0.0)]      # never served
    assert attach_from_requests(audit, reqs, default_slo_ms=100.0) == 1
    m = audit.entries[0].measured
    assert m["n_requests"] == 4 and m["goodput"] == pytest.approx(0.25)
    assert attach_from_requests(None, reqs) == 0  # opportunistic no-op


# ------------------------------------------------- scheduler describe()
def test_scheduler_describe_metadata():
    from repro.serving.sched import make_scheduler
    assert make_scheduler("fifo").describe() == {
        "policy": "fifo", "chunked": False, "admission": "fifo"}
    d = make_scheduler("chunked-fifo").describe()
    assert d["policy"] == "chunked-fifo" and d["chunked"] \
        and d["admission"] == "fifo"
    assert make_scheduler("edf").describe()["admission"] == "edf"


# ------------------------------------------------------ engine integration
def _run_traced_engine(**kw):
    """Tiny engine on a virtual clock; returns (engine, clock time)."""
    from repro.serving.api import Request
    clk = [0.0]
    eng = tiny_engine(clock=lambda: clk[0], trace=True, queue_cap=64, **kw)
    name = next(iter(eng.variant_defs))
    eng.apply_allocation(0.0, {name: 1})
    rng = np.random.default_rng(1)
    for i in range(6):
        eng.submit(Request(rid=i, tokens=rng.integers(0, VOCAB, PROMPT_LEN),
                           max_new=MAX_NEW, arrival=clk[0], slo_ms=1e6),
                   None)
        eng.step(clk[0])
        clk[0] += 0.01
    for _ in range(500):
        if not (eng.backlog(clk[0]) or eng.in_flight()):
            break
        eng.step(clk[0])
        clk[0] += 0.01
    assert len(eng.done) == 6
    return eng, clk[0]


@pytest.mark.parametrize("kw", [
    dict(),                                                    # fifo dense
    dict(scheduler="chunked", kv_cache="paged",
         kv_prefix_sharing=True, prefill_chunk=4),             # full stack
])
def test_engine_spans_and_registry_consistency(kw):
    eng, _ = _run_traced_engine(**kw)
    m = eng.metrics
    assert int(m.value("requests.submitted")) == 6
    assert int(m.value("requests.completed")) == 6
    assert int(m.value("requests.goodput_ok")) == 6
    lat = m.get("request.latency_ms")
    assert lat is not None and lat.count == 6
    # registry prefill counter == backend attribute sum (one counting path)
    attr = sum(b.prefill_tokens_total for b in eng.backends.values())
    assert int(m.value("engine.prefill_tokens_total")) == attr > 0
    # every completed request carries a monotone, terminated span stream
    for r in eng.done:
        assert r.spans, r.rid
        ts = [e.t for e in r.spans]
        assert ts == sorted(ts)
        names = [e.name for e in r.spans]
        assert names[0] == ev.QUEUED
        assert names[-1] == ev.COMPLETE
        assert ev.ADMITTED in names
        for name in names[:-1]:
            assert name not in ev.TERMINAL_EVENTS
    # tick records cover the run and the trace exports schema-valid
    assert eng.tracer.ticks and eng.tracer.dropped_events == 0
    assert validate_chrome_trace(to_chrome_trace(eng.tracer, "t")) > 0


def test_engine_summarize_agrees_with_registry():
    eng, _ = _run_traced_engine()
    s = eng.summarize(slo_ms=1e6, best_accuracy=70.0)
    m = eng.metrics
    assert s["n_requests"] == int(m.value("requests.completed"))
    lat = m.get("request.latency_ms")
    assert s["p99_ms"] == pytest.approx(lat.percentile(99))
    assert s["goodput"] == pytest.approx(
        m.value("requests.goodput_ok") / m.value("requests.completed"))


def test_kv_pool_stats_registry_backed():
    eng, _ = _run_traced_engine(scheduler="chunked", kv_cache="paged",
                                kv_prefix_sharing=True, prefill_chunk=4)
    stats = eng.kv_pool_stats()
    m = eng.metrics
    assert stats["prefix_lookups"] == int(m.value("kv.prefix_lookups")) > 0
    assert stats["fresh_pages_allocated"] == \
        int(m.value("kv.pages_allocated")) > 0
    assert stats["used_pages"] == 0          # everything drained


def test_engine_preemption_spans():
    """Preempt/requeue under deadline pressure: PREEMPT then RESUME appear
    on the same request, stream still monotone and terminated."""
    from repro.serving.api import Request
    clk = [0.0]
    eng = tiny_engine(clock=lambda: clk[0], trace=True, scheduler="edf",
                      preemption="requeue", kv_cache="paged", queue_cap=64)
    name = next(iter(eng.variant_defs))
    eng.apply_allocation(0.0, {name: 1})
    rng = np.random.default_rng(2)
    # hopeless requests (deadline long past) grab both slots first; then
    # feasible ones arrive and the EDF scheduler must preempt to serve them
    for i in range(2):
        eng.submit(Request(rid=i, tokens=rng.integers(0, VOCAB, PROMPT_LEN),
                           max_new=MAX_NEW, arrival=0.0, slo_ms=1.0), None)
    clk[0] = 100.0
    eng.step(clk[0])                          # admit the hopeless pair
    for i in range(2, 6):
        eng.submit(Request(rid=i, tokens=rng.integers(0, VOCAB, PROMPT_LEN),
                           max_new=MAX_NEW, arrival=0.0, slo_ms=1e9), None)
    for _ in range(400):
        if len(eng.done) == 6:
            break
        eng.step(clk[0])
        clk[0] += 0.01
    assert len(eng.done) == 6
    assert int(eng.metrics.value("requests.preempted")) > 0
    preempted = [r for r in eng.done
                 if any(e.name == ev.PREEMPT for e in (r.spans or ()))]
    assert preempted
    for r in preempted:
        names = [e.name for e in r.spans]
        assert ev.RESUME in names
        assert names.index(ev.PREEMPT) < names.index(ev.RESUME)
        ts = [e.t for e in r.spans]
        assert ts == sorted(ts)
        assert names[-1] in ev.TERMINAL_EVENTS


# ------------------------------------------------------- sim/engine parity
def test_sim_and_engine_emit_same_metric_names():
    from repro.core.profiles import paper_resnet_profiles
    from repro.serving.api import Request
    from repro.sim.cluster import SimCluster

    profiles = paper_resnet_profiles()
    sim = SimCluster(profiles, trace=True)
    name = next(iter(profiles))
    sim.apply_allocation(-100.0, {name: 2})
    rng = np.random.default_rng(3)
    for i in range(40):
        sim.submit(Request(rid=i, tokens=np.zeros(0, np.int64), max_new=1,
                           arrival=float(i) * 0.05, slo_ms=750.0), name)
    sim.drain(2.0)
    eng, _ = _run_traced_engine()
    core = {"requests.submitted", "requests.completed",
            "requests.goodput_ok", "request.latency_ms",
            "request.queue_wait_ms", "request.service_ms"}
    assert core <= set(sim.metrics.names())
    assert core <= set(eng.metrics.names())
    # sim requests got span streams too
    spanned = [rid for rid, evs in sim.tracer.events.items() if evs]
    assert len(spanned) == 40
    for evs in sim.tracer.events.values():
        assert [e.t for e in evs] == sorted(e.t for e in evs)
        assert evs[-1].name in (ev.COMPLETE, ev.DROP)


# ---------------------------------------------------------- overhead guard
def test_disabled_hooks_are_cheap():
    """A disabled-observability hook must cost no more than ~a few µs even
    on a loaded CI host — the real gate (≤2% of a tick) runs in
    bench_engine; this guards against accidentally giving NullInstrument
    or the disabled registry a slow path."""
    import time
    obs = Observability.disabled()
    m, tr = obs.metrics, obs.tracer
    c = m.counter("x")
    n = 50_000
    t0 = time.perf_counter()
    for _ in range(n):
        c.inc()
        m.inc("x", 2)
        m.observe("h", 1.0)
        tr.event(0, "e", 0.0)
    per_call_us = (time.perf_counter() - t0) / (n * 4) * 1e6
    assert per_call_us < 5.0, per_call_us


# ------------------------------------------------- controller audit (sim)
def test_controller_audit_end_to_end():
    from repro.core.adapter import ControllerConfig, InfAdapterController
    from repro.core.forecaster import MovingMaxForecaster
    from repro.core.profiles import paper_resnet_profiles
    from repro.sim.runner import run_experiment

    profiles = paper_resnet_profiles()
    cfg = ControllerConfig(interval_s=30, budget=20, slo_ms=750.0,
                           reactive=True)
    ctrl = InfAdapterController(profiles, MovingMaxForecaster(), cfg)
    trace = np.concatenate([np.full(40, 5.0), np.full(40, 15.0)])
    run_experiment("audit", ctrl, profiles, trace, slo_ms=750.0,
                   warm_start={min(profiles): 4})
    audit = ctrl.audit
    assert len(audit.entries) >= 3
    e0 = audit.entries[0]
    assert e0.controller == "InfAdapterController"
    assert {"lam", "lam_forecast", "backlog", "capacity_factor", "solver",
            "loaded"} <= set(e0.inputs)
    assert {"units", "quotas", "objective", "predicted"} <= set(e0.outputs)
    assert e0.outputs["predicted"]["capacity_rps"] > 0
    # measured outcomes + regret attached by the runner post-drain
    measured = [e for e in audit.entries
                if e.measured and e.measured["n_requests"]]
    assert measured and all(e.regret is not None for e in measured)
    reasons = {e.reason for e in audit.entries}
    assert "interval" in reasons


def test_summarize_requests_percentiles_and_slo_classes():
    from repro.serving.api import summarize_requests
    rng = np.random.default_rng(5)
    n = 200
    arrivals = np.arange(n, dtype=float)
    lats = rng.exponential(100.0, n)
    accs = np.full(n, 70.0)
    slos = np.where(np.arange(n) % 2 == 0, 150.0, 600.0)
    s = summarize_requests(arrivals, lats, accs, slo_ms=600.0,
                           best_accuracy=70.0, slo_list_ms=slos)
    for k in ("p50_ms", "p95_ms", "p99_ms"):
        assert k in s
    assert s["p50_ms"] == pytest.approx(np.percentile(lats, 50))
    assert s["p50_ms"] <= s["p95_ms"] <= s["p99_ms"]
    classes = s["slo_classes"]
    assert set(classes) == {"150", "600"}
    tight = classes["150"]
    assert tight["n_requests"] == 100
    assert tight["goodput"] == pytest.approx(
        np.mean(lats[::2] <= 150.0))
    # homogeneous SLOs: no per-class breakdown
    s2 = summarize_requests(arrivals, lats, accs, slo_ms=600.0,
                            best_accuracy=70.0,
                            slo_list_ms=np.full(n, 600.0))
    assert "slo_classes" not in s2
