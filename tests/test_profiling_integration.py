"""Profiling subsystem end to end against the real engine: measured sweep,
store persistence, queue/service split, drift detection on an injected
slowdown, and the recalibrated profile shifting the solver's allocation."""
import time

import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core.adapter import ControllerConfig, InfAdapterController
from repro.core.forecaster import MovingMaxForecaster
from repro.core.solver import solve_exact
from repro.profiling.calibrate import (calibrated_roofline_profile,
                                       roofline_scale_factor)
from repro.profiling.drift import DriftDetector, OnlineRecalibrator
from repro.profiling.measure import EngineProfiler, fit_latency
from repro.profiling.store import ProfileStore
from repro.serving.api import Request
from repro.serving.engine import InProcessServingEngine

MAX_NEW = 8
PROMPT = 8


def _variants():
    base = smoke_variant(get_config("tinyllama-1.1b")).replace(
        d_model=64, d_ff=128, vocab_size=128)
    return {"small": (base.replace(num_layers=2, name="small"), 70.0)}


def _engine(**kw):
    return InProcessServingEngine(_variants(), max_batch=4, prompt_len=PROMPT,
                                  max_new=MAX_NEW, decode_chunk=4,
                                  enforce_units=True, **kw)


def _submit(eng, n, rng, backend="small"):
    for i in range(n):
        eng.submit(Request(rid=i, tokens=rng.integers(0, 128, PROMPT).astype(np.int64),
                           max_new=MAX_NEW, arrival=time.time()), backend)
    eng.drain(0.0)


def _slow_down(backend, stall_s=0.02):
    """Inject drift: every decode chunk stalls, as under host contention."""
    orig = backend._decode_chunk
    backend._decode_chunk = lambda p, c, t: (time.sleep(stall_s),
                                             orig(p, c, t))[1]


@pytest.fixture(scope="module")
def profiled():
    """One measured sweep shared by the tests in this module (it's the
    expensive part: real prefill/decode at three allocation points)."""
    eng = _engine()
    profiler = EngineProfiler(eng, points=(1, 2, 4), requests_per_point=10,
                              warmup=3, max_units=8)
    return eng, profiler, profiler.profile_variant("small")


def test_measured_profile_shape(profiled):
    _, _, m = profiled
    assert [p.units for p in m.points] == [1, 2, 4]
    assert m.readiness_s > 0.0                    # actual jit warm-up time
    assert m.profile.rt == m.readiness_s
    # continuous batching amortizes prefill+chunk cost: capacity grows with
    # the allocation's concurrency
    assert m.points[-1].throughput_rps > m.points[0].throughput_rps
    assert 0.0 <= m.confidence <= 1.0
    assert 0.0 <= m.th_fit.r_squared <= 1.0
    for p in m.points:
        assert p.n_requests >= 10     # whole completion batches are counted
        assert p.mean_service_ms > 0.0
        # profiler admits directly into free slots: queue wait is negligible
        # next to service (the split is the point of the measurement)
        assert p.mean_queue_ms < p.mean_service_ms


def test_queue_service_split_in_serving(profiled):
    """Live serving stamps the split; components add up to end-to-end."""
    eng, _, _ = profiled
    eng.apply_allocation(0.0, {"small": 2})
    _submit(eng, 12, np.random.default_rng(0))
    assert len(eng.done) >= 12
    for r in eng.done:
        assert r.service_start > 0.0
        # components recompose end-to-end latency (float slack: the three
        # epoch-second differences each carry ~1e-7 s of rounding)
        assert abs(r.queue_wait_ms + r.service_ms - r.latency_ms) < 1e-2
    s = eng.summarize(slo_ms=60_000, best_accuracy=70.0)
    assert s["mean_service_ms"] > 0.0
    assert s["mean_queue_ms"] >= 0.0
    assert s["p99_service_ms"] <= s["p99_ms"] + 1e-9


def test_store_roundtrip_measured(profiled, tmp_path):
    _, _, m = profiled
    store = ProfileStore(str(tmp_path / "m.json"))
    store.register(m.profile, "measured", fit=m.th_fit,
                   meta={"confidence": m.confidence})
    loaded = ProfileStore.load(store.save())
    assert loaded.get("small") == m.profile
    assert loaded.entry("small").provenance == "measured"


def test_roofline_cross_calibration(profiled):
    """The calibrated roofline reproduces a measured variant's slope by
    construction (single-reference calibration) and scales latency
    inversely."""
    _, _, m = profiled
    cfgs = {n: c for n, (c, _) in _variants().items()}
    scale = roofline_scale_factor({"small": m}, cfgs)
    assert scale > 0.0
    cal = calibrated_roofline_profile(cfgs["small"], 70.0, scale=scale)
    raw = calibrated_roofline_profile(cfgs["small"], 70.0, scale=1.0)
    assert np.isclose(cal.th_slope, m.th_fit.slope, rtol=1e-6)
    assert np.isclose(cal.lat_k_ms * scale, raw.lat_k_ms, rtol=1e-6)


def test_drift_flagged_and_recalibration_shifts_allocation(profiled, tmp_path):
    """The acceptance scenario: healthy engine within band; slowed engine
    flagged; targeted re-profile patches store + controller and the Eq. 1
    solver provisions more units for the same load."""
    _, _, m = profiled
    store = ProfileStore(str(tmp_path / "d.json"))
    store.register(m.profile, "measured", fit=m.th_fit, meta=m.store_meta())

    eng = _engine()
    eng.apply_allocation(0.0, {"small": 2})
    # tolerance 1.0 -> band [0.5, 2.0]: wide enough that scheduler noise
    # between two separately-built backends can't trip it, narrow enough
    # that the injected ~10x stall lands far outside
    detector = DriftDetector(store, tolerance=1.0, min_requests=8)
    rng = np.random.default_rng(1)
    _submit(eng, 12, rng)
    detector.observe_engine(eng)
    healthy = detector.check("small", units=2)
    assert not healthy.drifted, healthy.reason
    assert healthy.n_obs >= 8

    # inject the slowdown mid-flight on the live backend
    _slow_down(eng.backends["small"], stall_s=0.03)
    _submit(eng, 12, rng)
    detector.observe_engine(eng)
    drifted = detector.check("small", units=2)
    assert drifted.drifted
    assert drifted.service_ratio > 2.0

    # targeted re-profile of just this variant, store + controller patched
    profiler = EngineProfiler(eng, requests_per_point=8, warmup=2, max_units=8)
    ctrl = InfAdapterController(store.profiles(), MovingMaxForecaster(window=5),
                               ControllerConfig(budget=8, slo_ms=10_000.0))
    recal = OnlineRecalibrator(profiler, store, controller=ctrl,
                               detector=detector, points=(1, 2),
                               requests_per_point=6)
    m2 = recal.recalibrate("small")
    assert m2.profile.throughput(1) < 0.8 * m.profile.throughput(1)
    assert ctrl.profiles["small"] == m2.profile          # live patch
    assert store.entry("small").meta["recalibrated"] is True
    assert detector.check("small", 2).reason.startswith("insufficient")

    lam = 0.8 * m.profile.throughput(1)
    before = solve_exact({"small": m.profile}, lam, 8, 10_000.0)
    after = solve_exact({"small": m2.profile}, lam, 8, 10_000.0)
    assert after.total_units() > before.total_units()


def test_fit_latency_degenerate_and_hyperbolic():
    base, k, r2 = fit_latency([(1, 130.0), (2, 80.0), (4, 55.0)])
    # exact hyperbola 30 + 100/n
    assert abs(base - 30.0) < 1e-6 and abs(k - 100.0) < 1e-6
    assert r2 > 0.999
    # flat data: constant model, perfect fit, never a negative k
    base, k, r2 = fit_latency([(1, 50.0), (2, 50.0), (4, 50.0)])
    assert base == 50.0 and k == 0.0 and r2 == 1.0
    # rising-in-n data degrades to the constant model (k clamped at 0)
    base, k, _ = fit_latency([(1, 40.0), (2, 50.0), (4, 60.0)])
    assert k == 0.0 and base == 50.0
