"""Cluster fabric: placement policies, rolling replica reconfiguration,
two-level routing balance, backlog semantics, and end-to-end failure
scenarios on the discrete-event backend."""
import numpy as np
import pytest

from repro.cluster import (FaultSchedule, FirstFitPlacement, Node,
                           PlacementError, ReplicaSpec, SpreadPlacement,
                           make_nodes, node_crash, node_recover,
                           replica_restore, replica_sizes, replica_slowdown)
from repro.core.adapter import ControllerConfig, InfAdapterController
from repro.core.forecaster import MovingMaxForecaster
from repro.core.profiles import VariantProfile, paper_resnet_profiles
from repro.serving.api import ClusterAPI, ServingAPI
from repro.sim.cluster import SimCluster
from repro.sim.runner import run_experiment

PROFILES = paper_resnet_profiles(noise=0.0)


# --------------------------------------------------------------- placement
def test_replica_sizes_even_split():
    assert replica_sizes(8, 2) == [2, 2, 2, 2]
    assert replica_sizes(5, 2) == [2, 2, 1]
    assert replica_sizes(3, 8) == [3]
    assert replica_sizes(0, 2) == []
    # total is always preserved
    for units in range(1, 30):
        for r in range(1, 9):
            assert sum(replica_sizes(units, r)) == units


def test_first_fit_packs_spread_spreads():
    nodes = make_nodes(3, 4)
    specs = [ReplicaSpec("m", i, 2) for i in range(3)]
    pl = FirstFitPlacement().place(nodes, specs, {})
    assert pl.feasible
    assert sorted(s.node_id for s in pl.placed) == ["node0", "node0", "node1"]
    specs = [ReplicaSpec("m", i, 2) for i in range(3)]
    pl = SpreadPlacement().place(nodes, specs, {})
    assert sorted(s.node_id for s in pl.placed) == ["node0", "node1", "node2"]


def test_placement_respects_existing_usage_and_dead_nodes():
    nodes = make_nodes(2, 4)
    nodes[0].alive = False
    pl = SpreadPlacement().place(nodes, [ReplicaSpec("m", 0, 4)],
                                 {"node1": 2})
    # node0 dead, node1 half full -> repair shrinks to the free 2 units
    assert pl.placed[0].node_id == "node1"
    assert pl.placed[0].units == 2
    assert pl.shortfall == {"m": 2}


def test_placement_strict_rejects_infeasible():
    nodes = make_nodes(1, 2)
    with pytest.raises(PlacementError):
        FirstFitPlacement().place(nodes, [ReplicaSpec("m", 0, 4)], {},
                                  strict=True)


def test_placement_repair_records_shortfall_when_full():
    nodes = make_nodes(1, 2)
    pl = FirstFitPlacement().place(nodes, [ReplicaSpec("m", 0, 2),
                                           ReplicaSpec("m", 1, 2)], {})
    assert len(pl.placed) == 1
    assert pl.shortfall == {"m": 2}


# ------------------------------------------------- rolling reconfiguration
def _fabric_cluster(**kw):
    kw.setdefault("nodes", make_nodes(4, 8))
    kw.setdefault("replica_size", 2)
    kw.setdefault("placement", "spread")
    return SimCluster(PROFILES, **kw)


def test_fabric_materializes_allocation_as_replicas():
    c = _fabric_cluster()
    c.apply_allocation(0.0, {"resnet50": 8})
    reps = c.fabric.group("resnet50")
    assert len(reps) == 4 and all(r.units == 2 for r in reps)
    assert len({r.node_id for r in reps}) == 4          # spread
    # warming: ready only after rt
    assert c.loaded_variants(0.0) == set()
    assert c.loaded_variants(PROFILES["resnet50"].rt + 0.1) == {"resnet50"}


def test_fabric_conforms_to_shared_protocols():
    c = _fabric_cluster()
    assert isinstance(c, ClusterAPI) and isinstance(c, ServingAPI)


def test_rolling_reconfig_capacity_never_dips():
    """Replica-granular create-then-remove: the old replicas retire only
    once every replacement is ready, so live capacity never drops below the
    old allocation during the transition."""
    c = _fabric_cluster()
    c.apply_allocation(0.0, {"resnet18": 4})
    c.mark_warm()
    old = {r.rid for r in c.fabric.group("resnet18")}
    c.apply_allocation(100.0, {"resnet50": 8})
    switch = 100.0 + PROFILES["resnet50"].rt
    for r in c.fabric.replicas.values():
        if r.rid in old:
            assert r.retire_at >= switch - 1e-9         # still serving
        else:
            assert r.ready_at == pytest.approx(switch)
    # mid-transition traffic lands on the old, still-live replicas
    c.dispatch(101.0, "resnet50")
    assert c.requests[-1].backend.startswith("resnet18#")
    c.dispatch(switch + 0.1, "resnet50")
    assert c.requests[-1].backend.startswith("resnet50#")


def test_reapply_same_allocation_is_churn_free():
    c = _fabric_cluster()
    c.apply_allocation(0.0, {"resnet50": 8})
    rids = {r.rid for r in c.fabric.replicas.values()}
    c.apply_allocation(50.0, {"resnet50": 8})
    assert {r.rid for r in c.fabric.replicas.values()} == rids
    assert all(r.retire_at == float("inf") for r in c.fabric.replicas.values())


def test_scale_down_keeps_matching_replicas():
    c = _fabric_cluster()
    c.apply_allocation(0.0, {"resnet50": 8})
    c.mark_warm()
    c.apply_allocation(50.0, {"resnet50": 4})
    live = [r for r in c.fabric.group("resnet50")
            if r.retire_at == float("inf")]
    assert sum(r.units for r in live) == 4
    # surplus retires immediately (no creates -> switch_t == t)
    gone = [r for r in c.fabric.group("resnet50") if r.retire_at <= 50.0]
    assert sum(r.units for r in gone) == 4


# ------------------------------------------------------- backlog semantics
def test_sim_backlog_counts_queued_not_in_service():
    """ClusterAPI.backlog: only queued-not-yet-in-service requests count —
    aligned with the engine's admission-queue-depth semantics."""
    prof = VariantProfile(name="v", accuracy=70.0, rt=0.0, th_slope=2.0,
                          th_intercept=0.0, lat_base_ms=500.0, lat_k_ms=0.0)
    c = SimCluster({"v": prof})
    c.apply_allocation(0.0, {"v": 1})           # th=2 rps, p=0.5s -> c=1
    assert c.backlog(0.0) == 0.0
    for _ in range(3):
        c.dispatch(0.0, "v")
    # one request in service, two queued behind it
    assert c.backlog(0.0) == pytest.approx(2.0)
    # in-service work alone is not backlog
    s = c.backends["v"].effective_service_s
    assert c.backlog(2 * s + 1e-6) == pytest.approx(0.0)


# ------------------------------------------------------- two-level routing
def test_p2c_keeps_replicas_balanced_under_poisson_load():
    """Power-of-two-choices: the time-averaged per-replica outstanding stays
    balanced (max/mean ratio bounded) under Poisson load at ~70% utilization
    — across seeds and replica counts (the balls-into-bins property)."""
    for seed in range(5):
        for n_rep in (2, 4, 8):
            c = SimCluster(PROFILES, nodes=make_nodes(n_rep, 2),
                           replica_size=2, router="p2c", placement="spread")
            c.apply_allocation(0.0, {"resnet50": 2 * n_rep})
            c.mark_warm()
            cap = sum(len(r.handle.server_free) / r.handle.effective_service_s
                      for r in c.fabric.replicas.values())
            rng = np.random.default_rng(seed)
            t, sums = 0.0, {}
            for _ in range(1500):
                t += rng.exponential(1.0 / (0.7 * cap))
                for r in c.fabric.replicas.values():
                    sums[r.rid] = sums.get(r.rid, 0.0) + \
                        r.handle.outstanding(t)
                c.dispatch(t, "resnet50")
            avg = np.array(list(sums.values())) / 1500.0
            assert avg.max() / max(avg.mean(), 1e-9) < 1.6, \
                f"imbalanced: seed={seed} n={n_rep} avgs={avg}"


def test_straggler_p2c_beats_load_blind_routing():
    """A slow replica (injected straggler) degrades rr/random routing far
    more than p2c — the reason two-level routing is load-aware."""
    p99 = {}
    for router in ("p2c", "random"):
        c = _fabric_cluster(router=router)
        c.apply_allocation(0.0, {"resnet50": 8})
        c.mark_warm()
        rid = sorted(c.fabric.replicas)[0]
        c.inject_fault(0.0, replica_slowdown(0.0, rid, 4.0))
        rng = np.random.default_rng(0)
        t = 0.0
        for _ in range(2500):
            t += rng.exponential(1.0 / 80.0)
            c.dispatch(t, "resnet50")
        p99[router] = c.summarize(750.0, 78.31)["p99_ms"]
    assert p99["p2c"] <= p99["random"]


def test_stale_replica_fault_events_are_noops():
    """A slowdown/restore targeting a replica that already retired must not
    crash the replay — stale fault events are skipped."""
    c = _fabric_cluster()
    c.apply_allocation(0.0, {"resnet50": 4})
    c.mark_warm()
    old = sorted(c.fabric.replicas)[0]
    c.apply_allocation(10.0, {"resnet18": 4})   # resnet50 retires
    c.dispatch(10.0 + PROFILES["resnet18"].rt + 1.0, "resnet18")  # purges
    assert old not in c.fabric.replicas
    c.inject_fault(30.0, replica_slowdown(30.0, old, 3.0))        # no-op
    c.inject_fault(31.0, replica_restore(31.0, old))              # no-op


def test_rr_router_cycles_per_variant():
    """The rr baseline must actually rotate within a variant even when
    traffic to other variants interleaves."""
    from repro.cluster import ReplicaView, RoundRobinReplicaRouter
    r = RoundRobinReplicaRouter()
    a = [ReplicaView("a#0", 0), ReplicaView("a#1", 0)]
    b = [ReplicaView("b#0", 0), ReplicaView("b#1", 0)]
    picks_a, picks_b = [], []
    for _ in range(4):                       # interleave a,b,a,b,...
        picks_a.append(r.pick(a))
        picks_b.append(r.pick(b))
    assert picks_a == ["a#0", "a#1", "a#0", "a#1"]
    assert picks_b == ["b#0", "b#1", "b#0", "b#1"]


def test_fault_injection_requires_fabric():
    c = SimCluster(PROFILES)
    with pytest.raises(RuntimeError, match="fabric"):
        c.inject_fault(0.0, node_crash(0.0, "node0"))


# -------------------------------------------------------- failure scenario
def _constant_trace(seconds=240, rate=60):
    return np.full(seconds, float(rate))


def _failure_run(faults=None, seed=3):
    # first-fit packs replicas onto few nodes, so the node crash takes a
    # measurable bite out of capacity (near-capacity budget: 12 @ 60 rps)
    cluster = SimCluster(PROFILES, nodes=make_nodes(4, 8), replica_size=2,
                         placement="first-fit", router="p2c")
    cfg = ControllerConfig(budget=12, beta=0.05, gamma=0.2, reactive=True)
    ctrl = InfAdapterController(PROFILES, MovingMaxForecaster(), cfg)
    res = run_experiment("failure", ctrl, PROFILES, _constant_trace(),
                         warm_start={"resnet18": 8}, reference_accuracy=78.31,
                         cluster=cluster, faults=faults, seed=seed)
    return cluster, res


def _viol_rate(cluster, t0, t1, slo_ms=750.0):
    win = [r for r in cluster.requests if t0 <= r.arrival < t1]
    assert win, f"no requests in [{t0},{t1})"
    return float(np.mean([r.latency_ms > slo_ms for r in win]))


def test_node_failure_recovery_restores_slo():
    """Kill a node mid-trace: the reactive controller re-places through
    apply_allocation (capacity_factor discounts lost replicas), the SLO
    spike is real but bounded, and the post-recovery violation rate
    returns to the no-fault baseline."""
    base_cluster, _ = _failure_run(faults=None)
    faults = FaultSchedule([node_crash(80.0, "node0"),
                            node_recover(150.0, "node0")])
    cluster, _ = _failure_run(faults=faults)
    assert len(faults) == 0                      # every event injected
    # the controller re-placed: full target capacity is live again
    assert cluster.fabric.capacity_factor(239.0) == 1.0
    assert cluster.fabric.nodes["node0"].alive
    # the crash has a measurable cost...
    spike = _viol_rate(cluster, 80.0, 95.0)
    assert spike > _viol_rate(base_cluster, 80.0, 95.0)
    # ...that stays bounded (re-placement begins at the next reactive check)
    assert spike < 0.8
    assert _viol_rate(cluster, 100.0, 150.0) < 0.05     # drained well before
    # full recovery: the tail of the trace matches the no-fault baseline
    post = _viol_rate(cluster, 180.0, 240.0)
    base = _viol_rate(base_cluster, 180.0, 240.0)
    assert post <= base + 0.02


def test_all_controllers_run_on_the_fabric():
    """Acceptance: InfAdapter, MS+, VPA+, INFaaS, and Cocktail all drive the
    replica fabric unchanged through the shared ClusterAPI."""
    from repro.core.adapter import MSPlusController, VPAPlusController
    from repro.core.cocktail import CocktailController
    from repro.core.infaas import INFaaSController
    trace = _constant_trace(seconds=120, rate=40)
    cfg = ControllerConfig(budget=16, beta=0.05, gamma=0.2)

    def fabric():
        return SimCluster(PROFILES, nodes=make_nodes(4, 8), replica_size=2,
                          placement="spread")

    runs = {
        "inf": InfAdapterController(PROFILES, MovingMaxForecaster(), cfg),
        "ms": MSPlusController(PROFILES, MovingMaxForecaster(), cfg),
        "vpa": VPAPlusController(PROFILES["resnet50"], cfg),
        "infaas": INFaaSController(PROFILES, cfg, min_accuracy=70.0),
        "cocktail": CocktailController(PROFILES, MovingMaxForecaster(), cfg),
    }
    for name, ctrl in runs.items():
        warm = {"resnet50": 8} if name == "vpa" else {"resnet18": 8}
        res = run_experiment(name, ctrl, PROFILES, trace, warm_start=warm,
                             reference_accuracy=78.31, cluster=fabric())
        assert res.summary["n_requests"] > 0, name
        assert res.summary["violation_rate"] < 0.5, name
        assert res.summary["avg_cost_units"] > 0, name
