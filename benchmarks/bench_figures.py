"""Paper-figure reproductions (Figs. 1, 2, 4, 5, 6, 7, 8 + appendix 9/10)."""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core.adapter import (ControllerConfig, InfAdapterController,
                                MSPlusController, VPAPlusController)
from repro.core.cocktail import CocktailController
from repro.core.forecaster import MovingMaxForecaster
from repro.core.profiles import (fit_throughput, measured_resnet_points,
                                 paper_resnet_profiles,
                                 roofline_decode_tokens_per_s)
from repro.core.solver import solve_exact, solve_single_variant
from repro.data.traces import paper_bursty_trace, paper_nonbursty_trace
from repro.sim.runner import run_experiment

Row = Tuple[str, float, str]
REF_ACC = 78.31
PROFILES = paper_resnet_profiles(noise=0.0)


def fig1_throughput() -> List[Row]:
    """Sustained throughput of variants under 8/14/20 cores (750ms P99)."""
    rows: List[Row] = []
    for name in ("resnet18", "resnet34", "resnet50", "resnet101", "resnet152"):
        p = PROFILES[name]
        for cores in (8, 14, 20):
            rows.append((f"{name}.c{cores}", 0.0,
                         f"th={p.throughput(cores):.1f}rps"))
    # the paper's two equivalence observations
    r = PROFILES
    rows.append(("obs.r18c8_vs_r50c20", 0.0,
                 f"{r['resnet18'].throughput(8):.0f}~{r['resnet50'].throughput(20):.0f}rps"))
    rows.append(("obs.r50c8_vs_r152c20", 0.0,
                 f"{r['resnet50'].throughput(8):.0f}~{r['resnet152'].throughput(20):.0f}rps"))
    return rows


def fig2_budget_accuracy() -> List[Row]:
    """Accuracy loss at 75 RPS for budgets 8/14/20: set vs single variant."""
    rows: List[Row] = []
    for budget in (8, 14, 20):
        t0 = time.time()
        inf = solve_exact(PROFILES, 75.0, budget, 750.0, beta=0.05, gamma=0.01)
        us = (time.time() - t0) * 1e6
        ms = solve_single_variant(PROFILES, 75.0, budget, 750.0, beta=0.05,
                                  gamma=0.01)
        rows.append((f"infadapter.b{budget}", us,
                     f"loss={REF_ACC - inf.aa:.2f}%"))
        rows.append((f"ms.b{budget}", 0.0, f"loss={REF_ACC - ms.aa:.2f}%"))
    return rows


def fig4_batching() -> List[Row]:
    """Batching study. CPU (paper): batching raises latency without
    throughput gains -> batch=1. TPU (adaptation): decode is bandwidth-bound;
    batching amortizes weight streaming -> large gains. Both reported."""
    from repro.configs import get_config
    rows: List[Row] = []
    # CPU model: M/D/c with batch aggregation: service time scales ~linearly
    p = PROFILES["resnet50"]
    for batch in (1, 2, 4, 8):
        th = p.throughput(8)                       # unchanged (paper Fig. 4)
        lat = p.p99_ms(8) * batch * 0.9            # waits for batch to fill
        rows.append((f"cpu.resnet50.b{batch}", 0.0,
                     f"th={th:.0f}rps lat={lat:.0f}ms"))
    cfg = get_config("tinyllama-1.1b")
    for batch in (1, 8, 32, 128):
        tps = roofline_decode_tokens_per_s(cfg, n_chips=1, batch=batch)
        rows.append((f"tpu.tinyllama.b{batch}", 0.0, f"tok/s={tps:.0f}"))
    return rows


def fig6_profile_fit() -> List[Row]:
    """Linear-regression throughput profiles: R² (paper: 0.996/0.994)."""
    rows: List[Row] = []
    for name in ("resnet18", "resnet50"):
        fit = fit_throughput(measured_resnet_points(name, noise=0.01))
        rows.append((name, 0.0, f"r2={fit.r_squared:.4f}"))
    return rows


def _trace_comparison(trace, tag: str, beta: float = 0.05,
                      reactive: bool = False) -> List[Row]:
    rows: List[Row] = []
    cfg = ControllerConfig(budget=20, beta=beta, gamma=0.2,
                           reactive=reactive, queue_aware=reactive)
    runs = []
    c = InfAdapterController(PROFILES, MovingMaxForecaster(), cfg)
    runs.append(("infadapter" + ("_reactive" if reactive else ""), c,
                 PROFILES, {"resnet18": 8}))
    if not reactive:
        c = MSPlusController(PROFILES, MovingMaxForecaster(), cfg)
        runs.append(("ms+", c, PROFILES, {"resnet18": 8}))
        c = CocktailController(PROFILES, MovingMaxForecaster(),
                               ControllerConfig(budget=40, beta=beta, gamma=0.2))
        runs.append(("cocktail.b40", c, PROFILES, {"resnet18": 8}))
        for v in ("resnet18", "resnet50", "resnet152"):
            c = VPAPlusController(PROFILES[v], cfg)
            runs.append((f"vpa.{v}", c, {v: PROFILES[v]}, {v: 8}))
    for name, ctrl, profs, warm in runs:
        t0 = time.time()
        r = run_experiment(name, ctrl, profs, trace, warm_start=warm,
                           reference_accuracy=REF_ACC)
        us = (time.time() - t0) * 1e6
        s = r.summary
        rows.append((f"{tag}.{name}", us,
                     f"viol={s['violation_rate']:.3f} "
                     f"loss={s['accuracy_loss']:.2f}% "
                     f"cost={s['avg_cost_units']:.1f} "
                     f"p99={s['p99_ms']:.0f}ms"))
    return rows


def fig5_bursty() -> List[Row]:
    trace = paper_bursty_trace()
    rows = _trace_comparison(trace, "bursty")
    rows += _trace_comparison(trace, "bursty", reactive=True)
    return rows


def fig8_nonbursty() -> List[Row]:
    return _trace_comparison(paper_nonbursty_trace(), "nonbursty")


def fig7_beta_sweep() -> List[Row]:
    """β ∈ {0.0125, 0.05, 0.2}: larger β/α -> cost-lean (appendix)."""
    rows: List[Row] = []
    trace = paper_nonbursty_trace()
    for beta in (0.0125, 0.05, 0.2):
        cfg = ControllerConfig(budget=20, beta=beta, gamma=0.2)
        c = InfAdapterController(PROFILES, MovingMaxForecaster(), cfg)
        r = run_experiment(f"b{beta}", c, PROFILES, trace,
                           warm_start={"resnet18": 8},
                           reference_accuracy=REF_ACC)
        s = r.summary
        rows.append((f"beta{beta}", 0.0,
                     f"loss={s['accuracy_loss']:.2f}% "
                     f"cost={s['avg_cost_units']:.1f} "
                     f"viol={s['violation_rate']:.3f}"))
    return rows
