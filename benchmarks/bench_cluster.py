"""Cluster-fabric benchmarks: replica scaling, routing policy, failure
recovery (DESIGN.md §Cluster fabric).

Three studies on the discrete-event backend (deterministic, seconds to run),
each persisted as JSON under ``reports/cluster/`` for
``repro.analysis.report`` to render into EXPERIMENTS.md:

  * **replica scaling** — fixed offered load (90 rps) and fixed total
    allocation (8 units of resnet50), split 1/2/4 ways: achieved throughput
    must scale monotonically with replica count (k replicas of n/k units
    have capacity k·th(n/k) = a·n + k·b > th(n)) and the tail collapses
    once capacity clears the offered load.
  * **routing policy** — two-level routing (WRR variant choice + p2c
    least-outstanding replica choice) vs WRR-only baselines (rr/random
    replica choice) on a heterogeneous node set (one 0.45× node): the
    acceptance bar is two-level P99 ≤ WRR-only P99 at equal load.
  * **failure recovery** — InfAdapter (reactive) on the fabric, node crash
    at t=80 s and recovery at t=150 s of a 240 s constant-rate trace:
    bounded violation spike during the fault window, post-recovery
    violation rate back at the pre-fault baseline.

Run: PYTHONPATH=src python -m benchmarks.run --only cluster_fabric
"""
from __future__ import annotations

import json
import os
from typing import List, Tuple

import numpy as np

REPORT_DIR = os.path.join("reports", "cluster")

LOAD_RPS = 90.0
TOTAL_UNITS = 8
N_REQUESTS = 4000
ROUTE_LOAD_RPS = 80.0
SLO_MS = 750.0


def _profiles():
    from repro.core.profiles import paper_resnet_profiles
    return paper_resnet_profiles(noise=0.0)


def _static_replay(profiles, nodes, replica_size, router, rate, n,
                   seed=0) -> dict:
    """Fixed allocation of resnet50, Poisson arrivals, full summary."""
    from repro.sim.cluster import SimCluster
    c = SimCluster(profiles, nodes=nodes, replica_size=replica_size,
                   placement="spread", router=router)
    c.apply_allocation(0.0, {"resnet50": TOTAL_UNITS})
    c.mark_warm()
    rng = np.random.default_rng(seed)
    t = 0.0
    for _ in range(n):
        t += rng.exponential(1.0 / rate)
        c.dispatch(t, "resnet50")
    s = c.summarize(SLO_MS, 78.31)
    makespan = max(r.completion for r in c.requests) - 0.0
    s["achieved_rps"] = n / makespan
    s["n_replicas"] = len(c.fabric.replicas)
    return s


def _scaling_study(profiles) -> List[dict]:
    from repro.cluster import make_nodes
    rows = []
    for k in (1, 2, 4):
        s = _static_replay(profiles, make_nodes(4, TOTAL_UNITS),
                           TOTAL_UNITS // k, "p2c", LOAD_RPS, N_REQUESTS)
        rows.append({"replicas": k, "units_per_replica": TOTAL_UNITS // k,
                     "offered_rps": LOAD_RPS,
                     "achieved_rps": round(s["achieved_rps"], 1),
                     "p99_ms": round(s["p99_ms"], 1),
                     "mean_ms": round(s["mean_latency_ms"], 1),
                     "violation_rate": round(s["violation_rate"], 4)})
    return rows


def _routing_study(profiles) -> List[dict]:
    from repro.cluster import make_nodes
    rows = []
    for router in ("p2c", "least", "rr", "random"):
        nodes = make_nodes(4, 2, speeds=(1.0, 1.0, 1.0, 0.45))
        s = _static_replay(profiles, nodes, 2, router, ROUTE_LOAD_RPS,
                           N_REQUESTS)
        rows.append({"router": router,
                     "two_level": router in ("p2c", "least"),
                     "offered_rps": ROUTE_LOAD_RPS,
                     "p99_ms": round(s["p99_ms"], 1),
                     "mean_ms": round(s["mean_latency_ms"], 1),
                     "violation_rate": round(s["violation_rate"], 4)})
    return rows


def _failure_study(profiles) -> List[dict]:
    """Node crash + recovery under InfAdapter (reactive) at near-capacity
    provisioning (budget 12 @ 60 rps). First-fit packs replicas onto few
    nodes, so the crash takes a visible bite (the bounded spike + recovery
    acceptance case); spread placement contains the same crash to a
    near-zero blip — the failure-domain argument for spreading."""
    from repro.cluster import FaultSchedule, make_nodes, node_crash, \
        node_recover
    from repro.core.adapter import ControllerConfig, InfAdapterController
    from repro.core.forecaster import MovingMaxForecaster
    from repro.sim.cluster import SimCluster
    from repro.sim.runner import run_experiment

    t_crash, t_recover, t_end = 80.0, 150.0, 240.0
    results = {}
    for scenario, placement, crash in (
            ("baseline", "first-fit", False),
            ("crash/first-fit", "first-fit", True),
            ("crash/spread", "spread", True)):
        cluster = SimCluster(profiles, nodes=make_nodes(4, 8),
                             replica_size=2, placement=placement)
        ctrl = InfAdapterController(
            profiles, MovingMaxForecaster(),
            ControllerConfig(budget=12, beta=0.05, gamma=0.2, reactive=True))
        faults = FaultSchedule(
            [node_crash(t_crash, "node0"),
             node_recover(t_recover, "node0")]) if crash else None
        run_experiment(scenario, ctrl, profiles,
                       np.full(int(t_end), 60.0), warm_start={"resnet18": 8},
                       reference_accuracy=78.31, cluster=cluster,
                       faults=faults, seed=3)
        results[scenario] = cluster
    rows = []
    for scenario, cluster in results.items():
        # pre-fault starts at 30 s: the t=0 variant switch away from the
        # warm-start set is the paper's cold-start transient, not steady state
        for phase, t0, t1 in (("pre-fault", 30.0, t_crash),
                              ("fault", t_crash, t_recover),
                              ("post-recovery", t_recover + 30.0, t_end)):
            reqs = [r for r in cluster.requests if t0 <= r.arrival < t1]
            rows.append({
                "scenario": scenario, "phase": phase,
                "violation_rate": round(float(np.mean(
                    [r.latency_ms > SLO_MS for r in reqs])), 4),
                "p99_ms": round(float(np.percentile(
                    [r.latency_ms for r in reqs], 99)), 1),
                "n": len(reqs)})
    return rows


def _persist(name: str, rows: List[dict]) -> None:
    os.makedirs(REPORT_DIR, exist_ok=True)
    with open(os.path.join(REPORT_DIR, f"{name}.json"), "w") as f:
        json.dump({"study": name, "rows": rows}, f, indent=1)


def run() -> List[Tuple[str, float, str]]:
    profiles = _profiles()
    out: List[Tuple[str, float, str]] = []

    scaling = _scaling_study(profiles)
    _persist("replica_scaling", scaling)
    for r in scaling:
        out.append((f"scale_k{r['replicas']}", r["p99_ms"] * 1000.0,
                    f"thr={r['achieved_rps']:.1f}rps "
                    f"p99={r['p99_ms']:.0f}ms viol={r['violation_rate']:.3f}"))
    thr = [r["achieved_rps"] for r in scaling]
    out.append(("scale_monotone", 0.0,
                "ok" if thr == sorted(thr) else f"NOT MONOTONE {thr}"))

    routing = _routing_study(profiles)
    _persist("routing_policy", routing)
    p99 = {r["router"]: r["p99_ms"] for r in routing}
    for r in routing:
        out.append((f"route_{r['router']}", r["p99_ms"] * 1000.0,
                    f"p99={r['p99_ms']:.0f}ms viol={r['violation_rate']:.3f}"))
    wrr_only = min(p99["rr"], p99["random"])
    out.append(("route_two_level_wins", (p99["p2c"] - wrr_only) * 1000.0,
                f"p2c/wrr-only={p99['p2c'] / max(wrr_only, 1e-9):.3f}"))

    failure = _failure_study(profiles)
    _persist("failure_recovery", failure)
    for r in failure:
        if r["scenario"].startswith("crash"):
            tag = r["scenario"].split("/")[1].replace("-", "")
            out.append((f"fail_{tag}_{r['phase'].replace('-', '_')}",
                        r["p99_ms"] * 1000.0,
                        f"viol={r['violation_rate']:.3f} "
                        f"p99={r['p99_ms']:.0f}ms n={r['n']}"))
    by = {(r["scenario"], r["phase"]): r for r in failure}
    post = by[("crash/first-fit", "post-recovery")]["violation_rate"]
    base = by[("baseline", "post-recovery")]["violation_rate"]
    spike = by[("crash/first-fit", "fault")]["violation_rate"]
    out.append(("fail_recovered", (post - base) * 1e6,
                f"spike={spike:.3f} post={post:.3f} baseline={base:.3f} "
                f"{'ok' if post <= base + 0.02 else 'NOT RECOVERED'}"))
    return out


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
