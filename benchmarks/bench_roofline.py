"""Roofline summary from reports/dryrun/*.json (§Roofline deliverable)."""
from __future__ import annotations

import glob
import json
import os
from typing import List, Tuple

Row = Tuple[str, float, str]

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports", "dryrun")


def run() -> List[Row]:
    rows: List[Row] = []
    files = sorted(glob.glob(os.path.join(REPORT_DIR, "*.json")))
    if not files:
        return [("missing", 0.0, "run repro.launch.dryrun first")]
    n_ok = n_skip = n_err = 0
    for f in files:
        with open(f) as fh:
            d = json.load(fh)
        tag = os.path.basename(f)[:-5]
        if d.get("skipped"):
            n_skip += 1
            continue
        if "error" in d:
            n_err += 1
            rows.append((tag, 0.0, "ERROR " + d["error"][:60]))
            continue
        n_ok += 1
        if d["mesh"] != "16x16":
            continue  # roofline table is single-pod; multi-pod proves lowering
        dom_ms = {"compute": d["compute_s"], "memory": d["memory_s"],
                  "collective": d["collective_s"]}[d["dominant"]] * 1e3
        rows.append((f"{d['arch']}.{d['shape']}", dom_ms * 1e3,
                     f"dom={d['dominant']} c={d['compute_s']*1e3:.2f}ms "
                     f"m={d['memory_s']*1e3:.2f}ms "
                     f"x={d['collective_s']*1e3:.2f}ms "
                     f"useful={d['usefulness']:.2f} "
                     f"fits={d.get('fits_v5e_16gb')}"))
    rows.append(("summary", 0.0,
                 f"compiled={n_ok} skipped={n_skip} errors={n_err}"))
    return rows
