"""Benchmark harness — one benchmark per paper table/figure (+ beyond-paper
studies). Prints ``name,us_per_call,derived`` CSV rows per the repo contract.

  fig1_throughput       variant throughput vs cores (paper Fig. 1)
  fig2_budget_accuracy  variant-set vs single-variant accuracy loss (Fig. 2)
  fig4_batching         batching/parallelism study, CPU + TPU-roofline (Fig. 4)
  fig5_bursty           20-min bursty trace comparison (Fig. 5)
  fig6_profile_fit      linear-regression profile R² (Fig. 6)
  fig7_beta_sweep       β sensitivity, cumulative metrics (Fig. 7/9/10)
  fig8_nonbursty        non-bursty trace comparison (Fig. 8)
  engine_serving        continuous vs pump + paged vs dense KV cache; writes
                        reports/BENCH_engine.json (DESIGN.md §Paged KV cache)
  async_overlap         sync vs two-phase dispatch/commit tick loop: step-time
                        ratio gate + greedy parity; merges into
                        BENCH_engine.json (DESIGN.md §Async tick loop)
  spec_decode           speculative decoding on the variant ladder: parity +
                        acceptance/tokens-per-verifier-step gates, virtual-
                        clock tick ratio; merges into BENCH_engine.json
                        (DESIGN.md §Speculative decoding)
  scheduler             FIFO vs EDF vs chunked+EDF on bimodal prompt lengths;
                        writes reports/BENCH_scheduler.json (§Scheduling)
  cluster_fabric        replica scaling, routing policy, failure recovery
  profiling             measured vs roofline vs paper-calibrated profile error
  forecaster            LSTM vs baselines MAE/under-rate (Fig. 5 top)
  solver_scalability    exact/greedy/bruteforce runtime + optimality gap (§7)
  kernels               Pallas kernel vs jnp-oracle wall time (interpret mode)
  roofline              summary table from reports/dryrun/*.json (§Roofline)

Run: PYTHONPATH=src python -m benchmarks.run [--only fig5_bursty,...]
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (bench_cluster, bench_engine, bench_figures,
                        bench_forecaster, bench_kernels, bench_profiling,
                        bench_robustness, bench_roofline, bench_scheduler,
                        bench_solver, bench_table1)

ALL = {
    "fig1_throughput": bench_figures.fig1_throughput,
    "fig2_budget_accuracy": bench_figures.fig2_budget_accuracy,
    "fig4_batching": bench_figures.fig4_batching,
    "fig6_profile_fit": bench_figures.fig6_profile_fit,
    "fig5_bursty": bench_figures.fig5_bursty,
    "fig8_nonbursty": bench_figures.fig8_nonbursty,
    "fig7_beta_sweep": bench_figures.fig7_beta_sweep,
    "engine_serving": bench_engine.run,
    "async_overlap": bench_engine.run_async_overlap,
    "spec_decode": bench_engine.run_spec_decode,
    "scheduler": bench_scheduler.run,
    "cluster_fabric": bench_cluster.run,
    "profiling": bench_profiling.run,
    "table1_systems": bench_table1.run,
    "profile_robustness": bench_robustness.run,
    "forecaster": bench_forecaster.run,
    "solver_scalability": bench_solver.run,
    "kernels": bench_kernels.run,
    "roofline": bench_roofline.run,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args(argv)
    names = [n.strip() for n in args.only.split(",") if n.strip()] or list(ALL)
    unknown = [n for n in names if n not in ALL]
    if unknown:
        sys.exit(f"unknown benchmark(s): {', '.join(unknown)} "
                 f"(available: {', '.join(ALL)})")

    print("name,us_per_call,derived")
    failed = []
    for name in names:
        fn = ALL[name]
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            print(f"{name},ERROR,{type(e).__name__}: {e}")
            failed.append(name)
            continue
        wall_us = (time.time() - t0) * 1e6
        for rname, us, derived in rows:
            print(f"{name}.{rname},{us:.1f},{derived}")
        print(f"{name}.total,{wall_us:.1f},ok")
        sys.stdout.flush()
    if failed:   # make benchmark crashes visible to CI
        sys.exit(f"benchmarks failed: {', '.join(failed)}")


if __name__ == "__main__":
    main()
