"""Forecaster comparison (paper Fig. 5 top panel): LSTM vs baselines."""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.core.forecaster import (EnsembleMaxForecaster, MovingMaxForecaster,
                                   forecast_mae, train_lstm_forecaster)
from repro.data.traces import synthetic_twitter_trace

Row = Tuple[str, float, str]


def run() -> List[Row]:
    rows: List[Row] = []
    trace = synthetic_twitter_trace(seconds=3 * 3600, seed=2)
    split = 2 * 3600
    t0 = time.time()
    lstm, losses = train_lstm_forecaster(trace[:split], steps=250, batch=32)
    train_us = (time.time() - t0) * 1e6
    rows.append(("lstm.train", train_us,
                 f"loss={losses[0]:.4f}->{losses[-1]:.4f}"))
    test = trace[split:]
    for name, fc in [("lstm", lstm), ("movingmax", MovingMaxForecaster()),
                     ("ensemble", EnsembleMaxForecaster(
                         members=(lstm, MovingMaxForecaster())))]:
        t0 = time.time()
        m = forecast_mae(fc, test, stride=300)
        us = (time.time() - t0) * 1e6
        rows.append((name, us,
                     f"mae={m['mae']:.2f} under={m['under_rate']:.2f}"))
    return rows
