"""Paper Table 1 as a measured benchmark: all five systems on one trace."""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.core.adapter import (ControllerConfig, InfAdapterController,
                                MSPlusController, VPAPlusController)
from repro.core.cocktail import CocktailController
from repro.core.forecaster import MovingMaxForecaster
from repro.core.infaas import INFaaSController
from repro.core.profiles import paper_resnet_profiles
from repro.data.traces import paper_bursty_trace
from repro.sim.runner import run_experiment

Row = Tuple[str, float, str]
REF = 78.31


def run() -> List[Row]:
    profiles = paper_resnet_profiles(noise=0.0)
    trace = paper_bursty_trace()
    cfg = ControllerConfig(budget=20, beta=0.05, gamma=0.2)
    systems = [
        ("infadapter", InfAdapterController(profiles, MovingMaxForecaster(), cfg),
         profiles, {"resnet18": 8}),
        ("ms+", MSPlusController(profiles, MovingMaxForecaster(), cfg),
         profiles, {"resnet18": 8}),
        ("infaas", INFaaSController(profiles, cfg, min_accuracy=76.0),
         profiles, {"resnet50": 8}),
        ("cocktail", CocktailController(profiles, MovingMaxForecaster(),
                                        ControllerConfig(budget=40, beta=0.05,
                                                         gamma=0.2)),
         profiles, {"resnet18": 8}),
        ("vpa.resnet50", VPAPlusController(profiles["resnet50"], cfg),
         {"resnet50": profiles["resnet50"]}, {"resnet50": 8}),
    ]
    rows: List[Row] = []
    for name, ctrl, profs, warm in systems:
        t0 = time.time()
        r = run_experiment(name, ctrl, profs, trace, warm_start=warm,
                           reference_accuracy=REF)
        us = (time.time() - t0) * 1e6
        s = r.summary
        rows.append((name, us,
                     f"viol={s['violation_rate']:.3f} "
                     f"loss={s['accuracy_loss']:.2f}% "
                     f"cost={s['avg_cost_units']:.1f}"))
    return rows
