"""Scheduler-layer benchmark: FIFO vs EDF vs chunked+EDF on the real engine
(DESIGN.md §Scheduling).

The paper's pain point restated as a workload: **bimodal prompt lengths** —
short interactive requests (tight SLO) mixed with long-prefill requests
(loose SLO) — at a FIXED allocation. Under strict FIFO with monolithic
prefill, every admission stalls the whole backend for a padded
``(max_batch, prompt_len)`` prefill and long prompts jump ahead of
tighter-deadline shorts; the controllers then over-provision against the
resulting P99. EDF fixes the ordering; chunked prefill (right-sized, fused
with decode) fixes the stall. The acceptance gate (ISSUE 5) is chunked+EDF
reaching **≥1.1× goodput** and **≤0.8× P99 latency** vs FIFO on this
workload.

Methodology — **virtual-clock replay**: every policy replays the IDENTICAL
Poisson arrival schedule / prompt-length mix / SLO assignment through the
real engine (real jitted prefill/decode, real queues, real scheduling
decisions), but the engine's injectable ``clock=`` is a virtual clock that
advances by the **median measured cost of each jitted call** (monolithic
prefill, fused chunk, decode chunk — calibrated on this host first). Wall
time would couple the gated ratios to whatever else the CI runner happens
to be doing; the virtual clock makes the replay deterministic per host
while latencies still reflect the true relative cost of each tick type.
The offered rate and the SLOs are likewise derived from the calibrated
costs (a "second" means the same amount of engine work everywhere).

Results land in the machine-readable ``reports/BENCH_scheduler.json`` (a
CI artifact) and are rendered into EXPERIMENTS.md by
``repro.analysis.report``.

Run: PYTHONPATH=src python -m benchmarks.run --only scheduler
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Tuple

import numpy as np

VOCAB = 128
# Geometry chosen so the monolithic-prefill stall is structurally large
# relative to a decode tick on ANY host (the cost RATIO is set by shapes,
# not machine speed): a padded (8, 512) admission prefill costs ~10-25
# decode ticks, while a fused chunk costs ~1.5 — that capacity gap, not a
# tuned rate, is what the gated ratios rest on.
MAX_BATCH = 8
PROMPT_LEN = 512          # capacity = the long prompt
MAX_NEW = 16
DECODE_CHUNK = 2
PREFILL_CHUNK = 32
SHORT_LEN = 16
LONG_FRAC = 0.25          # 1 in 4 requests drags a long prefill behind it
# SLOs in decode-tick units (one unit = the calibrated decode-chunk cost):
# a short request's ideal chunked service is ~1 fused admission tick + 16
# one-token ticks (~25 units), so 100 units is a realistic interactive
# deadline with queueing headroom; longs get 6x that
SHORT_SLO_TICKS = 100.0
LONG_SLO_TICKS = 600.0
# offered load: safely inside chunked's measured capacity (its queues stay
# bounded) — FIFO's open-loop trickle capacity sits far below it at this
# geometry (each small-cohort admission pays the full padded prefill), so
# FIFO is structurally overloaded at the same rate
CHUNKED_HEADROOM = 0.85
CALIB_REQS = 48
N_REQUESTS = 120          # arrivals per policy (fixes the sample size)
POLICIES = ("fifo", "edf", "chunked")
BENCH_JSON = os.path.join("reports", "BENCH_scheduler.json")


class _VClock:
    """Virtual clock the engine stamps from; the bench advances it by the
    calibrated cost of each tick."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def _variant():
    from repro.configs import get_config, smoke_variant
    base = smoke_variant(get_config("tinyllama-1.1b")).replace(
        d_model=64, d_ff=128, vocab_size=VOCAB, num_layers=2,
        name="bench-sched-2L")
    return {"bench-sched-2L": (base, 70.0)}


def _engine(policy: str):
    from repro.serving.engine import InProcessServingEngine
    clock = _VClock()
    eng = InProcessServingEngine(
        _variant(), max_batch=MAX_BATCH, prompt_len=PROMPT_LEN,
        max_new=MAX_NEW, decode_chunk=DECODE_CHUNK, queue_cap=100_000,
        scheduler=policy, prefill_chunk=PREFILL_CHUNK, clock=clock)
    eng.apply_allocation(0.0, {"bench-sched-2L": 1})   # fixed allocation
    return eng, clock


def _median_ms(fn, reps: int = 15) -> float:
    fn()                                   # ensure warm
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1000.0)
    return float(np.median(ts))


def _calibrate_costs(fifo_eng, chunked_eng) -> Dict[str, float]:
    """Median wall cost of each jitted tick body on this host: the
    monolithic admission prefill, the fused chunk, the decode chunk."""
    import jax.numpy as jnp
    bf = next(iter(fifo_eng.backends.values()))
    bc = next(iter(chunked_eng.backends.values()))
    toks = jnp.zeros((MAX_BATCH, PROMPT_LEN), jnp.int32)

    def prefill():
        logits, cache = bf._prefill(bf.params, {"tokens": toks})
        cache["pos"].block_until_ready()

    def decode():                          # donated: chain the state
        bf.cur_tok, bf.cache, _ = bf._decode_chunk(bf.params, bf.cache,
                                                   bf.cur_tok)
        bf.cur_tok.block_until_ready()

    ck = jnp.zeros((MAX_BATCH, PREFILL_CHUNK), jnp.int32)
    z = jnp.zeros((MAX_BATCH,), jnp.int32)
    m = jnp.zeros((MAX_BATCH,), bool)

    def chunk():
        bc.cur_tok, bc.cache = bc._prefill_chunk(bc.params, bc.cache,
                                                 bc.cur_tok, ck, z, z, m)
        bc.cur_tok.block_until_ready()

    return {"prefill_ms": _median_ms(prefill), "decode_ms": _median_ms(decode),
            "chunk_ms": _median_ms(chunk)}


def _drain_capacity(eng, clock, costs: Dict[str, float]) -> float:
    """Deterministic virtual-clock capacity: drain a closed burst of the
    bimodal mix, return completions per virtual second. Engine state is
    wiped after (slots empty by construction of drain)."""
    from repro.serving.api import Request
    rng = np.random.default_rng(7)
    is_long = rng.random(CALIB_REQS) < LONG_FRAC
    b = next(iter(eng.backends.values()))
    clock.t = 0.0
    for i in range(CALIB_REQS):
        n = PROMPT_LEN if is_long[i] else SHORT_LEN
        eng.submit(Request(rid=i, tokens=rng.integers(0, VOCAB, n),
                           max_new=MAX_NEW, arrival=0.0), None)
    while eng.backlog(0.0) or eng.in_flight():
        cost = _tick_cost_s(eng, b, costs)
        eng.step(clock.t)
        clock.t += cost
    cap = CALIB_REQS / max(clock.t, 1e-9)
    eng.done.clear()
    eng.rejected = 0
    clock.t = 0.0
    return cap


def _workload(seed: int, rate_rps: float, short_slo_ms: float,
              long_slo_ms: float):
    """One shared bimodal schedule (virtual seconds)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, size=N_REQUESTS)
    arrivals = np.cumsum(gaps)
    is_long = rng.random(len(arrivals)) < LONG_FRAC
    prompts = [rng.integers(0, VOCAB, PROMPT_LEN if lg else SHORT_LEN)
               for lg in is_long]
    slos = np.where(is_long, long_slo_ms, short_slo_ms)
    return arrivals, is_long, prompts, slos


def _tick_cost_s(eng, backend, costs: Dict[str, float]) -> float:
    """Virtual cost of the tick the engine is ABOUT to run, from the
    calibrated call costs and the observable pre-tick state (which call
    the tick will make is deterministic: see engine._tick)."""
    q = next(iter(eng.queues.values())) if eng.queues else ()
    admit = min(len(q), len(backend.free_slots)) > 0
    if eng.sched.chunked:
        if admit or backend._prefilling:
            return costs["chunk_ms"] / 1000.0          # fused tick
        return costs["decode_ms"] / 1000.0 if backend.active_slots \
            else 0.0
    cost = costs["prefill_ms"] / 1000.0 if admit else 0.0
    if backend.active_slots or admit:
        cost += costs["decode_ms"] / 1000.0
    return cost


def _replay(policy: str, workload, costs: Dict[str, float],
            engine=None) -> Dict:
    from repro.serving.api import Request

    arrivals, is_long, prompts, slos = workload
    eng, clock = engine if engine is not None else _engine(policy)
    b = next(iter(eng.backends.values()))
    clock.t = 0.0
    eng.metrics.reset()       # capacity-calibration traffic must not leak
    i = 0
    while i < len(arrivals) or eng.backlog(0.0) or eng.in_flight():
        if (i < len(arrivals) and eng.backlog(0.0) == 0
                and eng.in_flight() == 0 and arrivals[i] > clock.t):
            clock.t = float(arrivals[i])   # idle: fast-forward to work
        while i < len(arrivals) and arrivals[i] <= clock.t:
            eng.submit(Request(rid=i, tokens=prompts[i], max_new=MAX_NEW,
                               arrival=float(arrivals[i]),
                               slo_ms=float(slos[i])), None)
            i += 1
        cost = _tick_cost_s(eng, b, costs)
        eng.step(clock.t)
        clock.t += cost
    makespan = clock.t
    s = eng.summarize(slo_ms=float(slos.max()), best_accuracy=70.0)
    done = {r.rid: r for r in eng.done}
    short_lat = [done[j].latency_ms for j in range(len(arrivals))
                 if not is_long[j] and j in done]
    # gated numbers come from the metrics registry (per-request SLO
    # goodput, latency histogram, completion counter) — summarize() reads
    # the same underlying requests, so the two must agree (cross-checked)
    m = eng.metrics
    n_reg = int(m.value("requests.completed"))
    goodput_reg = m.value("requests.goodput_ok") / max(n_reg, 1)
    p99_reg = float(m.get("request.latency_ms").percentile(99))
    assert n_reg == s["n_requests"], (n_reg, s["n_requests"])
    return {
        "goodput": goodput_reg,
        "p99_ms": p99_reg,
        "mean_latency_ms": s["mean_latency_ms"],
        "p99_queue_ms": s.get("p99_queue_ms", 0.0),
        "short_p99_ms": float(np.percentile(short_lat, 99)),
        "throughput_rps": n_reg / max(makespan, 1e-9),
        "n_requests": n_reg,
        "summary_goodput": s["goodput"],   # summarize() parity reference
        "summary_p99_ms": s["p99_ms"],
    }


def run() -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []
    fifo_engine = _engine("fifo")
    chunked_engine = _engine("chunked")
    costs = _calibrate_costs(fifo_engine[0], chunked_engine[0])
    cap_f = _drain_capacity(*fifo_engine, costs)
    cap_c = _drain_capacity(*chunked_engine, costs)
    rate = CHUNKED_HEADROOM * cap_c
    short_slo = SHORT_SLO_TICKS * costs["decode_ms"]
    long_slo = LONG_SLO_TICKS * costs["decode_ms"]
    rows.append(("calibration", costs["prefill_ms"] * 1000.0,
                 f"prefill={costs['prefill_ms']:.1f}ms "
                 f"chunk={costs['chunk_ms']:.1f}ms "
                 f"decode={costs['decode_ms']:.1f}ms "
                 f"cap_fifo={cap_f:.1f}rps cap_chunked={cap_c:.1f}rps "
                 f"offered={rate:.1f}rps short_slo={short_slo:.0f}ms"))
    workload = _workload(42, rate, short_slo, long_slo)
    payload: Dict = {
        "config": {"costs_ms": costs, "rate_rps": rate,
                   "fifo_capacity_rps": cap_f, "chunked_capacity_rps": cap_c,
                   "n_requests": N_REQUESTS,
                   "short_slo_ms": short_slo, "long_slo_ms": long_slo,
                   "max_batch": MAX_BATCH, "prompt_len": PROMPT_LEN,
                   "short_len": SHORT_LEN, "long_frac": LONG_FRAC,
                   "max_new": MAX_NEW, "decode_chunk": DECODE_CHUNK,
                   "prefill_chunk": PREFILL_CHUNK, "vocab": VOCAB,
                   "layers": 2, "d_model": 64},
        "policies": {}}
    ready = {"fifo": fifo_engine, "chunked": chunked_engine}
    for policy in POLICIES:
        r = _replay(policy, workload, costs, engine=ready.get(policy))
        payload["policies"][policy] = r
        rows.append((policy, r["p99_ms"] * 1000.0,
                     f"goodput={r['goodput']:.3f} p99={r['p99_ms']:.0f}ms "
                     f"short_p99={r['short_p99_ms']:.0f}ms "
                     f"thr={r['throughput_rps']:.1f}rps n={r['n_requests']}"))
    fifo, chunked = payload["policies"]["fifo"], payload["policies"]["chunked"]
    payload["ratios"] = {
        "goodput_ratio": chunked["goodput"] / max(fifo["goodput"], 1e-9),
        "p99_ratio": chunked["p99_ms"] / max(fifo["p99_ms"], 1e-9),
        "short_p99_ratio": (chunked["short_p99_ms"]
                            / max(fifo["short_p99_ms"], 1e-9)),
    }
    rr = payload["ratios"]
    # acceptance gate: chunked+EDF >=1.1x goodput, <=0.8x P99 vs FIFO
    rows.append(("chunked_vs_fifo",
                 (chunked["p99_ms"] - fifo["p99_ms"]) * 1000.0,
                 f"goodput_ratio={rr['goodput_ratio']:.2f} (gate >=1.1) "
                 f"p99_ratio={rr['p99_ratio']:.2f} (gate <=0.8) "
                 f"short_p99_ratio={rr['short_p99_ratio']:.2f}"))
    os.makedirs(os.path.dirname(BENCH_JSON), exist_ok=True)
    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
