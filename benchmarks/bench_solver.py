"""Solver scalability (the paper's §7 'Scalability with ML' future work):
runtime + optimality gap of exact-DP and greedy vs brute force as the variant
ladder grows. Brute force is exponential; the exact DP answers the paper's
scalability concern without ML."""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core.profiles import VariantProfile
from repro.core.solver import solve_bruteforce, solve_exact, solve_greedy

Row = Tuple[str, float, str]


def _ladder(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    out = {}
    for i in range(n):
        frac = (i + 1) / n
        out[f"v{i}"] = VariantProfile(
            name=f"v{i}", accuracy=55.0 + 40.0 * frac ** 0.5,
            rt=2.0 + 14.0 * frac,
            th_slope=14.0 - 11.0 * frac + rng.normal(0, 0.2),
            th_intercept=max(0.0, 12.0 - 8.0 * frac),
            lat_base_ms=20.0 + 100.0 * frac,
            lat_k_ms=80.0 + 600.0 * frac)
    return out


def run() -> List[Row]:
    rows: List[Row] = []
    lam, slo = 80.0, 750.0
    for n, budget in [(5, 20), (10, 24), (25, 32), (50, 32), (100, 48)]:
        profiles = _ladder(n)
        t0 = time.time()
        e = solve_exact(profiles, lam, budget, slo)
        t_exact = (time.time() - t0) * 1e6
        t0 = time.time()
        g = solve_greedy(profiles, lam, budget, slo)
        t_greedy = (time.time() - t0) * 1e6
        gap = (e.objective - g.objective) if (e.feasible and g.feasible) else float("nan")
        rows.append((f"exact.n{n}", t_exact, f"obj={e.objective:.2f}"))
        rows.append((f"greedy.n{n}", t_greedy, f"gap={gap:.3f}"))
        if n <= 5:
            t0 = time.time()
            b = solve_bruteforce(profiles, lam, budget, slo)
            t_bf = (time.time() - t0) * 1e6
            rows.append((f"bruteforce.n{n}", t_bf,
                         f"exact_matches={abs(b.objective - e.objective) < 0.25}"))
    return rows
