"""Profiling subsystem benchmark: how wrong is each profile source?

For a smoke-scale two-variant ladder, measures (real engine, saturating
open-loop sweep) the ground-truth throughput at each allocation point and
reports, per variant, the median relative error of each profile source
against those measurements:

  * ``measured``  — the ``EngineProfiler`` regression fit itself (pure fit
    residual: how much the linear model th(n)=a·n+b loses on real points);
  * ``roofline``  — the analytic TPU roofline, cross-calibrated by
    ``roofline_scale_factor`` from the *other* variant (leave-one-out, so
    the calibration never sees the variant it predicts);
  * ``paper-calibrated`` — the paper's ResNet constants, checked the same
    way against their own synthetic measurement points (fit error under
    the paper's 1% measurement noise).

Also round-trips the measured store through ``reports/profiles/`` as a
persistence smoke check. Wall-clock real execution, ~15–30 s.

Run: PYTHONPATH=src python -m benchmarks.run --only profiling
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

POINTS = (1, 2, 4)
REQUESTS_PER_POINT = 24
WARMUP = 6
STORE_PATH = "reports/profiles/bench_profiling.json"


def _variants():
    from repro.configs import get_config, smoke_variant
    base = smoke_variant(get_config("tinyllama-1.1b")).replace(
        d_model=64, d_ff=128, vocab_size=128)
    return {
        "prof-2L": (base.replace(num_layers=2, name="prof-2L"), 70.0),
        "prof-3L": (base.replace(num_layers=3, name="prof-3L"), 75.0),
    }


def _median_rel_err(profile, points) -> float:
    errs = [abs(profile.throughput(n) - th) / max(th, 1e-9)
            for n, th in points]
    return float(np.median(errs))


def run() -> List[Tuple[str, float, str]]:
    from repro.core.profiles import (fit_throughput, measured_resnet_points,
                                     paper_resnet_profiles)
    from repro.profiling.calibrate import (calibrated_roofline_profile,
                                           roofline_scale_factor)
    from repro.profiling.measure import EngineProfiler
    from repro.profiling.store import ProfileStore
    from repro.serving.engine import InProcessServingEngine

    variants = _variants()
    cfgs = {name: cfg for name, (cfg, _) in variants.items()}
    eng = InProcessServingEngine(variants, max_batch=max(POINTS),
                                 prompt_len=8, max_new=8, decode_chunk=4)
    profiler = EngineProfiler(eng, points=POINTS,
                              requests_per_point=REQUESTS_PER_POINT,
                              warmup=WARMUP)
    store = ProfileStore(STORE_PATH)
    measurements = profiler.profile_all(store=store)

    rows: List[Tuple[str, float, str]] = []
    for name, m in measurements.items():
        truth = [(p.units, p.throughput_rps) for p in m.points]
        # measured source: the fit's own residual against its points
        err_meas = _median_rel_err(m.profile, truth)
        rows.append((f"measured_{name}", err_meas * 1e6,
                     f"relerr={err_meas:.3f} r2={m.th_fit.r_squared:.3f}"))
        # roofline source: leave-one-out cross-calibration
        others = {k: v for k, v in measurements.items() if k != name}
        scale = roofline_scale_factor(others, cfgs)
        roof = calibrated_roofline_profile(cfgs[name], m.profile.accuracy,
                                           scale=scale)
        err_roof = _median_rel_err(roof, truth)
        rows.append((f"roofline_{name}", err_roof * 1e6,
                     f"relerr={err_roof:.3f} scale={scale:.2e}"))

    # paper-calibrated source: fit error against its own noisy measurements
    paper = paper_resnet_profiles(noise=0.01, seed=0)
    for name in ("resnet18", "resnet152"):
        pts = measured_resnet_points(name, noise=0.01, seed=0)
        err = _median_rel_err(paper[name], pts)
        fit = fit_throughput(pts)
        rows.append((f"paper_{name}", err * 1e6,
                     f"relerr={err:.4f} r2={fit.r_squared:.4f}"))

    # persistence smoke: save -> load -> identical profiles
    path = store.save()
    loaded = ProfileStore.load(path)
    ok = all(loaded.get(n) == measurements[n].profile for n in measurements)
    rows.append(("store_roundtrip", float(len(loaded)),
                 f"identical={ok} path={path}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
