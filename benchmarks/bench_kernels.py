"""Kernel micro-bench: Pallas (interpret) vs pure-jnp oracle.

Interpret-mode wall time is NOT TPU performance — on CPU the interpreter is
expected to be slower; this bench exists to (a) pin call overheads, (b) keep a
correctness-at-speed regression guard, and (c) record the analytic FLOP rates
the kernels would need on a v5e (derived column)."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

Row = Tuple[str, float, str]


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def run() -> List[Row]:
    rows: List[Row] = []
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    B, S, H, KV, hd = 1, 512, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    flops = 4 * B * S * S * H * hd / 2
    us = _time(lambda a, b, c: ops.flash_prefill(a, b, c), q, k, v)
    rows.append(("flash_prefill.pallas", us,
                 f"v5e_t={flops/197e12*1e6:.2f}us_at_peak"))
    us = _time(lambda a, b, c: ref.ref_flash_prefill(a, b, c), q, k, v)
    rows.append(("flash_prefill.jnp_oracle", us, f"flops={flops:.2e}"))

    C = 2048
    qd = jax.random.normal(ks[3], (B, 1, H, hd))
    kd = jax.random.normal(ks[4], (B, C, KV, hd))
    vd = jax.random.normal(ks[5], (B, C, KV, hd))
    bias = jnp.zeros((B, C))
    dec_bytes = 2 * B * C * KV * hd * 4
    us = _time(lambda a, b, c, d: ops.flash_decode(a, b, c, d), qd, kd, vd, bias)
    rows.append(("flash_decode.pallas", us,
                 f"v5e_t={dec_bytes/819e9*1e6:.2f}us_hbm_bound"))
    us = _time(lambda a, b, c, d: ref.ref_flash_decode(a, b, c, d), qd, kd, vd, bias)
    rows.append(("flash_decode.jnp_oracle", us, f"bytes={dec_bytes:.2e}"))

    b, s, h, p, n = 1, 512, 8, 64, 64
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.abs(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, s, n))
    Cm = jax.random.normal(ks[4], (b, s, n))
    ssd_flops = 2 * b * s * 128 * h * p + 4 * b * s * h * p * n
    us = _time(lambda *a: ops.ssd_scan(*a)[0], x, dt, A, Bm, Cm)
    rows.append(("ssd_scan.pallas", us,
                 f"v5e_t={ssd_flops/197e12*1e6:.2f}us_at_peak"))
    us = _time(lambda *a: ref.ref_ssd(*a)[0], x, dt, A, Bm, Cm)
    rows.append(("ssd_scan.jnp_oracle", us, f"flops={ssd_flops:.2e}"))
    return rows
