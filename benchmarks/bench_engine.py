"""Continuous batching vs legacy pump serving: throughput + tail latency.

Replays the same Poisson arrival schedule against the real-execution engine
in both modes at several offered loads and reports per-mode P99 / mean
latency / achieved throughput, plus the continuous/pump P99 ratio at each
rate. This measures the tentpole claim of the continuous-batching PR: at
equal offered load the slot-based engine's tail latency is no worse than the
blocking micro-batch path (it strictly wins once arrivals collide with
in-flight generations — head-of-line blocking).

Wall-clock real execution (CPU, smoke-scale variant) — a few seconds per
(mode, rate) cell.

Run: PYTHONPATH=src python -m benchmarks.run --only engine_serving
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

RATES_RPS = (20.0, 60.0, 120.0)
DURATION_S = 3.0
PROMPT_LEN = 16
MAX_NEW = 24
MAX_BATCH = 8
VOCAB = 128


def _variant():
    from repro.configs import get_config, smoke_variant
    base = smoke_variant(get_config("tinyllama-1.1b")).replace(
        d_model=64, d_ff=128, vocab_size=VOCAB, num_layers=2, name="bench-2L")
    return {"bench-2L": (base, 70.0)}


def _replay(mode: str, arrivals: np.ndarray, seed: int) -> dict:
    from repro.serving.api import Request
    from repro.serving.engine import InProcessServingEngine

    eng = InProcessServingEngine(
        _variant(), max_batch=MAX_BATCH, prompt_len=PROMPT_LEN, mode=mode,
        max_new=MAX_NEW, decode_chunk=4, queue_cap=100_000)
    eng.apply_allocation(0.0, {"bench-2L": 1})
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, VOCAB, (len(arrivals), PROMPT_LEN))
    t0 = time.time()
    i = 0
    while i < len(arrivals) or eng.backlog(0.0) or eng.in_flight():
        now = time.time() - t0
        while i < len(arrivals) and arrivals[i] <= now:
            eng.submit(Request(rid=i, tokens=prompts[i], max_new=MAX_NEW,
                               arrival=t0 + arrivals[i]), None)
            i += 1
        eng.step(now)
    makespan = time.time() - t0
    s = eng.summarize(slo_ms=1e12, best_accuracy=70.0)
    s["throughput_rps"] = s["n_requests"] / makespan
    return s


def run() -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []
    for rate in RATES_RPS:
        rng = np.random.default_rng(int(rate))
        gaps = rng.exponential(1.0 / rate, size=int(rate * DURATION_S))
        arrivals = np.cumsum(gaps)
        p99 = {}
        for mode in ("pump", "continuous"):
            s = _replay(mode, arrivals, seed=int(rate))
            p99[mode] = s["p99_ms"]
            rows.append((
                f"{mode}_r{int(rate)}", s["p99_ms"] * 1000.0,
                f"thr={s['throughput_rps']:.1f}rps "
                f"mean={s['mean_latency_ms']:.0f}ms n={s['n_requests']}"))
        # us column carries the absolute P99 gap; the ratio rides in derived
        rows.append((f"p99_ratio_r{int(rate)}",
                     (p99["continuous"] - p99["pump"]) * 1000.0,
                     f"continuous/pump={p99['continuous'] / max(p99['pump'], 1e-9):.3f}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
