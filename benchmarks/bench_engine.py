"""Serving-engine benchmarks: continuous batching vs pump, dense vs paged KV.

Two studies against the real-execution engine:

1. **Continuous vs pump** (PR 1 tentpole): identical Poisson arrival
   schedules in both modes at several offered loads; per-mode P99 / mean
   latency / achieved throughput and the continuous/pump P99 ratio.

2. **Paged vs dense KV cache** (DESIGN.md §Paged KV cache): at 25/50/75%
   slot occupancy with short sequences, per-engine-tick P50/P99 latency and
   closed-loop throughput under the dense per-slot ring cache vs the paged
   pool (right-sized prefill + length-aware decode); plus a mixed-length
   throughput cell and a context-scaling sweep showing paged step time
   follows *live* context while dense follows capacity. Results land in the
   machine-readable ``reports/BENCH_engine.json`` (a CI artifact) and are
   rendered into EXPERIMENTS.md by ``repro.analysis.report``.

Also home to the standalone ``async_overlap`` (sync vs two-phase
dispatch/commit tick loop) and ``spec_decode`` (draft/verify on the
variant ladder: parity + acceptance/tokens-per-step gates under a virtual
clock) studies, which merge their payloads into the same
``reports/BENCH_engine.json``.

Wall-clock real execution (CPU, smoke-scale variant) — a few seconds per
cell.

Run: PYTHONPATH=src python -m benchmarks.run --only engine_serving
"""
from __future__ import annotations

import gc
import json
import os
import time
from typing import Dict, List, Tuple

import numpy as np

RATES_RPS = (20.0, 60.0, 120.0)
DURATION_S = 3.0
PROMPT_LEN = 16
MAX_NEW = 24
MAX_BATCH = 8
VOCAB = 128

# --- paged-vs-dense study geometry ---
# Capacity C = PG_PROMPT + PG_MAX_NEW = 1024 tokens/slot: big enough that
# capacity-proportional KV reads dominate a decode tick on CPU (the regime
# where the cache discipline matters); short requests use ~150 of those
# tokens, so dense pays ~7x their live context every step. The bench variant
# unrolls its 2 layers (scan_layers=False) and ticks one decode step at a
# time (decode_chunk=1): a multi-step chunk scan would thread the whole
# cache through the scan carry, copying capacity-sized buffers per step in
# BOTH disciplines and masking the one under comparison.
PG_PROMPT = 128
PG_MAX_NEW = 896
PG_SHORT_NEW = 16
PG_PAGE = 128
PG_CHUNK = 1
PG_BATCH = 16           # 16 slots × 1024 tokens: capacity reads dominate
OCCUPANCIES = (0.25, 0.5, 0.75)

# prefix-sharing study (DESIGN.md §Prefix sharing): every prompt opens with
# the same PS_SHARED-token system prefix (3 of 4 prompt blocks at page 8),
# admissions staggered one per tick so lifetimes overlap — sharing only
# happens between live requests (index entries die with their pages)
PS_PROMPT = 32
PS_PAGE = 8
PS_SHARED = 24
PS_N = 20
PS_MAX_NEW = 16
PS_BATCH = 4

# async-overlap study (DESIGN.md §Async tick loop): a geometry where the
# per-tick host cost (dispatch + D2H read + bookkeeping) is a meaningful
# fraction of device compute — the regime the dispatch/commit pipeline is
# built for. decode_chunk=1 so every tick pays the full host round-trip.
AS_PROMPT = 32
AS_MAX_NEW = 48
AS_BATCH = 8
AS_STEPS = 120
AS_REPS = 3             # alternating sync/async repetitions (drift control)

# speculative-decoding study (DESIGN.md §Speculative decoding): a paged
# engine under a virtual clock, so "step latency" is tick COUNT — each
# tick is one verifier execution (decode_chunk=1 on the target arm, one
# draft+verify round on the speculative arm) and the ratio is exact, not
# wall-clock noise. Two drafters: "correlated" shares the verifier's
# weights (acceptance must saturate — the gated arm), "ladder" is a
# genuinely smaller variant one rung down (report-only: acceptance there
# measures how much the tiny random-weight ladder actually agrees).
SP_PROMPT = 16
SP_MAX_NEW = 32
SP_BATCH = 4
SP_K = 4
SP_N = 8
SP_PAGE = 8
SP_ACCEPT_GATE = 0.9    # correlated drafter: acceptance must saturate
SP_TPS_GATE = 1.5       # accepted tokens per verifier step (ISSUE gate)
BENCH_JSON = os.path.join("reports", "BENCH_engine.json")


def _variant():
    from repro.configs import get_config, smoke_variant
    base = smoke_variant(get_config("tinyllama-1.1b")).replace(
        d_model=64, d_ff=128, vocab_size=VOCAB, num_layers=2, name="bench-2L")
    return {"bench-2L": (base, 70.0)}


def _replay(mode: str, arrivals: np.ndarray, seed: int) -> dict:
    from repro.serving.api import Request
    from repro.serving.engine import InProcessServingEngine

    eng = InProcessServingEngine(
        _variant(), max_batch=MAX_BATCH, prompt_len=PROMPT_LEN, mode=mode,
        max_new=MAX_NEW, decode_chunk=4, queue_cap=100_000)
    eng.apply_allocation(0.0, {"bench-2L": 1})
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, VOCAB, (len(arrivals), PROMPT_LEN))
    t0 = time.time()
    i = 0
    while i < len(arrivals) or eng.backlog(0.0) or eng.in_flight():
        now = time.time() - t0
        while i < len(arrivals) and arrivals[i] <= now:
            eng.submit(Request(rid=i, tokens=prompts[i], max_new=MAX_NEW,
                               arrival=t0 + arrivals[i]), None)
            i += 1
        eng.step(now)
    makespan = time.time() - t0
    s = eng.summarize(slo_ms=1e12, best_accuracy=70.0)
    s["throughput_rps"] = s["n_requests"] / makespan
    return s


def _paged_variant():
    from repro.configs import get_config, smoke_variant
    base = smoke_variant(get_config("tinyllama-1.1b")).replace(
        d_model=64, d_ff=128, vocab_size=VOCAB, num_layers=2,
        scan_layers=False, name="bench-paged-2L")
    return {"bench-paged-2L": (base, 70.0)}


def _paged_engine(kv_cache: str):
    from repro.serving.engine import InProcessServingEngine
    eng = InProcessServingEngine(
        _paged_variant(), max_batch=PG_BATCH, prompt_len=PG_PROMPT,
        max_new=PG_MAX_NEW, decode_chunk=PG_CHUNK, queue_cap=100_000,
        kv_cache=kv_cache, kv_page_size=PG_PAGE)
    eng.apply_allocation(0.0, {"bench-paged-2L": 1})
    return eng


def _closed_loop_pair(engines: Dict, k: int, max_new, n_steps: int,
                      seed: int) -> Dict[str, Dict]:
    """Drive every engine through the SAME closed-loop workload (exactly
    ``k`` in flight, identical per-request ``max_new`` draws), alternating
    one tick per engine so machine-load drift hits all of them equally —
    the ratios, which the acceptance criteria gate on, stay meaningful on a
    noisy host. ``max_new`` is an int or a callable(rng)->int.

    Returns per-engine per-tick P50/P99 ms and completions per second of
    *own* busy time (each engine's throughput as if running alone)."""
    from repro.serving.api import Request
    draw = max_new if callable(max_new) else (lambda _rng: max_new)
    st = {kv: {"rng": np.random.default_rng(seed), "rid": 0, "ticks": [],
               "busy_s": 0.0, "done0": len(eng.done)}
          for kv, eng in engines.items()}

    def top_up(kv):
        s, eng = st[kv], engines[kv]
        while eng.backlog(0.0) + eng.in_flight() < k:
            eng.submit(Request(
                rid=s["rid"], tokens=s["rng"].integers(0, VOCAB, PG_PROMPT),
                max_new=int(draw(s["rng"])), arrival=time.time()), None)
            s["rid"] += 1

    for kv in engines:
        top_up(kv)
    for _ in range(4):                    # settle into steady state
        for kv, eng in engines.items():
            eng.step(0.0)
            top_up(kv)
    for kv in engines:
        st[kv]["done0"] = len(engines[kv].done)
    gc.disable()                          # measured loop: no GC pauses
    try:
        for _ in range(n_steps):
            for kv, eng in engines.items():
                t1 = time.perf_counter()
                eng.step(0.0)
                dt = time.perf_counter() - t1
                st[kv]["ticks"].append(dt * 1000.0)
                st[kv]["busy_s"] += dt
                top_up(kv)
    finally:
        gc.enable()
    out = {}
    for kv, eng in engines.items():
        completed = len(eng.done) - st[kv]["done0"]
        eng.drain(0.0)
        ticks = np.asarray(st[kv]["ticks"])
        out[kv] = {"p50_step_ms": float(np.percentile(ticks, 50)),
                   "p99_step_ms": float(np.percentile(ticks, 99)),
                   "mean_step_ms": float(ticks.mean()),
                   "throughput_rps": completed / st[kv]["busy_s"]}
    return out


def _context_scaling_pair(engines: Dict, k: int, seed: int,
                          gen: int = 320) -> Dict[str, List[Dict]]:
    """Admit ``k`` identical long generations on every engine and record
    mean tick time as the live context grows, alternating ticks across
    engines (same drift-cancelling rationale as ``_closed_loop_pair``) —
    paged tick time should track context, dense capacity."""
    from repro.serving.api import Request
    for kv, eng in engines.items():
        rng = np.random.default_rng(seed)
        for i in range(k):
            eng.submit(Request(rid=i, tokens=rng.integers(0, VOCAB, PG_PROMPT),
                               max_new=gen, arrival=time.time()), None)
        eng.step(0.0)                     # admission (prefill) tick
    bins: Dict[str, Dict[int, List[float]]] = {kv: {} for kv in engines}
    ctx = PG_PROMPT
    gc.disable()
    try:
        while any(eng.in_flight() for eng in engines.values()):
            for kv, eng in engines.items():
                if not eng.in_flight():
                    continue
                t1 = time.perf_counter()
                eng.step(0.0)
                dt_ms = (time.perf_counter() - t1) * 1000.0
                bins[kv].setdefault(ctx // 128 * 128, []).append(dt_ms)
            ctx += PG_CHUNK
    finally:
        gc.enable()
    for eng in engines.values():
        eng.drain(0.0)
    return {kv: [{"context_tokens": c, "mean_step_ms": float(np.mean(v))}
                 for c, v in sorted(b.items())]
            for kv, b in bins.items()}


def paged_vs_dense() -> Tuple[List[Tuple[str, float, str]], Dict]:
    """The §Paged KV cache study: occupancy cells, mixed-length throughput,
    context scaling. Returns benchmark rows + the BENCH_engine.json payload."""
    rows: List[Tuple[str, float, str]] = []
    engines = {kv: _paged_engine(kv) for kv in ("dense", "paged")}
    payload: Dict = {
        "config": {"prompt_len": PG_PROMPT, "max_new": PG_MAX_NEW,
                   "short_max_new": PG_SHORT_NEW, "max_batch": PG_BATCH,
                   "page_size": PG_PAGE, "decode_chunk": PG_CHUNK,
                   "vocab": VOCAB, "layers": 2, "d_model": 64},
        "occupancy": [], "mixed_load": {}, "context_scaling": {}}

    # short sequences in a narrow band around PG_SHORT_NEW: identical
    # lengths would retire whole admission cohorts at once, which is neither
    # realistic nor how steady-state occupancy behaves
    def short(rng):
        return int(rng.integers(PG_SHORT_NEW - 4, PG_SHORT_NEW + 5))

    for occ in OCCUPANCIES:
        k = max(1, int(round(occ * PG_BATCH)))
        cell = {"occupancy": occ, "slots": k}
        cell.update(_closed_loop_pair(engines, k, short, n_steps=80,
                                      seed=int(occ * 100)))
        cell["p99_ratio"] = (cell["paged"]["p99_step_ms"]
                             / max(cell["dense"]["p99_step_ms"], 1e-9))
        cell["throughput_ratio"] = (cell["paged"]["throughput_rps"]
                                    / max(cell["dense"]["throughput_rps"], 1e-9))
        payload["occupancy"].append(cell)
        rows.append((
            f"paged_occ{int(occ * 100)}",
            cell["paged"]["p99_step_ms"] * 1000.0,
            f"p99_ratio={cell['p99_ratio']:.3f} "
            f"thr_ratio={cell['throughput_ratio']:.2f} "
            f"dense_p99={cell['dense']['p99_step_ms']:.2f}ms "
            f"paged_p99={cell['paged']['p99_step_ms']:.2f}ms"))

    # Mixed-length load: short-heavy mix whose live contexts (≤256 tokens)
    # sit well under the provisioned 1024-token capacity — the paper's
    # dynamic-workload regime (slots sized for the worst case, traffic mostly
    # short). Dense pays capacity per step regardless; paged pays the mix.
    def mixed(rng):
        return int(rng.choice((8, 16, 32, 128), p=(0.4, 0.3, 0.2, 0.1)))

    payload["mixed_load"] = _closed_loop_pair(engines, PG_BATCH, mixed,
                                              n_steps=100, seed=7)
    thr_ratio = (payload["mixed_load"]["paged"]["throughput_rps"]
                 / max(payload["mixed_load"]["dense"]["throughput_rps"], 1e-9))
    payload["mixed_load"]["throughput_ratio"] = thr_ratio
    rows.append(("paged_mixed_thr", thr_ratio * 1e6,
                 f"paged/dense={thr_ratio:.2f}x "
                 f"({payload['mixed_load']['paged']['throughput_rps']:.1f} vs "
                 f"{payload['mixed_load']['dense']['throughput_rps']:.1f} rps)"))

    payload["context_scaling"] = _context_scaling_pair(engines, k=4, seed=11)
    for kv in ("dense", "paged"):
        pts = payload["context_scaling"][kv]
        if len(pts) >= 2:
            lo, hi = pts[0]["mean_step_ms"], pts[-1]["mean_step_ms"]
            rows.append((f"ctx_scaling_{kv}", hi * 1000.0,
                         f"step_ms {lo:.2f}->{hi:.2f} over context "
                         f"{pts[0]['context_tokens']}->"
                         f"{pts[-1]['context_tokens']}tok"))
    return rows, payload


def prefix_sharing() -> Tuple[List[Tuple[str, float, str]], Dict]:
    """The §Prefix sharing study: the SAME shared-system-prompt workload on
    a sharing-on and a sharing-off paged engine. Records admission hit
    rate, prefill-token reduction (the engines count every prompt token
    they actually prefilled), and effective-capacity uplift (worst-case
    page budget vs fresh pages actually allocated)."""
    from repro.serving.api import Request
    rng = np.random.default_rng(23)
    prefix = rng.integers(0, VOCAB, PS_SHARED)
    prompts = [np.concatenate([prefix,
                               rng.integers(0, VOCAB, PS_PROMPT - PS_SHARED)])
               for _ in range(PS_N)]

    def serve(sharing: bool) -> Dict:
        from repro.serving.engine import InProcessServingEngine
        eng = InProcessServingEngine(
            _paged_variant(), max_batch=PS_BATCH, prompt_len=PS_PROMPT,
            max_new=PS_MAX_NEW, decode_chunk=2, queue_cap=100_000,
            kv_cache="paged", kv_page_size=PS_PAGE,
            kv_prefix_sharing=sharing)
        eng.apply_allocation(0.0, {"bench-paged-2L": 1})
        b = eng.backends["bench-paged-2L"]
        t0 = time.time()
        for i, p in enumerate(prompts):   # staggered: one admission per tick
            eng.submit(Request(rid=i, tokens=p, max_new=PS_MAX_NEW,
                               arrival=time.time()), None)
            eng.step(0.0)
        eng.drain(0.0)
        makespan = time.time() - t0
        assert len(eng.done) == PS_N
        assert b.pool.used_pages == 0     # shared pages all returned
        # gated numbers come from the metrics registry; the backend's
        # attribute counter must agree (single prefill-accounting path)
        stats = eng.kv_pool_stats()
        prefill_tokens = int(eng.metrics.value("engine.prefill_tokens_total"))
        assert prefill_tokens == b.prefill_tokens_total, \
            (prefill_tokens, b.prefill_tokens_total)
        return {"prefill_tokens": prefill_tokens,
                "prefix_lookups": stats["prefix_lookups"],
                "prefix_hits": stats["prefix_hits"],
                "prefix_hit_rate": stats["prefix_hit_rate"],
                "fresh_pages_allocated": stats["fresh_pages_allocated"],
                "worst_case_pages": PS_N * b.pages_per_slot,
                "makespan_s": makespan}

    cell: Dict = {"config": {"prompt_len": PS_PROMPT, "page_size": PS_PAGE,
                             "shared_prefix": PS_SHARED, "n_requests": PS_N,
                             "max_new": PS_MAX_NEW, "max_batch": PS_BATCH},
                  "off": serve(False), "on": serve(True)}
    on, off = cell["on"], cell["off"]
    cell["prefill_token_reduction"] = (off["prefill_tokens"]
                                       / max(on["prefill_tokens"], 1))
    cell["capacity_uplift"] = (on["worst_case_pages"]
                               / max(on["fresh_pages_allocated"], 1))
    rows = [
        ("prefix_hit_rate", on["prefix_hit_rate"] * 1e6,
         f"hits={on['prefix_hits']}/{on['prefix_lookups']} "
         f"rate={on['prefix_hit_rate']:.2f}"),
        ("prefix_prefill_reduction", cell["prefill_token_reduction"] * 1e6,
         f"prefill_tokens off/on={off['prefill_tokens']}/"
         f"{on['prefill_tokens']} = {cell['prefill_token_reduction']:.2f}x"),
        ("prefix_capacity_uplift", cell["capacity_uplift"] * 1e6,
         f"worst_case/fresh={on['worst_case_pages']}/"
         f"{on['fresh_pages_allocated']} = {cell['capacity_uplift']:.2f}x"),
    ]
    return rows, cell


def observability() -> Tuple[List[Tuple[str, float, str]], Dict]:
    """The §Observability overhead study + trace artifact production.

    Five identical paged engines differing only in observability level —
    fully disabled, metrics-only (the default), full tracing, tracing +
    rolling windows, and tracing + the dispatch profiler sampling EVERY
    tick — run the same closed loop through ``_closed_loop_pair``; the
    payload records the per-tick cost ratios (windows gate against
    disabled; the profiler, whose fence deliberately serializes dispatch
    and compute, gates against traced with its own ``sampling_gate``). A
    no-op-hook microbench then times the disabled instruments directly:
    ``disabled_hook_frac`` is the fraction of a disabled-mode tick a
    *generous* per-tick hook budget would cost, and the acceptance gate
    requires it ≤ 2% (``gate_frac``). The profiled engine's fenced ticks
    feed ``dispatch_floor`` (the EXPERIMENTS.md §Dispatch floor baseline).
    A small virtual-clock traced run exports ``reports/TRACE_engine.json``
    + ``METRICS_engine.jsonl``, schema-validates both, and asserts the
    tracer dropped nothing (the CI gate re-validates the shipped artifacts
    via ``python -m repro.obs.export --assert-zero``). Finally
    ``_burn_rate_smoke`` runs the full online-reaction path — fault →
    SLO burn → alert → flight dump — and hard-asserts it end to end.
    """
    from repro.obs import Observability, dispatch_floor_summary
    from repro.obs.export import (assert_zero, validate_metrics_file,
                                  validate_trace_file, write_chrome_trace,
                                  write_metrics_jsonl)
    from repro.serving.api import Request
    from repro.serving.engine import InProcessServingEngine

    def mk(**kw):
        eng = InProcessServingEngine(
            _paged_variant(), max_batch=PG_BATCH, prompt_len=PG_PROMPT,
            max_new=PG_MAX_NEW, decode_chunk=PG_CHUNK, queue_cap=100_000,
            kv_cache="paged", kv_page_size=PG_PAGE, **kw)
        eng.apply_allocation(0.0, {"bench-paged-2L": 1})
        return eng

    engines = {"disabled": mk(obs=Observability.disabled()),
               "metrics": mk(),
               "traced": mk(trace=True),
               "windowed": mk(obs=Observability(trace=True, windows=True)),
               "profiled": mk(trace=True, profile_dispatch=1)}

    def short(rng):
        return int(rng.integers(PG_SHORT_NEW - 4, PG_SHORT_NEW + 5))

    ticks = _closed_loop_pair(engines, k=PG_BATCH // 2, max_new=short,
                              n_steps=60, seed=3)
    base_ms = max(ticks["disabled"]["mean_step_ms"], 1e-9)
    traced_ms = max(ticks["traced"]["mean_step_ms"], 1e-9)
    payload: Dict = {
        "ticks": ticks,
        "metrics_over_disabled": ticks["metrics"]["mean_step_ms"] / base_ms,
        "traced_over_disabled": ticks["traced"]["mean_step_ms"] / base_ms,
        "windowed_over_disabled":
            ticks["windowed"]["mean_step_ms"] / base_ms,
        "profiled_over_traced":
            ticks["profiled"]["mean_step_ms"] / traced_ms,
        "sampling_gate": 1.5,
    }
    payload["dispatch_floor"] = dispatch_floor_summary(
        engines["profiled"].tracer.ticks)

    # --- no-op hook microbench: what do the disabled instruments cost? ---
    obs = Observability.disabled()
    m, tr, w = obs.metrics, obs.tracer, obs.windows
    c, h, g = m.counter("noop.c"), m.histogram("noop.h"), m.gauge("noop.g")
    n_iter, calls_per_iter = 20_000, 11
    t0 = time.perf_counter()
    for _ in range(n_iter):
        c.inc(); c.inc(4); h.observe(1.0); g.set(2.0)       # noqa: E702
        m.inc("noop.c"); m.observe("noop.h", 1.0)           # noqa: E702
        tr.event(0, "x", 0.0); tr.event(1, "y", 1.0)        # noqa: E702
        if tr.on:
            pass
        if m.enabled:
            pass
        if w.on:       # the windows-off hook the hot paths actually run
            pass
    per_hook_s = (time.perf_counter() - t0) / (n_iter * calls_per_iter)
    # generous per-tick budget: a few per-phase hooks + a handful per slot
    hooks_per_tick = 8 + 6 * PG_BATCH
    frac = per_hook_s * hooks_per_tick / (base_ms / 1e3)
    payload.update({"noop_hook_ns": per_hook_s * 1e9,
                    "hooks_per_tick_budget": hooks_per_tick,
                    "disabled_hook_frac": frac, "gate_frac": 0.02})

    # --- artifact run: small traced workload on one virtual clock ---
    t_art = [0.0]
    art = InProcessServingEngine(
        _paged_variant(), max_batch=8, prompt_len=32, max_new=16,
        decode_chunk=4, queue_cap=100_000, kv_cache="paged", kv_page_size=8,
        scheduler="chunked", preemption="requeue",
        clock=lambda: t_art[0], trace=True)
    art.apply_allocation(0.0, {"bench-paged-2L": 1})
    rng = np.random.default_rng(5)
    for i in range(24):
        art.submit(Request(rid=i, tokens=rng.integers(0, VOCAB, 32),
                           max_new=int(rng.integers(4, 16)),
                           arrival=t_art[0], slo_ms=500.0), None)
        art.step(t_art[0])
        t_art[0] += 0.01
    while art.backlog(t_art[0]) or art.in_flight():
        art.step(t_art[0])
        t_art[0] += 0.01
    os.makedirs("reports", exist_ok=True)
    tp = os.path.join("reports", "TRACE_engine.json")
    mp = os.path.join("reports", "METRICS_engine.jsonl")
    n_ev = write_chrome_trace(tp, art.tracer, label="bench_engine")
    n_m = write_metrics_jsonl(
        mp, art.metrics,
        extra=[{"name": "run.config", "kind": "meta",
                "bench": "engine_serving.observability",
                "scheduler": "chunked", "kv_cache": "paged"}])
    # the tracer must never have dropped a span/tick on this workload —
    # same zero the CI step re-asserts on the shipped artifact
    assert_zero(mp, "obs.spans_dropped")
    assert_zero(mp, "obs.ticks_dropped")
    payload["artifacts"] = {"trace": tp, "trace_events": n_ev,
                            "trace_valid": validate_trace_file(tp),
                            "metrics": mp, "metric_rows": n_m,
                            "metrics_valid": validate_metrics_file(mp),
                            "requests": len(art.done),
                            "trace_summary": art.tracer.summary()}

    payload["burn_smoke"] = _burn_rate_smoke()

    fl = payload["dispatch_floor"]
    floor_note = " ".join(
        f"{k}:off_device={d['dispatch_frac'] + d['host_sync_frac']:.2f}"
        f"(n={d['n_sampled']})" for k, d in sorted(fl.items())) or "no samples"
    rows = [
        ("obs_disabled_hook_frac", frac * 1e6,
         f"hook={per_hook_s * 1e9:.0f}ns x{hooks_per_tick}/tick "
         f"= {frac:.4f} of a {base_ms:.2f}ms tick (gate<=0.02)"),
        ("obs_metrics_tick_ratio", payload["metrics_over_disabled"] * 1e6,
         f"metrics/disabled={payload['metrics_over_disabled']:.3f}"),
        ("obs_traced_tick_ratio", payload["traced_over_disabled"] * 1e6,
         f"traced/disabled={payload['traced_over_disabled']:.3f} "
         f"({n_ev} events exported)"),
        ("obs_windowed_tick_ratio", payload["windowed_over_disabled"] * 1e6,
         f"windowed/disabled={payload['windowed_over_disabled']:.3f}"),
        ("obs_profiled_tick_ratio", payload["profiled_over_traced"] * 1e6,
         f"profiled/traced={payload['profiled_over_traced']:.3f} "
         f"(sampling every tick; gate<={payload['sampling_gate']})"),
        ("obs_dispatch_floor", 0.0, floor_note),
        ("obs_burn_smoke", payload["burn_smoke"]["alerts_fired"] * 1e6,
         f"alerts={payload['burn_smoke']['alerts_fired']} "
         f"resolves={payload['burn_smoke']['burn_resolves']} "
         f"flight={os.path.basename(payload['burn_smoke']['flight_dump'])}"),
    ]
    return rows, payload


def _burn_rate_smoke() -> Dict:
    """End-to-end online-reaction smoke on the REAL engine, wall clock:
    a fabric-backed engine serves a closed loop, a ``replica_slowdown``
    fault stretches decode mid-run, the SLO burn-rate monitor sees both
    the fast and the slow window breach, and the alert's ``FlightTrigger``
    sink dumps a flight recording. Hard-asserts (CI gates, via run.py's
    nonzero exit): the alert fires, the dump exists and schema-validates,
    and the tracer dropped nothing. The controller-re-solve-on-alert path
    is covered by tests/test_obs_online.py on the virtual clock."""
    from repro.cluster import make_nodes
    from repro.cluster.faults import replica_slowdown
    from repro.obs import (BurnRateRule, CollectingSink, FlightRecorder,
                           FlightTrigger, Observability, SLOMonitor)
    from repro.obs.export import validate_trace_file
    from repro.serving.api import Request
    from repro.serving.driver import ElapsedClock
    from repro.serving.engine import InProcessServingEngine

    os.makedirs("reports", exist_ok=True)
    for old in os.listdir("reports"):        # fresh dumps for this run
        if old.startswith("FLIGHT_"):
            os.remove(os.path.join("reports", old))
    fr = FlightRecorder(out_dir="reports", min_interval_s=0.0)
    obs = Observability(trace=True, windows=True, flight=fr)
    clk = ElapsedClock()
    eng = InProcessServingEngine(
        _paged_variant(), max_batch=8, prompt_len=32, max_new=8,
        decode_chunk=4, queue_cap=100_000, kv_cache="paged", kv_page_size=8,
        nodes=make_nodes(1, 2), replica_size=1, obs=obs, clock=clk)
    eng.apply_allocation(0.0, {"bench-paged-2L": 1})
    rng = np.random.default_rng(7)
    rid = [0]

    def pump(seconds: float, slo_ms: float, monitor=None) -> None:
        t_end = clk() + seconds
        while clk() < t_end:
            while eng.backlog(clk()) + eng.in_flight() < 4:
                eng.submit(Request(rid=rid[0],
                                   tokens=rng.integers(0, VOCAB, 32),
                                   max_new=8, arrival=clk(), slo_ms=slo_ms),
                           None)
                rid[0] += 1
            eng.step(clk())
            if monitor is not None:
                monitor.check(clk())

    pump(1.0, slo_ms=1e9)              # warm + calibrate on a non-SLO class
    lats = [r.latency_ms for r in eng.done if r.service_start > 0]
    slo_ms = float(max(np.percentile(lats, 50) * 4.0, 50.0))
    sink = CollectingSink()
    monitor = SLOMonitor(obs.windows, budget=0.05,
                         rules=(BurnRateRule(fast_s=0.5, slow_s=1.5,
                                             threshold=2.0),),
                         sinks=(sink, FlightTrigger(fr)),
                         cooldown_s=30.0, min_requests=3)
    pump(0.8, slo_ms=slo_ms, monitor=monitor)        # healthy phase
    healthy_alerts = len(monitor.alerts)
    rep = next(iter(eng.fabric.replicas))            # degrade every replica
    eng.inject_fault(clk(), replica_slowdown(clk(), rep, 30.0))
    pump(2.5, slo_ms=slo_ms, monitor=monitor)        # burning phase
    assert len(monitor.alerts) > healthy_alerts, \
        f"burn-rate alert did not fire (slo_ms={slo_ms:.0f}, " \
        f"{len(eng.done)} done)"
    burn_dumps = [p for p in fr.dumps
                  if os.path.basename(p).startswith("FLIGHT_burn_rate")]
    assert burn_dumps, f"no burn-rate flight dump (dumps={fr.dumps})"
    n_ev = validate_trace_file(burn_dumps[-1])
    spans_dropped = obs.metrics.counter("obs.spans_dropped").value
    ticks_dropped = obs.metrics.counter("obs.ticks_dropped").value
    assert spans_dropped == 0 and ticks_dropped == 0, \
        f"tracer dropped spans={spans_dropped} ticks={ticks_dropped}"
    return {"slo_ms": slo_ms, "alerts_fired": len(monitor.alerts),
            "burn_resolves": 0,   # controller path covered in tests
            "flight_dump": burn_dumps[-1], "flight_events": n_ev,
            "spans_dropped": float(spans_dropped),
            "ticks_dropped": float(ticks_dropped),
            "n_requests": len(eng.done)}


def _async_engine(async_tick: bool, **kw):
    from repro.serving.engine import InProcessServingEngine
    eng = InProcessServingEngine(
        _paged_variant(), max_batch=AS_BATCH, prompt_len=AS_PROMPT,
        max_new=AS_MAX_NEW, decode_chunk=1, queue_cap=100_000,
        async_tick=async_tick, **kw)
    eng.apply_allocation(0.0, {"bench-paged-2L": 1})
    return eng


def _closed_loop_alone(eng, k: int, n_steps: int, seed: int) -> Dict:
    """One engine, alone on the machine, through a k-in-flight closed loop.

    The paged-vs-dense studies interleave ticks across engines so drift
    cancels out of their *ratios* — but interleaving is exactly wrong here:
    engine A's tick would execute under engine B's in-flight device work,
    contaminating the overlap being measured. Sync/async drift control
    comes from alternating whole repetitions instead (see async_overlap)."""
    from repro.serving.api import Request
    rng = np.random.default_rng(seed)
    rid, ticks = [0], []

    def top_up():
        while eng.backlog(0.0) + eng.in_flight() < k:
            eng.submit(Request(rid=rid[0],
                               tokens=rng.integers(0, VOCAB, AS_PROMPT),
                               max_new=AS_MAX_NEW, arrival=time.time()), None)
            rid[0] += 1

    top_up()
    for _ in range(6):                    # settle: prefill + pipeline primed
        eng.step(0.0)
        top_up()
    gc.disable()
    try:
        for _ in range(n_steps):
            t1 = time.perf_counter()
            eng.step(0.0)
            ticks.append((time.perf_counter() - t1) * 1000.0)
            top_up()
    finally:
        gc.enable()
    eng.drain(0.0)
    arr = np.asarray(ticks)
    return {"mean_step_ms": float(arr.mean()),
            "p50_step_ms": float(np.percentile(arr, 50)),
            "p99_step_ms": float(np.percentile(arr, 99))}


def _async_parity() -> Dict:
    """Hard gate: async and sync greedy outputs are bitwise identical on
    the same staggered workload (chunked scheduler, paged KV, mixed
    lengths — the hairiest commit-lag path). tests/test_async_engine.py
    covers the full matrix; this keeps the bench self-validating."""
    from repro.serving.api import Request
    outs = {}
    for async_tick in (False, True):
        eng = _async_engine(async_tick, kv_cache="paged", kv_page_size=8,
                            scheduler="chunked")
        rng = np.random.default_rng(31)
        reqs = [(rng.integers(0, VOCAB, AS_PROMPT),
                 int(rng.integers(4, AS_MAX_NEW))) for _ in range(12)]
        for i, (p, n) in enumerate(reqs):   # staggered: one submit per tick
            eng.submit(Request(rid=i, tokens=p, max_new=n, arrival=0.0), None)
            eng.step(0.0)
        eng.drain(0.0)
        outs[async_tick] = {r.rid: np.asarray(r.output) for r in eng.done}
    assert set(outs[True]) == set(outs[False]), "done-sets differ"
    for rid in outs[False]:
        assert np.array_equal(outs[True][rid], outs[False][rid]), \
            f"async output diverged from sync for rid={rid}"
    return {"n_requests": len(outs[False]), "bitwise_equal": True}


def async_overlap() -> Tuple[List[Tuple[str, float, str]], Dict]:
    """The §Async tick loop study: sync vs two-phase dispatch/commit step
    time at a geometry where the host share of a tick is large
    (decode_chunk=1, short context — every tick pays dispatch + D2H +
    bookkeeping against a small device kernel).

    Measurement: AS_REPS alternating sync/async repetitions (A/B/A/B...),
    each engine alone on the machine for its repetition (interleaving
    ticks would run one engine's host work under the other's in-flight
    exec — see ``_closed_loop_alone``); per-mode step time is the median
    of repetition means. **Gate** (run.py exits nonzero on assert): on
    multi-core hosts — CI runners — async mean step must be ≤ 0.90x sync;
    on a single-core host dispatch/commit overlap cannot buy wall time
    (host and device share the core), so the gate degrades to a ≤ 1.15x
    no-regression sanity bound and the payload carries
    ``"single_core": true`` so the report can say which bound applied.

    Attribution for the EXPERIMENTS.md dispatch-floor table: the sync
    baseline's off-device fraction comes from the fenced profiler
    (dispatch + host-sync share of exec); the async column's *exposed*
    off-device fraction is ``commit_wait_ms`` (time actually blocked on
    the un-synced token array) over mean step — every other host phase
    runs with an exec structurally in flight (= ``hidden_host_ms``).

    Also exports the async traced artifacts (TRACE_engine_async.json,
    METRICS_engine_async.jsonl) that CI schema-validates with
    ``--assert-zero``, and the admit-phase mean after the
    ``jnp.pad``-on-device admission fix."""
    import math as _math

    from repro.obs import dispatch_floor_summary
    from repro.obs.export import (assert_zero, validate_metrics_file,
                                  validate_trace_file, write_chrome_trace,
                                  write_metrics_jsonl)
    from repro.serving.api import Request

    cores = len(os.sched_getaffinity(0))
    single_core = cores < 2
    payload: Dict = {
        "config": {"prompt_len": AS_PROMPT, "max_new": AS_MAX_NEW,
                   "max_batch": AS_BATCH, "decode_chunk": 1,
                   "n_steps": AS_STEPS, "reps": AS_REPS, "vocab": VOCAB,
                   "layers": 2, "d_model": 64},
        "cores": cores, "single_core": single_core,
        "parity": _async_parity(),
    }

    # one engine per mode, reused across repetitions (shared jit cache);
    # drained between reps so every repetition starts from an empty batch
    engines = {"sync": _async_engine(False), "async": _async_engine(True)}
    reps: Dict[str, List[Dict]] = {"sync": [], "async": []}
    for rep in range(AS_REPS):
        for mode in ("sync", "async"):    # alternate: drift hits both
            reps[mode].append(_closed_loop_alone(
                engines[mode], k=AS_BATCH, n_steps=AS_STEPS, seed=100 + rep))
    payload["reps"] = reps
    med = {mode: float(np.median([r["mean_step_ms"] for r in rs]))
           for mode, rs in reps.items()}
    ratio = med["async"] / max(med["sync"], 1e-9)
    gate = 1.15 if single_core else 0.90
    payload.update({"sync": {"mean_step_ms": med["sync"]},
                    "async": {"mean_step_ms": med["async"]},
                    "step_ratio": ratio, "gate": gate})
    assert ratio <= gate, (
        f"async/sync step ratio {ratio:.3f} over gate {gate} "
        f"({cores} core(s); async={med['async']:.3f}ms "
        f"sync={med['sync']:.3f}ms)")

    # --- attribution runs: fenced sync baseline + traced async commit ---
    def attributed(async_tick: bool) -> Dict:
        kw = dict(trace=True) if async_tick else dict(trace=True,
                                                      profile_dispatch=1)
        eng = _async_engine(async_tick, **kw)
        rng = np.random.default_rng(9)
        for i in range(AS_BATCH):
            eng.submit(Request(rid=i, tokens=rng.integers(0, VOCAB, AS_PROMPT),
                               max_new=AS_MAX_NEW, arrival=0.0), None)
        for _ in range(AS_MAX_NEW + 8):
            eng.step(0.0)
        eng.drain(0.0)
        recs = list(eng.tracer.ticks)
        admit = [r.admit_ms for r in recs if _math.isfinite(r.admit_ms)]
        out = {"dispatch_floor": dispatch_floor_summary(recs),
               "admit_ms_mean": float(np.mean(admit)) if admit else 0.0}
        if async_tick:
            com = [r for r in recs if _math.isfinite(r.commit_ms)]
            out["commit"] = {
                "n_ticks": len(com),
                "commit_ms_mean": float(np.mean([r.commit_ms for r in com])),
                "commit_wait_ms_mean":
                    float(np.mean([r.commit_wait_ms for r in com])),
                "commit_gap_ms_mean":
                    float(np.mean([r.commit_gap_ms for r in com])),
                "hidden_host_ms_mean":
                    float(np.mean([r.hidden_host_ms for r in com])),
            }
            out["engine"] = eng               # reused for artifact export
        return out

    sync_attr = attributed(False)
    async_attr = attributed(True)
    art_eng = async_attr.pop("engine")
    payload["sync"]["dispatch_floor"] = sync_attr["dispatch_floor"]
    payload["sync"]["admit_ms_mean"] = sync_attr["admit_ms_mean"]
    payload["async"].update(
        {k: v for k, v in async_attr.items() if k != "dispatch_floor"})
    # admit-phase cost: async ticks fall back to chunked admission, so a
    # joiner costs one pipelined chunk dispatch instead of a blocking
    # monolithic prefill inside the tick
    payload["admit_ratio"] = (async_attr["admit_ms_mean"]
                              / max(sync_attr["admit_ms_mean"], 1e-9))

    # exposed off-device fraction per mode (the dispatch-floor table's
    # async column): sync exposes dispatch + host-sync every tick; async
    # exposes only the commit wait — the rest runs behind the in-flight
    # exec. decode rows only (the steady-state tick kind at this geometry).
    dd = sync_attr["dispatch_floor"].get("decode", {})
    sync_off = dd.get("dispatch_frac", 0.0) + dd.get("host_sync_frac", 0.0)
    async_off = (async_attr["commit"]["commit_wait_ms_mean"]
                 / max(med["async"], 1e-9))
    payload["off_device_frac"] = {"sync": sync_off, "async": async_off}
    assert async_off < sync_off, (
        f"async exposed off-device fraction {async_off:.3f} not below "
        f"sync baseline {sync_off:.3f}")

    # --- async traced artifacts for the CI --assert-zero validation ---
    os.makedirs("reports", exist_ok=True)
    tp = os.path.join("reports", "TRACE_engine_async.json")
    mp = os.path.join("reports", "METRICS_engine_async.jsonl")
    n_ev = write_chrome_trace(tp, art_eng.tracer, label="bench_async")
    n_m = write_metrics_jsonl(
        mp, art_eng.metrics,
        extra=[{"name": "run.config", "kind": "meta",
                "bench": "async_overlap", "async_tick": True}])
    assert_zero(mp, "obs.spans_dropped")
    assert_zero(mp, "obs.ticks_dropped")
    payload["artifacts"] = {"trace": tp, "trace_events": n_ev,
                            "trace_valid": validate_trace_file(tp),
                            "metrics": mp, "metric_rows": n_m,
                            "metrics_valid": validate_metrics_file(mp)}

    hid = async_attr["commit"]["hidden_host_ms_mean"]
    rows = [
        ("async_step_ratio", ratio * 1e6,
         f"async/sync={ratio:.3f} (gate<={gate}, {cores} core(s)) "
         f"async={med['async']:.3f}ms sync={med['sync']:.3f}ms"),
        ("async_off_device", async_off * 1e6,
         f"exposed off-device async={async_off:.3f} vs sync={sync_off:.3f} "
         f"(hidden_host={hid:.3f}ms/tick)"),
        ("async_parity", payload["parity"]["n_requests"] * 1e6,
         f"bitwise-equal outputs on {payload['parity']['n_requests']} "
         f"staggered chunked+paged requests"),
        ("async_admit", async_attr["admit_ms_mean"] * 1e3,
         f"chunked-admission admit={async_attr['admit_ms_mean']:.3f}ms vs "
         f"sync monolithic {sync_attr['admit_ms_mean']:.3f}ms "
         f"(x{payload['admit_ratio']:.2f})"),
    ]
    return rows, payload


def _spec_variants() -> Dict:
    """3-layer verifier + two drafters: its weight-sharing twin and a
    2-layer ladder rung (same init seed, so the shared-depth weights
    coincide — the realistic correlated-but-not-identical case)."""
    from repro.configs import get_config, smoke_variant
    base = smoke_variant(get_config("tinyllama-1.1b")).replace(
        d_model=64, d_ff=128, vocab_size=VOCAB)
    target = base.replace(num_layers=3, name="bench-spec-3L")
    return {"bench-spec-3L": (target, 75.0),
            "bench-spec-twin": (target.replace(name="bench-spec-twin"), 60.0),
            "bench-spec-2L": (base.replace(num_layers=2,
                                           name="bench-spec-2L"), 70.0)}


def _spec_run(speculative) -> Tuple[Dict, int, Dict]:
    """Serve SP_N requests to completion; returns (outputs by rid, tick
    count, engine) under the virtual clock."""
    from repro.serving.api import Request
    from repro.serving.engine import InProcessServingEngine
    kw = dict(speculative=speculative, spec_k=SP_K) if speculative else {}
    eng = InProcessServingEngine(
        _spec_variants(), max_batch=SP_BATCH, prompt_len=SP_PROMPT,
        max_new=SP_MAX_NEW, decode_chunk=1, queue_cap=100_000,
        kv_cache="paged", kv_page_size=SP_PAGE, clock=lambda: 0.0, **kw)
    eng.apply_allocation(0.0, {"bench-spec-3L": 1})
    rng = np.random.default_rng(17)
    for i in range(SP_N):
        eng.submit(Request(rid=i, tokens=rng.integers(0, VOCAB, SP_PROMPT),
                           max_new=SP_MAX_NEW, arrival=0.0), None)
    ticks = 0
    while len(eng.done) < SP_N:
        eng.step(0.0)
        ticks += 1
        assert ticks < 10_000, "spec bench failed to converge"
    return {r.rid: np.asarray(r.output) for r in eng.done}, ticks, eng


def _spec_leak_check(eng) -> Dict:
    """Pool balance after drain: every rollback returned its pages — on
    the verifier pool AND the hidden drafter mirror's pool."""
    pools = {"verifier": eng.backends["bench-spec-3L"].pool}
    pair = eng.backends["bench-spec-3L"]._spec_pair
    if pair is not None:
        pools["drafter"] = pair.d.pool
    out = {}
    for name, pool in pools.items():
        assert pool.used_pages == 0, \
            f"{name} pool leaked {pool.used_pages} pages after drain"
        out[f"{name}_used_pages"] = int(pool.used_pages)
        out[f"{name}_retained_pages"] = int(pool.retained_pages)
    return out


def spec_decode() -> Tuple[List[Tuple[str, float, str]], Dict]:
    """The §Speculative decoding study: draft-k/verify-once on the variant
    ladder vs target-only decoding, paged KV, virtual clock.

    **Gates** (run.py exits nonzero on assert): the correlated arm's
    outputs are bitwise identical to target-only, its acceptance rate is
    >= SP_ACCEPT_GATE, mean accepted tokens per verifier step is
    >= SP_TPS_GATE, and no pool page leaks after drain (verifier or
    drafter mirror). The ladder arm (2L drafter under the 3L verifier)
    reports the same stats ungated — parity still must hold there, since
    greedy acceptance guarantees it for ANY drafter."""
    ref, ref_ticks, _ = _spec_run(None)

    payload: Dict = {"config": {
        "prompt_len": SP_PROMPT, "max_new": SP_MAX_NEW,
        "max_batch": SP_BATCH, "k": SP_K, "n_requests": SP_N,
        "kv": "paged", "page_size": SP_PAGE,
        "accept_gate": SP_ACCEPT_GATE, "tps_gate": SP_TPS_GATE},
        "target": {"ticks": ref_ticks}}
    rows: List[Tuple[str, float, str]] = []
    for arm, drafter in (("correlated", "bench-spec-twin"),
                         ("ladder", "bench-spec-2L")):
        out, ticks, eng = _spec_run(f"{drafter}:bench-spec-3L")
        for rid in ref:                       # parity holds for ANY drafter
            assert np.array_equal(ref[rid], out[rid]), \
                f"{arm} spec output diverged from target-only (rid={rid})"
        pair = eng.backends["bench-spec-3L"]._spec_pair
        stats = pair.acceptance_stats()
        cell = dict(stats)
        cell["ticks"] = ticks
        cell["tick_ratio"] = ticks / max(ref_ticks, 1)
        cell["parity"] = True
        cell["leaks"] = _spec_leak_check(eng)
        payload[arm] = cell
        rows.append((
            f"spec_{arm}_tps", stats["tokens_per_step"] * 1e6,
            f"accept={stats['accept_rate']:.3f} "
            f"tokens/step={stats['tokens_per_step']:.2f} "
            f"ticks={ticks} vs target {ref_ticks} "
            f"(x{cell['tick_ratio']:.2f})"))
    acc = payload["correlated"]["accept_rate"]
    tps = payload["correlated"]["tokens_per_step"]
    assert acc >= SP_ACCEPT_GATE, \
        f"correlated acceptance {acc:.3f} under gate {SP_ACCEPT_GATE}"
    assert tps >= SP_TPS_GATE, \
        f"correlated tokens/verifier-step {tps:.2f} under gate {SP_TPS_GATE}"
    return rows, payload


def run_spec_decode() -> List[Tuple[str, float, str]]:
    """Standalone entry (``--only spec_decode``): merges its payload into
    BENCH_engine.json under ``"spec_decode"`` (read-modify-write — the
    ``engine_serving`` study owns the rest of the file)."""
    rows, payload = spec_decode()
    data: Dict = {}
    if os.path.exists(BENCH_JSON):
        with open(BENCH_JSON) as f:
            data = json.load(f)
    data["spec_decode"] = payload
    os.makedirs(os.path.dirname(BENCH_JSON), exist_ok=True)
    with open(BENCH_JSON, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
    return rows


def run_async_overlap() -> List[Tuple[str, float, str]]:
    """Standalone entry (``--only async_overlap``): merges its payload into
    BENCH_engine.json under ``"async_overlap"`` — read-modify-write, since
    the ``engine_serving`` study owns (and rewrites) the rest of the file."""
    rows, payload = async_overlap()
    data: Dict = {}
    if os.path.exists(BENCH_JSON):
        with open(BENCH_JSON) as f:
            data = json.load(f)
    data["async_overlap"] = payload
    os.makedirs(os.path.dirname(BENCH_JSON), exist_ok=True)
    with open(BENCH_JSON, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
    return rows


def run() -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []
    for rate in RATES_RPS:
        rng = np.random.default_rng(int(rate))
        gaps = rng.exponential(1.0 / rate, size=int(rate * DURATION_S))
        arrivals = np.cumsum(gaps)
        p99 = {}
        for mode in ("pump", "continuous"):
            s = _replay(mode, arrivals, seed=int(rate))
            p99[mode] = s["p99_ms"]
            rows.append((
                f"{mode}_r{int(rate)}", s["p99_ms"] * 1000.0,
                f"thr={s['throughput_rps']:.1f}rps "
                f"mean={s['mean_latency_ms']:.0f}ms n={s['n_requests']}"))
        # us column carries the absolute P99 gap; the ratio rides in derived
        rows.append((f"p99_ratio_r{int(rate)}",
                     (p99["continuous"] - p99["pump"]) * 1000.0,
                     f"continuous/pump={p99['continuous'] / max(p99['pump'], 1e-9):.3f}"))

    paged_rows, payload = paged_vs_dense()
    rows.extend(paged_rows)
    sharing_rows, sharing_cell = prefix_sharing()
    rows.extend(sharing_rows)
    payload["prefix_sharing"] = sharing_cell
    obs_rows, obs_cell = observability()
    rows.extend(obs_rows)
    payload["observability"] = obs_cell
    os.makedirs(os.path.dirname(BENCH_JSON), exist_ok=True)
    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
