"""Profile-noise robustness (beyond paper): the paper's solver trusts its
linear-regression profiles; how much accuracy/SLO headroom is lost when the
profiled throughputs are off by ±sigma? The solver plans on noisy profiles;
the simulator executes on the true ones."""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core.adapter import ControllerConfig, InfAdapterController
from repro.core.forecaster import MovingMaxForecaster
from repro.core.profiles import paper_resnet_profiles
from repro.data.traces import paper_nonbursty_trace
from repro.sim.runner import run_experiment

Row = Tuple[str, float, str]
REF = 78.31


def run() -> List[Row]:
    rows: List[Row] = []
    true_profiles = paper_resnet_profiles(noise=0.0)
    trace = paper_nonbursty_trace(seconds=600)
    for sigma in (0.0, 0.05, 0.15, 0.30):
        planned = paper_resnet_profiles(noise=sigma, seed=7)
        cfg = ControllerConfig(budget=20, beta=0.05, gamma=0.2)
        ctrl = InfAdapterController(planned, MovingMaxForecaster(), cfg)
        t0 = time.time()
        # the CLUSTER uses the true profiles; the CONTROLLER plans on noisy
        r = run_experiment(f"sigma{sigma}", ctrl, true_profiles, trace,
                           warm_start={"resnet18": 8}, reference_accuracy=REF)
        us = (time.time() - t0) * 1e6
        s = r.summary
        rows.append((f"sigma{sigma}", us,
                     f"viol={s['violation_rate']:.3f} "
                     f"loss={s['accuracy_loss']:.2f}% "
                     f"cost={s['avg_cost_units']:.1f}"))
    return rows
