"""Generate the §Dry-run, §Roofline, §Profiles, §Cluster-fabric, and
§Paged-KV markdown tables in EXPERIMENTS.md from reports/dryrun/*.json,
reports/profiles/*.json, reports/cluster/*.json, and
reports/BENCH_engine.json (the latter two written by
``benchmarks/bench_cluster.py`` / ``benchmarks/bench_engine.py``).

Usage: PYTHONPATH=src python -m repro.analysis.report [--dir reports/dryrun]
           [--profiles-dir reports/profiles] [--cluster-dir reports/cluster]
           [--bench-engine reports/BENCH_engine.json]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirname):
    rows = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(f) as fh:
            rows.append(json.load(fh))
    return rows


def dryrun_table(rows) -> str:
    out = ["| arch | shape | 16×16 | 2×16×16 | HBM-est/dev | fallbacks |",
           "|---|---|---|---|---|---|"]
    by_key = {}
    for d in rows:
        if d.get("skipped"):
            by_key.setdefault((d["arch"], d["shape"]), {})["skip"] = d["reason"]
            continue
        if "error" in d:
            by_key.setdefault((d["arch"], d["shape"]), {})[d.get("mesh", "?")] = "ERROR"
            continue
        by_key.setdefault((d["arch"], d["shape"]), {})[d["mesh"]] = d
    for (arch, shape), entry in sorted(by_key.items()):
        if "skip" in entry:
            out.append(f"| {arch} | {shape} | SKIP | SKIP | — | "
                       f"{entry['skip'][:60]}… |")
            continue
        d1 = entry.get("16x16")
        d2 = entry.get("2x16x16")
        def cell(d):
            if d is None:
                return "—"
            if d == "ERROR":
                return "FAIL"
            return f"✓ {d['compile_s']:.0f}s"
        hbm = (f"{d1['hbm_estimate_bytes']/1e9:.1f} GB "
               f"({'fits' if d1.get('fits_v5e_16gb') else 'needs μbatch'})"
               if isinstance(d1, dict) else "—")
        fb = len(d1.get("sharding_fallbacks", [])) if isinstance(d1, dict) else 0
        out.append(f"| {arch} | {shape} | {cell(d1)} | {cell(d2)} | {hbm} | "
                   f"{fb} |")
    return "\n".join(out)


def roofline_table(rows) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | dominant | "
           "useful | note |",
           "|---|---|---|---|---|---|---|---|"]
    for d in sorted(rows, key=lambda d: (d.get("arch", ""), d.get("shape", ""))):
        if d.get("skipped") or "error" in d or d.get("mesh") != "16x16":
            continue
        note = (d.get("notes") or "")[:48]
        out.append(
            f"| {d['arch']} | {d['shape']} | {d['compute_s']:.3f} | "
            f"{d['memory_s']:.3f} | {d['collective_s']:.3f} | "
            f"**{d['dominant']}** | {d['usefulness']:.2f} | {note} |")
    return "\n".join(out)


def profiles_table(profiles_dir: str) -> str:
    """One row per stored variant profile across every store JSON in the
    directory: provenance, fitted curves, confidence — the §Profiles audit
    table (which numbers the solver is trusting, and why)."""
    out = ["| store | variant | provenance | th(n) rps | R² | p(n) ms | "
           "rt s | acc |",
           "|---|---|---|---|---|---|---|---|"]
    from repro.profiling.store import ProfileStore
    for f in sorted(glob.glob(os.path.join(profiles_dir, "*.json"))):
        try:
            store = ProfileStore.load(f)
        except (ValueError, KeyError, json.JSONDecodeError):
            out.append(f"| {os.path.basename(f)} | — | UNREADABLE | | | | | |")
            continue
        for name in store.names():
            e = store.entry(name)
            p = e.profile
            r2 = f"{e.fit.r_squared:.3f}" if e.fit is not None else "—"
            out.append(
                f"| {os.path.basename(f)} | {name} | {e.provenance} | "
                f"{p.th_slope:.1f}·n{p.th_intercept:+.1f} | {r2} | "
                f"{p.lat_base_ms:.1f}+{p.lat_k_ms:.1f}/n | {p.rt:.2f} | "
                f"{p.accuracy:.1f} |")
    return "\n".join(out)


def _cluster_rows(cluster_dir: str, study: str):
    path = os.path.join(cluster_dir, f"{study}.json")
    if not os.path.exists(path):
        return []
    try:
        with open(path) as f:
            return json.load(f).get("rows", [])
    except (ValueError, json.JSONDecodeError):
        return []


def cluster_scaling_table(cluster_dir: str) -> str:
    """Replica scaling + routing policy (§Cluster fabric): throughput/P99 vs
    replica count at fixed load, and two-level vs WRR-only routing."""
    out = ["| study | config | offered rps | achieved rps | p99 ms | viol |",
           "|---|---|---|---|---|---|"]
    for d in _cluster_rows(cluster_dir, "replica_scaling"):
        out.append(f"| scaling | {d['replicas']}×{d['units_per_replica']}u "
                   f"| {d['offered_rps']:.0f} | {d['achieved_rps']:.1f} | "
                   f"{d['p99_ms']:.0f} | {d['violation_rate']:.3f} |")
    for d in _cluster_rows(cluster_dir, "routing_policy"):
        kind = "two-level" if d["two_level"] else "WRR-only"
        out.append(f"| routing | {d['router']} ({kind}) | "
                   f"{d['offered_rps']:.0f} | — | {d['p99_ms']:.0f} | "
                   f"{d['violation_rate']:.3f} |")
    return "\n".join(out)


def cluster_failure_table(cluster_dir: str) -> str:
    """Failure-recovery phases (§Cluster fabric): violation rate and P99
    before, during, and after a node crash, per scenario."""
    out = ["| scenario | phase | viol | p99 ms | n |",
           "|---|---|---|---|---|"]
    for d in _cluster_rows(cluster_dir, "failure_recovery"):
        out.append(f"| {d['scenario']} | {d['phase']} | "
                   f"{d['violation_rate']:.3f} | {d['p99_ms']:.0f} | "
                   f"{d['n']} |")
    return "\n".join(out)


def paged_engine_tables(bench_path: str):
    """§Paged KV cache: occupancy cells (P50/P99 step latency + throughput,
    dense vs paged) and the context-scaling sweep, from the machine-readable
    BENCH_engine.json the engine benchmark emits (also a CI artifact)."""
    occ = ["| occupancy | slots | dense p50/p99 ms | paged p50/p99 ms | "
           "p99 ratio | thr ratio |",
           "|---|---|---|---|---|---|"]
    ctx = ["| context tokens | dense step ms | paged step ms |",
           "|---|---|---|"]
    if not os.path.exists(bench_path):
        return "\n".join(occ), "\n".join(ctx)
    try:
        with open(bench_path) as f:
            data = json.load(f)
    except (ValueError, json.JSONDecodeError):
        return "\n".join(occ), "\n".join(ctx)
    for c in data.get("occupancy", []):
        d, p = c["dense"], c["paged"]
        occ.append(f"| {c['occupancy']:.0%} | {c['slots']} | "
                   f"{d['p50_step_ms']:.1f}/{d['p99_step_ms']:.1f} | "
                   f"{p['p50_step_ms']:.1f}/{p['p99_step_ms']:.1f} | "
                   f"**{c['p99_ratio']:.2f}** | {c['throughput_ratio']:.2f} |")
    ml = data.get("mixed_load", {})
    if "dense" in ml and "paged" in ml:
        occ.append(f"| mixed load | {data['config']['max_batch']} | "
                   f"thr {ml['dense']['throughput_rps']:.1f} rps | "
                   f"thr {ml['paged']['throughput_rps']:.1f} rps | — | "
                   f"**{ml['throughput_ratio']:.2f}** |")
    cs = data.get("context_scaling", {})
    dense_pts = {r["context_tokens"]: r["mean_step_ms"]
                 for r in cs.get("dense", [])}
    paged_pts = {r["context_tokens"]: r["mean_step_ms"]
                 for r in cs.get("paged", [])}
    for c in sorted(set(dense_pts) | set(paged_pts)):
        dv = f"{dense_pts[c]:.1f}" if c in dense_pts else "—"
        pv = f"{paged_pts[c]:.1f}" if c in paged_pts else "—"
        ctx.append(f"| {c} | {dv} | {pv} |")
    return "\n".join(occ), "\n".join(ctx)


def prefix_sharing_table(bench_path: str) -> str:
    """§Prefix sharing: sharing-off vs sharing-on on the shared-prefix
    workload — prefill tokens actually computed, fresh pages allocated vs
    the worst-case (refcount-free) footprint, and the index hit rate —
    from the ``prefix_sharing`` cell of BENCH_engine.json."""
    out = ["| metric | sharing off | sharing on | ratio |",
           "|---|---|---|---|"]
    if not os.path.exists(bench_path):
        return "\n".join(out)
    try:
        with open(bench_path) as f:
            data = json.load(f)
    except (ValueError, json.JSONDecodeError):
        return "\n".join(out)
    c = data.get("prefix_sharing")
    if not c:
        return "\n".join(out)
    off, on = c["off"], c["on"]
    out.append(f"| prefill tokens | {off['prefill_tokens']} | "
               f"{on['prefill_tokens']} | "
               f"**{c['prefill_token_reduction']:.2f}×** (gate ≥2) |")
    out.append(f"| fresh pages allocated | {off['fresh_pages_allocated']} | "
               f"{on['fresh_pages_allocated']} | "
               f"{c['capacity_uplift']:.2f}× fewer |")
    out.append(f"| prefix hit rate | — | "
               f"{on['prefix_hits']}/{on['prefix_lookups']} = "
               f"**{on['prefix_hit_rate']:.2f}** (gate ≥0.8) | — |")
    out.append(f"| makespan s | {off['makespan_s']:.2f} | "
               f"{on['makespan_s']:.2f} | "
               f"{off['makespan_s'] / max(on['makespan_s'], 1e-9):.2f}× |")
    return "\n".join(out)


def scheduler_table(bench_path: str) -> str:
    """§Scheduling: per-policy goodput / P99 / short-class P99 / throughput
    on the bimodal prompt-length workload at fixed allocation, plus the
    chunked-vs-FIFO acceptance ratios, from BENCH_scheduler.json (written
    by ``benchmarks/bench_scheduler.py``, a CI artifact)."""
    out = ["| policy | goodput | p99 ms | short p99 ms | queue p99 ms | "
           "thr rps |",
           "|---|---|---|---|---|---|"]
    if not os.path.exists(bench_path):
        return "\n".join(out)
    try:
        with open(bench_path) as f:
            data = json.load(f)
    except (ValueError, json.JSONDecodeError):
        return "\n".join(out)
    for name, d in data.get("policies", {}).items():
        out.append(f"| {name} | {d['goodput']:.3f} | {d['p99_ms']:.0f} | "
                   f"{d['short_p99_ms']:.0f} | {d['p99_queue_ms']:.0f} | "
                   f"{d['throughput_rps']:.1f} |")
    rr = data.get("ratios", {})
    if rr:
        out.append(f"| **chunked / fifo** | "
                   f"**{rr['goodput_ratio']:.2f}×** (gate ≥1.1) | "
                   f"**{rr['p99_ratio']:.2f}×** (gate ≤0.8) | "
                   f"{rr['short_p99_ratio']:.2f}× | — | — |")
    return "\n".join(out)


def observability_table(bench_path: str) -> str:
    """§Observability: per-tick cost at each instrumentation level
    (disabled / metrics-only / traced), the no-op-hook overhead gate, and
    the exported artifact inventory — from the ``observability`` cell of
    BENCH_engine.json."""
    out = ["| level | mean tick ms | p99 tick ms | ratio |",
           "|---|---|---|---|"]
    if not os.path.exists(bench_path):
        return "\n".join(out)
    try:
        with open(bench_path) as f:
            data = json.load(f)
    except (ValueError, json.JSONDecodeError):
        return "\n".join(out)
    c = data.get("observability")
    if not c:
        return "\n".join(out)
    ticks = c.get("ticks", {})
    ratios = {"disabled": (1.0, "—"),
              "metrics": (c.get("metrics_over_disabled"), "vs disabled"),
              "traced": (c.get("traced_over_disabled"), "vs disabled"),
              "windowed": (c.get("windowed_over_disabled"), "vs disabled"),
              "profiled": (c.get("profiled_over_traced"), "vs traced")}
    for level in ("disabled", "metrics", "traced", "windowed", "profiled"):
        t = ticks.get(level)
        if not t:
            continue
        r, vs = ratios[level]
        rs = f"{r:.3f}× {vs}" if isinstance(r, (int, float)) else "—"
        out.append(f"| {level} | {t['mean_step_ms']:.2f} | "
                   f"{t['p99_step_ms']:.2f} | {rs} |")
    out.append(f"| no-op hook budget | "
               f"{c.get('noop_hook_ns', float('nan')):.0f} ns × "
               f"{c.get('hooks_per_tick_budget', 0)}/tick | — | "
               f"**{c.get('disabled_hook_frac', float('nan')):.4f}** "
               f"(gate ≤{c.get('gate_frac', 0.02)}) |")
    smoke = c.get("burn_smoke")
    if smoke:
        out.append(f"| burn-rate smoke | {smoke.get('alerts_fired', 0)} "
                   f"alerts | flight: "
                   f"{os.path.basename(smoke.get('flight_dump') or '—')} | "
                   f"drops {smoke.get('spans_dropped', 0):.0f}/"
                   f"{smoke.get('ticks_dropped', 0):.0f} |")
    art = c.get("artifacts", {})
    if art:
        out.append(f"| artifacts | {art.get('trace', '—')} "
                   f"({art.get('trace_events', 0)} events) | "
                   f"{art.get('metrics', '—')} "
                   f"({art.get('metric_rows', 0)} rows) | "
                   f"{art.get('requests', 0)} traced requests |")
    return "\n".join(out)


def spec_decode_table(bench_path: str) -> str:
    """§Speculative decoding: per-drafter-arm acceptance, accepted tokens
    per verifier step, and the virtual-clock tick count against target-only
    decoding — the ``spec_decode`` cell of BENCH_engine.json. Both arms are
    parity-gated (greedy acceptance makes speculative output bitwise equal
    to the verifier's own stream for ANY drafter); only the correlated
    arm's acceptance/speedup is a hard gate."""
    out = ["| drafter arm | accept rate | tokens/verifier step | "
           "ticks (vs target-only) | parity | pages leaked |",
           "|---|---|---|---|---|---|"]
    if not os.path.exists(bench_path):
        return "\n".join(out)
    try:
        with open(bench_path) as f:
            data = json.load(f)
    except (ValueError, json.JSONDecodeError):
        return "\n".join(out)
    c = data.get("spec_decode")
    if not c:
        return "\n".join(out)
    tgt = c.get("target", {}).get("ticks", 0)
    cfg = c.get("config", {})
    for arm in ("correlated", "ladder"):
        cell = c.get(arm)
        if not cell:
            continue
        leaks = cell.get("leaks", {})
        leaked = (leaks.get("verifier_used_pages", 0)
                  + leaks.get("drafter_used_pages", 0))
        out.append(
            f"| {arm} (k={cfg.get('k', '—')}) | "
            f"{cell.get('accept_rate', float('nan')):.3f} | "
            f"**{cell.get('tokens_per_step', float('nan')):.2f}** "
            f"(gate ≥{cfg.get('tps_gate', 1.5)}"
            f"{' on this arm' if arm == 'correlated' else ', ungated'}) | "
            f"{cell.get('ticks', 0)} vs {tgt} "
            f"(×{cell.get('tick_ratio', float('nan')):.2f}) | "
            f"{'bitwise' if cell.get('parity') else 'FAIL'} | {leaked} |")
    return "\n".join(out)


def dispatch_floor_table(bench_path: str) -> str:
    """§Dispatch floor: per-tick-type host/device split from the sampled
    (fenced) ticks — the ``dispatch_floor`` cell of BENCH_engine.json. The
    off-device fraction (dispatch + host-sync share of the exec phase) is
    the budget the async two-phase tick loop overlaps away; when the
    ``async_overlap`` study has run, a second table compares the sync
    baseline's exposed fraction against the async loop's (only the commit
    wait stays exposed — dispatch, bookkeeping, and the D2H read ride
    behind the in-flight exec; DESIGN.md §Async tick loop)."""
    out = ["| tick kind | n | dispatch ms mean/p50 | device ms mean/p50 | "
           "host-sync ms mean/p50 | exec ms | off-device frac |",
           "|---|---|---|---|---|---|---|"]
    if not os.path.exists(bench_path):
        return "\n".join(out)
    try:
        with open(bench_path) as f:
            data = json.load(f)
    except (ValueError, json.JSONDecodeError):
        return "\n".join(out)
    floor = (data.get("observability") or {}).get("dispatch_floor") or {}
    for kind, d in sorted(floor.items()):
        off = d["dispatch_frac"] + d["host_sync_frac"]
        out.append(
            f"| {kind} | {d['n_sampled']} | "
            f"{d['dispatch_ms_mean']:.2f}/{d['dispatch_ms_p50']:.2f} | "
            f"{d['device_ms_mean']:.2f}/{d['device_ms_p50']:.2f} | "
            f"{d['host_sync_ms_mean']:.2f}/{d['host_sync_ms_p50']:.2f} | "
            f"{d['exec_ms_mean']:.2f} | **{off:.2f}** |")
    ao = data.get("async_overlap") or {}
    if ao:
        com = (ao.get("async") or {}).get("commit") or {}
        offd = ao.get("off_device_frac") or {}
        gate_note = ("single-core host: no-regression bound"
                     if ao.get("single_core")
                     else f"multi-core gate <= {ao.get('gate', 0.9)}")
        out += ["",
                "Async two-phase tick loop vs sync at the overlap geometry "
                "(`async_overlap` study; decode ticks):",
                "",
                "| mode | mean step ms | exposed off-device frac | "
                "hidden host ms/tick | commit wait ms |",
                "|---|---|---|---|---|",
                f"| sync | {ao.get('sync', {}).get('mean_step_ms', 0):.3f} | "
                f"**{offd.get('sync', 0):.3f}** | — | — |",
                f"| async | {ao.get('async', {}).get('mean_step_ms', 0):.3f}"
                f" | **{offd.get('async', 0):.3f}** | "
                f"{com.get('hidden_host_ms_mean', 0):.3f} | "
                f"{com.get('commit_wait_ms_mean', 0):.3f} |",
                "",
                f"step ratio async/sync = {ao.get('step_ratio', 0):.3f} "
                f"({ao.get('cores', '?')} core(s); {gate_note}); greedy "
                f"outputs bitwise identical on "
                f"{(ao.get('parity') or {}).get('n_requests', 0)} requests."]
    return "\n".join(out)


def audit_table(audit_path: str, max_rows: int = 12) -> str:
    """§Observability: controller decisions with predicted vs measured
    latency/goodput and the regret per decision window — from the
    AUDIT_decisions.jsonl a traced driver run exports (empty table until
    one has been run)."""
    out = ["| t | reason | units | pred p99 / meas p99 ms | "
           "pred / meas goodput | p99 regret ms |",
           "|---|---|---|---|---|---|"]
    if not os.path.exists(audit_path):
        return "\n".join(out)
    rows = []
    try:
        with open(audit_path) as f:
            for line in f:
                line = line.strip()
                if line:
                    rows.append(json.loads(line))
    except (ValueError, json.JSONDecodeError):
        return "\n".join(out)
    for d in rows[:max_rows]:
        units = {m: n for m, n in d.get("outputs", {}).get("units", {}).items()
                 if n}
        pred = d.get("outputs", {}).get("predicted", {})
        meas = d.get("measured") or {}
        reg = d.get("regret") or {}
        ustr = ",".join(f"{m}:{n}" for m, n in sorted(units.items())) or "—"

        def num(v, fmt="{:.0f}"):
            return fmt.format(v) if isinstance(v, (int, float)) else "—"
        out.append(
            f"| {d['t']:.0f} | {d.get('reason', '?')} | {ustr} | "
            f"{num(pred.get('p99_ms'))} / {num(meas.get('p99_ms'))} | "
            f"{num(pred.get('goodput'), '{:.2f}')} / "
            f"{num(meas.get('goodput'), '{:.2f}')} | "
            f"{num(reg.get('p99_ms'), '{:+.0f}')} |")
    if len(rows) > max_rows:
        out.append(f"| … | {len(rows) - max_rows} more decisions "
                   f"in {audit_path} | | | | |")
    return "\n".join(out)


def inject(md_path: str, marker: str, table: str) -> None:
    with open(md_path) as f:
        text = f.read()
    begin = f"<!-- {marker} -->"
    end = f"<!-- /{marker} -->"
    block = f"{begin}\n{table}\n{end}"
    if begin in text and end in text:
        pre = text.split(begin)[0]
        post = text.split(end)[1]
        text = pre + block + post
    elif begin in text:
        text = text.replace(begin, block)
    with open(md_path, "w") as f:
        f.write(text)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="reports/dryrun")
    ap.add_argument("--profiles-dir", default="reports/profiles")
    ap.add_argument("--cluster-dir", default="reports/cluster")
    ap.add_argument("--bench-engine", default="reports/BENCH_engine.json")
    ap.add_argument("--bench-scheduler",
                    default="reports/BENCH_scheduler.json")
    ap.add_argument("--audit", default="reports/AUDIT_decisions.jsonl")
    ap.add_argument("--md", default="EXPERIMENTS.md")
    args = ap.parse_args()
    rows = load(args.dir)
    inject(args.md, "DRYRUN_TABLE", dryrun_table(rows))
    inject(args.md, "ROOFLINE_TABLE", roofline_table(rows))
    inject(args.md, "PROFILES_TABLE", profiles_table(args.profiles_dir))
    inject(args.md, "CLUSTER_SCALING_TABLE",
           cluster_scaling_table(args.cluster_dir))
    inject(args.md, "CLUSTER_FAILURE_TABLE",
           cluster_failure_table(args.cluster_dir))
    occ_tbl, ctx_tbl = paged_engine_tables(args.bench_engine)
    inject(args.md, "PAGED_ENGINE_TABLE", occ_tbl)
    inject(args.md, "PAGED_CONTEXT_TABLE", ctx_tbl)
    inject(args.md, "PREFIX_SHARING_TABLE",
           prefix_sharing_table(args.bench_engine))
    inject(args.md, "SCHEDULER_TABLE", scheduler_table(args.bench_scheduler))
    inject(args.md, "OBS_OVERHEAD_TABLE",
           observability_table(args.bench_engine))
    inject(args.md, "OBS_AUDIT_TABLE", audit_table(args.audit))
    inject(args.md, "DISPATCH_FLOOR_TABLE",
           dispatch_floor_table(args.bench_engine))
    inject(args.md, "SPEC_DECODE_TABLE",
           spec_decode_table(args.bench_engine))
    n_ok = sum(1 for d in rows if not d.get("skipped") and "error" not in d)
    n_skip = sum(1 for d in rows if d.get("skipped"))
    n_err = sum(1 for d in rows if "error" in d)
    print(f"tables written: ok={n_ok} skip={n_skip} err={n_err}")


if __name__ == "__main__":
    main()
