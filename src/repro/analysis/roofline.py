"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch × shape × mesh), all *per device* (XLA's cost model for
an SPMD module is per-device):

    compute_s    = HLO_FLOPs / peak_FLOP/s          (197 TFLOP/s bf16, v5e)
    memory_s     = HLO_bytes / HBM_bw               (819 GB/s)
    collective_s = collective_bytes / link_bw       (~50 GB/s/link ICI)

``collective_bytes`` is not in cost_analysis: we parse the compiled HLO and
sum the *result* sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (all-reduce counted twice: reduce+broadcast
phases each move the payload over the links in a ring schedule).

Also reported: MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference fwd) with
N = (active) params, D = tokens — and the usefulness ratio
MODEL_FLOPS / (HLO_FLOPs × chips), which catches remat/redundancy waste.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|(\w+\[[\d,]*\][^\s]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(typestr: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(typestr):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Tuple[float, Dict[str, float]]:
    """Sum result sizes of collective ops in (per-device) HLO text."""
    per_kind: Dict[str, float] = {}
    seen_done = set()
    for m in _COLL_RE.finditer(hlo_text):
        tuple_part, single, kind = m.groups()
        typestr = tuple_part if tuple_part else single
        nbytes = _shape_bytes(typestr)
        # async pairs (-start/-done) would double count; -done result equals
        # -start's: count the op once by keying on position text
        factor = 2.0 if kind == "all-reduce" else 1.0
        per_kind[kind] = per_kind.get(kind, 0.0) + nbytes * factor
    # subtract double-counted async -done ops: count ratio of starts/dones
    starts = len(re.findall(r"(all-reduce|all-gather|reduce-scatter|"
                            r"all-to-all|collective-permute)-start", hlo_text))
    dones = len(re.findall(r"(all-reduce|all-gather|reduce-scatter|"
                           r"all-to-all|collective-permute)-done", hlo_text))
    total = sum(per_kind.values())
    if starts and dones:
        total *= 0.5  # each async collective appeared as start+done
        per_kind = {k: v * 0.5 for k, v in per_kind.items()}
    return total, per_kind


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    collective_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_global: float
    usefulness: float            # MODEL_FLOPS / (HLO_FLOPs · chips)
    collectives_by_kind: Dict[str, float] = field(default_factory=dict)
    memory_per_device_bytes: Optional[float] = None
    notes: str = ""

    def to_dict(self) -> Dict:
        return asdict(self)


def analyze(arch: str, shape_name: str, mesh_name: str, chips: int,
            cost: Dict, hlo_text: str, model_flops_global: float,
            memory_bytes: Optional[float] = None, notes: str = "",
            extra_flops: float = 0.0, extra_bytes: float = 0.0,
            collective_override: Optional[float] = None) -> RooflineReport:
    flops = float(cost.get("flops", 0.0)) + extra_flops
    byts = float(cost.get("bytes accessed", 0.0)) + extra_bytes
    coll, per_kind = collective_bytes(hlo_text)
    if collective_override is not None:
        coll = collective_override
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = coll / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    usefulness = (model_flops_global / (flops * chips)) if flops else 0.0
    return RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops_per_device=flops, hlo_bytes_per_device=byts,
        collective_bytes_per_device=coll, compute_s=compute_s,
        memory_s=memory_s, collective_s=collective_s, dominant=dominant,
        model_flops_global=model_flops_global, usefulness=usefulness,
        collectives_by_kind=per_kind, memory_per_device_bytes=memory_bytes,
        notes=notes)


def scan_corrections(cfg, shape, *, batch_shard: int, model_shard: int,
                     heads_sharded: bool) -> Tuple[float, float, str]:
    """Exact analytic correction for inner lax.scan loops whose body XLA's
    cost analysis counts once (layers are unrolled in the dry-run; the only
    scanned loops left are the q-block flash attention and the SSD chunk
    recurrence). Returns (flops, bytes) PER DEVICE to add, + a note.

    Closed forms (per layer, forward, global):
      attention q-block scan (trips nq = S/bq):
        matmul  4·B·S²·H·hd      (scores + PV over full-S blocks)
        softmax ~8·B·H·S²        (mask/max/exp/sum/div elementwise)
        bytes   nq·(2·2·B·S·KV·hd)  (K/V re-read per block)
                + 3·4·B·H·bq·S·nq   (score buffer traffic, f32)
      SSD chunk scan (trips c = S/chunk):
        matmuls 2·B·S·chunk·h·p + 4·B·S·h·p·n (+ q²-decay elementwise ~4·B·S·chunk·h)
        bytes   ~B·S·(chunk·h + 2·h·p)·4
    Training multiplies by 4 (fwd + remat-replay + 2·bwd); prefill by 1.
    The scanned body was counted once, so we add (trips-1)/trips of the total.
    """
    from repro.models.attention import FLASH_JNP_BQ, FLASH_JNP_THRESHOLD
    if shape.kind == "decode":
        return 0.0, 0.0, ""
    B, S = shape.global_batch, shape.seq_len
    mult = 4.0 if shape.kind == "train" else 1.0
    flops = 0.0
    byts = 0.0
    notes = []
    L = cfg.num_layers
    if cfg.num_heads and S > FLASH_JNP_THRESHOLD:
        H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
        bq = FLASH_JNP_BQ
        nq = -(-S // bq)
        f = 4.0 * B * S * S * H * hd + 8.0 * B * H * S * S
        by = nq * (4.0 * B * S * KV * hd) + 3.0 * 4.0 * B * H * bq * S * nq
        scale = (nq - 1.0) / nq * mult * L / batch_shard
        if heads_sharded:
            scale /= model_shard
        flops += f * scale
        byts += by * scale
        notes.append(f"attn qblock scan x{nq}")
    if cfg.family in ("ssm", "hybrid") and cfg.ssm_state:
        h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        ch = min(cfg.ssd_chunk, S)
        c = -(-S // ch)
        f = 2.0 * B * S * ch * h * p + 4.0 * B * S * h * p * n + 4.0 * B * S * ch * h
        by = 4.0 * B * S * (ch * h + 2 * h * p)
        scale = (c - 1.0) / max(c, 1) * mult * L / batch_shard
        flops += f * scale
        byts += by * scale
        notes.append(f"ssd chunk scan x{c}")
    return flops, byts, "; ".join(notes)


def analytic_hbm_bytes(cfg, shape, *, param_bytes_global: float,
                       model_shard: int, batch_shard: int,
                       fsdp_shard: int = 1, train: bool,
                       microbatches: int = 1) -> float:
    """Closed-form per-device HBM estimate for the TPU target.

    XLA:CPU's buffer assignment (what memory_analysis() reports in this
    container) is far more conservative than the TPU compiler's arena reuse,
    so the fits-in-HBM judgement uses this analytic model; both numbers are
    recorded. Terms: sharded params (+grads+Adam moments fp32 for training),
    remat-saved layer inputs, the fp32 logits pipeline (~3 live copies), and
    one layer's transient working set (flash blocks / FFN activations).
    """
    B, S = shape.global_batch, shape.seq_len
    D, L, Vp = cfg.d_model, cfg.num_layers, cfg.padded_vocab
    shards = model_shard * fsdp_shard
    mem = param_bytes_global / shards
    if train:
        mem += param_bytes_global / shards          # grads
        mem += 2 * 4 * (param_bytes_global / 4) / shards  # Adam mu+nu fp32
    B_loc = B / batch_shard
    if shape.kind == "train":
        B_mb = B_loc / microbatches             # grad-accumulation slices
        mem += L * B_mb * S * D * 2             # remat layer inputs (bf16)
        mem += 3 * 4 * B_mb * S * (Vp / model_shard)    # fp32 logits pipeline
        mem += 2 * 4 * B_mb * 512 * S * max(cfg.num_heads, 1) / model_shard
        mem += 2 * B_mb * S * max(cfg.d_ff, D) / max(model_shard, 1) * 4
        if microbatches > 1:
            mem += param_bytes_global / (model_shard * fsdp_shard)  # grad acc
    elif shape.kind == "prefill":
        mem += 2 * B_loc * S * D * 2                # activations in flight
        mem += 3 * 4 * B_loc * (Vp / model_shard)   # last-token logits only
        # KV cache being built
        mem += 2 * L * B_loc * min(S, cfg.sliding_window or S) \
            * max(cfg.num_kv_heads, 1) * cfg.resolved_head_dim * 2 / model_shard
    else:  # decode
        C = min(S, cfg.sliding_window or S)
        if cfg.family != "ssm":
            mem += 2 * L * B_loc * C * max(cfg.num_kv_heads, 1) \
                * cfg.resolved_head_dim * 2 / model_shard
        if cfg.family in ("ssm", "hybrid"):
            mem += L * B_loc * cfg.ssm_heads * cfg.ssm_head_dim \
                * cfg.ssm_state * 4
        mem += 3 * 4 * B_loc * (Vp / model_shard)
    return float(mem)


def model_flops(cfg, shape) -> float:
    """6·N·D for training, 2·N_active·D for inference forward passes."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch   # one decoded token per sequence
