"""Controller decision audit log — why the solver chose what it chose,
and what actually happened afterwards.

Each adaptation interval, a controller records one ``DecisionRecord``:

* **inputs** — what the decision was conditioned on: the arrival-rate
  estimate (forecast + any backlog inflation), ``capacity_factor``, the
  profile snapshot used (per-variant base/slope latency, throughput), and
  the reason the solve ran (``interval`` timer vs ``reactive`` headroom
  trigger).
* **outputs** — the chosen variant set with units and quotas, the Eq. 1
  objective terms (aa/rc/lc), and *predicted* latency/goodput derived
  from the same profiles the solver optimized against
  (``predict_outputs``).
* **measured** — attached after the run by ``attach_measured``: requests
  are bucketed into decision windows ``[t_i, t_{i+1})`` by arrival time
  and each window's realized p99 latency and goodput land on the decision
  that governed it, together with the prediction error (**regret**):
  ``regret_p99_ms = measured_p99 - predicted_p99`` and
  ``regret_goodput = predicted_goodput - measured_goodput`` (positive =
  the solver was optimistic).

The log is backend-agnostic: ``sim/runner.py`` attaches measurements from
DES ``ServedRequest``s and ``serving/driver.py`` from engine ``Request``s.
Export with ``to_jsonl`` (one decision per line, rendered into
EXPERIMENTS.md §Observability by ``analysis/report.py``).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Sequence

import numpy as np

__all__ = ["DecisionRecord", "DecisionAudit", "predict_outputs",
           "attach_from_requests"]


def attach_from_requests(audit: "DecisionAudit", requests: Sequence[Any],
                         default_slo_ms: float = 0.0,
                         horizon: Optional[float] = None) -> int:
    """Attach measured outcomes to ``audit`` from served-request records.

    Duck-typed over both backends' per-request types (the engine's
    ``Request`` and the DES's ``ServedRequest``): each record needs
    ``arrival``/``completion`` stamps, and a request counts toward goodput
    when it entered service (``service_start > 0``), was not ``dropped``,
    and met its per-request SLO (falling back to ``default_slo_ms`` when
    the request carries none). No-op (returns 0) when ``audit`` is None or
    has no entries — callers attach opportunistically post-drain.
    """
    if audit is None or not audit.entries or not requests:
        return 0
    arr: List[float] = []
    lat: List[float] = []
    ok: List[bool] = []
    for r in requests:
        arr.append(float(r.arrival))
        l_ms = (float(r.completion) - float(r.arrival)) * 1000.0
        lat.append(l_ms)
        slo = float(getattr(r, "slo_ms", 0.0))
        if slo <= 0:
            slo = default_slo_ms
        served = (float(getattr(r, "service_start", 1.0)) > 0.0
                  and not getattr(r, "dropped", False))
        ok.append(served and (slo <= 0 or l_ms <= slo))
    return audit.attach_measured(arr, lat, ok, horizon=horizon)


def predict_outputs(profiles: Mapping[str, Any], alloc: Any, lam: float,
                    slo_ms: float) -> Dict[str, float]:
    """Predicted latency/goodput implied by an ``Allocation``.

    Duck-typed over ``core.objective``: ``alloc`` needs ``units``/
    ``quotas``; each profile needs ``p99_ms(n)`` and ``throughput(n)``.
    Predicted p99 is reported two ways — quota-weighted mean across active
    variants (what a random admitted request sees) and the max (worst
    variant) — and predicted goodput is the quota share routed to variants
    whose profile-predicted p99 meets the SLO, capped by predicted
    capacity vs the load estimate.
    """
    active = [(m, n) for m, n in alloc.units.items() if n > 0]
    if not active:
        return {"p99_ms": float("nan"), "p99_max_ms": float("nan"),
                "goodput": 0.0, "capacity_rps": 0.0}
    quotas = {m: float(alloc.quotas.get(m, 0.0)) for m, _ in active}
    qsum = sum(quotas.values()) or 1.0
    p99s = {m: float(profiles[m].p99_ms(n)) for m, n in active}
    cap = sum(float(profiles[m].throughput(n)) for m, n in active)
    mean_p99 = sum(quotas[m] / qsum * p99s[m] for m, _ in active)
    ok_share = sum(quotas[m] / qsum for m, _ in active
                   if slo_ms <= 0 or p99s[m] <= slo_ms)
    served_frac = min(1.0, cap / lam) if lam > 0 else 1.0
    return {"p99_ms": mean_p99, "p99_max_ms": max(p99s.values()),
            "goodput": ok_share * served_frac, "capacity_rps": cap}


@dataclass
class DecisionRecord:
    """One controller adaptation: inputs, outputs, and (later) outcome."""
    t: float
    controller: str
    reason: str          # "interval" | "reactive" | "burn_rate" | "warm_start"
    inputs: Dict[str, Any] = field(default_factory=dict)
    outputs: Dict[str, Any] = field(default_factory=dict)
    measured: Optional[Dict[str, Any]] = None
    regret: Optional[Dict[str, float]] = None

    def to_dict(self) -> Dict[str, Any]:
        d = {"t": self.t, "controller": self.controller,
             "reason": self.reason, "inputs": self.inputs,
             "outputs": self.outputs}
        if self.measured is not None:
            d["measured"] = self.measured
        if self.regret is not None:
            d["regret"] = self.regret
        return d


class DecisionAudit:
    """Append-only decision log with post-hoc measurement attachment."""

    def __init__(self) -> None:
        self.entries: List[DecisionRecord] = []

    def record(self, t: float, controller: str, inputs: Dict[str, Any],
               outputs: Dict[str, Any],
               reason: str = "interval") -> DecisionRecord:
        rec = DecisionRecord(t=float(t), controller=controller,
                             reason=reason, inputs=inputs, outputs=outputs)
        self.entries.append(rec)
        return rec

    # ------------------------------------------------------------ outcomes
    def attach_measured(self, arrivals: Sequence[float],
                        latencies_ms: Sequence[float],
                        ok: Sequence[bool],
                        horizon: Optional[float] = None) -> int:
        """Bucket per-request outcomes into decision windows and attach
        measured p99/goodput + regret to each entry. Requests arriving
        before the first decision are credited to it (warm-up). Entries
        recorded out of timestamp order are SORTED by ``t`` before
        bucketing (windows are defined by decision time, not record
        order) — never an error. Entries whose window caught no requests
        get ``measured={"n_requests": 0}`` and do not count toward the
        returned total. Returns the number of entries that received
        measurements."""
        if not self.entries or not len(arrivals):
            return 0
        order = sorted(range(len(self.entries)),
                       key=lambda i: self.entries[i].t)
        bounds = [self.entries[i].t for i in order]
        arr = np.asarray(arrivals, dtype=float)
        lat = np.asarray(latencies_ms, dtype=float)
        okv = np.asarray(ok, dtype=bool)
        # window k covers [bounds[k], bounds[k+1]); k=0 also takes warm-up
        idx = np.searchsorted(bounds, arr, side="right") - 1
        idx = np.clip(idx, 0, len(bounds) - 1)
        n_attached = 0
        for k, ei in enumerate(order):
            entry = self.entries[ei]
            mask = idx == k
            if horizon is not None and k == len(order) - 1:
                mask &= arr <= horizon
            n = int(mask.sum())
            if n == 0:
                entry.measured = {"n_requests": 0}
                continue
            w_lat = lat[mask]
            measured = {
                "n_requests": n,
                "p99_ms": float(np.percentile(w_lat, 99)),
                "p50_ms": float(np.percentile(w_lat, 50)),
                "mean_ms": float(np.mean(w_lat)),
                "goodput": float(np.mean(okv[mask])),
            }
            entry.measured = measured
            pred = entry.outputs.get("predicted", {})
            if pred:
                entry.regret = {
                    "p99_ms": measured["p99_ms"] - pred.get("p99_ms",
                                                            float("nan")),
                    "goodput": pred.get("goodput", float("nan"))
                               - measured["goodput"],
                }
            n_attached += 1
        return n_attached

    # -------------------------------------------------------------- export
    def to_dicts(self) -> List[Dict[str, Any]]:
        return [e.to_dict() for e in self.entries]

    def to_jsonl(self, path: str) -> int:
        with open(path, "w") as f:
            for e in self.entries:
                f.write(json.dumps(e.to_dict(), sort_keys=True,
                                   default=float) + "\n")
        return len(self.entries)

    def summary(self) -> Dict[str, float]:
        """Aggregate regret across measured decisions (NaN when none)."""
        regs = [e.regret for e in self.entries if e.regret]
        out = {"n_decisions": float(len(self.entries)),
               "n_measured": float(len(regs))}
        if regs:
            gp = [r["goodput"] for r in regs if np.isfinite(r["goodput"])]
            p99 = [r["p99_ms"] for r in regs if np.isfinite(r["p99_ms"])]
            out["mean_abs_goodput_regret"] = (float(np.mean(np.abs(gp)))
                                              if gp else float("nan"))
            out["mean_p99_regret_ms"] = (float(np.mean(p99))
                                         if p99 else float("nan"))
        return out
