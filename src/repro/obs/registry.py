"""In-process metrics registry — the one surface every serving component
publishes into (DESIGN.md §Observability).

InfAdapter's premise is a control loop driven by *measured* signals; before
this module those signals lived in ad-hoc summary dicts computed after the
fact (``summarize``, ``kv_pool_stats``, per-backend attribute counters).
The registry replaces them with three instrument kinds, named with the
Prometheus-style ``component.metric`` convention so the engine and the
discrete-event simulator emit the SAME metric names:

  * ``Counter``   — monotone totals (``requests.completed``,
    ``engine.prefill_tokens_total``). ``inc`` only.
  * ``Gauge``     — last-write-wins levels (``kv.occupancy``).
  * ``Histogram`` — bounded-reservoir distributions
    (``request.latency_ms``): the first ``cap`` observations are kept
    verbatim, later ones reservoir-sample (Vitter's algorithm R with a
    deterministic per-instrument RNG, so snapshots are reproducible at a
    fixed workload); ``count``/``sum`` stay exact, quantiles are estimates
    over the reservoir. p50/p95/p99 come from ``percentile``.

Zero dependencies, near-zero overhead: instruments are plain attribute
arithmetic, and a registry constructed with ``enabled=False`` hands out a
shared ``NullInstrument`` whose methods are no-ops — the disabled-mode cost
of an instrumented call site is one method call (benchmarked by the
``observability`` study in ``benchmarks/bench_engine.py``, gated ≤2% of a
tick). ``NULL_REGISTRY`` is the module-wide disabled singleton components
default to when no registry is mounted.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Union

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "NullInstrument",
           "MetricsRegistry", "NULL_REGISTRY"]

# reservoir size per histogram: large enough that p99 over a smoke run is
# exact (runs complete < cap requests), small enough to bound memory
DEFAULT_RESERVOIR = 4096


class Counter:
    """Monotone total. ``inc`` with a negative amount is a bug (raises)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, amount: Union[int, float] = 1) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative inc {amount}")
        self.value += amount

    def snapshot(self) -> Dict:
        return {"name": self.name, "kind": "counter", "value": self.value}


class Gauge:
    """Last-write-wins level."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, value: Union[int, float]) -> None:
        self.value = float(value)

    def snapshot(self) -> Dict:
        return {"name": self.name, "kind": "gauge", "value": self.value}


class Histogram:
    """Bounded-reservoir distribution with exact count/sum.

    The reservoir keeps the first ``cap`` observations, then replaces
    uniformly at random (algorithm R) so quantiles remain an unbiased
    estimate of the full stream. The RNG is seeded from the metric name —
    identical workloads snapshot identically.
    """

    __slots__ = ("name", "cap", "count", "sum", "min", "max", "_res", "_rng")

    def __init__(self, name: str, cap: int = DEFAULT_RESERVOIR):
        self.name = name
        self.cap = cap
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._res: List[float] = []
        self._rng = np.random.default_rng(abs(hash(name)) % (2 ** 32))

    def observe(self, value: Union[int, float]) -> None:
        v = float(value)
        self.count += 1
        self.sum += v
        self.min = v if v < self.min else self.min
        self.max = v if v > self.max else self.max
        if len(self._res) < self.cap:
            self._res.append(v)
        else:                          # algorithm R: keep with prob cap/count
            j = int(self._rng.integers(self.count))
            if j < self.cap:
                self._res[j] = v

    def percentile(self, p: float) -> float:
        """Quantile estimate over the reservoir (NaN when empty)."""
        if not self._res:
            return float("nan")
        return float(np.percentile(np.asarray(self._res), p))

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else float("nan")

    def snapshot(self) -> Dict:
        out = {"name": self.name, "kind": "histogram", "count": self.count,
               "sum": self.sum}
        if self.count:
            out.update(mean=self.mean, min=self.min, max=self.max,
                       p50=self.percentile(50), p95=self.percentile(95),
                       p99=self.percentile(99))
        return out


class NullInstrument:
    """Shared no-op standing in for every instrument kind when the registry
    is disabled — call sites never branch, they just pay one no-op call."""

    __slots__ = ()
    name = "null"
    value = 0.0
    count = 0
    sum = 0.0
    mean = float("nan")

    def inc(self, amount: Union[int, float] = 1) -> None:
        pass

    def set(self, value: Union[int, float]) -> None:
        pass

    def observe(self, value: Union[int, float]) -> None:
        pass

    def percentile(self, p: float) -> float:
        return float("nan")

    def snapshot(self) -> Dict:
        return {}


_NULL_INSTRUMENT = NullInstrument()


class MetricsRegistry:
    """Name -> instrument map. One per serving backend (engine or sim);
    components receive it at construction and publish through it.

    ``enabled=False`` makes every factory return the shared
    ``NullInstrument`` and every convenience helper a cheap early-return —
    the whole instrumentation layer reduces to no-op calls.
    """

    def __init__(self, enabled: bool = True,
                 reservoir: int = DEFAULT_RESERVOIR):
        self.enabled = enabled
        self.reservoir = reservoir
        self._metrics: Dict[str, object] = {}

    # ------------------------------------------------------------ factories
    def _get(self, name: str, cls, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, **kw)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, requested {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        if not self.enabled:
            return _NULL_INSTRUMENT
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        if not self.enabled:
            return _NULL_INSTRUMENT
        return self._get(name, Gauge)

    def histogram(self, name: str, cap: Optional[int] = None) -> Histogram:
        if not self.enabled:
            return _NULL_INSTRUMENT
        return self._get(name, Histogram, cap=cap or self.reservoir)

    # ---------------------------------------------------------- convenience
    def inc(self, name: str, amount: Union[int, float] = 1) -> None:
        if self.enabled:
            self.counter(name).inc(amount)

    def set(self, name: str, value: Union[int, float]) -> None:
        if self.enabled:
            self.gauge(name).set(value)

    def observe(self, name: str, value: Union[int, float]) -> None:
        if self.enabled:
            self.histogram(name).observe(value)

    def value(self, name: str, default: float = 0.0) -> float:
        """Current value of a counter/gauge (``default`` when absent)."""
        m = self._metrics.get(name)
        return m.value if m is not None and hasattr(m, "value") else default

    def get(self, name: str):
        """The instrument itself, or None — for histogram percentiles."""
        return self._metrics.get(name)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def reset(self) -> None:
        """Drop every instrument. Publishers address instruments by name
        through the registry (never by cached object), so benchmarks that
        reuse one engine across warm-up and measured phases can zero the
        slate between them."""
        self._metrics.clear()

    # -------------------------------------------------------------- export
    def snapshot(self) -> List[Dict]:
        """One dict per instrument, name-sorted (the JSONL dump rows)."""
        return [self._metrics[n].snapshot() for n in self.names()]

    def dump_jsonl(self, path: str,
                   extra: Optional[Iterable[Dict]] = None) -> int:
        """Write ``snapshot()`` (+ optional extra rows) one JSON object per
        line — the METRICS_engine.jsonl exporter format. Returns #rows."""
        rows = list(extra or []) + self.snapshot()
        with open(path, "w") as f:
            for row in rows:
                f.write(json.dumps(row, sort_keys=True) + "\n")
        return len(rows)


NULL_REGISTRY = MetricsRegistry(enabled=False)
