"""Per-SLO-class error budgets and multi-window burn-rate alerting.

InfAdapter's objective is goodput under a latency SLO (PAPER.md); PR 7's
audit measures how well each decision did *after the run*. This module is
the live half: it reads the rolling windows (``obs.windows``) that both
backends feed at completion time and answers, per SLO class, "how fast is
the error budget burning *right now*" — the SRE multi-window multi-burn-
rate pattern:

* **Error budget** — a target bad-request fraction (``budget``, e.g. 0.05:
  up to 5% of requests may miss their deadline or be dropped).
* **Burn rate** — (observed bad fraction over a window) / budget. Burn 1.0
  consumes the budget exactly; burn 4.0 exhausts it 4x too fast.
* **Multi-window rule** — an alert fires only when BOTH a fast window
  (seconds: catches the spike) and a slow window (the fast window's
  context: filters one-bucket blips) burn above ``threshold``. Each rule
  re-arms after ``cooldown_s`` so a sustained breach re-alerts at a
  bounded rate instead of every check.

SLO **classes** partition requests by their per-request deadline. Class
keys use the same ``f"{slo_ms:g}"`` format as ``summarize_requests``'s
``slo_classes`` (``"150"``, ``"600"``); requests without a deadline fall
in class ``"none"`` (bad = dropped). Backends feed two windowed counters
per class — ``slo.class.<key>.good`` / ``slo.class.<key>.bad`` — from
their completion sinks (engine ``_obs_complete``, DES ``_record``), so
the monitor itself is backend-agnostic and the engine/sim emit identical
windowed names and alert semantics (parity-tested).

Alerts flow to ``AlertSink``s: ``CollectingSink`` queues them for
``InfAdapterController.maybe_react`` (re-solve on breach — the first
consumer of the goodput-aware-control roadmap item) and
``flightrec.FlightTrigger`` dumps a flight snapshot.

Clock-domain rule: ``observe``/``check`` take the owning backend's clock
(wall for the engine, virtual for the DES) — the same stamps the windows
are keyed by.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .windows import MetricWindows

__all__ = ["slo_class_key", "Alert", "AlertSink", "CollectingSink",
           "BurnRateRule", "SLOMonitor", "DEFAULT_RULES"]

_CLASS_PREFIX = "slo.class."


def slo_class_key(slo_ms: float) -> str:
    """Class key for a per-request SLO — the ``summarize_requests``
    ``slo_classes`` format (``750.0 -> "750"``); no deadline -> "none"."""
    return f"{slo_ms:g}" if slo_ms > 0 else "none"


def good_metric(cls: str) -> str:
    return f"{_CLASS_PREFIX}{cls}.good"


def bad_metric(cls: str) -> str:
    return f"{_CLASS_PREFIX}{cls}.bad"


@dataclass(frozen=True)
class Alert:
    """One burn-rate breach: class + rule + the rates that tripped it."""
    t: float
    slo_class: str
    rule: str                 # "fast5s/slow30s" style rule label
    burn_fast: float
    burn_slow: float
    budget: float
    kind: str = "burn_rate"

    def to_dict(self) -> Dict:
        return {"t": self.t, "kind": self.kind, "slo_class": self.slo_class,
                "rule": self.rule, "burn_fast": self.burn_fast,
                "burn_slow": self.burn_slow, "budget": self.budget}


class AlertSink:
    """Receiver interface for burn-rate alerts (``emit`` per alert)."""

    def emit(self, alert: Alert) -> None:  # pragma: no cover - interface
        raise NotImplementedError


class CollectingSink(AlertSink):
    """Queue alerts for a consumer that polls (``maybe_react``): ``alerts``
    keeps the full history, ``pop_pending`` drains the unconsumed tail."""

    def __init__(self) -> None:
        self.alerts: List[Alert] = []
        self._pending: List[Alert] = []

    def emit(self, alert: Alert) -> None:
        self.alerts.append(alert)
        self._pending.append(alert)

    def pending(self) -> int:
        return len(self._pending)

    def pop_pending(self) -> List[Alert]:
        out, self._pending = self._pending, []
        return out


@dataclass(frozen=True)
class BurnRateRule:
    """Alert when burn >= ``threshold`` on BOTH windows (fast AND slow)."""
    fast_s: float = 5.0
    slow_s: float = 30.0
    threshold: float = 2.0

    @property
    def label(self) -> str:
        return f"fast{self.fast_s:g}s/slow{self.slow_s:g}s"


DEFAULT_RULES: Tuple[BurnRateRule, ...] = (BurnRateRule(),)


@dataclass
class _ClassState:
    last_alert_t: Dict[str, float] = field(default_factory=dict)  # rule ->


class SLOMonitor:
    """Evaluate burn-rate rules over the per-class good/bad windows.

    ``check(t)`` discovers classes from the window names (anything a
    backend fed as ``slo.class.<key>.good|bad``), computes each rule's
    fast/slow burn rates, and emits an ``Alert`` to every sink when a rule
    trips outside its cooldown. Windows with fewer than ``min_requests``
    completions (fast window) stay silent — no alerting on noise.
    """

    def __init__(self, windows: MetricWindows, budget: float = 0.05,
                 rules: Sequence[BurnRateRule] = DEFAULT_RULES,
                 sinks: Sequence[AlertSink] = (),
                 cooldown_s: float = 10.0, min_requests: int = 5):
        assert 0 < budget <= 1.0, budget
        self.windows = windows
        self.budget = budget
        self.rules = tuple(rules)
        self.sinks = list(sinks)
        self.cooldown_s = cooldown_s
        self.min_requests = min_requests
        self.alerts: List[Alert] = []            # full history, all classes
        self._state: Dict[str, _ClassState] = {}

    # -------------------------------------------------------------- queries
    def classes(self) -> List[str]:
        seen = set()
        for name in self.windows.names():
            if name.startswith(_CLASS_PREFIX):
                seen.add(name[len(_CLASS_PREFIX):].rsplit(".", 1)[0])
        return sorted(seen)

    def counts(self, cls: str, t: float,
               window_s: float) -> Tuple[float, float]:
        """(good, bad) completions for ``cls`` over the trailing window."""
        g = self.windows.get(good_metric(cls))
        b = self.windows.get(bad_metric(cls))
        return (g.total(t, window_s) if g is not None else 0.0,
                b.total(t, window_s) if b is not None else 0.0)

    def burn_rate(self, cls: str, t: float,
                  window_s: float) -> Optional[float]:
        """(bad fraction over window) / budget; None below min_requests."""
        good, bad = self.counts(cls, t, window_s)
        total = good + bad
        if total < self.min_requests:
            return None
        return (bad / total) / self.budget

    # --------------------------------------------------------------- checks
    def check(self, t: float) -> List[Alert]:
        """Evaluate every (class, rule) pair at clock ``t``; emit + return
        the alerts that fired."""
        if not self.windows.on:
            return []
        fired: List[Alert] = []
        for cls in self.classes():
            st = self._state.setdefault(cls, _ClassState())
            for rule in self.rules:
                bf = self.burn_rate(cls, t, rule.fast_s)
                bs = self.burn_rate(cls, t, rule.slow_s)
                if bf is None or bs is None:
                    continue
                if bf < rule.threshold or bs < rule.threshold:
                    continue
                last = st.last_alert_t.get(rule.label)
                if last is not None and t - last < self.cooldown_s:
                    continue
                st.last_alert_t[rule.label] = t
                a = Alert(t=t, slo_class=cls, rule=rule.label, burn_fast=bf,
                          burn_slow=bs, budget=self.budget)
                fired.append(a)
        for a in fired:
            self.alerts.append(a)
            for sink in self.sinks:
                sink.emit(a)
        return fired
