"""Anomaly flight recorder — a bounded rear-view ring that dumps on demand.

Full tracing keeps everything (bounded only by the big tracer caps); the
flight recorder keeps only the *recent past* — deques of the last
``max_spans`` span events, ``max_ticks`` tick records, and
``max_metric_snaps`` registry counter-delta snapshots — and serializes
them to a Perfetto-loadable ``FLIGHT_<reason>.json`` when something goes
wrong:

* a burn-rate alert (``FlightTrigger`` is an ``slo.AlertSink``),
* a fault event (both backends' ``inject_fault`` trigger
  ``fault_<kind>``),
* an explicit ``trigger(reason, t)`` call.

The ring is fed by the ``Tracer`` (constructed with ``flight=``): every
span/tick lands in the ring even when the tracer's own buffers are full —
the tracer drops the *newest* past its cap (post-run artifact), the
recorder evicts the *oldest* (what just happened matters). Metric deltas
come from ``snap_metrics(t, registry)``, called periodically by the
serving loop; each snapshot stores the counters that changed since the
previous one and renders as Chrome ``"C"`` counter events (pid 3), so the
dump shows request rates around the anomaly, not lifetime totals.

Dumps are rate-limited (``min_interval_s`` per reason, ``max_dumps``
total) and validated against the same trace_event schema subset the CI
gate enforces before they hit disk.
"""
from __future__ import annotations

import json
import os
import re
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

from .registry import MetricsRegistry
from .slo import Alert, AlertSink
from .trace import (SpanEvent, TickRecord, _request_lane,
                    validate_chrome_trace)

__all__ = ["FlightRecorder", "FlightTrigger"]

_US = 1e6


def _sanitize(reason: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]+", "_", reason).strip("_") or "anomaly"


class FlightRecorder:
    """Bounded ring of recent spans / ticks / metric deltas + the dumper."""

    def __init__(self, out_dir: str = "reports", max_spans: int = 4096,
                 max_ticks: int = 2048, max_metric_snaps: int = 256,
                 max_dumps: int = 8, min_interval_s: float = 5.0):
        self.out_dir = out_dir
        self.spans: Deque[SpanEvent] = deque(maxlen=max_spans)
        self.ticks: Deque[TickRecord] = deque(maxlen=max_ticks)
        # (t, {counter_name: delta_since_previous_snap})
        self.metric_snaps: Deque[Tuple[float, Dict[str, float]]] = \
            deque(maxlen=max_metric_snaps)
        self.max_dumps = max_dumps
        self.min_interval_s = min_interval_s
        self.dumps: List[str] = []           # paths written, in order
        self._last_dump_t: Dict[str, float] = {}   # reason -> t
        self._dump_seq: Dict[str, int] = {}
        self._last_counters: Dict[str, float] = {}

    # ------------------------------------------------------------- feeding
    def push_event(self, ev: SpanEvent) -> None:
        self.spans.append(ev)

    def push_tick(self, rec: TickRecord) -> None:
        self.ticks.append(rec)

    def snap_metrics(self, t: float, registry: MetricsRegistry) -> None:
        """Record counter movement since the previous snapshot (empty
        deltas are kept — a quiet period is signal too)."""
        deltas: Dict[str, float] = {}
        for row in registry.snapshot():
            if row.get("kind") != "counter":
                continue
            name, val = row["name"], float(row["value"])
            prev = self._last_counters.get(name, 0.0)
            if val != prev:
                deltas[name] = val - prev
            self._last_counters[name] = val
        self.metric_snaps.append((float(t), deltas))

    # ------------------------------------------------------------ dumping
    def to_chrome(self, reason: str, t: float,
                  extra: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        """Render the ring as a Chrome trace_event object: request lanes on
        pid 1 (same rendering as the full tracer), tick slices on pid 2,
        metric-delta counter tracks on pid 3."""
        out: List[Dict] = [
            {"name": "process_name", "ph": "M", "pid": 1, "tid": 0, "ts": 0,
             "args": {"name": "flight: requests"}},
            {"name": "process_name", "ph": "M", "pid": 2, "tid": 0, "ts": 0,
             "args": {"name": "flight: engine ticks"}},
            {"name": "process_name", "ph": "M", "pid": 3, "tid": 0, "ts": 0,
             "args": {"name": "flight: metric deltas"}},
        ]
        by_rid: Dict[int, List[SpanEvent]] = {}
        for ev in self.spans:
            by_rid.setdefault(ev.rid, []).append(ev)
        for rid in sorted(by_rid):
            _request_lane(rid, by_rid[rid], out)
        backends = sorted({r.backend for r in self.ticks})
        tid_of = {b: i for i, b in enumerate(backends)}
        for b in backends:
            out.append({"name": "thread_name", "ph": "M", "pid": 2,
                        "tid": tid_of[b], "ts": 0, "args": {"name": b}})
        for rec in self.ticks:
            args = rec.to_dict()
            args.pop("backend", None)
            out.append({"name": f"tick:{rec.kind}", "ph": "X",
                        "ts": rec.t * _US,
                        "dur": max(0.0, rec.total_ms * 1e3),
                        "pid": 2, "tid": tid_of[rec.backend], "args": args})
        for ts, deltas in self.metric_snaps:
            for name, d in deltas.items():
                out.append({"name": name, "ph": "C", "ts": ts * _US,
                            "pid": 3, "tid": 0, "args": {"delta": d}})
        return {"traceEvents": out, "displayTimeUnit": "ms",
                "otherData": {"flight_reason": reason, "t": t,
                              "spans": len(self.spans),
                              "ticks": len(self.ticks),
                              "metric_snaps": len(self.metric_snaps),
                              **(extra or {})}}

    def trigger(self, reason: str, t: float,
                extra: Optional[Dict[str, Any]] = None) -> Optional[str]:
        """Dump ``FLIGHT_<reason>.json`` (suffixed ``_2``, ``_3``, ... on
        repeats) unless rate-limited. Returns the path, or None when the
        dump was suppressed. The object is schema-validated before writing
        — a flight dump that Perfetto can't load is worse than none."""
        reason = _sanitize(reason)
        if len(self.dumps) >= self.max_dumps:
            return None
        last = self._last_dump_t.get(reason)
        if last is not None and t - last < self.min_interval_s:
            return None
        self._last_dump_t[reason] = t
        seq = self._dump_seq.get(reason, 0) + 1
        self._dump_seq[reason] = seq
        fname = (f"FLIGHT_{reason}.json" if seq == 1
                 else f"FLIGHT_{reason}_{seq}.json")
        obj = self.to_chrome(reason, t, extra=extra)
        validate_chrome_trace(obj)
        os.makedirs(self.out_dir, exist_ok=True)
        path = os.path.join(self.out_dir, fname)
        with open(path, "w") as f:
            json.dump(obj, f)
        self.dumps.append(path)
        return path


class FlightTrigger(AlertSink):
    """AlertSink that turns a burn-rate alert into a flight dump."""

    def __init__(self, recorder: FlightRecorder):
        self.recorder = recorder

    def emit(self, alert: Alert) -> None:
        self.recorder.trigger(f"burn_rate_{alert.slo_class}", alert.t,
                              extra=alert.to_dict())
