"""Request lifecycle tracing and per-tick phase records.

Two record kinds, one clock domain (DESIGN.md §Observability):

* ``SpanEvent`` — a typed point on one request's timeline. Every stamp
  comes from the owning backend's single clock (``engine.clock``, the DES
  virtual clock, or a benchmark's replay clock), so events across requests
  and ticks are totally ordered in one time base. The taxonomy::

      arrival -> queued -> admitted -> prefill_chunk* -> prefill_complete
              -> decode ticks -> (preempt -> queued -> resume)* ->
              cow_bind? -> complete | drop | rejected

* ``TickRecord`` — one row per engine tick per backend: which phase the
  tick took (fused chunk vs pure decode), wall-clock cost of the
  preempt/admit/execute phases (``time.perf_counter`` — wall cost even
  when the *timeline* clock is virtual), batch geometry, queue depth, and
  paged-pool occupancy. On dispatch-profiled ticks
  (``InProcessServingEngine(profile_dispatch=N)``) the execute phase is
  further split into ``dispatch_ms`` (jit call returning — jax async
  dispatch), ``device_ms`` (``block_until_ready`` fence — device
  compute), and ``host_sync_ms`` (``np.asarray`` copy + host
  bookkeeping); NaN on unsampled ticks (see ``obs.profiler``).

``Tracer`` stores both, bounded (drops-past-cap are counted, never
silently lost — and surfaced as registry counters ``obs.spans_dropped``/
``obs.ticks_dropped`` when constructed with ``metrics=``), optionally
mirrors everything into a ``FlightRecorder`` ring (``flight=`` — the
recorder keeps the recent past even after the tracer's own caps fill),
and converts to Chrome ``trace_event`` JSON — load
``reports/TRACE_engine.json`` at https://ui.perfetto.dev. Request lanes
live under pid 1 (one thread per rid: queued/prefill/decode/preempted
slices + instants for chunks, CoW binds, preemptions); engine tick lanes
under pid 2 (one thread per backend, phase costs in ``args``).

A tracer constructed with ``enabled=False`` (or the shared
``NULL_TRACER``) keeps ``on == False`` and every hook is a one-branch
no-op — the engine's disabled-mode overhead gate in
``benchmarks/bench_engine.py`` measures exactly this path.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

__all__ = ["SpanEvent", "TickRecord", "Tracer", "NULL_TRACER",
           "EVENT_TAXONOMY", "to_chrome_trace", "validate_chrome_trace"]

# ----------------------------------------------------------------- taxonomy
ARRIVAL = "arrival"
QUEUED = "queued"
REJECTED = "rejected"
ADMITTED = "admitted"
PREFILL_CHUNK = "prefill_chunk"
PREFILL_COMPLETE = "prefill_complete"
COW_BIND = "cow_bind"
PREEMPT = "preempt"
RESUME = "resume"
COMPLETE = "complete"
DROP = "drop"
ROUTED = "routed"

EVENT_TAXONOMY = (ARRIVAL, QUEUED, REJECTED, ADMITTED, PREFILL_CHUNK,
                  PREFILL_COMPLETE, COW_BIND, PREEMPT, RESUME, COMPLETE,
                  DROP, ROUTED)

# events that end a request's timeline — nothing may be stamped after one
TERMINAL_EVENTS = frozenset({COMPLETE, DROP, REJECTED})


@dataclass(frozen=True)
class SpanEvent:
    """One typed point on a request timeline (t in clock seconds)."""
    rid: int
    name: str
    t: float
    attrs: Optional[Dict[str, Any]] = None

    def to_dict(self) -> Dict[str, Any]:
        d = {"rid": self.rid, "name": self.name, "t": self.t}
        if self.attrs:
            d["attrs"] = self.attrs
        return d


@dataclass
class TickRecord:
    """Phase costs + batch geometry for one engine tick on one backend."""
    backend: str
    t: float                  # timeline clock at tick start (seconds)
    kind: str                 # "fused" | "decode" | "idle"
    preempt_ms: float = 0.0   # wall cost of the preemption phase
    admit_ms: float = 0.0     # wall cost of the admission phase
    exec_ms: float = 0.0      # wall cost of the fused-chunk / decode step
    active: int = 0           # occupied slots after admission
    prefilling: int = 0       # slots mid-prefill (chunked backends)
    queued: int = 0           # admission-queue depth after the tick
    admitted: int = 0         # requests admitted this tick
    preempted: int = 0        # requests preempted this tick
    completed: int = 0        # requests finished this tick
    pool_occupancy: float = float("nan")  # paged pool occupancy (NaN: dense)
    # dispatch-profiler split of exec_ms (NaN unless this tick was sampled
    # under profile_dispatch — fenced with block_until_ready)
    dispatch_ms: float = float("nan")   # jitted call returned (async enqueue)
    device_ms: float = float("nan")     # block_until_ready wait (device work)
    host_sync_ms: float = float("nan")  # exec remainder: D2H copy + host loop
    # async tick loop overlap fields (NaN unless engine(async_tick=True)
    # committed a previous tick's exec on this tick): with the one-tick-lag
    # commit queue, exec_ms above is the DISPATCH phase only and the
    # fields below describe the commit of tick t-1 riding this tick
    commit_ms: float = float("nan")       # commit phase wall (read + books)
    commit_gap_ms: float = float("nan")   # t-1 dispatch -> commit-read gap
    commit_wait_ms: float = float("nan")  # blocked inside the D2H read
    hidden_host_ms: float = float("nan")  # host work overlapped with t-1's
    #                                       in-flight exec (preempt + admit
    #                                       + this tick's dispatch)

    @property
    def total_ms(self) -> float:
        commit = self.commit_ms if math.isfinite(self.commit_ms) else 0.0
        return self.preempt_ms + self.admit_ms + self.exec_ms + commit

    def to_dict(self) -> Dict[str, Any]:
        d = dict(self.__dict__)
        d["total_ms"] = self.total_ms
        return d


class Tracer:
    """Bounded store for span events and tick records.

    Hot-path contract: every hook first checks ``self.on`` and returns —
    a disabled tracer costs one attribute load + branch per call site.
    """

    def __init__(self, enabled: bool = True, max_events: int = 200_000,
                 max_ticks: int = 100_000, metrics=None, flight=None):
        self.on = enabled
        self.max_events = max_events
        self.max_ticks = max_ticks
        self.events: Dict[int, List[SpanEvent]] = {}
        self.ticks: List[TickRecord] = []
        self.n_events = 0
        self.dropped_events = 0
        self.dropped_ticks = 0
        # registry surfacing drops (obs.spans_dropped / obs.ticks_dropped)
        # so silent truncation shows in METRICS jsonl; None = count-only
        self.metrics = metrics
        # FlightRecorder ring: fed BEFORE the cap check — the recorder
        # keeps the recent past, the tracer keeps the bounded whole
        self.flight = flight

    # ------------------------------------------------------------ recording
    def event(self, rid: int, name: str, t: float, **attrs) -> None:
        """Stamp one lifecycle event for request ``rid`` at clock ``t``."""
        if not self.on:
            return
        span = SpanEvent(rid, name, t, attrs or None)
        if self.flight is not None:
            self.flight.push_event(span)
        if self.n_events >= self.max_events:
            self.dropped_events += 1
            if self.metrics is not None:
                self.metrics.inc("obs.spans_dropped")
            return
        lst = self.events.get(rid)
        if lst is None:
            lst = self.events[rid] = []
        lst.append(span)
        self.n_events += 1

    def request_event(self, req, name: str, t: float, **attrs) -> None:
        """Like ``event`` but also mounts the span list on ``req.spans`` so
        the Request object itself accumulates its timeline."""
        if not self.on:
            return
        self.event(req.rid, name, t, **attrs)
        req.spans = self.events.get(req.rid)

    def tick(self, record: TickRecord) -> None:
        if not self.on:
            return
        if self.flight is not None:
            self.flight.push_tick(record)
        if len(self.ticks) >= self.max_ticks:
            self.dropped_ticks += 1
            if self.metrics is not None:
                self.metrics.inc("obs.ticks_dropped")
            return
        self.ticks.append(record)

    # -------------------------------------------------------------- queries
    def events_for(self, rid: int) -> List[SpanEvent]:
        return self.events.get(rid, [])

    def summary(self) -> Dict[str, Any]:
        return {"requests": len(self.events), "events": self.n_events,
                "ticks": len(self.ticks),
                "dropped_events": self.dropped_events,
                "dropped_ticks": self.dropped_ticks}

    def to_chrome_trace(self, label: str = "repro") -> Dict[str, Any]:
        return to_chrome_trace(self, label=label)


NULL_TRACER = Tracer(enabled=False)


# ------------------------------------------------------- chrome trace_event
# phase boundaries: event name -> slice name the event OPENS on a request
# lane (None closes without opening — terminal events)
_OPENS = {QUEUED: "queued", ADMITTED: "prefill", RESUME: "prefill",
          PREFILL_COMPLETE: "decode", PREEMPT: "preempted"}
_INSTANT = {PREFILL_CHUNK, COW_BIND, ARRIVAL, ROUTED, REJECTED}

_US = 1e6  # timeline seconds -> trace_event microseconds


def _request_lane(rid: int, evs: List[SpanEvent], out: List[Dict]) -> None:
    open_name: Optional[str] = None
    open_ts = 0.0
    for ev in sorted(evs, key=lambda e: e.t):
        ts = ev.t * _US
        if ev.name in _INSTANT:
            out.append({"name": ev.name, "ph": "i", "ts": ts, "pid": 1,
                        "tid": rid, "s": "t",
                        "args": ev.attrs or {}})
            continue
        if open_name is not None:
            out.append({"name": open_name, "ph": "X", "ts": open_ts,
                        "dur": max(0.0, ts - open_ts), "pid": 1, "tid": rid,
                        "args": {}})
            open_name = None
        nxt = _OPENS.get(ev.name)
        if nxt is not None:
            open_name, open_ts = nxt, ts
        elif ev.name in (COMPLETE, DROP):
            out.append({"name": ev.name, "ph": "i", "ts": ts, "pid": 1,
                        "tid": rid, "s": "t", "args": ev.attrs or {}})
    if open_name is not None:  # request still in flight at export time
        out.append({"name": open_name + " (open)", "ph": "i", "ts": open_ts,
                    "pid": 1, "tid": rid, "s": "t", "args": {}})


def to_chrome_trace(tracer: Tracer, label: str = "repro") -> Dict[str, Any]:
    """Render a ``Tracer`` as a Chrome ``trace_event`` JSON object.

    Request lifecycles become "X" complete slices (queued/prefill/decode/
    preempted) plus "i" instants on pid 1, one tid per rid; tick records
    become "X" slices on pid 2, one tid per backend, with phase costs and
    batch geometry in ``args``. ``ts`` is the *timeline* clock in µs;
    tick ``dur`` is the measured wall cost of the tick's phases.
    """
    out: List[Dict] = [
        {"name": "process_name", "ph": "M", "pid": 1, "tid": 0, "ts": 0,
         "args": {"name": f"{label}: requests"}},
        {"name": "process_name", "ph": "M", "pid": 2, "tid": 0, "ts": 0,
         "args": {"name": f"{label}: engine ticks"}},
    ]
    for rid in sorted(tracer.events):
        _request_lane(rid, tracer.events[rid], out)

    backends = sorted({r.backend for r in tracer.ticks})
    tid_of = {b: i for i, b in enumerate(backends)}
    for b in backends:
        out.append({"name": "thread_name", "ph": "M", "pid": 2,
                    "tid": tid_of[b], "ts": 0, "args": {"name": b}})
    for rec in tracer.ticks:
        args = rec.to_dict()
        args.pop("backend", None)
        out.append({"name": f"tick:{rec.kind}", "ph": "X",
                    "ts": rec.t * _US,
                    "dur": max(0.0, rec.total_ms * 1e3),  # ms -> µs
                    "pid": 2, "tid": tid_of[rec.backend], "args": args})
    return {"traceEvents": out, "displayTimeUnit": "ms",
            "otherData": {"label": label, **tracer.summary()}}


_KNOWN_PH = {"X", "B", "E", "i", "I", "M", "C"}


def validate_chrome_trace(obj: Any) -> int:
    """Validate an object against the Chrome trace_event schema subset we
    emit. Returns the number of events; raises ``ValueError`` on the first
    malformed event (this is the CI schema gate)."""
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("trace must be a JSON object with 'traceEvents'")
    events = obj["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i}: not an object")
        for key in ("name", "ph", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"event {i}: missing required key {key!r}")
        if not isinstance(ev["name"], str) or not ev["name"]:
            raise ValueError(f"event {i}: 'name' must be a non-empty string")
        ph = ev["ph"]
        if ph not in _KNOWN_PH:
            raise ValueError(f"event {i}: unknown phase {ph!r}")
        if ph != "M":
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)):
                raise ValueError(f"event {i}: 'ts' must be a number")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"event {i}: 'X' needs numeric dur >= 0")
        if ph in ("i", "I") and ev.get("s", "t") not in ("g", "p", "t"):
            raise ValueError(f"event {i}: instant scope must be g|p|t")
        if "args" in ev and not isinstance(ev["args"], dict):
            raise ValueError(f"event {i}: 'args' must be an object")
    return len(events)
