"""Host/device dispatch-floor attribution over sampled tick records.

The engine's execute phase is three different costs wearing one
``exec_ms`` number: the Python/jit **dispatch** (jax returns before the
device finishes — building and enqueueing the computation), the actual
**device** compute (exposed by fencing the call's outputs with
``jax.block_until_ready``), and the **host sync** tail (the
device-to-host ``np.asarray`` copy plus per-slot token bookkeeping).

With ``InProcessServingEngine(profile_dispatch=N)`` every Nth tick fences
its jitted call and lands the split on its ``TickRecord``
(``dispatch_ms`` / ``device_ms`` / ``host_sync_ms``; NaN on unsampled
ticks). Fencing serializes dispatch and compute, so a sampled tick is a
*measurement*, not the steady state — which is exactly the point: the
dispatch + host-sync floor is the budget the async double-buffered tick
loop (ROADMAP) must hide, and this table is the baseline it gets compared
against.

``dispatch_floor_summary`` aggregates the sampled records per tick type
(fused vs decode) for the EXPERIMENTS.md §Dispatch floor table.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List

import numpy as np

from .trace import TickRecord

__all__ = ["dispatch_floor_summary"]


def dispatch_floor_summary(ticks: Iterable[TickRecord]) -> Dict[str, Dict]:
    """Per-tick-type means/medians of the sampled dispatch/device/host-sync
    split. ``dispatch_frac``/``host_sync_frac`` are the shares of the
    sampled exec phase spent off-device — together, the floor an async
    tick loop could overlap away.

    When the records come from an ``async_tick`` engine, each sampled tick
    also carries the one-tick-lag commit columns (``commit_ms`` /
    ``commit_wait_ms`` / ``hidden_host_ms`` — see ``TickRecord``); their
    means land in the summary so the dispatch-floor table can show how
    much host time the pipeline actually hid (``hidden_host_ms_mean``)
    next to the sync baseline's exposed floor."""
    by_kind: Dict[str, List[TickRecord]] = {}
    for r in ticks:
        if math.isfinite(r.dispatch_ms):
            by_kind.setdefault(r.kind, []).append(r)
    out: Dict[str, Dict] = {}
    for kind, recs in sorted(by_kind.items()):
        disp = np.asarray([r.dispatch_ms for r in recs])
        dev = np.asarray([r.device_ms for r in recs])
        host = np.asarray([r.host_sync_ms for r in recs])
        total = np.maximum(disp + dev + host, 1e-9)
        out[kind] = {
            "n_sampled": len(recs),
            "dispatch_ms_mean": float(disp.mean()),
            "dispatch_ms_p50": float(np.percentile(disp, 50)),
            "device_ms_mean": float(dev.mean()),
            "device_ms_p50": float(np.percentile(dev, 50)),
            "host_sync_ms_mean": float(host.mean()),
            "host_sync_ms_p50": float(np.percentile(host, 50)),
            "exec_ms_mean": float(total.mean()),
            "dispatch_frac": float((disp / total).mean()),
            "host_sync_frac": float((host / total).mean()),
        }
        # async overlap columns: only ticks that committed a previous exec
        acom = [r for r in recs if math.isfinite(r.commit_ms)]
        if acom:
            commit = np.asarray([r.commit_ms for r in acom])
            wait = np.asarray([r.commit_wait_ms for r in acom])
            hidden = np.asarray([r.hidden_host_ms for r in acom])
            out[kind].update({
                "n_async_sampled": len(acom),
                "commit_ms_mean": float(commit.mean()),
                "commit_wait_ms_mean": float(wait.mean()),
                "hidden_host_ms_mean": float(hidden.mean()),
            })
    return out
