"""Rolling-window metric aggregation — the online tier of the registry
(DESIGN.md §Observability, "Online tier").

The cumulative instruments in ``registry.py`` answer "what happened since
the run started"; controllers reacting mid-run need "what happened in the
last N seconds". This module adds time-bucketed ring-buffer instruments:

* ``WindowedCounter``   — per-bucket increment totals; query ``total``/
  ``rate`` over any sub-window up to the ring span.
* ``WindowedHistogram`` — per-bucket count/sum plus a bounded sample list;
  query ``percentile``/``mean``/``count`` over a sub-window.
* ``MetricWindows``     — the name -> windowed-instrument map mounted on an
  ``Observability`` bundle next to the cumulative registry. Publishers feed
  BOTH surfaces under the SAME metric names (``requests.completed``,
  ``request.latency_ms``, ...), so a dashboard reading windows and a
  post-run report reading the registry never disagree on vocabulary.

Clock-domain rule (the same one span tracing obeys): every ``t`` handed to
a windowed instrument comes from the owning backend's ONE clock — the
engine's ``clock=`` callable, the DES virtual time, or a benchmark replay
clock. The ring has no clock of its own; it only quantizes the stamps it
is given into ``bucket_s``-wide buckets.

Advance is O(1) amortized: moving the newest bucket forward zeroes at most
``n_buckets`` slots regardless of how far the clock jumped (a jump past
the whole ring resets it wholesale). Stamps that arrive *behind* the
newest bucket (DES completions observed out of submit order) clamp into
the newest bucket instead of resurrecting expired ones — windows are
approximations by construction; monotone per-backend clocks make the
approximation exact.

``NULL_WINDOWS`` is the shared disabled singleton: ``on`` is False and
every hook no-ops, so an un-windowed engine pays one attribute check per
call site (covered by the bench_engine disabled-hook gate).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

__all__ = ["WindowedCounter", "WindowedHistogram", "MetricWindows",
           "NULL_WINDOWS", "DEFAULT_WINDOW_S", "DEFAULT_BUCKETS"]

DEFAULT_WINDOW_S = 60.0   # ring span: the slowest burn-rate window fits
DEFAULT_BUCKETS = 60      # 1 s buckets — fast windows quantize to seconds
DEFAULT_BUCKET_SAMPLES = 64  # histogram samples kept per bucket


class _Ring:
    """Shared ring-index arithmetic: absolute bucket index -> slot."""

    __slots__ = ("name", "bucket_s", "n", "_cur")

    def __init__(self, name: str, window_s: float, n_buckets: int):
        assert window_s > 0 and n_buckets > 0
        self.name = name
        self.bucket_s = window_s / n_buckets
        self.n = n_buckets
        self._cur: Optional[int] = None   # absolute index of newest bucket

    @property
    def window_s(self) -> float:
        return self.bucket_s * self.n

    def _bucket(self, t: float) -> int:
        return int(t // self.bucket_s)

    def _advance(self, t: float) -> int:
        """Move the newest bucket to cover ``t``; zero the buckets stepped
        over (at most ``n`` of them — O(1) amortized). Returns the slot for
        ``t``; a stamp behind the newest bucket clamps to it."""
        b = self._bucket(t)
        cur = self._cur
        if cur is None:
            self._cur = cur = b
        elif b > cur:
            for i in range(min(b - cur, self.n)):
                self._clear((cur + 1 + i) % self.n)
            self._cur = cur = b
        return cur % self.n

    def _live_slots(self, t: float, window_s: Optional[float]) -> List[int]:
        """Slots covering the last ``window_s`` seconds ending at the newest
        bucket (after advancing to ``t``)."""
        self._advance(t)
        w = self.window_s if window_s is None else \
            min(window_s, self.window_s)
        k = max(1, min(self.n, int(np.ceil(w / self.bucket_s))))
        cur = self._cur
        return [(cur - i) % self.n for i in range(k)]

    def _clear(self, slot: int) -> None:  # pragma: no cover - overridden
        raise NotImplementedError


class WindowedCounter(_Ring):
    """Ring of per-bucket increment totals."""

    __slots__ = ("_vals",)

    def __init__(self, name: str, window_s: float = DEFAULT_WINDOW_S,
                 n_buckets: int = DEFAULT_BUCKETS):
        super().__init__(name, window_s, n_buckets)
        self._vals = [0.0] * n_buckets

    def _clear(self, slot: int) -> None:
        self._vals[slot] = 0.0

    def inc(self, t: float, amount: float = 1) -> None:
        if amount < 0:
            raise ValueError(f"window {self.name}: negative inc {amount}")
        self._vals[self._advance(t)] += amount

    def total(self, t: float, window_s: Optional[float] = None) -> float:
        """Sum over the trailing ``window_s`` (whole ring by default)."""
        return sum(self._vals[s] for s in self._live_slots(t, window_s))

    def rate(self, t: float, window_s: Optional[float] = None) -> float:
        """Events per second over the trailing window."""
        w = self.window_s if window_s is None else \
            min(window_s, self.window_s)
        return self.total(t, window_s) / max(w, 1e-12)

    def snapshot(self, t: float) -> Dict:
        return {"name": self.name, "kind": "window_counter",
                "window_s": self.window_s, "total": self.total(t),
                "rate": self.rate(t)}


class WindowedHistogram(_Ring):
    """Ring of per-bucket (count, sum, bounded samples) cells. Quantiles
    merge the live buckets' samples — estimates once a bucket overflows
    ``cap`` samples (first-``cap`` kept; count/sum stay exact)."""

    __slots__ = ("cap", "_count", "_sum", "_samples")

    def __init__(self, name: str, window_s: float = DEFAULT_WINDOW_S,
                 n_buckets: int = DEFAULT_BUCKETS,
                 cap: int = DEFAULT_BUCKET_SAMPLES):
        super().__init__(name, window_s, n_buckets)
        self.cap = cap
        self._count = [0] * n_buckets
        self._sum = [0.0] * n_buckets
        self._samples: List[List[float]] = [[] for _ in range(n_buckets)]

    def _clear(self, slot: int) -> None:
        self._count[slot] = 0
        self._sum[slot] = 0.0
        self._samples[slot] = []

    def observe(self, t: float, value: float) -> None:
        s = self._advance(t)
        v = float(value)
        self._count[s] += 1
        self._sum[s] += v
        if len(self._samples[s]) < self.cap:
            self._samples[s].append(v)

    def count(self, t: float, window_s: Optional[float] = None) -> int:
        return sum(self._count[s] for s in self._live_slots(t, window_s))

    def mean(self, t: float, window_s: Optional[float] = None) -> float:
        slots = self._live_slots(t, window_s)
        n = sum(self._count[s] for s in slots)
        return sum(self._sum[s] for s in slots) / n if n else float("nan")

    def percentile(self, t: float, p: float,
                   window_s: Optional[float] = None) -> float:
        vals: List[float] = []
        for s in self._live_slots(t, window_s):
            vals.extend(self._samples[s])
        if not vals:
            return float("nan")
        return float(np.percentile(np.asarray(vals), p))

    def snapshot(self, t: float) -> Dict:
        out = {"name": self.name, "kind": "window_histogram",
               "window_s": self.window_s, "count": self.count(t)}
        if out["count"]:
            out.update(mean=self.mean(t), p50=self.percentile(t, 50),
                       p99=self.percentile(t, 99))
        return out


class MetricWindows:
    """Name -> windowed instrument map, one per serving backend, mounted on
    the ``Observability`` bundle next to the cumulative registry.

    Hot-path contract mirrors the tracer's: call sites check ``self.on``
    and skip — a disabled ``MetricWindows`` (or the shared
    ``NULL_WINDOWS``) costs one attribute load + branch.
    """

    def __init__(self, enabled: bool = True,
                 window_s: float = DEFAULT_WINDOW_S,
                 n_buckets: int = DEFAULT_BUCKETS,
                 hist_cap: int = DEFAULT_BUCKET_SAMPLES):
        self.on = enabled
        self.window_s = window_s
        self.n_buckets = n_buckets
        self.hist_cap = hist_cap
        self._metrics: Dict[str, _Ring] = {}

    # ------------------------------------------------------------ factories
    def counter(self, name: str) -> WindowedCounter:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = WindowedCounter(
                name, self.window_s, self.n_buckets)
        return m

    def histogram(self, name: str) -> WindowedHistogram:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = WindowedHistogram(
                name, self.window_s, self.n_buckets, cap=self.hist_cap)
        return m

    # ---------------------------------------------------------- convenience
    def inc(self, name: str, t: float, amount: float = 1) -> None:
        if self.on:
            self.counter(name).inc(t, amount)

    def observe(self, name: str, t: float, value: float) -> None:
        if self.on:
            self.histogram(name).observe(t, value)

    def get(self, name: str):
        return self._metrics.get(name)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def rate(self, name: str, t: float,
             window_s: Optional[float] = None) -> float:
        m = self._metrics.get(name)
        return m.rate(t, window_s) if isinstance(m, WindowedCounter) else 0.0

    # -------------------------------------------------------------- export
    def snapshot(self, t: float) -> List[Dict]:
        """One row per instrument at clock ``t`` — rows carry
        ``kind: window_counter | window_histogram`` so they can ride in the
        same METRICS jsonl dump as the cumulative registry's rows."""
        return [self._metrics[n].snapshot(t) for n in self.names()]


NULL_WINDOWS = MetricWindows(enabled=False)
