"""Observability layer: metrics registry, request lifecycle tracing,
controller decision audit, and the online tier — rolling windows, SLO
burn-rate alerting, and the anomaly flight recorder (DESIGN.md
§Observability).

Everything funnels through one ``Observability`` bundle — a metrics
registry, a tracer, and a rolling-window map — constructed once per
serving backend (engine or SimCluster) and handed down to schedulers,
variant backends, the paged-KV pool, and routers. Metrics are on by
default (counter bumps cost what the old ad-hoc attribute counters cost);
tracing (``trace=True``) and windows (``windows=True``) are opt-in
because they allocate per-request/per-bucket state. A ``flight=``
``FlightRecorder`` mirrors spans/ticks into a bounded recent-past ring
(and implies tracing — the recorder rides the tracer's hooks).
``Observability.disabled()`` turns the whole layer into shared no-op
singletons for overhead studies.
"""
from __future__ import annotations

from typing import Optional

from .audit import (DecisionAudit, DecisionRecord, attach_from_requests,
                    predict_outputs)
from .flightrec import FlightRecorder, FlightTrigger
from .registry import (Counter, Gauge, Histogram, MetricsRegistry,
                       NullInstrument, NULL_REGISTRY)
from .slo import (Alert, AlertSink, BurnRateRule, CollectingSink,
                  SLOMonitor, slo_class_key)
from .profiler import dispatch_floor_summary
from .trace import (EVENT_TAXONOMY, NULL_TRACER, SpanEvent, TickRecord,
                    Tracer, to_chrome_trace, validate_chrome_trace)
from .windows import (MetricWindows, NULL_WINDOWS, WindowedCounter,
                      WindowedHistogram)

__all__ = ["Observability", "MetricsRegistry", "NULL_REGISTRY", "Counter",
           "Gauge", "Histogram", "NullInstrument", "Tracer", "NULL_TRACER",
           "SpanEvent", "TickRecord", "EVENT_TAXONOMY", "to_chrome_trace",
           "validate_chrome_trace", "DecisionAudit", "DecisionRecord",
           "predict_outputs", "attach_from_requests", "MetricWindows",
           "NULL_WINDOWS", "WindowedCounter", "WindowedHistogram", "Alert",
           "AlertSink", "BurnRateRule", "CollectingSink", "SLOMonitor",
           "slo_class_key", "FlightRecorder", "FlightTrigger",
           "dispatch_floor_summary"]


class Observability:
    """One registry + one tracer + one window map, the unit components are
    wired with.

    Hot paths should cache ``obs.metrics`` / ``obs.tracer`` /
    ``obs.windows`` locally and call the instruments directly — the bundle
    is plumbing, not a hop.
    """

    def __init__(self, trace: bool = False, metrics: bool = True,
                 max_events: int = 200_000, windows: bool = False,
                 flight: Optional[FlightRecorder] = None):
        self.metrics = MetricsRegistry() if metrics else NULL_REGISTRY
        self.flight = flight
        if flight is not None and self.metrics.enabled:
            # drop counters exist from t=0 so METRICS dumps always carry
            # them (the CI smoke asserts them zero) — same below for trace
            trace = True   # the flight ring rides the tracer's hooks
        if trace and self.metrics.enabled:
            self.metrics.counter("obs.spans_dropped")
            self.metrics.counter("obs.ticks_dropped")
        self.tracer = (Tracer(enabled=True, max_events=max_events,
                              metrics=(self.metrics if self.metrics.enabled
                                       else None), flight=flight)
                       if trace else NULL_TRACER)
        self.windows = MetricWindows() if windows else NULL_WINDOWS

    @classmethod
    def disabled(cls) -> "Observability":
        return cls(trace=False, metrics=False)

    @property
    def tracing(self) -> bool:
        return self.tracer.on

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"Observability(metrics={self.metrics.enabled}, "
                f"trace={self.tracer.on}, windows={self.windows.on}, "
                f"flight={self.flight is not None})")
