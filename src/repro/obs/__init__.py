"""Observability layer: metrics registry, request lifecycle tracing, and
controller decision audit (DESIGN.md §Observability).

Everything funnels through one ``Observability`` bundle — a metrics
registry plus a tracer — constructed once per serving backend (engine or
SimCluster) and handed down to schedulers, variant backends, the paged-KV
pool, and routers. Metrics are on by default (counter bumps cost what the
old ad-hoc attribute counters cost); tracing is opt-in (``trace=True``)
because it allocates per-request event lists. ``Observability.disabled()``
turns the whole layer into shared no-op singletons for overhead studies.
"""
from __future__ import annotations

from typing import Optional

from .audit import (DecisionAudit, DecisionRecord, attach_from_requests,
                    predict_outputs)
from .registry import (Counter, Gauge, Histogram, MetricsRegistry,
                       NullInstrument, NULL_REGISTRY)
from .trace import (EVENT_TAXONOMY, NULL_TRACER, SpanEvent, TickRecord,
                    Tracer, to_chrome_trace, validate_chrome_trace)

__all__ = ["Observability", "MetricsRegistry", "NULL_REGISTRY", "Counter",
           "Gauge", "Histogram", "NullInstrument", "Tracer", "NULL_TRACER",
           "SpanEvent", "TickRecord", "EVENT_TAXONOMY", "to_chrome_trace",
           "validate_chrome_trace", "DecisionAudit", "DecisionRecord",
           "predict_outputs", "attach_from_requests"]


class Observability:
    """One registry + one tracer, the unit components are wired with.

    Hot paths should cache ``obs.metrics`` / ``obs.tracer`` locally and
    call the instruments directly — the bundle is plumbing, not a hop.
    """

    def __init__(self, trace: bool = False, metrics: bool = True,
                 max_events: int = 200_000):
        self.metrics = MetricsRegistry() if metrics else NULL_REGISTRY
        self.tracer = (Tracer(enabled=True, max_events=max_events)
                       if trace else NULL_TRACER)

    @classmethod
    def disabled(cls) -> "Observability":
        return cls(trace=False, metrics=False)

    @property
    def tracing(self) -> bool:
        return self.tracer.on

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"Observability(metrics={self.metrics.enabled}, "
                f"trace={self.tracer.on})")
