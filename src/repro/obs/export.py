"""File exporters + schema validators for the observability artifacts.

Three artifact kinds, all written under ``reports/`` by benchmarks and
``examples/serve_autoscale.py --trace``:

* ``TRACE_engine.json``    — Chrome ``trace_event`` JSON (Perfetto-loadable)
* ``METRICS_engine.jsonl`` — one registry instrument snapshot per line
* ``AUDIT_decisions.jsonl``— one controller decision per line

(Flight-recorder ``FLIGHT_<reason>.json`` dumps are the same Chrome
trace_event schema as ``TRACE_engine.json`` — validate them with
``--validate-trace`` too.)

The module doubles as the CI schema gate::

    python -m repro.obs.export --validate-trace reports/TRACE_engine.json \
                               --validate-metrics reports/METRICS_engine.jsonl \
                               --assert-zero obs.spans_dropped

exits non-zero on the first malformed artifact, and ``--assert-zero NAME``
fails if any validated metrics file carries a nonzero (or missing) counter
``NAME`` — the CI smoke uses it to prove the tracer never dropped a span.

``--summarize <file.jsonl>`` pretty-prints a metrics or audit dump (the
file kind is sniffed from the rows) as an aligned table for eyeballing
runs without loading artifacts into a UI.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, Iterable, Optional

from .audit import DecisionAudit
from .registry import MetricsRegistry
from .trace import Tracer, to_chrome_trace, validate_chrome_trace

__all__ = ["write_chrome_trace", "write_metrics_jsonl", "write_audit_jsonl",
           "validate_trace_file", "validate_metrics_file", "assert_zero",
           "summarize_file"]


def write_chrome_trace(path: str, tracer: Tracer,
                       label: str = "repro") -> int:
    """Render ``tracer`` to Chrome trace_event JSON at ``path``. The
    object is validated before writing — we never emit a malformed trace.
    Returns the event count."""
    obj = to_chrome_trace(tracer, label=label)
    n = validate_chrome_trace(obj)
    with open(path, "w") as f:
        json.dump(obj, f)
    return n


def write_metrics_jsonl(path: str, registry: MetricsRegistry,
                        extra: Optional[Iterable[Dict]] = None) -> int:
    """Dump every registry instrument as one JSON object per line."""
    return registry.dump_jsonl(path, extra=extra)


def write_audit_jsonl(path: str, audit: DecisionAudit) -> int:
    """Dump the controller decision log, one decision per line."""
    return audit.to_jsonl(path)


# ------------------------------------------------------------- validation
def validate_trace_file(path: str) -> int:
    """Load + schema-check a trace_event JSON file. Returns event count;
    raises ``ValueError`` on malformed content."""
    with open(path) as f:
        obj = json.load(f)
    return validate_chrome_trace(obj)


def validate_metrics_file(path: str) -> int:
    """Schema-check a metrics JSONL dump: every line a JSON object with a
    ``name`` and a known ``kind``. Returns the row count."""
    kinds = {"counter", "gauge", "histogram", "meta",
             "window_counter", "window_histogram"}
    n = 0
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            row = json.loads(line)
            if not isinstance(row, dict):
                raise ValueError(f"{path}:{i + 1}: row is not an object")
            if not isinstance(row.get("name"), str):
                raise ValueError(f"{path}:{i + 1}: missing 'name'")
            if row.get("kind") not in kinds:
                raise ValueError(f"{path}:{i + 1}: unknown kind "
                                 f"{row.get('kind')!r}")
            n += 1
    if n == 0:
        raise ValueError(f"{path}: empty metrics dump")
    return n


def assert_zero(path: str, name: str) -> None:
    """Assert that counter ``name`` exists in metrics JSONL ``path`` with
    value 0 — missing is as loud as nonzero (an absent drop counter means
    the instrumentation was never armed, which is its own bug)."""
    found = None
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            row = json.loads(line)
            if row.get("name") == name and row.get("kind") == "counter":
                found = float(row.get("value", 0.0))
    if found is None:
        raise ValueError(f"{path}: counter {name!r} not present")
    if found != 0.0:
        raise ValueError(f"{path}: counter {name!r} = {found:g}, expected 0")


# ------------------------------------------------------------- summarize
def _load_jsonl(path: str) -> list:
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows


def _table(header: list, rows: Iterable[list]) -> str:
    """Align columns: first column left, the rest right."""
    cells = [header] + [[str(c) for c in r] for r in rows]
    widths = [max(len(r[i]) for r in cells) for i in range(len(header))]
    out = []
    for r in cells:
        out.append("  ".join(
            r[i].ljust(widths[i]) if i == 0 else r[i].rjust(widths[i])
            for i in range(len(r))))
    return "\n".join(out)


def _fmt(v: Any, nd: int = 1) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.{nd}f}"
    return str(v)


def _summarize_metrics(rows: list) -> str:
    header = ["name", "kind", "value", "count", "mean", "p50", "p95", "p99"]
    body = []
    for r in sorted(rows, key=lambda r: (r.get("kind") == "meta",
                                         r.get("name", ""))):
        body.append([r.get("name", "?"), r.get("kind", "?"),
                     _fmt(r.get("value")), _fmt(r.get("count")),
                     _fmt(r.get("mean")), _fmt(r.get("p50")),
                     _fmt(r.get("p95")), _fmt(r.get("p99"))])
    return _table(header, body)


def _summarize_audit(rows: list) -> str:
    header = ["t", "reason", "controller", "lam", "units", "objective",
              "pred_p99", "meas_p99", "n_req"]
    body = []
    for r in rows:
        ins = r.get("inputs", {}) or {}
        outs = r.get("outputs", {}) or {}
        pred = outs.get("predicted", {}) or {}
        meas = r.get("measured", {}) or {}
        units = outs.get("units", {}) or {}
        body.append([_fmt(r.get("t")), r.get("reason", "-"),
                     r.get("controller", "-"), _fmt(ins.get("lam")),
                     "+".join(f"{m}:{n}" for m, n in sorted(units.items())
                              if n) or "-",
                     _fmt(outs.get("objective"), 3),
                     _fmt(pred.get("p99_ms")), _fmt(meas.get("p99_ms")),
                     _fmt(meas.get("n_requests"))])
    return _table(header, body)


def summarize_file(path: str) -> str:
    """Aligned pretty-print of a metrics or audit JSONL dump; the kind is
    sniffed from the first row (metrics rows carry ``kind``, audit rows
    ``controller``/``inputs``)."""
    rows = _load_jsonl(path)
    if not rows:
        raise ValueError(f"{path}: empty dump")
    if "kind" in rows[0]:
        return _summarize_metrics(rows)
    if "controller" in rows[0] or "inputs" in rows[0]:
        return _summarize_audit(rows)
    raise ValueError(f"{path}: rows look like neither metrics nor audit")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--validate-trace", action="append", default=[],
                    help="trace_event JSON file(s) to schema-check "
                         "(TRACE_*.json and FLIGHT_*.json)")
    ap.add_argument("--validate-metrics", action="append", default=[],
                    help="metrics JSONL file(s) to schema-check")
    ap.add_argument("--assert-zero", action="append", default=[],
                    metavar="NAME",
                    help="fail unless counter NAME is present and 0 in "
                         "every --validate-metrics file")
    ap.add_argument("--summarize", action="append", default=[],
                    help="metrics/audit JSONL file(s) to pretty-print")
    args = ap.parse_args(argv)
    ok = True
    for path in args.validate_trace:
        try:
            n = validate_trace_file(path)
            print(f"OK {path}: {n} trace events")
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"FAIL {path}: {e}", file=sys.stderr)
            ok = False
    for path in args.validate_metrics:
        try:
            n = validate_metrics_file(path)
            print(f"OK {path}: {n} metric rows")
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"FAIL {path}: {e}", file=sys.stderr)
            ok = False
        for name in args.assert_zero:
            try:
                assert_zero(path, name)
                print(f"OK {path}: {name} == 0")
            except (OSError, ValueError, json.JSONDecodeError) as e:
                print(f"FAIL {path}: {e}", file=sys.stderr)
                ok = False
    if args.assert_zero and not args.validate_metrics:
        print("FAIL --assert-zero requires --validate-metrics",
              file=sys.stderr)
        ok = False
    for path in args.summarize:
        try:
            print(f"== {path}")
            print(summarize_file(path))
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"FAIL {path}: {e}", file=sys.stderr)
            ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
