"""File exporters + schema validators for the observability artifacts.

Three artifact kinds, all written under ``reports/`` by benchmarks and
``examples/serve_autoscale.py --trace``:

* ``TRACE_engine.json``    — Chrome ``trace_event`` JSON (Perfetto-loadable)
* ``METRICS_engine.jsonl`` — one registry instrument snapshot per line
* ``AUDIT_decisions.jsonl``— one controller decision per line

The module doubles as the CI schema gate::

    python -m repro.obs.export --validate-trace reports/TRACE_engine.json \
                               --validate-metrics reports/METRICS_engine.jsonl

exits non-zero on the first malformed artifact.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, Iterable, Optional

from .audit import DecisionAudit
from .registry import MetricsRegistry
from .trace import Tracer, to_chrome_trace, validate_chrome_trace

__all__ = ["write_chrome_trace", "write_metrics_jsonl", "write_audit_jsonl",
           "validate_trace_file", "validate_metrics_file"]


def write_chrome_trace(path: str, tracer: Tracer,
                       label: str = "repro") -> int:
    """Render ``tracer`` to Chrome trace_event JSON at ``path``. The
    object is validated before writing — we never emit a malformed trace.
    Returns the event count."""
    obj = to_chrome_trace(tracer, label=label)
    n = validate_chrome_trace(obj)
    with open(path, "w") as f:
        json.dump(obj, f)
    return n


def write_metrics_jsonl(path: str, registry: MetricsRegistry,
                        extra: Optional[Iterable[Dict]] = None) -> int:
    """Dump every registry instrument as one JSON object per line."""
    return registry.dump_jsonl(path, extra=extra)


def write_audit_jsonl(path: str, audit: DecisionAudit) -> int:
    """Dump the controller decision log, one decision per line."""
    return audit.to_jsonl(path)


# ------------------------------------------------------------- validation
def validate_trace_file(path: str) -> int:
    """Load + schema-check a trace_event JSON file. Returns event count;
    raises ``ValueError`` on malformed content."""
    with open(path) as f:
        obj = json.load(f)
    return validate_chrome_trace(obj)


def validate_metrics_file(path: str) -> int:
    """Schema-check a metrics JSONL dump: every line a JSON object with a
    ``name`` and a known ``kind``. Returns the row count."""
    kinds = {"counter", "gauge", "histogram", "meta"}
    n = 0
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            row = json.loads(line)
            if not isinstance(row, dict):
                raise ValueError(f"{path}:{i + 1}: row is not an object")
            if not isinstance(row.get("name"), str):
                raise ValueError(f"{path}:{i + 1}: missing 'name'")
            if row.get("kind") not in kinds:
                raise ValueError(f"{path}:{i + 1}: unknown kind "
                                 f"{row.get('kind')!r}")
            n += 1
    if n == 0:
        raise ValueError(f"{path}: empty metrics dump")
    return n


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--validate-trace", action="append", default=[],
                    help="trace_event JSON file(s) to schema-check")
    ap.add_argument("--validate-metrics", action="append", default=[],
                    help="metrics JSONL file(s) to schema-check")
    args = ap.parse_args(argv)
    ok = True
    for path in args.validate_trace:
        try:
            n = validate_trace_file(path)
            print(f"OK {path}: {n} trace events")
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"FAIL {path}: {e}", file=sys.stderr)
            ok = False
    for path in args.validate_metrics:
        try:
            n = validate_metrics_file(path)
            print(f"OK {path}: {n} metric rows")
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"FAIL {path}: {e}", file=sys.stderr)
            ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
