"""Production mesh builders.

Target: TPU v5e. Single pod = 16×16 (256 chips, axes data×model);
multi-pod = 2×16×16 (512 chips, axes pod×data×model) where the pod axis is an
outer data-parallel / replica axis (gradient all-reduce over DCN in training;
independent serving replicas — i.e. the resource pools InfAdapter's solver
allocates variants into).

Functions, not module constants: importing this module never touches jax
device state (the 512-device XLA flag is set only by dryrun.py).
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=axis_types)


def batch_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes the global batch is sharded over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis_size(mesh) -> int:
    return mesh.shape["model"]


def batch_axis_size(mesh) -> int:
    out = 1
    for a in batch_axes(mesh):
        out *= mesh.shape[a]
    return out
