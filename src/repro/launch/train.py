"""Training launcher.

CPU demo: train a reduced config with the full substrate. On TPU the same
``make_train_step`` lowers against ``make_production_mesh()`` with the
sharding policy (exactly what launch/dryrun.py proves for every arch × shape).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --steps 100 --batch 8 --seq 128 [--microbatches 2]
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, smoke_variant
from repro.data.tokens import SyntheticTokenPipeline
from repro.launch.steps import make_train_step
from repro.models.model import build_model
from repro.train import checkpoint as ckpt
from repro.train.optimizer import adam_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--full-config", action="store_true",
                    help="use the production config (TPU-scale; CPU will OOM)")
    ap.add_argument("--ckpt-dir", default="",
                    help="checkpoint directory (resume if it has checkpoints)")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = smoke_variant(cfg).replace(num_layers=4, d_model=256, d_ff=512,
                                         vocab_size=512, remat=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params, microbatches={args.microbatches}")

    step = jax.jit(make_train_step(cfg, microbatches=args.microbatches))
    opt = adam_init(params)
    start = 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        state, meta = ckpt.restore(args.ckpt_dir, {"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        start = ckpt.latest_step(args.ckpt_dir) + 1
        print(f"resumed from step {start - 1}")
    pipe = SyntheticTokenPipeline(vocab=cfg.vocab_size, seq_len=args.seq,
                                  batch=args.batch)
    t0 = time.time()
    for i in range(start, start + args.steps):
        params, opt, metrics = step(params, opt, pipe.next_batch())
        if i % max(args.steps // 10, 1) == 0:
            print(f"step {i:4d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e}")
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, i, {"params": params, "opt": opt},
                      metadata={"loss": float(metrics['loss'])})
            ckpt.prune(args.ckpt_dir, keep=3)
    dt = time.time() - t0
    print(f"{args.steps} steps in {dt:.1f}s "
          f"({args.steps * args.batch * args.seq / dt:.0f} tok/s), "
          f"final loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
