import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape) pair, lower + compile the appropriate
step function on the production mesh — single-pod 16×16 (256 chips) and
multi-pod 2×16×16 (512 chips) — and record memory/cost/collective analysis
for EXPERIMENTS.md §Dry-run and §Roofline.

The two lines above MUST stay the first statements in this module: jax locks
the device count at first init, and only the dry-run should see 512
placeholder devices.

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all [--multi-pod] \
      [--out reports/dryrun]
"""
import argparse   # noqa: E402
import json       # noqa: E402
import sys        # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax                          # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.analysis import roofline as rl                   # noqa: E402
from repro.configs import (ALL_ARCHS, SHAPES, adapt_config_for_shape,  # noqa: E402
                           get_config, get_shape)
from repro.launch import steps as steps_mod                 # noqa: E402
from repro.launch.mesh import make_production_mesh          # noqa: E402
from repro.sharding.context import activation_sharding  # noqa: E402
from repro.sharding.policy import (batch_specs, cache_specs,  # noqa: E402
                                   param_specs)

# Serving weights that exceed one device's HBM under 16-way TP fall back to
# ZeRO-style extra sharding over the data axis (qwen3-moe-235b).
SERVE_FSDP_BYTES = 12e9


def _ns(mesh, tree):
    return jax.tree_util.tree_map(
        lambda sp: NamedSharding(mesh, sp), tree,
        is_leaf=lambda x: isinstance(x, P))


def _compile_once(cfg, shape, mesh, microbatches: int = 1, zero: int = 3):
    """Lower + compile one step function; return (compiled, seconds, report).

    ``zero``: 3 = fully sharded params+optimizer over the data axis (default);
    2 = optimizer state sharded, params TP-only (no per-layer weight gathers).
    """
    fn, args = steps_mod.input_specs(cfg, shape, microbatches=microbatches)
    params = args[0]
    param_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree_util.tree_leaves(params))
    fsdp = (shape.kind == "train"
            or param_bytes / mesh.shape["model"] > SERVE_FSDP_BYTES)
    if shape.kind == "train" and zero == 2:
        pspecs, report = param_specs(cfg, params, mesh, fsdp=False)
        ospecs_m, _ = param_specs(cfg, params, mesh, fsdp=True)
    else:
        pspecs, report = param_specs(cfg, params, mesh, fsdp=fsdp)
        ospecs_m = pspecs

    if shape.kind == "train":
        ospecs = type(args[1])(step=P(), mu=ospecs_m, nu=ospecs_m)
        bspecs = batch_specs(cfg, args[2], mesh, shape.global_batch)
        in_sh = (_ns(mesh, pspecs), _ns(mesh, ospecs), _ns(mesh, bspecs))
        out_sh = (_ns(mesh, pspecs), _ns(mesh, ospecs), None)
    elif shape.kind == "prefill":
        bspecs = batch_specs(cfg, args[1], mesh, shape.global_batch)
        in_sh = (_ns(mesh, pspecs), _ns(mesh, bspecs))
        out_sh = None
    else:
        cspecs = cache_specs(cfg, args[1], mesh, shape.global_batch)
        tspecs = batch_specs(cfg, args[2], mesh, shape.global_batch)
        in_sh = (_ns(mesh, pspecs), _ns(mesh, cspecs), _ns(mesh, tspecs))
        # cache comes back with the same sharding: no per-step resharding
        out_sh = (None, _ns(mesh, cspecs))

    t0 = time.time()
    # NamedShardings carry the mesh; the activation-sharding context addition-
    # ally pins batch shardings inside the model (§Perf hillclimb A).
    baxes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    gb = shape.global_batch
    bsz = 1
    for a in baxes:
        bsz *= mesh.shape[a]
    baxes = baxes if (gb % bsz == 0 and gb >= bsz) else None
    with activation_sharding(mesh, baxes):
        compiled = jax.jit(fn, in_shardings=in_sh,
                           out_shardings=out_sh).lower(*args).compile()
    return compiled, time.time() - t0, report, fsdp, param_bytes


def _cost_of(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    coll, _ = rl.collective_bytes(hlo)
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll, hlo)


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            overrides: Optional[Dict] = None, verbose: bool = True,
            microbatches: int = 1, zero: int = 3) -> Dict:
    shape = get_shape(shape_name)
    cfg = get_config(arch)
    cfg, note = adapt_config_for_shape(cfg, shape)
    if cfg is None:
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": note}
    cfg = cfg.replace(dtype="bfloat16",
                      param_dtype="float32" if shape.kind == "train"
                      else "bfloat16")
    if overrides:
        cfg = cfg.replace(**overrides)

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    mesh_name = "x".join(str(mesh.shape[a]) for a in mesh.axis_names)

    # 1) THE dry-run artifact: full config, layer-scanned, lower + compile.
    compiled, compile_s, report, fsdp, param_bytes = _compile_once(
        cfg.replace(scan_layers=True), shape, mesh, microbatches=microbatches,
        zero=zero)
    f_s, b_s, x_s, hlo = _cost_of(compiled)
    mem = compiled.memory_analysis()
    mem_per_dev = None
    if mem is not None:
        mem_per_dev = float(mem.argument_size_in_bytes
                            + mem.output_size_in_bytes
                            + mem.temp_size_in_bytes)

    # 2) Cost calibration. XLA's cost analysis counts a while/scan body once,
    # and the layer-scan adds stacked-cache slice traffic + XLA:CPU convert
    # artifacts a TPU in-place/donated execution would not pay. Per-layer cost
    # is therefore recovered from two fast *unrolled* compiles:
    #   unroll-1L: v_1 = outside + layer
    #   unroll-2L: v_2 = outside + 2·layer
    #   => total(L) = outside + L·layer = 2·v_1 − v_2 + L·(v_2 − v_1)
    # Exact for the uniform layer stacks all assigned archs use.
    L = cfg.num_layers
    t_cal = time.time()
    # Serve shapes calibrate in fp32 and halve the byte/collective totals:
    # XLA:CPU inserts bf16→f32 convert copies around every dot that a TPU's
    # native-bf16 MXU never materializes; an all-fp32 run has no converts and
    # exactly 2× the TPU-bf16 traffic. (Training is mixed fp32-state/bf16-
    # compute, so its numbers are kept as-is and documented as upper bounds.)
    if shape.kind == "train":
        cal_base, byte_scale = cfg, 1.0
    else:
        cal_base = cfg.replace(dtype="float32", param_dtype="float32")
        byte_scale = 0.5
    cal1 = cal_base.replace(scan_layers=False, num_layers=1,
                            enc_layers=min(cfg.enc_layers, 1))
    compiled1, _, _, _, _ = _compile_once(cal1, shape, mesh,
                                          microbatches=microbatches, zero=zero)
    f_1, b_1, x_1, _ = _cost_of(compiled1)
    cal2 = cal_base.replace(scan_layers=False, num_layers=2,
                            enc_layers=min(cfg.enc_layers, 2))
    compiled2, _, _, _, _ = _compile_once(cal2, shape, mesh,
                                          microbatches=microbatches, zero=zero)
    f_2, b_2, x_2, _ = _cost_of(compiled2)
    cal_s = time.time() - t_cal

    def extrap(v_1, v_2):
        layer = max(v_2 - v_1, 0.0)
        outside = max(v_1 - layer, 0.0)
        return outside + L * layer

    cost = {"flops": extrap(f_1, f_2) * microbatches,
            "bytes accessed": extrap(b_1, b_2) * microbatches * byte_scale}
    coll_total = extrap(x_1, x_2) * microbatches * byte_scale
    baxes = [a for a in ("pod", "data") if a in mesh.shape]
    bshard = 1
    for a in baxes:
        bshard *= mesh.shape[a]
    if shape.global_batch % bshard or shape.global_batch < bshard:
        bshard = 1   # batch replicated (long_500k)
    heads_sharded = cfg.num_heads > 0 and cfg.num_heads % mesh.shape["model"] == 0
    xf, xb, corr_note = rl.scan_corrections(
        cfg, shape, batch_shard=bshard, model_shard=mesh.shape["model"],
        heads_sharded=heads_sharded)
    rep = rl.analyze(arch, shape_name, mesh_name, chips, cost, hlo,
                     rl.model_flops(cfg, shape), memory_bytes=mem_per_dev,
                     notes="; ".join(x for x in (note, corr_note) if x),
                     extra_flops=xf, extra_bytes=xb,
                     collective_override=coll_total)
    hbm_est = rl.analytic_hbm_bytes(
        cfg, shape, param_bytes_global=param_bytes,
        model_shard=mesh.shape["model"],
        batch_shard=bshard,
        fsdp_shard=mesh.shape.get("data", 1) if fsdp else 1,
        train=shape.kind == "train", microbatches=microbatches)
    out = rep.to_dict()
    out.update({
        "skipped": False,
        "compile_s": compile_s,
        "calibration_compile_s": cal_s,
        "hbm_estimate_bytes": hbm_est,
        "fits_v5e_16gb": hbm_est < 16e9,
        "fsdp": fsdp,
        "param_bytes_global": param_bytes,
        "sharding_fallbacks": report.fallbacks[:8],
        "n_sharded": len(report.sharded),
        "n_replicated": len(report.replicated),
        "memory_analysis": {
            "argument_bytes": mem.argument_size_in_bytes if mem else None,
            "output_bytes": mem.output_size_in_bytes if mem else None,
            "temp_bytes": mem.temp_size_in_bytes if mem else None,
        },
    })
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: compile "
              f"{compile_s:.1f}s, hbm-est "
              f"{hbm_est/1e9:.2f} GB ({'fits' if hbm_est < 16e9 else 'OVER'} "
              f"16GB v5e; xla-cpu temp {(mem_per_dev or 0)/1e9:.1f}), "
              f"dominant={rep.dominant} "
              f"(c={rep.compute_s*1e3:.2f}ms m={rep.memory_s*1e3:.2f}ms "
              f"x={rep.collective_s*1e3:.2f}ms) useful={rep.usefulness:.2f}")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="reports/dryrun")
    args = ap.parse_args(argv)

    archs = ALL_ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[dryrun] {tag}: cached")
                    continue
                try:
                    res = run_one(arch, shape, multi_pod=mp)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    failures.append(tag)
                    res = {"arch": arch, "shape": shape, "skipped": False,
                           "error": str(e)[:2000]}
                with open(path, "w") as f:
                    json.dump(res, f, indent=2, default=str)
    if failures:
        print("FAILURES:", failures)
        return 1
    print("dry-run complete")
    return 0


if __name__ == "__main__":
    sys.exit(main())
