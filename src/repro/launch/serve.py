"""Serving launcher: InfAdapter control loop over real JAX backends.

CPU-sized by default (smoke-scale variants). On a real TPU deployment the
same controller drives per-variant submeshes; resource units become chips
(see DESIGN.md §Continuous-batching serving engine) and profiles come from
`roofline_profile`.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --seconds 30 --budget 3 --beta 0.05
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.adapter import ControllerConfig, InfAdapterController
from repro.core.forecaster import MovingMaxForecaster
from repro.core.profiles import VariantProfile
from repro.serving.driver import rise_fall_load, run_serving_loop
from repro.serving.engine import InProcessServingEngine


def build_ladder(arch: str, depths=(2, 4, 6), accs=(70.0, 75.0, 78.0)):
    base = smoke_variant(get_config(arch)).replace(d_model=128)
    return {
        f"{arch}-L{d}": (base.replace(num_layers=d, name=f"{arch}-L{d}"), a)
        for d, a in zip(depths, accs)
    }


def calibrate(engine, variants, reps=3):
    profiles = {}
    for name in variants:
        engine.apply_allocation(0.0, {name: 1})
        b = engine.backends[name]
        prompts = np.ones((b.max_batch, b.prompt_len), np.int64)
        t0 = time.time()
        for _ in range(reps):
            b.generate(prompts, max_new=8)
        per_req = (time.time() - t0) / (reps * b.max_batch)
        profiles[name] = VariantProfile(
            name=name, accuracy=variants[name][1], rt=b.readiness_s,
            th_slope=1.0 / per_req, th_intercept=0.0,
            lat_base_ms=per_req * 1000,
            lat_k_ms=per_req * 1000 * b.max_batch, max_units=4)
    engine.apply_allocation(0.0, {})
    return profiles


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--seconds", type=int, default=30)
    ap.add_argument("--interval", type=float, default=6.0)
    ap.add_argument("--budget", type=int, default=3)
    ap.add_argument("--beta", type=float, default=0.05)
    ap.add_argument("--slo-ms", type=float, default=2000.0)
    args = ap.parse_args()

    variants = build_ladder(args.arch)
    engine = InProcessServingEngine(variants, max_batch=8, prompt_len=16,
                                    max_new=8, decode_chunk=4)
    print("calibrating variants...")
    profiles = calibrate(engine, variants)
    for n, p in profiles.items():
        print(f"  {n}: {p.th_slope:.1f} rps/unit, rt {p.rt:.2f}s")

    cfg = ControllerConfig(interval_s=args.interval, budget=args.budget,
                           slo_ms=args.slo_ms, beta=args.beta, gamma=0.05,
                           reactive=True, queue_aware=True)
    ctrl = InfAdapterController(profiles, MovingMaxForecaster(window=10), cfg)
    run_serving_loop(engine, ctrl, seconds=args.seconds,
                     interval=args.interval,
                     load_fn=rise_fall_load(max(args.seconds, 1)))
    s = engine.summarize(args.slo_ms, max(p.accuracy for p in profiles.values()))
    if not s:
        print(f"\nno requests completed ({engine.rejected} rejected)")
        return
    print(f"\n{s['n_requests']} requests: viol={s['violation_rate']:.1%} "
          f"p99={s['p99_ms']:.0f}ms acc_loss={s['accuracy_loss']:.2f}%")


if __name__ == "__main__":
    main()
