"""Step functions + ShapeDtypeStruct input specs for every (arch × shape).

``input_specs(cfg, shape)`` returns weak-type-correct ShapeDtypeStructs (no
device allocation) for the function the shape's kind lowers:
  train_4k     -> train_step(params, opt, batch)  (loss + Adam update, remat)
  prefill_32k  -> prefill_step(params, batch)     (prompt -> cache + logits)
  decode_*     -> serve_step(params, cache, toks) (ONE token, KV/state cache)

Audio/VLM frontends are stubs per the assignment: ``input_specs`` provides
precomputed frame/patch embeddings of the right shape.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models.model import AUDIO_FRAME_DIM, VISION_EMBED_DIM, build_model
from repro.train.optimizer import AdamConfig, adam_init, adam_update

TRAIN_ADAM = AdamConfig(lr=3e-4, warmup_steps=100, total_steps=10_000)


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def batch_specs_for(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    B = shape.global_batch
    S = shape.seq_len
    batch: Dict[str, Any] = {}
    if shape.kind == "train":
        batch["tokens"] = sds((B, S), jnp.int32)
        batch["labels"] = sds((B, S), jnp.int32)
    elif shape.kind == "prefill":
        batch["tokens"] = sds((B, S), jnp.int32)
    if cfg.is_encoder_decoder and shape.kind in ("train", "prefill"):
        batch["frames"] = sds((B, cfg.enc_seq, AUDIO_FRAME_DIM), cfg.dtype)
    if cfg.frontend == "vision_patches" and shape.kind in ("train", "prefill"):
        batch["patch_embeds"] = sds((B, cfg.num_frontend_tokens,
                                     VISION_EMBED_DIM), cfg.dtype)
    return batch


def params_shapes(cfg: ModelConfig):
    m = build_model(cfg)
    return jax.eval_shape(lambda: m.init(jax.random.PRNGKey(0)))


def opt_shapes(params):
    return jax.eval_shape(adam_init, params)


def cache_shapes(cfg: ModelConfig, shape: InputShape):
    m = build_model(cfg)
    return jax.eval_shape(
        lambda: m.init_cache(shape.global_batch, shape.seq_len))


def make_train_step(cfg: ModelConfig, microbatches: int = 1) -> Callable:
    """Training step: loss + Adam update. ``microbatches > 1`` enables
    gradient accumulation (sequential lax.scan over batch slices) — trades a
    k× smaller activation working set for k× weight re-streaming."""
    m = build_model(cfg)

    def grads_of(params, batch):
        def loss_fn(p):
            loss, metrics = m.loss(p, batch)
            return loss, metrics
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            def slice_mb(x, i):
                mb = x.shape[0] // microbatches
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

            def body(carry, i):
                acc = carry
                mb_batch = jax.tree_util.tree_map(
                    lambda x: slice_mb(x, i), batch)
                (l, met), g = grads_of(params, mb_batch)
                acc = jax.tree_util.tree_map(jnp.add, acc, g)
                return acc, (l, met["aux_loss"])

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, auxes) = jax.lax.scan(
                body, zeros, jnp.arange(microbatches))
            grads = jax.tree_util.tree_map(
                lambda g: g / microbatches, grads)
            loss = jnp.mean(losses)
            metrics = {"ce_loss": loss, "aux_loss": jnp.mean(auxes)}
        params, opt_state, opt_metrics = adam_update(TRAIN_ADAM, grads,
                                                     opt_state, params)
        metrics = dict(metrics, **opt_metrics, loss=loss)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, max_len: int) -> Callable:
    m = build_model(cfg)

    def prefill_step(params, batch):
        return m.prefill(params, batch, max_len=max_len)

    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    m = build_model(cfg)

    def serve_step(params, cache, tokens):
        return m.decode_step(params, cache, tokens)

    return serve_step


def input_specs(cfg: ModelConfig, shape: InputShape,
                microbatches: int = 1) -> Tuple[Callable, Tuple]:
    """Returns (step_fn, example ShapeDtypeStruct args)."""
    params = params_shapes(cfg)
    if shape.kind == "train":
        fn = make_train_step(cfg, microbatches=microbatches)
        return fn, (params, opt_shapes(params), batch_specs_for(cfg, shape))
    if shape.kind == "prefill":
        fn = make_prefill_step(cfg, max_len=shape.seq_len)
        return fn, (params, batch_specs_for(cfg, shape))
    # decode
    fn = make_serve_step(cfg)
    cache = cache_shapes(cfg, shape)
    toks = sds((shape.global_batch,), jnp.int32)
    return fn, (params, cache, toks)
