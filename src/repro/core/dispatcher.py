"""Dispatcher: weighted round-robin load balancing over variant backends.

Implements smooth weighted round-robin (the nginx algorithm): deterministic,
starvation-free, and over any window of W requests each backend receives a
share proportional to its weight — the property the paper needs so realized
per-variant load matches the solver's quota λ_m. Property-tested in
tests/test_dispatcher.py.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np


class WeightedRoundRobinDispatcher:
    def __init__(self):
        self._weights: Dict[str, float] = {}
        self._current: Dict[str, float] = {}
        self.dispatched: Dict[str, int] = {}

    def set_weights(self, quotas: Dict[str, float]) -> None:
        """quotas: solver's λ_m per backend (only positive entries kept)."""
        self._weights = {m: float(q) for m, q in quotas.items() if q > 1e-12}
        for m in self._weights:
            self._current.setdefault(m, 0.0)
            self.dispatched.setdefault(m, 0)
        for m in list(self._current):
            if m not in self._weights:
                del self._current[m]

    @property
    def backends(self) -> List[str]:
        return sorted(self._weights)

    def next_backend(self) -> Optional[str]:
        """Smooth WRR: add weights to currents, pick the max, subtract total."""
        if not self._weights:
            return None
        total = sum(self._weights.values())
        best, best_v = None, -np.inf
        for m, w in self._weights.items():
            self._current[m] += w
            if self._current[m] > best_v:
                best, best_v = m, self._current[m]
        self._current[best] -= total
        self.dispatched[best] = self.dispatched.get(best, 0) + 1
        return best

    def realized_shares(self) -> Dict[str, float]:
        tot = sum(self.dispatched.values())
        return {m: c / tot for m, c in self.dispatched.items()} if tot else {}

    def reset(self) -> None:
        """Zero the dispatch counters (and the smooth-WRR phase) so
        ``realized_shares`` reflects only the run that follows — the
        experiment harness calls this at the start of every replay, so a
        reused dispatcher never reports shares polluted by a previous
        trace. Weights are kept: convergence-to-quota restarts cleanly
        (property-tested in tests/test_dispatcher.py)."""
        self.dispatched = {m: 0 for m in self._weights}
        self._current = {m: 0.0 for m in self._weights}
