"""The InfAdapter control loop + the VPA+/MS+ baseline controllers.

Every ``interval_s`` (paper: 30 s) the adapter:
  1. reads per-second load history from the monitor,
  2. forecasts the next-minute max load,
  3. solves Eq. 1 for a variant set + allocations + quotas,
  4. enacts the config on the cluster (new variants become ready after their
     readiness time rt_m — the zero-downtime create-then-remove semantics the
     paper patched into VPA is the default here),
  5. pushes quotas to the dispatcher.

The cluster is abstract — the shared ``ClusterAPI`` protocol lives in
``repro.serving.api``; the discrete-event simulator (``SimCluster``) and the
real JAX serving engine (``InProcessServingEngine``) both implement it, so
every controller in this module drives either backend unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Optional, Set

import numpy as np

from repro.core.dispatcher import WeightedRoundRobinDispatcher
from repro.core.monitoring import RateMonitor
from repro.core.objective import Allocation, evaluate
from repro.core.profiles import VariantProfile
from repro.core.solver import SOLVERS
from repro.obs.audit import DecisionAudit, predict_outputs
from repro.obs.slo import CollectingSink
from repro.serving.api import ClusterAPI  # noqa: F401  (re-export: public API)


@dataclass
class ControllerConfig:
    interval_s: float = 30.0
    budget: int = 20
    slo_ms: float = 750.0
    alpha: float = 1.0
    beta: float = 0.05
    gamma: float = 0.01
    solver: str = "exact"
    min_load: float = 1.0          # floor for the predicted load
    # --- beyond-paper extensions (off by default = paper-faithful) ---
    reactive: bool = False         # emergency re-solve when observed load
    reactive_check_s: float = 5.0  # exceeds provisioned capacity
    queue_aware: bool = False      # inflate λ by backlog/interval to drain


@dataclass
class Decision:
    t: float
    predicted_load: float
    allocation: Allocation


class InfAdapterController:
    """The paper's Adapter component (forecaster + solver)."""

    def __init__(self, profiles: Mapping[str, VariantProfile],
                 forecaster, cfg: ControllerConfig,
                 dispatcher: Optional[WeightedRoundRobinDispatcher] = None,
                 audit: Optional[DecisionAudit] = None,
                 burn_alerts: Optional[CollectingSink] = None):
        self.profiles = dict(profiles)
        self.forecaster = forecaster
        self.cfg = cfg
        self.dispatcher = dispatcher or WeightedRoundRobinDispatcher()
        self.monitor = RateMonitor()
        self.decisions: List[Decision] = []
        self.audit = audit if audit is not None else DecisionAudit()
        self.burn_alerts = burn_alerts
        self._decide_reason = "interval"

    def update_profiles(self, updates: Mapping[str, VariantProfile]) -> None:
        """Online recalibration hook (``repro.profiling.drift``): swap in
        re-measured profiles between control intervals. The next ``decide``
        solves Eq. 1 against the refreshed th_m(n)/p_m(n) curves — the paper
        treats profiles as static inputs; keeping them honest against the
        live engine is the drift-recalibration extension."""
        self.profiles.update(updates)

    def predict(self) -> float:
        """Next-interval peak load λ̂ (requests/s) from the last 10 min of
        per-second history — the paper's LSTM forecaster input window (§4.1,
        Fig. 5 top); floored at ``min_load`` so Eq. 1 always has demand."""
        recent = self.monitor.history(600)
        lam = self.forecaster.predict(recent)
        return max(lam, self.cfg.min_load)

    def decide(self, t: float, cluster: ClusterAPI) -> Decision:
        """One planning pass (no actuation): forecast λ for the next interval
        (paper §4.1) and solve Eq. 1 — maximize α·AA − β·RC − γ·LC subject to
        the latency SLO and budget — seeding LC with the cluster's currently
        loaded variants."""
        lam_forecast = self.predict()
        lam = lam_forecast
        backlog = cluster.backlog(t)
        if self.cfg.queue_aware:
            lam += backlog / self.cfg.interval_s  # drain in one interval
        loaded = cluster.loaded_variants(t)
        solver = SOLVERS[self.cfg.solver]
        alloc = solver(self.profiles, lam, self.cfg.budget, self.cfg.slo_ms,
                       alpha=self.cfg.alpha, beta=self.cfg.beta,
                       gamma=self.cfg.gamma, loaded=loaded)
        d = Decision(t=t, predicted_load=lam, allocation=alloc)
        self.decisions.append(d)
        self._audit(t, cluster, lam_forecast, lam, backlog, loaded, alloc)
        return d

    def _audit(self, t: float, cluster: ClusterAPI, lam_forecast: float,
               lam: float, backlog: float, loaded: Set[str],
               alloc: Allocation) -> None:
        """Append this adaptation's inputs/outputs to the decision audit
        log (``repro.obs.audit``), including the profile-implied predicted
        p99/goodput so post-run ``attach_measured`` can compute regret."""
        cap_fn = getattr(cluster, "capacity_factor", None)
        inputs = {
            "lam_forecast": float(lam_forecast),
            "lam": float(lam),
            "backlog": float(backlog),
            "capacity_factor": (float(cap_fn(t)) if cap_fn is not None
                                else 1.0),
            "loaded": sorted(loaded),
            "solver": self.cfg.solver,
            "budget": self.cfg.budget,
            "slo_ms": self.cfg.slo_ms,
        }
        outputs = {
            "units": dict(alloc.units),
            "quotas": {m: float(q) for m, q in alloc.quotas.items()},
            "objective": float(alloc.objective),
            "aa": float(alloc.aa), "rc": float(alloc.rc),
            "lc": float(alloc.lc), "feasible": bool(alloc.feasible),
            "predicted": predict_outputs(self.profiles, alloc, lam,
                                         self.cfg.slo_ms),
        }
        reason, self._decide_reason = self._decide_reason, "interval"
        self.audit.record(t, type(self).__name__, inputs, outputs,
                          reason=reason)

    def step(self, t: float, cluster: ClusterAPI) -> Decision:
        """One full control iteration (paper Fig. 3, every ``interval_s``):
        decide, enact on the cluster (create-then-remove reconfiguration),
        and push the solver's per-variant quotas λ_m to the dispatcher."""
        d = self.decide(t, cluster)
        cluster.apply_allocation(t, d.allocation.units)
        if d.allocation.quotas:
            self.dispatcher.set_weights(d.allocation.quotas)
        return d

    def maybe_react(self, t: float, cluster: ClusterAPI) -> Optional[Decision]:
        """Beyond-paper: between intervals, if the observed short-window rate
        exceeds the last decision's provisioned capacity, re-solve immediately
        (MArk-style reactive scaling on top of the proactive loop).

        Replica-fabric clusters report ``capacity_factor`` — the fraction of
        the target allocation actually live (node crashes, placement
        shortfall). Provisioned capacity is discounted by it, so losing a
        node triggers a re-solve (and thereby re-placement) at the next
        reactive check instead of waiting out the control interval.

        A ``burn_alerts`` sink (``repro.obs.slo.CollectingSink`` fed by an
        ``SLOMonitor``) adds a second trigger: any pending burn-rate alert
        forces an immediate re-solve, independent of ``cfg.reactive`` —
        the SLO is already burning, so capacity-vs-rate arithmetic is moot.
        This is the first consumer of the goodput-aware-control roadmap
        item: the control loop reacts to *measured* SLO attainment, not
        just offered load."""
        if self.burn_alerts is not None and self.decisions:
            fired = self.burn_alerts.pop_pending()
            if fired:
                self._decide_reason = "burn_rate"
                return self.step(t, cluster)
        if not self.cfg.reactive or not self.decisions:
            return None
        last = self.decisions[-1].allocation
        cap = sum(self.profiles[m].throughput(n)
                  for m, n in last.units.items() if n > 0)
        cap_fn = getattr(cluster, "capacity_factor", None)
        if cap_fn is not None:
            cap *= cap_fn(t)
        observed = self.monitor.current_rate(window=5) * 1.1
        backlog = cluster.backlog(t)
        if observed > cap or backlog > cap * 2.0:
            self._decide_reason = "reactive"
            return self.step(t, cluster)
        return None


class MSPlusController(InfAdapterController):
    """Model-Switching+ (baseline): single variant + predictive sizing,
    same objective — the paper's MS extension."""

    def __init__(self, profiles, forecaster, cfg: ControllerConfig, **kw):
        cfg = ControllerConfig(**{**cfg.__dict__, "solver": "single"})
        super().__init__(profiles, forecaster, cfg, **kw)


class VPAPlusController:
    """Kubernetes VPA, as patched by the paper (VPA+): one *fixed* variant;
    the recommender tracks a usage percentile with headroom, scales up
    immediately, scales down conservatively (hysteresis). Zero-downtime
    create-then-remove is modeled by the cluster's readiness semantics.

    Resource recommendation follows Autopilot-style target utilization:
        n = ceil(cores needed for peak recent load / target_util)
    using the variant's own throughput profile.
    """

    def __init__(self, profile: VariantProfile, cfg: ControllerConfig,
                 target_util: float = 0.8, peak_window_s: int = 120,
                 downscale_patience: int = 4,
                 dispatcher: Optional[WeightedRoundRobinDispatcher] = None,
                 audit: Optional[DecisionAudit] = None):
        self.profile = profile
        self.cfg = cfg
        self.target_util = target_util
        self.peak_window_s = peak_window_s
        self.downscale_patience = downscale_patience
        self.dispatcher = dispatcher or WeightedRoundRobinDispatcher()
        self.monitor = RateMonitor()
        self.decisions: List[Decision] = []
        self.audit = audit if audit is not None else DecisionAudit()
        self._below_count = 0
        self._last_units = 0

    def _units_for(self, lam: float) -> int:
        p = self.profile
        need = lam / max(self.target_util, 1e-6)
        if p.th_slope <= 0:
            return self.cfg.budget
        n = int(np.ceil((need - p.th_intercept) / p.th_slope))
        lo = p.min_feasible_units(self.cfg.slo_ms) or 1
        return int(np.clip(n, lo, self.cfg.budget))

    def step(self, t: float, cluster: ClusterAPI) -> Decision:
        peak = self.monitor.history(self.peak_window_s)
        lam = float(peak.max()) if len(peak) else self.cfg.min_load
        lam = max(lam, self.cfg.min_load)
        n = self._units_for(lam)
        if n < self._last_units:
            # paper: dropped the lower bound to scale up faster; scale DOWN
            # keeps hysteresis so transient dips don't thrash
            self._below_count += 1
            if self._below_count < self.downscale_patience:
                n = self._last_units
            else:
                self._below_count = 0
        else:
            self._below_count = 0
        self._last_units = n
        units = {self.profile.name: n}
        cluster.apply_allocation(t, units)
        alloc = evaluate({self.profile.name: self.profile}, units, lam,
                         self.cfg.slo_ms, alpha=self.cfg.alpha,
                         beta=self.cfg.beta, gamma=self.cfg.gamma)
        self.dispatcher.set_weights({self.profile.name: 1.0})
        d = Decision(t=t, predicted_load=lam, allocation=alloc)
        self.decisions.append(d)
        profs = {self.profile.name: self.profile}
        self.audit.record(
            t, type(self).__name__,
            inputs={"lam": float(lam), "target_util": self.target_util,
                    "slo_ms": self.cfg.slo_ms, "budget": self.cfg.budget},
            outputs={"units": dict(units),
                     "predicted": predict_outputs(profs, alloc, lam,
                                                  self.cfg.slo_ms)})
        return d
