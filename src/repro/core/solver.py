"""Solvers for the paper's ILP (Eq. 1).

The paper brute-forces the configuration space through Gurobi. We provide:

  * ``solve_exact``   — exact dynamic program over (variant, budget, unserved
    load) with the loading-cost ``max`` handled by enumerating its O(|M|)
    possible values. Polynomial where brute force is exponential — this is
    already a beyond-paper scalability contribution, answering the paper's
    own "Scalability with ML" future-work section with an exact method.
  * ``solve_bruteforce`` — literal enumeration (paper-faithful semantics);
    used as the ground truth in property tests at small scale.
  * ``solve_greedy``  — marginal-gain heuristic with local repair; scales to
    hundreds of variants (evaluated vs exact in benchmarks/solver_scalability).
  * ``solve_single_variant`` — the MS+ baseline restriction (|M'| = 1).

All solvers share the objective/quota machinery in ``objective.py``. Loads are
discretized to integer RPS in the DP (documented approximation; bruteforce
cross-check bounds the error in tests).
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Mapping, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.objective import Allocation, evaluate, loading_cost
from repro.core.profiles import VariantProfile


def _feasible_units(p: VariantProfile, slo_ms: float, budget: int) -> List[int]:
    """Unit counts (excluding 0) meeting the latency SLO within budget."""
    lo = p.min_feasible_units(slo_ms)
    if lo is None or lo > budget:
        return []
    return list(range(lo, min(budget, p.max_units) + 1))


def _best_effort(profiles: Mapping[str, VariantProfile], lam: float,
                 budget: int, slo_ms: float, **kw) -> Allocation:
    """When no config covers λ: maximize capacity (paper's under-provision
    regime — violations happen, serve as much as possible)."""
    best: Optional[Allocation] = None
    # greedy: put all budget on the highest-capacity-per-unit feasible variant,
    # then refine with the greedy solver seeded at max capacity.
    alloc = solve_greedy(profiles, lam, budget, slo_ms,
                         prefer_capacity=True, **kw)
    return alloc


def solve_bruteforce(profiles: Mapping[str, VariantProfile], lam: float,
                     budget: int, slo_ms: float, *, alpha: float = 1.0,
                     beta: float = 0.05, gamma: float = 0.01,
                     loaded: Optional[Set[str]] = None) -> Allocation:
    """Enumerate every allocation (paper semantics). Exponential — small M/B."""
    loaded = loaded or set()
    names = sorted(profiles)
    options = []
    for m in names:
        options.append([0] + _feasible_units(profiles[m], slo_ms, budget))
    best = Allocation(predicted_load=lam)
    for combo in itertools.product(*options):
        if sum(combo) > budget or sum(combo) == 0:
            continue
        units = dict(zip(names, combo))
        a = evaluate(profiles, units, lam, slo_ms, alpha=alpha, beta=beta,
                     gamma=gamma, loaded=loaded)
        if not a.feasible:
            continue
        if a.objective > best.objective or not best.feasible:
            best = a
    if not best.feasible:
        return _best_effort(profiles, lam, budget, slo_ms, alpha=alpha,
                            beta=beta, gamma=gamma, loaded=loaded)
    return best


def solve_exact(profiles: Mapping[str, VariantProfile], lam: float,
                budget: int, slo_ms: float, *, alpha: float = 1.0,
                beta: float = 0.05, gamma: float = 0.01,
                loaded: Optional[Set[str]] = None) -> Allocation:
    """Exact DP. State: (variant idx, budget used, unserved load) — variants
    sorted by accuracy descending so the water-fill quota assignment is the
    DP's min() transition. LC's max-term is handled by solving once per
    candidate LC value and keeping the best total objective."""
    loaded = loaded or set()
    names = sorted(profiles, key=lambda m: -profiles[m].accuracy)
    # load-grid resolution: finer grid shrinks the floor()-discretization
    # error (bounded by max_acc·units_dropped/(λ·res)); capped for memory
    res = int(max(1, min(8, 4096 // max(int(lam), 1))))
    lam_i = int(np.ceil(lam * res))
    # candidate LC caps: 0 (only already-loaded variants) + rt values. With
    # many variants, quantile-dedupe to <= 8 caps (the γ·LC term is coarse —
    # bounded objective error of γ·(rt-gap), negligible at paper scale).
    rts = sorted({profiles[m].rt for m in names if m not in loaded})
    if len(rts) > 8:
        idx = np.linspace(0, len(rts) - 1, 8).round().astype(int)
        rts = [rts[i] for i in idx]
        if rts[-1] != max(rts):
            rts.append(max(rts))
    caps = sorted({0.0} | set(rts))
    best = Allocation(predicted_load=lam)
    for cap in caps:
        usable = [m for m in names
                  if m in loaded or profiles[m].rt <= cap + 1e-12]
        a = _dp_solve(profiles, usable, lam, lam_i, budget, slo_ms,
                      alpha, beta, res=res)
        if a is None:
            continue
        obj = a.objective - gamma * cap
        if obj > best.objective or not best.feasible:
            a.lc = loading_cost(profiles, a.active_variants(), loaded)
            a.objective = a.aa * alpha - beta * a.rc - gamma * a.lc
            best = a
    if not best.feasible:
        return _best_effort(profiles, lam, budget, slo_ms, alpha=alpha,
                            beta=beta, gamma=gamma, loaded=loaded)
    return best


def _dp_solve(profiles, names, lam, lam_i, budget, slo_ms, alpha, beta,
              res: int = 1) -> Optional[Allocation]:
    """DP over (budget, unserved-load) maximizing α·AA − β·RC with full
    coverage required. Vectorized over the (budget × load) grid; returns None
    if no feasible allocation."""
    NEG = -1e18
    U = lam_i
    # V[b, u]: best partial objective having spent b units with u load unserved
    V = np.full((budget + 1, U + 1), NEG)
    V[0, U] = 0.0
    lam_f = max(lam, 1e-9)
    # back-pointers: for each variant, (chosen n, previous u) per state
    back_n: List[np.ndarray] = []
    back_u: List[np.ndarray] = []

    us = np.arange(U + 1)
    for i, m in enumerate(names):
        p = profiles[m]
        V_new = V.copy()                     # n_i = 0 keeps state
        bn = np.zeros((budget + 1, U + 1), np.int32)
        bu = np.tile(us, (budget + 1, 1)).astype(np.int32)
        for n in _feasible_units(p, slo_ms, budget):
            th = int(p.throughput(n) * res)
            gain = (alpha * p.accuracy * np.minimum(us, th) / (lam_f * res)
                    - beta * n)
            rows = V[:budget - n + 1] + gain        # (B', U+1) candidates
            TH = min(th, U)
            # u <= TH all collapse to nu=0: take the best of them per row
            left_u = np.argmax(rows[:, :TH + 1], axis=1)
            left = rows[np.arange(rows.shape[0]), left_u]        # (B',)
            # u > TH map to nu = u - TH (unique)
            right = rows[:, TH + 1:]                             # (B', U-TH)
            cand = np.concatenate([left[:, None], right], axis=1)
            prev_u = np.concatenate(
                [left_u[:, None], np.tile(us[TH + 1:], (rows.shape[0], 1))],
                axis=1).astype(np.int32)
            width = cand.shape[1]
            region = V_new[n:, :width]
            improved = cand > region
            np.copyto(region, cand, where=improved)
            np.copyto(bn[n:, :width], n, where=improved)
            np.copyto(bu[n:, :width], prev_u, where=improved)
        back_n.append(bn)
        back_u.append(bu)
        V = V_new

    # Consider final states within one load-grid cell of full coverage: the
    # floor() discretization can reject a config whose true capacity exactly
    # covers λ. Each candidate is re-validated with exact floats by evaluate().
    best_alloc: Optional[Allocation] = None
    for u_final in range(0, res + 1):
        if u_final > U:
            break
        col = V[:, u_final]
        final_b = int(np.argmax(col))
        if col[final_b] <= NEG / 2:
            continue
        units = {m: 0 for m in names}
        b, u = final_b, u_final
        for i in range(len(names) - 1, -1, -1):
            n = int(back_n[i][b, u])
            pu = int(back_u[i][b, u])
            units[names[i]] = n
            b, u = b - n, pu
        alloc = evaluate(profiles, units, lam, slo_ms, alpha=alpha, beta=beta,
                         gamma=0.0)
        if alloc.feasible and (best_alloc is None
                               or alloc.objective > best_alloc.objective):
            best_alloc = alloc
    return best_alloc


def solve_greedy(profiles: Mapping[str, VariantProfile], lam: float,
                 budget: int, slo_ms: float, *, alpha: float = 1.0,
                 beta: float = 0.05, gamma: float = 0.01,
                 loaded: Optional[Set[str]] = None,
                 prefer_capacity: bool = False) -> Allocation:
    """Heuristic for Eq. 1: marginal-gain construction + steepest local
    repair, O(M·B) objective evaluations — the scalable answer to the
    paper's "Scalability with ML" concern (§7); optimality gap vs
    ``solve_exact`` is measured in benchmarks/solver_scalability."""
    loaded = loaded or set()
    units: Dict[str, int] = {m: 0 for m in profiles}

    def score(u: Dict[str, int]) -> Tuple[float, float]:
        a = evaluate(profiles, u, lam, slo_ms, alpha=alpha, beta=beta,
                     gamma=gamma, loaded=loaded)
        cap = sum(profiles[m].throughput(n) for m, n in u.items() if n > 0)
        if prefer_capacity:
            return (min(cap, lam), a.objective)
        # lexicographic: feasibility first, then objective
        return (1.0 if a.feasible else min(cap / max(lam, 1e-9), 1.0) - 1.0,
                a.objective)

    cur = score(units)
    improved = True
    while improved:
        improved = False
        best_mv, best_sc = None, cur
        used = sum(units.values())
        for m, p in profiles.items():
            lo = p.min_feasible_units(slo_ms)
            if lo is None:
                continue
            # grow moves
            n = units[m]
            step = lo if n == 0 else 1
            if used + step <= budget and n + step <= p.max_units:
                trial = dict(units); trial[m] = n + step
                sc = score(trial)
                if sc > best_sc:
                    best_sc, best_mv = sc, trial
            # shrink / drop moves (cost reduction)
            if n > 0:
                trial = dict(units)
                trial[m] = n - 1 if n - 1 >= lo else 0
                sc = score(trial)
                if sc > best_sc:
                    best_sc, best_mv = sc, trial
        if best_mv is not None:
            units, cur, improved = best_mv, best_sc, True
    out = evaluate(profiles, units, lam, slo_ms, alpha=alpha, beta=beta,
                   gamma=gamma, loaded=loaded)
    return out


def solve_single_variant(profiles: Mapping[str, VariantProfile], lam: float,
                         budget: int, slo_ms: float, *, alpha: float = 1.0,
                         beta: float = 0.05, gamma: float = 0.01,
                         loaded: Optional[Set[str]] = None) -> Allocation:
    """MS+ baseline: exactly one variant + its size, same objective (Eq. 1)."""
    loaded = loaded or set()
    best = Allocation(predicted_load=lam)
    for m, p in profiles.items():
        for n in _feasible_units(p, slo_ms, budget):
            a = evaluate(profiles, {m: n}, lam, slo_ms, alpha=alpha,
                         beta=beta, gamma=gamma, loaded=loaded)
            if a.feasible and (a.objective > best.objective or not best.feasible):
                best = a
    if not best.feasible:
        # under-provisioned: pick max-capacity single variant
        for m, p in profiles.items():
            ns = _feasible_units(p, slo_ms, budget)
            if not ns:
                continue
            n = ns[-1]
            a = evaluate(profiles, {m: n}, lam, slo_ms, alpha=alpha,
                         beta=beta, gamma=gamma, loaded=loaded)
            if a.served > best.served or (a.served == best.served
                                          and a.objective > best.objective):
                best = a
    return best


SOLVERS = {
    "exact": solve_exact,
    "bruteforce": solve_bruteforce,
    "greedy": solve_greedy,
    "single": solve_single_variant,
}
