"""Monitoring daemon: per-second arrival-rate history from the dispatcher."""
from __future__ import annotations

from collections import deque
from typing import Deque, List

import numpy as np


class RateMonitor:
    """Counts request arrivals into 1-second buckets (paper's monitoring
    component fetches exactly this from the dispatcher)."""

    def __init__(self, horizon_s: int = 3600 * 4):
        self.horizon_s = horizon_s
        self._counts: Deque[int] = deque(maxlen=horizon_s)
        self._bucket_t: int = 0
        self._current: int = 0
        self._started = False

    def record(self, t: float, n: int = 1) -> None:
        """Record n arrivals at time t (seconds, monotone nondecreasing)."""
        sec = int(t)
        if not self._started:
            self._bucket_t, self._started = sec, True
        while sec > self._bucket_t:
            self._counts.append(self._current)
            self._current = 0
            self._bucket_t += 1
        self._current += n

    def advance_to(self, t: float) -> None:
        """Flush empty seconds up to time t."""
        self.record(t, 0)

    def history(self, seconds: int = 600) -> np.ndarray:
        """Per-second rates for the trailing window (excludes current bucket)."""
        h = np.asarray(self._counts, np.float32)
        return h[-seconds:] if len(h) else np.zeros((0,), np.float32)

    def current_rate(self, window: int = 10) -> float:
        h = self.history(window)
        return float(h.mean()) if len(h) else 0.0
