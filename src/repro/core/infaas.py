"""INFaaS-style baseline (Romero et al., ATC '21) — the remaining row of the
paper's Table 1.

INFaaS is "model-less": each request (class) declares requirements and the
system picks, per request, the cheapest loaded variant meeting them, scaling
variants up/down reactively as load shifts. Key behavioural contrasts the
paper's Table 1 encodes:

  * cost-aware ✓ (cheapest variant meeting the latency requirement)
  * accuracy-maximizing ✗ (accuracy is a constraint, not an objective —
    INFaaS stops at "meets the requirement")
  * reactive, not predictive ✗ (scales on observed load)

Our controller: given a per-request latency requirement (the SLO) and a
minimum-accuracy requirement, pick the CHEAPEST variant satisfying both,
sized reactively for the observed peak; spillover to the next-cheapest
variant when the budget caps the primary (INFaaS's variant-autoscaling).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional

import numpy as np

from repro.core.adapter import ControllerConfig, Decision
from repro.core.dispatcher import WeightedRoundRobinDispatcher
from repro.core.monitoring import RateMonitor
from repro.core.objective import evaluate
from repro.core.profiles import VariantProfile


class INFaaSController:
    """Model-less reactive baseline."""

    def __init__(self, profiles: Mapping[str, VariantProfile],
                 cfg: ControllerConfig, min_accuracy: float = 0.0,
                 peak_window_s: int = 60, headroom: float = 1.1):
        self.profiles = dict(profiles)
        self.cfg = cfg
        self.min_accuracy = min_accuracy
        self.peak_window_s = peak_window_s
        self.headroom = headroom
        self.monitor = RateMonitor()
        self.dispatcher = WeightedRoundRobinDispatcher()
        self.decisions: List[Decision] = []

    def _eligible(self) -> List[str]:
        """Variants meeting the accuracy requirement, cheapest-first
        (cost-per-RPS ascending)."""
        ok = [m for m, p in self.profiles.items()
              if p.accuracy >= self.min_accuracy
              and p.min_feasible_units(self.cfg.slo_ms) is not None]
        return sorted(ok, key=lambda m: 1.0 / max(self.profiles[m].th_slope, 1e-9))

    def step(self, t: float, cluster) -> Decision:
        peak = self.monitor.history(self.peak_window_s)
        lam = max(float(peak.max()) if len(peak) else 0.0, self.cfg.min_load)
        lam *= self.headroom
        units: Dict[str, int] = {}
        remaining, budget_left = lam, self.cfg.budget
        for m in self._eligible():
            if remaining <= 0 or budget_left <= 0:
                break
            p = self.profiles[m]
            lo = p.min_feasible_units(self.cfg.slo_ms)
            n = lo
            while n < min(p.max_units, budget_left) and p.throughput(n) < remaining:
                n += 1
            n = min(n, budget_left)
            units[m] = n
            remaining -= p.throughput(n)
            budget_left -= n
        cluster.apply_allocation(t, units)
        alloc = evaluate(self.profiles, units, lam, self.cfg.slo_ms,
                         alpha=self.cfg.alpha, beta=self.cfg.beta,
                         gamma=self.cfg.gamma,
                         loaded=cluster.loaded_variants(t))
        if alloc.quotas:
            self.dispatcher.set_weights(alloc.quotas)
        d = Decision(t=t, predicted_load=lam, allocation=alloc)
        self.decisions.append(d)
        return d
