"""Workload forecasting — the paper's LSTM + simpler ensemble baselines.

Paper-faithful configuration (§5 "Load forecaster"): a 25-unit LSTM layer
followed by a 1-unit dense output, trained with Adam on MSE; input is the
per-second load of the past 10 minutes (600 steps), target is the *maximum*
load of the next minute. Implemented from scratch in JAX.

Beyond-paper: ``SeasonalMaxForecaster`` (seasonal-naive max) and an ensemble
that takes the elementwise max — measured against the LSTM in benchmarks
(fig. "forecaster_mae").
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.optimizer import AdamConfig, adam_init, adam_update

HISTORY = 600     # seconds of input history (10 min)
HORIZON = 60      # predict max load over the next minute


# ---------------------------------------------------------------------------
# LSTM core
# ---------------------------------------------------------------------------

def lstm_init(key, hidden: int = 25, input_dim: int = 1) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    scale = 1.0 / np.sqrt(hidden)
    return {
        "wx": jax.random.normal(k1, (input_dim, 4 * hidden)) * scale,
        "wh": jax.random.normal(k2, (hidden, 4 * hidden)) * scale,
        "b": jnp.zeros((4 * hidden,)),
        "dense_w": jax.random.normal(k3, (hidden, 1)) * scale,
        "dense_b": jnp.zeros((1,)),
    }


def lstm_apply(params: Dict, seq: jax.Array) -> jax.Array:
    """seq: (B, T, 1) normalized loads -> (B,) predicted (normalized) max."""
    B = seq.shape[0]
    H = params["wh"].shape[0]

    def cell(carry, x_t):
        h, c = carry
        z = x_t @ params["wx"] + h @ params["wh"] + params["b"]
        i, f, g, o = jnp.split(z, 4, axis=-1)
        c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), None

    init = (jnp.zeros((B, H)), jnp.zeros((B, H)))
    (h, _), _ = jax.lax.scan(cell, init, seq.transpose(1, 0, 2))
    out = h @ params["dense_w"] + params["dense_b"]
    return out[:, 0]


def _windows(trace: np.ndarray, history: int, horizon: int, stride: int = 30
             ) -> Tuple[np.ndarray, np.ndarray]:
    xs, ys = [], []
    for t in range(history, len(trace) - horizon, stride):
        xs.append(trace[t - history:t])
        ys.append(trace[t:t + horizon].max())
    return np.asarray(xs, np.float32), np.asarray(ys, np.float32)


@dataclass
class LSTMForecaster:
    """Paper's forecaster. Normalizes by the training trace's max."""
    params: Dict
    scale: float
    history: int = HISTORY
    horizon: int = HORIZON

    def predict(self, recent: np.ndarray) -> float:
        """recent: per-second loads (uses the trailing ``history`` seconds)."""
        h = np.asarray(recent, np.float32)[-self.history:]
        if len(h) < self.history:
            h = np.pad(h, (self.history - len(h), 0), mode="edge")
        x = jnp.asarray(h / self.scale)[None, :, None]
        y = float(lstm_apply(self.params, x)[0]) * self.scale
        return max(y, 0.0)


def train_lstm_forecaster(trace: np.ndarray, *, hidden: int = 25,
                          steps: int = 400, batch: int = 64,
                          history: int = HISTORY, horizon: int = HORIZON,
                          lr: float = 3e-3, seed: int = 0,
                          ) -> Tuple[LSTMForecaster, List[float]]:
    """Train on a per-second load trace (the paper uses 2 weeks of the
    Twitter trace; we train on the generator's training split)."""
    scale = float(max(trace.max(), 1.0))
    xs, ys = _windows(trace, history, horizon)
    xs, ys = xs / scale, ys / scale
    params = lstm_init(jax.random.PRNGKey(seed), hidden)
    opt_cfg = AdamConfig(lr=lr, warmup_steps=20, total_steps=steps,
                         schedule="cosine", grad_clip=1.0)
    opt_state = adam_init(params)
    rng = np.random.default_rng(seed)

    @jax.jit
    def step_fn(params, opt_state, xb, yb):
        def loss_fn(p):
            pred = lstm_apply(p, xb[:, :, None])
            return jnp.mean(jnp.square(pred - yb))
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, _ = adam_update(opt_cfg, grads, opt_state, params)
        return params, opt_state, loss

    losses = []
    for s in range(steps):
        idx = rng.integers(0, len(xs), size=batch)
        params, opt_state, loss = step_fn(params, opt_state,
                                          jnp.asarray(xs[idx]),
                                          jnp.asarray(ys[idx]))
        losses.append(float(loss))
    return LSTMForecaster(params=params, scale=scale, history=history,
                          horizon=horizon), losses


# ---------------------------------------------------------------------------
# Baseline / ensemble forecasters (beyond paper)
# ---------------------------------------------------------------------------

@dataclass
class MovingMaxForecaster:
    """max over the recent window, with a safety headroom factor."""
    window: int = 120
    headroom: float = 1.1

    def predict(self, recent: np.ndarray) -> float:
        h = np.asarray(recent, np.float32)
        if len(h) == 0:
            return 0.0
        return float(h[-self.window:].max() * self.headroom)


@dataclass
class SeasonalMaxForecaster:
    """Seasonal-naive: max of the same minute one period ago and the recent
    minute (captures diurnal repeats in the Twitter-like trace)."""
    period: int = 3600
    fallback: MovingMaxForecaster = None  # type: ignore[assignment]

    def __post_init__(self):
        if self.fallback is None:
            self.fallback = MovingMaxForecaster()
        self._buffer: List[float] = []

    def observe(self, value: float):
        self._buffer.append(value)

    def predict(self, recent: np.ndarray) -> float:
        base = self.fallback.predict(recent)
        buf = self._buffer
        if len(buf) >= self.period:
            seasonal = max(buf[-self.period:-self.period + HORIZON] or [0.0])
            return max(base, seasonal)
        return base


@dataclass
class EnsembleMaxForecaster:
    """Elementwise max of member forecasts: conservative (SLO-protective)."""
    members: Tuple = ()

    def predict(self, recent: np.ndarray) -> float:
        return max(m.predict(recent) for m in self.members)


def forecast_mae(forecaster, trace: np.ndarray, history: int = HISTORY,
                 horizon: int = HORIZON, stride: int = 60) -> Dict[str, float]:
    """Evaluation used by the forecaster benchmark: MAE + under-prediction
    rate (under-predictions are what cause SLO violations)."""
    errs, unders = [], []
    for t in range(history, len(trace) - horizon, stride):
        pred = forecaster.predict(trace[:t])
        true = trace[t:t + horizon].max()
        errs.append(abs(pred - true))
        unders.append(1.0 if pred < true else 0.0)
    return {"mae": float(np.mean(errs)),
            "under_rate": float(np.mean(unders))}
