"""Variant profiles: throughput/latency models per (variant, resource units).

Faithful to the paper's profiling methodology (§5): each variant is profiled
at a handful of allocations (1, 2, 4, 8, 16 cores) and a *linear regression*
``th_m(n) = a·n + b`` predicts throughput at any allocation; processing
latency is modeled as ``p_m(n) = base + k / n``.

Three profile sources, distinguished by *provenance* in the profile store
(``repro.profiling.store.ProfileStore``):
  * ``paper-calibrated`` — ``paper_resnet_profiles()``: the paper's
    ResNet-18/34/50/101/152 family, calibrated so every relation the paper
    reports holds (Fig. 1/2; see EXPERIMENTS.md §Paper-validation for the
    checked claims).
  * ``roofline`` — ``roofline_profile(cfg, ...)``: TPU adaptation —
    throughput of an LLM variant on n chips derived from the analytic
    roofline (bf16 197 TFLOP/s, 819 GB/s HBM per chip), used by the TPU
    serving path and cross-calibrated against measured smoke-scale variants
    by ``repro.profiling.calibrate``.
  * ``measured`` — ``repro.profiling.measure.EngineProfiler``: profiles
    regression-fitted from actual ``InProcessServingEngine`` measurements,
    the subsystem this module's fit machinery feeds.

``paper_resnet_profiles``/``variant_ladder_profiles`` accept an optional
``store`` (duck-typed ``ProfileStore``) and register what they build, so
examples and controllers load profiles from one persistent place instead of
constructing constants inline.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig

# TPU v5e hardware constants (per chip) — shared with repro.analysis.roofline
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


@dataclass(frozen=True)
class VariantProfile:
    """Profiled/predicted behaviour of one model variant."""
    name: str
    accuracy: float            # % (or quality-proxy score)
    rt: float                  # readiness time (load+init), seconds
    th_slope: float            # RPS per resource unit
    th_intercept: float        # RPS
    lat_base_ms: float         # floor latency
    lat_k_ms: float            # p(n) = lat_base + lat_k / n
    max_units: int = 64

    def throughput(self, n: int) -> float:
        if n <= 0:
            return 0.0
        return max(0.0, self.th_slope * n + self.th_intercept)

    def p99_ms(self, n: int) -> float:
        if n <= 0:
            return float("inf")
        return self.lat_base_ms + self.lat_k_ms / n

    def min_feasible_units(self, slo_ms: float) -> Optional[int]:
        """Smallest allocation meeting the latency SLO, or None."""
        if self.lat_base_ms >= slo_ms:
            return None
        n = int(np.ceil(self.lat_k_ms / max(slo_ms - self.lat_base_ms, 1e-9)))
        return max(1, n)


@dataclass
class LinearRegressionFit:
    """Least-squares fit of throughput profiles (reproduces paper Fig. 6)."""
    slope: float
    intercept: float
    r_squared: float
    points: List[Tuple[int, float]] = field(default_factory=list)


def fit_throughput(points: Sequence[Tuple[int, float]]) -> LinearRegressionFit:
    ns = np.array([p[0] for p in points], float)
    th = np.array([p[1] for p in points], float)
    A = np.stack([ns, np.ones_like(ns)], axis=1)
    (slope, intercept), *_ = np.linalg.lstsq(A, th, rcond=None)
    pred = slope * ns + intercept
    ss_res = float(np.sum((th - pred) ** 2))
    ss_tot = float(np.sum((th - np.mean(th)) ** 2))
    r2 = 1.0 - ss_res / max(ss_tot, 1e-12)
    return LinearRegressionFit(float(slope), float(intercept), r2, list(points))


# ---------------------------------------------------------------------------
# Paper-calibrated ResNet profiles (CPU cores as the resource unit)
# ---------------------------------------------------------------------------
# Ground-truth linear profiles th(n) = a·n + b calibrated to satisfy the
# paper's reported relations (see tests/test_profiles.py):
#   * th_18(8)  ≈ th_50(20)   (Fig. 1 observation)
#   * th_50(8)  ≈ th_152(20)  (Fig. 1 observation, looser)
#   * th_50(2) + th_101(6) + th_152(6) ≥ 75 RPS  (Fig. 2's chosen config)
#   * th_50(14) ≥ 75 > th_101(14)  (so MS's best single variant at B=14 is R50)
_RESNET_TRUTH = {
    #            a      b     lat_base  lat_k    acc     rt
    "resnet18": (13.0, 15.0, 25.0, 110.0, 69.76, 4.0),
    "resnet34": (8.5, 12.0, 38.0, 180.0, 73.31, 6.0),
    "resnet50": (5.0, 10.0, 55.0, 300.0, 76.13, 8.0),
    "resnet101": (4.0, 8.0, 85.0, 520.0, 77.37, 12.0),
    "resnet152": (3.2, 5.0, 110.0, 740.0, 78.31, 15.0),
}
PROFILE_CORE_POINTS = (1, 2, 4, 8, 16)  # the paper profiles only these


def measured_resnet_points(name: str, noise: float = 0.0,
                           seed: int = 0) -> List[Tuple[int, float]]:
    """Synthetic 'measured' profile points at the paper's 5 allocations."""
    a, b, *_ = _RESNET_TRUTH[name]
    rng = np.random.default_rng(seed + hash(name) % 1000)
    pts = []
    for n in PROFILE_CORE_POINTS:
        th = a * n + b
        if noise:
            th *= 1.0 + rng.normal(0.0, noise)
        pts.append((n, max(th, 0.0)))
    return pts


def paper_resnet_profiles(noise: float = 0.01, seed: int = 0,
                          store=None) -> Dict[str, VariantProfile]:
    """The paper's five-variant family with regression-fitted throughput.

    With ``store`` (a ``repro.profiling.store.ProfileStore``) every profile
    is registered under provenance ``"paper-calibrated"`` with its fit."""
    out = {}
    for name, (a, b, lb, lk, acc, rt) in _RESNET_TRUTH.items():
        fit = fit_throughput(measured_resnet_points(name, noise, seed))
        out[name] = VariantProfile(
            name=name, accuracy=acc, rt=rt,
            th_slope=fit.slope, th_intercept=fit.intercept,
            lat_base_ms=lb, lat_k_ms=lk)
        if store is not None:
            store.register(out[name], "paper-calibrated", fit=fit)
    return out


# ---------------------------------------------------------------------------
# TPU roofline-derived profiles for LLM variant ladders (hardware adaptation)
# ---------------------------------------------------------------------------

def roofline_decode_tokens_per_s(cfg: ModelConfig, n_chips: int,
                                 batch: int = 8, kv_len: int = 2048,
                                 mfu: float = 0.4, hbm_eff: float = 0.7) -> float:
    """Decode throughput bound on n chips: min(compute, weight+KV streaming)."""
    n_active = cfg.active_param_count()
    flops_per_tok = 2.0 * n_active
    compute = n_chips * PEAK_FLOPS_BF16 * mfu / flops_per_tok * batch
    bytes_per_step = 2.0 * n_active  # weights streamed once per step (bf16)
    KV, hd, L = max(cfg.num_kv_heads, 1), cfg.resolved_head_dim, cfg.num_layers
    if cfg.family != "ssm":
        bytes_per_step += 2 * batch * kv_len * KV * hd * L * 2
    memory = n_chips * HBM_BW * hbm_eff / bytes_per_step * batch
    return min(compute, memory)


def roofline_profile(cfg: ModelConfig, accuracy: float, *,
                     tokens_per_request: int = 128, max_chips: int = 64,
                     ) -> VariantProfile:
    """Linear-regression profile over chip counts (paper methodology on TPU)."""
    pts = []
    for n in PROFILE_CORE_POINTS:
        rps = roofline_decode_tokens_per_s(cfg, n) / tokens_per_request
        pts.append((n, rps))
    fit = fit_throughput(pts)
    # latency: time to generate one request's tokens at per-chip rate
    tok_s_1 = roofline_decode_tokens_per_s(cfg, 1)
    lat_k = tokens_per_request / max(tok_s_1, 1e-9) * 1000.0
    # readiness: HBM fill time for the weights + compile slack
    load_s = 2.0 * cfg.param_count() / HBM_BW + 2.0
    return VariantProfile(
        name=cfg.name, accuracy=accuracy, rt=load_s,
        th_slope=fit.slope, th_intercept=fit.intercept,
        lat_base_ms=5.0, lat_k_ms=lat_k, max_units=max_chips)


def variant_ladder_profiles(base: ModelConfig, *, fractions=(0.25, 0.5, 0.75, 1.0),
                            acc_max: float = 80.0, acc_span: float = 12.0,
                            store=None) -> Dict[str, VariantProfile]:
    """Depth-scaled variant family for an assigned arch + scaling-law accuracy
    proxy acc(N) = acc_max - acc_span · (N/N_full)^(-0.28) + acc_span
    (documented proxy — monotone in N with diminishing returns).

    With ``store`` every profile is registered under provenance
    ``"roofline"`` (analytic, not measured)."""
    out = {}
    n_full = base.param_count()
    for f in fractions:
        L = max(2, int(round(base.num_layers * f)))
        cfg = base.replace(name=f"{base.name}-L{L}", num_layers=L)
        ratio = cfg.param_count() / n_full
        acc = acc_max - acc_span * (ratio ** -0.28 - 1.0) - acc_span * 0.0
        acc = float(np.clip(acc, 1.0, 99.9))
        out[cfg.name] = roofline_profile(cfg, acc)
        if store is not None:
            store.register(out[cfg.name], "roofline",
                           meta={"base": base.name, "fraction": f})
    return out
