"""Cocktail-style ensembling baseline (Gunasekaran et al., NSDI '22).

The paper's Table 1 positions Cocktail as the closest related work but could
not compare against it ("due to fundamental structural differences"). We close
that gap with a faithful-in-spirit ensemble controller:

  * Cocktail serves each request through an ENSEMBLE of (cheaper) variants
    and majority-votes, reaching (or beating) the accuracy of the largest
    single model while autoscaling each ensemble member independently.
  * Cost model: every request runs on every ensemble member, so each member
    must individually sustain the full load λ — this is exactly the cost
    inefficiency the paper calls out ("all the requests should be sent to all
    the ML models").
  * Ensemble accuracy: majority vote of k independent-ish classifiers with
    per-model accuracy p_i. We use the standard independence upper bound with
    a correlation discount ρ (errors of sibling models correlate; ρ=0.6 by
    default, matching the 2-4% ensemble gains Cocktail reports rather than
    the unrealistic independence numbers).

The controller picks the ensemble (subset of variants, odd-sized) + sizes
that maximize the same Eq. 1 objective with AA replaced by ensemble accuracy.
"""
from __future__ import annotations

from dataclasses import dataclass
from itertools import combinations
from typing import Dict, List, Mapping, Optional, Set

import numpy as np

from repro.core.adapter import ControllerConfig, Decision
from repro.core.dispatcher import WeightedRoundRobinDispatcher
from repro.core.monitoring import RateMonitor
from repro.core.objective import Allocation
from repro.core.profiles import VariantProfile


def majority_vote_accuracy(accs: List[float], rho: float = 0.6) -> float:
    """Majority-vote accuracy of an odd ensemble, correlation-discounted.

    Independence would give  P(majority correct) = sum over majorities;
    real sibling models correlate, so we interpolate between the best single
    model (ρ=1) and the independent ensemble (ρ=0).
    """
    k = len(accs)
    if k == 1:
        return accs[0]
    ps = np.array(accs, float) / 100.0
    # independent majority vote via DP over correct-count distribution
    dist = np.zeros(k + 1)
    dist[0] = 1.0
    for p in ps:
        dist = np.roll(dist, 1) * p + dist * (1 - p)
        # np.roll trick: new[j] = old[j-1]*p + old[j]*(1-p)
    indep = float(dist[(k // 2 + 1):].sum())
    best = float(ps.max())
    return 100.0 * (rho * best + (1 - rho) * indep)


def _min_units_for_load(p: VariantProfile, lam: float, budget: int,
                        slo_ms: float) -> Optional[int]:
    lo = p.min_feasible_units(slo_ms)
    if lo is None:
        return None
    for n in range(lo, budget + 1):
        if p.throughput(n) >= lam:
            return n
    return None


def solve_cocktail(profiles: Mapping[str, VariantProfile], lam: float,
                   budget: int, slo_ms: float, *, alpha: float = 1.0,
                   beta: float = 0.05, gamma: float = 0.01,
                   loaded: Optional[Set[str]] = None,
                   max_ensemble: int = 5, rho: float = 0.6) -> Allocation:
    """Best odd ensemble + per-member sizing under Eq. 1 semantics.

    Every member must sustain the FULL load λ (requests fan out to all)."""
    loaded = loaded or set()
    names = sorted(profiles)
    best = Allocation(predicted_load=lam)
    for k in (1, 3, max_ensemble):
        if k > len(names):
            continue
        for combo in combinations(names, k):
            units: Dict[str, int] = {}
            ok = True
            for m in combo:
                n = _min_units_for_load(profiles[m], lam, budget, slo_ms)
                if n is None:
                    ok = False
                    break
                units[m] = n
            if not ok or sum(units.values()) > budget:
                continue
            acc = majority_vote_accuracy([profiles[m].accuracy for m in combo],
                                         rho)
            rc = float(sum(units.values()))
            cold = [profiles[m].rt for m in combo if m not in loaded]
            lc = max(cold) if cold else 0.0
            obj = alpha * acc - beta * rc - gamma * lc
            if obj > best.objective or not best.feasible:
                best = Allocation(
                    units=units, quotas={m: lam for m in combo},
                    objective=obj, aa=acc, rc=rc, lc=lc, feasible=True,
                    served=lam, predicted_load=lam)
    return best


class CocktailController:
    """Ensembling autoscaler baseline. NOTE the dispatcher fans out: every
    request goes to EVERY ensemble member (the simulator models this by
    dispatching to each backend)."""

    def __init__(self, profiles: Mapping[str, VariantProfile], forecaster,
                 cfg: ControllerConfig, rho: float = 0.6):
        self.profiles = dict(profiles)
        self.forecaster = forecaster
        self.cfg = cfg
        self.rho = rho
        self.monitor = RateMonitor()
        self.dispatcher = WeightedRoundRobinDispatcher()
        self.decisions: List[Decision] = []
        self.current_ensemble: List[str] = []

    def step(self, t: float, cluster) -> Decision:
        lam = max(self.forecaster.predict(self.monitor.history(600)),
                  self.cfg.min_load)
        alloc = solve_cocktail(self.profiles, lam, self.cfg.budget,
                               self.cfg.slo_ms, alpha=self.cfg.alpha,
                               beta=self.cfg.beta, gamma=self.cfg.gamma,
                               loaded=cluster.loaded_variants(t), rho=self.rho)
        cluster.apply_allocation(t, alloc.units)
        self.current_ensemble = sorted(alloc.active_variants())
        # fan-out dispatch is handled by the runner via `fanout_backends`
        self.dispatcher.set_weights({m: 1.0 for m in self.current_ensemble}
                                    if self.current_ensemble else {})
        d = Decision(t=t, predicted_load=lam, allocation=alloc)
        self.decisions.append(d)
        return d

    def fanout_backends(self) -> List[str]:
        return list(self.current_ensemble)
