"""Equation 1 of the paper: objective terms + optimal quota assignment.

    max  α·AA − (β·RC + γ·LC)
    s.t. λ ≤ Σ th_m(n_m);  λ_m ≤ th_m(n_m);  p_m(n_m) ≤ L;  Σ n_m ≤ B

AA is the traffic-weighted average accuracy. For a *fixed* allocation the
quota assignment maximizing AA is the accuracy-descending water-fill (send as
much traffic as possible to the most accurate variant first) — provably
optimal because accuracies are constants and capacity is interchangeable.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, Mapping, Optional, Set

from repro.core.profiles import VariantProfile


@dataclass
class Allocation:
    """Solver output: per-variant resource units + traffic quotas."""
    units: Dict[str, int] = field(default_factory=dict)
    quotas: Dict[str, float] = field(default_factory=dict)
    objective: float = float("-inf")
    aa: float = 0.0
    rc: float = 0.0
    lc: float = 0.0
    feasible: bool = False
    served: float = 0.0            # RPS coverable (= min(λ, Σ th))
    predicted_load: float = 0.0

    def total_units(self) -> int:
        return sum(self.units.values())

    def active_variants(self) -> Set[str]:
        return {m for m, n in self.units.items() if n > 0}


def assign_quotas(profiles: Mapping[str, VariantProfile],
                  units: Mapping[str, int], lam: float) -> Dict[str, float]:
    """Accuracy-descending water-fill of λ over variant capacities."""
    order = sorted((m for m, n in units.items() if n > 0),
                   key=lambda m: -profiles[m].accuracy)
    remaining = lam
    quotas: Dict[str, float] = {}
    for m in order:
        cap = profiles[m].throughput(units[m])
        q = min(cap, remaining)
        quotas[m] = q
        remaining -= q
    return quotas


def loading_cost(profiles: Mapping[str, VariantProfile],
                 selected: Iterable[str], loaded: Set[str]) -> float:
    """LC = max{tc_m · rt_m}: readiness time of the slowest cold-started
    variant (0 when every selected variant is already resident)."""
    cold = [profiles[m].rt for m in selected if m not in loaded]
    return max(cold) if cold else 0.0


def evaluate(profiles: Mapping[str, VariantProfile], units: Mapping[str, int],
             lam: float, slo_ms: float, *, alpha: float = 1.0,
             beta: float = 0.05, gamma: float = 0.01,
             loaded: Optional[Set[str]] = None) -> Allocation:
    """Score an allocation under Eq. 1 (quotas water-filled)."""
    loaded = loaded or set()
    active = {m: n for m, n in units.items() if n > 0}
    # latency SLO feasibility per variant
    for m, n in active.items():
        if profiles[m].p99_ms(n) > slo_ms:
            return Allocation(units=dict(units), feasible=False,
                              predicted_load=lam)
    cap = sum(profiles[m].throughput(n) for m, n in active.items())
    quotas = assign_quotas(profiles, active, lam)
    served = sum(quotas.values())
    aa = (sum(quotas[m] * profiles[m].accuracy for m in quotas) / lam
          if lam > 0 else 0.0)
    rc = float(sum(active.values()))
    lc = loading_cost(profiles, active, loaded)
    obj = alpha * aa - (beta * rc + gamma * lc)
    return Allocation(units=dict(units), quotas=quotas, objective=obj, aa=aa,
                      rc=rc, lc=lc, feasible=cap + 1e-9 >= lam, served=served,
                      predicted_load=lam)
