"""Workload traces.

The paper evaluates on a 20-minute sample of the Twitter-trace (2021-08) plus
two weeks of it for LSTM training. The dataset isn't redistributable/offline,
so we provide:

  * ``paper_bursty_trace``   — the paper's Fig. 5 shape: steady (0-600 s),
    spike (600-800 s), gradual decrease (800-1000 s), return (1000-1200 s).
  * ``paper_nonbursty_trace`` — the Fig. 8 gentle-variation counterpart.
  * ``synthetic_twitter_trace`` — long diurnal + AR(1) noise + random bursts,
    statistically matched to published Twitter-trace characteristics
    (CoV ~0.1-0.3 within hours, diurnal swing ~2x, burst factor 1.5-2.5x);
    used to train the LSTM forecaster.

All traces are per-second request rates (np.ndarray, RPS).
"""
from __future__ import annotations

import numpy as np


def paper_bursty_trace(base: float = 40.0, spike: float = 95.0,
                       seconds: int = 1200, noise: float = 0.05,
                       seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    t = np.arange(seconds, dtype=np.float32)
    rate = np.full(seconds, base, np.float32)
    # spike 600-800
    ramp = np.clip((t - 600) / 30.0, 0, 1) * np.clip((800 - t) / 30.0, 0, 1)
    rate += (spike - base) * np.clip(ramp * 3, 0, 1) * ((t >= 600) & (t < 800))
    # gradual decrease 800-1000 back toward base*0.6
    dec = (t >= 800) & (t < 1000)
    rate[dec] = np.linspace(spike, base * 0.6, dec.sum())
    # return to initial 1000-1200
    ret = t >= 1000
    rate[ret] = np.linspace(base * 0.6, base, ret.sum())
    rate *= 1.0 + rng.normal(0, noise, seconds).astype(np.float32)
    return np.clip(rate, 0.5, None)


def paper_nonbursty_trace(base: float = 45.0, seconds: int = 1200,
                          swing: float = 0.35, noise: float = 0.05,
                          seed: int = 1) -> np.ndarray:
    rng = np.random.default_rng(seed)
    t = np.arange(seconds, dtype=np.float32)
    rate = base * (1.0 + swing * np.sin(2 * np.pi * t / 900.0))
    rate *= 1.0 + rng.normal(0, noise, seconds).astype(np.float32)
    return np.clip(rate, 0.5, None)


def synthetic_twitter_trace(seconds: int = 6 * 3600, base: float = 45.0,
                            seed: int = 2) -> np.ndarray:
    """Diurnal + AR(1) + bursts; for forecaster training/eval."""
    rng = np.random.default_rng(seed)
    t = np.arange(seconds, dtype=np.float32)
    diurnal = 1.0 + 0.5 * np.sin(2 * np.pi * t / 86_400.0 - 0.8)
    hourly = 1.0 + 0.15 * np.sin(2 * np.pi * t / 3600.0)
    # AR(1) noise
    ar = np.empty(seconds, np.float32)
    ar[0] = 0.0
    phi, sig = 0.995, 0.02
    eps = rng.normal(0, sig, seconds).astype(np.float32)
    for i in range(1, seconds):
        ar[i] = phi * ar[i - 1] + eps[i]
    # random bursts (Poisson arrivals, exponential decay)
    burst = np.zeros(seconds, np.float32)
    n_bursts = max(1, seconds // 1800)
    starts = rng.integers(0, seconds, n_bursts)
    for s in starts:
        amp = rng.uniform(0.5, 1.5)
        dur = rng.integers(60, 240)
        end = min(s + dur, seconds)
        burst[s:end] += amp * np.exp(-np.arange(end - s) / (dur / 3.0))
    rate = base * diurnal * hourly * (1.0 + ar) * (1.0 + burst)
    return np.clip(rate, 0.5, None).astype(np.float32)


def arrivals_from_rate(rate: np.ndarray, seed: int = 0) -> np.ndarray:
    """Poisson arrival timestamps (seconds) for a per-second rate trace."""
    rng = np.random.default_rng(seed)
    times = []
    for sec, lam in enumerate(rate):
        n = rng.poisson(lam)
        if n:
            times.append(sec + np.sort(rng.random(n)))
    return (np.concatenate(times) if times else np.zeros((0,))).astype(np.float64)
