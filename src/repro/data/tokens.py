"""Synthetic token data pipeline for LM training (offline container: no
downloadable corpora). Generates a learnable Markov-chain token stream —
losses drop well below the uniform-entropy floor iff the model learns."""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np


class SyntheticTokenPipeline:
    """Order-1 Markov stream with a skewed transition matrix + shift labels."""

    def __init__(self, vocab: int = 512, seq_len: int = 128, batch: int = 8,
                 seed: int = 0, branching: int = 8):
        self.vocab = vocab
        self.seq_len = seq_len
        self.batch = batch
        rng = np.random.default_rng(seed)
        # each token can transition to `branching` successors w/ Zipf weights
        self._succ = rng.integers(0, vocab, size=(vocab, branching))
        w = 1.0 / np.arange(1, branching + 1)
        self._w = w / w.sum()
        self._rng = rng

    def next_batch(self) -> Dict[str, jnp.ndarray]:
        toks = np.empty((self.batch, self.seq_len + 1), np.int32)
        toks[:, 0] = self._rng.integers(0, self.vocab, self.batch)
        for t in range(self.seq_len):
            choice = self._rng.choice(self._succ.shape[1], size=self.batch,
                                      p=self._w)
            toks[:, t + 1] = self._succ[toks[:, t], choice]
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}
