"""Persistent, versioned variant-profile store.

The paper's Adapter consumes profiles as static inputs; INFaaS
(arXiv 1905.13348) showed that model-less serving at scale needs a
first-class *registry* of variant profiles instead. This module is that
registry: every ``VariantProfile`` the system knows about lives here,
tagged with

  * **provenance** — how the numbers were obtained: ``"measured"`` (the
    offline ``EngineProfiler`` ran the real engine), ``"roofline"``
    (analytic TPU roofline, optionally cross-calibrated), or
    ``"paper-calibrated"`` (the paper's ResNet constants);
  * the **regression fit** behind the throughput line (slope/intercept/R²
    and the raw (n, th) points), so confidence is auditable; and
  * free-form ``meta`` (calibration scale factors, recalibration history).

The on-disk form is a single versioned JSON document (default location
``reports/profiles/``); ``save``/``load`` round-trip exactly — JSON floats
preserve the shortest-repr encoding, so ``load(save(store))`` reproduces
bit-identical ``VariantProfile`` dataclasses (tested).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.profiles import LinearRegressionFit, VariantProfile

SCHEMA_VERSION = 1
PROVENANCES = ("measured", "roofline", "paper-calibrated")
DEFAULT_STORE_DIR = os.path.join("reports", "profiles")
DEFAULT_STORE_PATH = os.path.join(DEFAULT_STORE_DIR, "profiles.json")


@dataclass
class StoredProfile:
    """One registry entry: the profile + how we know it."""
    profile: VariantProfile
    provenance: str
    updated_at: float
    fit: Optional[LinearRegressionFit] = None
    meta: Dict = field(default_factory=dict)


class ProfileStore:
    """Name -> ``StoredProfile`` registry with JSON persistence.

    ``register`` upserts (a re-measurement overwrites the stale entry and
    records the previous provenance in ``meta["superseded"]``);
    ``profiles()`` is the view controllers/solvers consume.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path or DEFAULT_STORE_PATH
        self._entries: Dict[str, StoredProfile] = {}

    # ------------------------------------------------------------- registry
    def register(self, profile: VariantProfile, provenance: str, *,
                 fit: Optional[LinearRegressionFit] = None,
                 meta: Optional[Dict] = None,
                 updated_at: Optional[float] = None) -> StoredProfile:
        if provenance not in PROVENANCES:
            raise ValueError(f"unknown provenance {provenance!r} "
                             f"(expected one of {PROVENANCES})")
        meta = dict(meta or {})
        prev = self._entries.get(profile.name)
        if prev is not None and prev.provenance != provenance:
            meta.setdefault("superseded", prev.provenance)
        entry = StoredProfile(profile=profile, provenance=provenance,
                              updated_at=updated_at if updated_at is not None
                              else time.time(), fit=fit, meta=meta)
        self._entries[profile.name] = entry
        return entry

    def get(self, name: str) -> VariantProfile:
        return self._entries[name].profile

    def entry(self, name: str) -> StoredProfile:
        return self._entries[name]

    def profiles(self) -> Dict[str, VariantProfile]:
        """The plain name -> profile mapping solvers/controllers take."""
        return {n: e.profile for n, e in self._entries.items()}

    def names(self) -> List[str]:
        return sorted(self._entries)

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    # ---------------------------------------------------------- persistence
    def to_json(self) -> Dict:
        doc = {"schema_version": SCHEMA_VERSION, "profiles": {}}
        for name, e in sorted(self._entries.items()):
            rec = {
                "profile": dataclasses.asdict(e.profile),
                "provenance": e.provenance,
                "updated_at": e.updated_at,
                "meta": e.meta,
            }
            if e.fit is not None:
                rec["fit"] = {
                    "slope": e.fit.slope, "intercept": e.fit.intercept,
                    "r_squared": e.fit.r_squared,
                    "points": [[int(n), float(th)] for n, th in e.fit.points],
                }
            doc["profiles"][name] = rec
        return doc

    def save(self, path: Optional[str] = None) -> str:
        path = path or self.path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
            f.write("\n")
        self.path = path
        return path

    @classmethod
    def from_json(cls, doc: Dict, path: Optional[str] = None) -> "ProfileStore":
        ver = doc.get("schema_version")
        if ver != SCHEMA_VERSION:
            raise ValueError(f"profile store schema_version {ver!r} "
                             f"unsupported (expected {SCHEMA_VERSION})")
        store = cls(path=path)
        for name, rec in doc.get("profiles", {}).items():
            prof = VariantProfile(**rec["profile"])
            fit = None
            if "fit" in rec:
                f = rec["fit"]
                pts: List[Tuple[int, float]] = [
                    (int(n), float(th)) for n, th in f.get("points", [])]
                fit = LinearRegressionFit(f["slope"], f["intercept"],
                                          f["r_squared"], pts)
            store.register(prof, rec["provenance"], fit=fit,
                           meta=rec.get("meta", {}),
                           updated_at=rec.get("updated_at", 0.0))
        return store

    @classmethod
    def load(cls, path: str) -> "ProfileStore":
        with open(path) as f:
            return cls.from_json(json.load(f), path=path)
