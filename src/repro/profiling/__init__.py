"""Measured profiling subsystem: engine-driven variant profiles, a
persistent profile store, and online drift recalibration (paper §5's
Profiler as a first-class component; see DESIGN.md §Profiling).

Import layout mirrors ``repro.serving``: the store and drift machinery are
numpy-only; the offline profiler (``measure``) pulls in the JAX engine only
when used, so simulator-only paths stay light.
"""
from repro.profiling.store import (DEFAULT_STORE_DIR,  # noqa: F401
                                   DEFAULT_STORE_PATH, PROVENANCES,
                                   SCHEMA_VERSION, ProfileStore,
                                   StoredProfile)
from repro.profiling.drift import (DriftDetector, DriftReport,  # noqa: F401
                                   OnlineRecalibrator)
