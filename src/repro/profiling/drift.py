"""Online profile-drift detection and targeted recalibration.

A stored profile is a *claim* about the engine: serve at allocation n and
processing latency will be ≈ p(n), capacity ≈ th(n). Engines drift — a
changed decode chunk, CPU contention, a different kernel path — and a
controller solving Eq. 1 against stale claims provisions wrongly (Loki,
arXiv 2407.03583, makes the same observation for GPU pipelines).

``DriftDetector`` folds completed requests (their measured queue/service
split) into per-variant sliding windows and compares, per variant:

  * observed mean service time  vs  the profile's mean-service model
    (stored in meta by measured profiles; falls back to the p99 curve,
    conservatively, when absent) at the current allocation — ratio outside
    the tolerance band ``[1/(1+tol), 1+tol]`` flags drift in either
    direction. Service time is load-independent, so this is the primary
    signal.
  * observed completion rate    vs  profiled capacity th(n) — reported in
    every ``DriftReport``; it *flags* drift only when ``throughput_band``
    is set AND the observation runs over capacity (below capacity is the
    normal partial-load regime, not evidence the profile is wrong).
    Capacity comparisons only mean anything when the engine enforces the
    units -> concurrency mapping the profiles were measured under
    (``InProcessServingEngine(enforce_units=True)``), hence opt-in.

``OnlineRecalibrator`` acts on a flagged variant between control
intervals: a quick targeted re-profile of that single variant (the
``EngineProfiler`` with a reduced sweep), the store patched under
provenance ``"measured"``, and the live controller's profile swapped via
``InfAdapterController.update_profiles`` — the next solve allocates
against reality.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Mapping, Optional, Tuple

import numpy as np

DEFAULT_TOLERANCE = 0.35          # ±35% band before a profile counts as stale


@dataclass
class DriftReport:
    """Verdict for one variant at one check."""
    variant: str
    drifted: bool
    service_ratio: float          # observed mean service / profiled p(n)
    throughput_ratio: float       # observed rate / profiled th(n) (0 if idle)
    n_obs: int
    reason: str = ""


class _VariantWindow:
    """Sliding window of completions for one variant."""

    def __init__(self, window: int):
        self.service_ms: Deque[float] = deque(maxlen=window)
        self.completions: Deque[float] = deque(maxlen=window)

    def add(self, service_ms: float, completion_t: float) -> None:
        self.service_ms.append(service_ms)
        self.completions.append(completion_t)

    def observed_rate(self) -> float:
        """Completion rate over the window's wall-clock span (0 if <2 obs)."""
        if len(self.completions) < 2:
            return 0.0
        span = max(self.completions[-1] - self.completions[0], 1e-9)
        return (len(self.completions) - 1) / span


class DriftDetector:
    """Compares live observations against stored profiles.

    ``profiles`` may be a ``ProfileStore`` or a plain name -> profile
    mapping (anything with ``profiles()`` or dict semantics)."""

    def __init__(self, profiles, *, tolerance: float = DEFAULT_TOLERANCE,
                 min_requests: int = 10, window: int = 256,
                 throughput_band: Optional[float] = None):
        self._source = profiles
        self.tolerance = tolerance
        self.min_requests = min_requests
        self.window = window
        self.throughput_band = throughput_band
        self._stats: Dict[str, _VariantWindow] = {}
        self._consumed = 0        # engine.done cursor for observe_engine

    def _profiles(self) -> Mapping:
        if hasattr(self._source, "profiles"):
            return self._source.profiles()
        return self._source

    def _meta(self, name: str) -> Optional[Dict]:
        """Store meta for ``name`` when the source is a ProfileStore."""
        if hasattr(self._source, "entry") and name in self._source:
            return self._source.entry(name).meta
        return None

    # ---------------------------------------------------------- observations
    def observe(self, req) -> None:
        """Fold one completed request (needs ``backend``, ``service_ms``,
        ``completion``) into its variant's window."""
        if not req.backend:
            return
        w = self._stats.setdefault(req.backend, _VariantWindow(self.window))
        w.add(req.service_ms, req.completion)

    def observe_engine(self, engine) -> int:
        """Consume completions appended to ``engine.done`` since last call."""
        new = engine.done[self._consumed:]
        self._consumed = len(engine.done)
        for r in new:
            self.observe(r)
        return len(new)

    def reset(self, name: str) -> None:
        """Forget a variant's window (after recalibration: the old
        observations described the profile we just replaced)."""
        self._stats.pop(name, None)

    # ---------------------------------------------------------------- checks
    def check(self, name: str, units: int = 1) -> DriftReport:
        profiles = self._profiles()
        if name not in profiles:
            return DriftReport(name, False, 0.0, 0.0, 0, "no profile")
        w = self._stats.get(name)
        n_obs = len(w.service_ms) if w else 0
        if n_obs < self.min_requests:
            return DriftReport(name, False, 0.0, 0.0, n_obs,
                               f"insufficient observations ({n_obs})")
        p = profiles[name]
        # compare observed MEAN service against the profile's mean-service
        # model (store meta, measured profiles); fall back to the p99 curve
        # when no mean model exists — conservative: mean/p99 < 1, so only
        # large slowdowns cross the upper band
        meta = self._meta(name)
        model = (meta or {}).get("mean_latency_model")
        if model:
            predicted_ms = max(model[0] + model[1] / max(units, 1), 1e-9)
        else:
            predicted_ms = max(p.p99_ms(units), 1e-9)
        observed_ms = float(np.mean(w.service_ms))
        service_ratio = observed_ms / predicted_ms
        cap = max(p.throughput(units), 1e-9)
        throughput_ratio = w.observed_rate() / cap
        hi, lo = 1.0 + self.tolerance, 1.0 / (1.0 + self.tolerance)
        reasons = []
        if service_ratio > hi:
            reasons.append(f"service {service_ratio:.2f}x slower than p({units})")
        elif service_ratio < lo:
            reasons.append(f"service {service_ratio:.2f}x of p({units}) — "
                           "profile pessimistic")
        if (self.throughput_band is not None
                and throughput_ratio > 1.0 + self.throughput_band):
            reasons.append(f"throughput {throughput_ratio:.2f}x profiled "
                           f"capacity th({units})")
        return DriftReport(name, bool(reasons), service_ratio,
                           throughput_ratio, n_obs, "; ".join(reasons))

    def check_all(self, units: Mapping[str, int]) -> List[DriftReport]:
        return [self.check(m, n) for m, n in sorted(units.items()) if n > 0]


class OnlineRecalibrator:
    """Targeted re-profiling of drifted variants between control intervals.

    Wires detector -> profiler -> store -> controller: one quick sweep of
    only the flagged variant, the store patched (provenance stays
    ``"measured"``, recalibration history in meta), the live controller's
    profile table updated in place."""

    def __init__(self, profiler, store, *, controller=None, detector=None,
                 points: Tuple[int, ...] = (1, 2, 4),
                 requests_per_point: int = 8):
        self.profiler = profiler
        self.store = store
        self.controller = controller
        self.detector = detector
        self.points = points
        self.requests_per_point = requests_per_point
        self.recalibrations: List[Tuple[float, str]] = []

    def recalibrate(self, name: str):
        """Re-measure one variant and propagate the fresh profile."""
        m = self.profiler.profile_variant(
            name, points=self.points,
            requests_per_point=self.requests_per_point)
        prev = self.store.entry(name).updated_at if name in self.store else None
        self.store.register(
            m.profile, "measured", fit=m.th_fit,
            meta={**m.store_meta(), "recalibrated": True,
                  "previous_updated_at": prev})
        if self.controller is not None:
            self.controller.update_profiles({name: m.profile})
        if self.detector is not None:
            self.detector.reset(name)
        self.recalibrations.append((time.time(), name))
        return m

    def run_check(self, units: Mapping[str, int]) -> List[DriftReport]:
        """Check every allocated variant; recalibrate the drifted ones.
        Returns the reports (recalibrated variants have ``drifted=True``)."""
        if self.detector is None:
            return []
        reports = self.detector.check_all(units)
        for rep in reports:
            if rep.drifted:
                self.recalibrate(rep.variant)
        return reports
