"""Roofline-backed profiles for configs the CPU engine cannot run,
cross-calibrated against measured smoke-scale variants.

The offline profiler (``measure.EngineProfiler``) can only sweep variants
small enough to execute in-process; the TPU-scale ladder (e.g. a 6B model
on 1–64 chips) must come from the analytic roofline
(``repro.core.profiles.roofline_profile``). Analytic rooflines are
systematically optimistic — they ignore dispatch overhead, host
orchestration, and kernel inefficiency. This module closes that gap the
INFaaS way: run the *same* analytic model over the smoke-scale variants we
DID measure, compare predicted vs measured throughput slopes, and carry the
resulting correction factor onto the unrunnable configs.

The factor is a geometric mean of per-variant measured/analytic slope
ratios (geometric so a single outlier variant cannot dominate), applied as
  th'(n)   = scale · th(n)
  p'(n)    = base + k/scale / n        (latency moves inversely)
On real TPU hardware the measured points come from the TPU engine and the
factor converges toward 1; on the CPU smoke rig it mostly captures
software overhead — either way it is *measured*, not assumed.
"""
from __future__ import annotations

from typing import Dict, Mapping, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.profiles import VariantProfile, roofline_profile

from repro.profiling.measure import ProfileMeasurement


def roofline_scale_factor(measurements: Mapping[str, ProfileMeasurement],
                          cfgs: Mapping[str, ModelConfig], *,
                          tokens_per_request: int = 128) -> float:
    """Cross-calibration factor: geometric mean over reference variants of
    (measured throughput slope) / (analytic roofline slope)."""
    ratios = []
    for name, m in measurements.items():
        cfg = cfgs.get(name)
        if cfg is None:
            continue
        analytic = roofline_profile(cfg, accuracy=m.profile.accuracy,
                                    tokens_per_request=tokens_per_request)
        a_slope = max(analytic.th_slope, 1e-12)
        m_slope = max(m.th_fit.slope, 1e-12)
        ratios.append(m_slope / a_slope)
    if not ratios:
        return 1.0
    return float(np.exp(np.mean(np.log(ratios))))


def calibrated_roofline_profile(cfg: ModelConfig, accuracy: float, *,
                                scale: float = 1.0,
                                tokens_per_request: int = 128,
                                max_chips: int = 64) -> VariantProfile:
    """Analytic profile for an unrunnable config, throughput scaled by the
    measured correction factor (latency scaled inversely)."""
    p = roofline_profile(cfg, accuracy, tokens_per_request=tokens_per_request,
                         max_chips=max_chips)
    s = max(scale, 1e-12)
    return VariantProfile(
        name=p.name, accuracy=p.accuracy, rt=p.rt,
        th_slope=p.th_slope * s, th_intercept=p.th_intercept * s,
        lat_base_ms=p.lat_base_ms, lat_k_ms=p.lat_k_ms / s,
        max_units=p.max_units)


def profile_unrunnable(cfgs: Sequence[ModelConfig],
                       accuracies: Sequence[float],
                       measurements: Mapping[str, ProfileMeasurement],
                       reference_cfgs: Mapping[str, ModelConfig], *,
                       tokens_per_request: int = 128, max_chips: int = 64,
                       store=None) -> Dict[str, VariantProfile]:
    """Profile TPU-scale configs via the cross-calibrated roofline; register
    into ``store`` under provenance ``"roofline"`` with the factor recorded."""
    scale = roofline_scale_factor(measurements, reference_cfgs,
                                  tokens_per_request=tokens_per_request)
    out: Dict[str, VariantProfile] = {}
    for cfg, acc in zip(cfgs, accuracies):
        p = calibrated_roofline_profile(
            cfg, acc, scale=scale, tokens_per_request=tokens_per_request,
            max_chips=max_chips)
        out[p.name] = p
        if store is not None:
            store.register(p, "roofline",
                           meta={"calibration_scale": scale,
                                 "references": sorted(measurements)})
    return out
