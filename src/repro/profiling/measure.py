"""Offline engine profiler — the paper's Profiler (§5), made real.

The paper profiles every variant at a handful of allocations
(``PROFILE_CORE_POINTS``) and regression-fits ``th_m(n) = a·n + b`` and
``p_m(n) = base + k/n`` from *measurements*. This module does exactly that
against the real ``InProcessServingEngine``:

  * an allocation of ``n`` units maps to an engine **concurrency cap** of
    ``n`` slots (points beyond ``max_batch`` are unmeasurable on a backend
    and are skipped, not extrapolated into the fit);
  * each point is measured under **saturating open-loop load**: the
    profiler keeps exactly ``n`` requests in flight at all times, so the
    completion rate *is* the saturation throughput at that allocation;
  * processing latency is taken from the queue-wait / service-time split
    (``Request.service_ms`` — prefill + decode, *excluding* admission-queue
    wait), which is what the paper's p_m(n) means;
  * readiness time rt_m is the backend's actually measured jit warm-up
    (``VariantBackend.readiness_s``), not an assumed constant.

The emitted ``VariantProfile`` carries the regression fit (R² as the
confidence signal) and slots straight into the Eq. 1 solver; the
``ProfileMeasurement`` wrapper keeps the raw points for the profile store.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.profiles import (PROFILE_CORE_POINTS, LinearRegressionFit,
                                 VariantProfile, fit_throughput)
from repro.serving.api import Request


@dataclass
class MeasuredPoint:
    """One profiled allocation point (paper §5 measures five of these)."""
    units: int                  # allocation = engine concurrency cap
    throughput_rps: float       # saturation completion rate
    mean_service_ms: float      # processing latency p(n), queue wait excluded
    p99_service_ms: float
    mean_queue_ms: float        # ≈0 under the profiler's direct admission
    n_requests: int


@dataclass
class ProfileMeasurement:
    """A full measured profile: raw points + fits + the resulting profile.

    ``lat_base_ms``/``lat_k_ms`` fit the per-point **P99** service time —
    the semantics every consumer of ``VariantProfile.p99_ms`` assumes (the
    solver's SLO feasibility gate, ``min_feasible_units``). The parallel
    **mean**-service model (``lat_mean_*``) is what the drift detector
    compares live mean observations against; it travels in store meta."""
    name: str
    points: List[MeasuredPoint]
    th_fit: LinearRegressionFit
    lat_base_ms: float            # p99-service fit
    lat_k_ms: float
    lat_r_squared: float
    lat_mean_base_ms: float       # mean-service fit (drift reference)
    lat_mean_k_ms: float
    readiness_s: float
    profile: VariantProfile

    @property
    def confidence(self) -> float:
        """Joint fit confidence in [0, 1]: the weaker of the two R²s."""
        return float(np.clip(min(self.th_fit.r_squared, self.lat_r_squared),
                             0.0, 1.0))

    def store_meta(self) -> dict:
        """The standard meta block a ``ProfileStore`` entry carries for a
        measured profile (consumed by ``DriftDetector``)."""
        return {"lat_r_squared": self.lat_r_squared,
                "confidence": self.confidence,
                "mean_latency_model": [self.lat_mean_base_ms,
                                       self.lat_mean_k_ms],
                "points": [[p.units, p.throughput_rps, p.mean_service_ms]
                           for p in self.points]}


def fit_latency(points: Sequence[Tuple[int, float]]
                ) -> Tuple[float, float, float]:
    """Least-squares fit of the paper's latency model p(n) = base + k/n.

    Returns (base_ms, k_ms, r_squared). Engines whose service time is flat
    in n (chunked decode: batch-wide step cost) yield k ≈ 0; a negative k
    (latency *rising* with allocation — measurement noise) degenerates to
    the constant model, for which R² is reported as 1 when the data really
    is constant."""
    ns = np.array([p[0] for p in points], float)
    lat = np.array([p[1] for p in points], float)
    if len(ns) >= 2:
        A = np.stack([np.ones_like(ns), 1.0 / ns], axis=1)
        (base, k), *_ = np.linalg.lstsq(A, lat, rcond=None)
    else:
        base, k = float(lat.mean()), 0.0
    if k < 0.0:
        base, k = float(lat.mean()), 0.0
    base = max(float(base), 0.0)
    pred = base + k / ns
    ss_res = float(np.sum((lat - pred) ** 2))
    ss_tot = float(np.sum((lat - np.mean(lat)) ** 2))
    if ss_tot <= 1e-9 * max(1.0, float(np.mean(lat)) ** 2):
        r2 = 1.0          # constant data, constant model: perfect fit
    else:
        # clamping base/k above can leave the model worse than the mean;
        # floor at 0 so R² stays a valid [0, 1] confidence signal
        r2 = max(1.0 - ss_res / ss_tot, 0.0)
    return base, float(k), float(r2)


class EngineProfiler:
    """Sweeps ``InProcessServingEngine`` variants across allocation points.

    Drives each ``VariantBackend`` directly (admission + decode chunks),
    bypassing the engine queues so profiling traffic never pollutes
    ``engine.done`` metrics. A variant already loaded on the engine is
    profiled in place (its in-flight work is drained to ``engine.done``
    first); an unloaded one gets a throwaway backend — so targeted
    re-profiling between control intervals never retires live variants.
    """

    def __init__(self, engine, *, points: Sequence[int] = PROFILE_CORE_POINTS,
                 requests_per_point: int = 24, warmup: int = 4,
                 vocab: int = 128, max_units: int = 64, seed: int = 0):
        self.engine = engine
        self.points = tuple(points)
        self.requests_per_point = requests_per_point
        self.warmup = warmup
        self.vocab = vocab
        self.max_units = max_units
        self.seed = seed

    # ------------------------------------------------------------- backends
    def _backend(self, name: str):
        eng = self.engine
        if name in eng.backends:
            b = eng.backends[name]
            eng.done.extend(b.drain_slots(time.time()))  # free all slots
            return b
        # throwaway backend built by the engine's own factory, so it carries
        # the engine's KV discipline (dense ring vs paged pool) — a paged
        # engine must be profiled under paged admission/decode semantics or
        # the fitted th(n)/p(n) describe a backend it never runs
        return eng._make_backend(name)

    # ----------------------------------------------------------- measurement
    def _measure_point(self, b, cap: int, rpp: int) -> MeasuredPoint:
        """Saturating open-loop measurement at concurrency ``cap``: keep
        exactly ``cap`` requests in flight; after the warm-up quota, time
        at least ``rpp`` further completions.

        Completions retire in lock-step batches (equal token budgets, joint
        admission), so the warm-up quota is consumed in *whole batches* —
        counting the tail of a partially-warm batch as measured would stamp
        ``t_meas0`` mid-batch and inflate throughput by up to a batch's
        worth of near-zero elapsed time."""
        rng = np.random.default_rng(self.seed + 7919 * cap)
        rid = 0
        warm_left = self.warmup
        measured: List[Request] = []
        t_meas0: Optional[float] = time.time() if warm_left == 0 else None
        # arrivals must come from the SAME clock the backend stamps
        # service_start/completion with (the engine's injectable clock may
        # be an elapsed-seconds domain) — mixing domains corrupts the
        # queue-wait split this profiler fits p(n) from
        clk = getattr(b, "clock", time.time)

        def new_request() -> Request:
            nonlocal rid
            r = Request(rid=rid,
                        tokens=rng.integers(0, self.vocab,
                                            b.prompt_len).astype(np.int64),
                        max_new=b.max_new, arrival=clk())
            rid += 1
            return r

        while len(measured) < rpp:
            now = time.time()
            want = cap - b.active_slots
            done = b.admit([new_request() for _ in range(want)], now) \
                if want > 0 else []
            done += b.decode_step_batch(time.time())
            if not done:
                continue
            if warm_left > 0:
                warm_left -= len(done)       # whole batch is warm-up
                if warm_left <= 0:
                    t_meas0 = time.time()
                continue
            measured.extend(done)
        elapsed = max(time.time() - t_meas0, 1e-9)
        b.drain_slots(time.time())        # discard in-flight leftovers
        svc = np.array([r.service_ms for r in measured])
        que = np.array([r.queue_wait_ms for r in measured])
        return MeasuredPoint(
            units=cap, throughput_rps=len(measured) / elapsed,
            mean_service_ms=float(svc.mean()),
            p99_service_ms=float(np.percentile(svc, 99)),
            mean_queue_ms=float(que.mean()), n_requests=len(measured))

    def profile_variant(self, name: str, *,
                        points: Optional[Sequence[int]] = None,
                        requests_per_point: Optional[int] = None
                        ) -> ProfileMeasurement:
        """Measure one variant across the allocation sweep and fit profiles."""
        b = self._backend(name)
        rpp = requests_per_point or self.requests_per_point
        usable = sorted({p for p in (points or self.points)
                         if 1 <= p <= b.max_batch})
        if not usable:
            usable = [b.max_batch]
        # the sweep sets its own concurrency per point — suspend any
        # enforce_units cap on a live backend for the measurement
        saved_cap, b.slot_cap = b.slot_cap, None
        try:
            m_points = [self._measure_point(b, cap, rpp) for cap in usable]
        finally:
            b.slot_cap = saved_cap

        th_pts = [(p.units, p.throughput_rps) for p in m_points]
        if len(th_pts) >= 2:
            th_fit = fit_throughput(th_pts)
        else:   # single measurable point: capacity line through the origin
            (n0, th0), = th_pts
            th_fit = LinearRegressionFit(th0 / n0, 0.0, 1.0, list(th_pts))
        # profile latency = p99-service fit (what p99_ms consumers assume);
        # the mean-service fit rides along for the drift detector
        lat_base, lat_k, lat_r2 = fit_latency(
            [(p.units, p.p99_service_ms) for p in m_points])
        mean_base, mean_k, _ = fit_latency(
            [(p.units, p.mean_service_ms) for p in m_points])
        profile = VariantProfile(
            name=name, accuracy=b.accuracy, rt=b.readiness_s,
            th_slope=th_fit.slope, th_intercept=th_fit.intercept,
            lat_base_ms=lat_base, lat_k_ms=lat_k, max_units=self.max_units)
        return ProfileMeasurement(
            name=name, points=m_points, th_fit=th_fit, lat_base_ms=lat_base,
            lat_k_ms=lat_k, lat_r_squared=lat_r2,
            lat_mean_base_ms=mean_base, lat_mean_k_ms=mean_k,
            readiness_s=b.readiness_s, profile=profile)

    def profile_all(self, store=None) -> Dict[str, ProfileMeasurement]:
        """Sweep every variant the engine knows; optionally register each
        result in a ``ProfileStore`` under provenance ``"measured"``."""
        out = {}
        for name in sorted(self.engine.variant_defs):
            m = self.profile_variant(name)
            out[name] = m
            if store is not None:
                store.register(m.profile, "measured", fit=m.th_fit,
                               meta=m.store_meta())
        return out
