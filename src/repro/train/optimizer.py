"""Optimizers from scratch (no optax): Adam/AdamW + schedules + clipping.

State is a params-shaped pytree, so any sharding PartitionSpec tree derived
for the params applies verbatim to the optimizer moments (ZeRO-1-style when
the params are sharded over the mesh).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"   # "cosine" | "constant"


def _schedule(cfg: AdamConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "cosine":
        frac = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def adam_init(params) -> AdamState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros,
                     nu=jax.tree_util.tree_map(jnp.copy, zeros))


def adam_update(cfg: AdamConfig, grads, state: AdamState, params
                ) -> Tuple[Any, AdamState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    if cfg.grad_clip > 0:
        scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
    step = state.step + 1
    lr = _schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    mu = jax.tree_util.tree_map(
        lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads)
    nu = jax.tree_util.tree_map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if cfg.weight_decay:
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd, params, mu, nu)
    return new_params, AdamState(step, mu, nu), {"grad_norm": gnorm, "lr": lr}
