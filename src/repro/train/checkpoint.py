"""Checkpointing: save/restore arbitrary pytrees (params + optimizer state)
without external deps (no orbax in this container).

Layout (one directory per step):
    <dir>/step_000100/
        manifest.json      tree structure + leaf dtypes/shapes + metadata
        arrays.npz         leaf arrays keyed by flattened path

Atomic via write-to-tmp + rename. ``latest_step``/``restore`` round-trip any
params/opt pytree produced by this framework (dict/NamedTuple nesting).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}

    def visit(path, leaf):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    jax.tree_util.tree_map_with_path(visit, tree)
    return flat


def save(directory: str, step: int, tree: Any,
         metadata: Optional[Dict] = None) -> str:
    """Save a pytree checkpoint; returns the checkpoint path."""
    treedef = jax.tree_util.tree_structure(tree)
    flat = _flatten(tree)
    final = os.path.join(directory, f"step_{step:09d}")
    os.makedirs(directory, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k: v for k, v in flat.items()})
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "keys": sorted(flat),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "metadata": metadata or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    finally:
        if os.path.exists(tmp):
            shutil.rmtree(tmp, ignore_errors=True)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(directory: str, like: Any, step: Optional[int] = None
            ) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like`` (shapes/dtypes validated)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(path, "arrays.npz"))
    flat_like = _flatten(like)
    if sorted(flat_like) != manifest["keys"]:
        missing = set(manifest["keys"]) ^ set(flat_like)
        raise ValueError(f"checkpoint structure mismatch: {sorted(missing)[:5]}")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)

    def rebuild(path, leaf):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = arrays[key]
        if list(arr.shape) != list(np.asarray(leaf).shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        return arr.astype(np.asarray(leaf).dtype)

    tree = jax.tree_util.tree_map_with_path(rebuild, like)
    return tree, manifest["metadata"]


def prune(directory: str, keep: int = 3) -> None:
    """Keep only the newest ``keep`` checkpoints."""
    if not os.path.isdir(directory):
        return
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"),
                      ignore_errors=True)
