"""Model/config registry for all assigned architectures + the paper's ResNets.

Every architecture in the assignment pool is expressed as a ``ModelConfig``.
``REGISTRY`` maps ``--arch <id>`` names to full production configs;
``smoke_variant(cfg)`` derives the reduced CPU-testable config (<=2 layers,
d_model<=512, <=4 experts) from the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

VOCAB_PAD_MULTIPLE = 256  # pad vocab so it shards over the 16-way model axis


def pad_vocab(v: int, multiple: int = VOCAB_PAD_MULTIPLE) -> int:
    return ((v + multiple - 1) // multiple) * multiple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm | resnet
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    router_jitter: float = 0.0
    aux_loss_coef: float = 0.01
    moe_capacity_factor: float = 1.25
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    ssd_chunk: int = 128
    # --- attention ---
    rope_theta: float = 10_000.0
    sliding_window: int = 0        # 0 = full attention
    global_layer_every: int = 0    # hybrid: every k-th layer uses full attn
    attn_logit_softcap: float = 0.0
    # --- block wiring ---
    mlp_type: str = "swiglu"       # swiglu | geglu | gelu
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # --- encoder/decoder (whisper) ---
    is_encoder_decoder: bool = False
    enc_layers: int = 0
    enc_seq: int = 1500            # encoder frames (stub frontend output length)
    # --- multimodal stub frontend ---
    frontend: str = ""             # "" | "audio_frames" | "vision_patches"
    num_frontend_tokens: int = 0
    # --- numerics / execution ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    use_pallas: bool = False
    remat: bool = True
    scan_layers: bool = True   # False: unroll (dry-run cost analysis counts
    #                            a scan body once; unrolling keeps it honest)
    source: str = ""               # citation (paper / model card)

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size) if self.vocab_size else 0

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True if a 500k-token decode is sub-quadratic for this config."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Rough parameter count (used for accuracy-proxy scaling laws & rooflines).
    def param_count(self) -> int:
        D, F, L = self.d_model, self.d_ff, self.num_layers
        H, KV, hd = self.num_heads, self.num_kv_heads, self.resolved_head_dim
        n = 0
        if self.vocab_size:
            n += self.padded_vocab * D          # embed
            if not self.tie_embeddings:
                n += D * self.padded_vocab      # lm head
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "audio", "hybrid"):
            per_layer += D * H * hd + 2 * D * KV * hd + H * hd * D   # qkvo
        if self.family in ("dense", "vlm", "audio"):
            n_mats = 3 if self.mlp_type in ("swiglu", "geglu") else 2
            per_layer += n_mats * D * F
        elif self.family == "moe":
            per_layer += D * self.num_experts   # router
            per_layer += self.num_experts * 3 * D * F
        if self.family in ("ssm", "hybrid"):
            di, N, Hs = self.d_inner, self.ssm_state, self.ssm_heads
            proj_in = 2 * di + 2 * N + Hs       # z,x,B,C,dt (ngroups=1)
            per_layer += D * proj_in + di * D + self.conv_width * (di + 2 * N)
        if self.family == "hybrid":
            n_mats = 3 if self.mlp_type in ("swiglu", "geglu") else 2
            per_layer += n_mats * D * F
        per_layer += 2 * D                      # norms
        n += L * per_layer
        if self.is_encoder_decoder:
            # encoder layers + cross attention in decoder
            enc = self.enc_layers * (4 * D * H * hd + 2 * D * F + 2 * D)
            cross = L * (D * H * hd + 2 * D * KV * hd + H * hd * D + D)
            n += enc + cross
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE uses top-k of experts)."""
        if not self.is_moe:
            return self.param_count()
        D, F, L = self.d_model, self.d_ff, self.num_layers
        dense = self.param_count() - L * self.num_experts * 3 * D * F
        return dense + L * self.experts_per_token * 3 * D * F


REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(fn: Callable[[], ModelConfig]) -> Callable[[], ModelConfig]:
    cfg = fn()
    REGISTRY[cfg.name] = fn
    return fn


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]()


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: <=2 layers, d_model<=512, <=4 experts."""
    d_model = min(cfg.d_model, 256)
    head_dim = min(cfg.resolved_head_dim, 64)
    heads = max(2, min(cfg.num_heads, d_model // head_dim)) if cfg.num_heads else 0
    kv = max(1, min(cfg.num_kv_heads, heads)) if cfg.num_kv_heads else 0
    if heads and kv:
        while heads % kv:
            kv -= 1
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=2,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=head_dim if cfg.num_heads else 0,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512) if cfg.vocab_size else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=min(cfg.ssm_head_dim, 32),
        enc_layers=min(cfg.enc_layers, 2),
        enc_seq=min(cfg.enc_seq, 32),
        num_frontend_tokens=min(cfg.num_frontend_tokens, 8),
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
        dtype="float32",
        param_dtype="float32",
        remat=False,
    )
    if cfg.is_moe:
        # dropless at test scale so decode == teacher forcing exactly
        kw.update(num_experts=4, experts_per_token=2, moe_capacity_factor=16.0)
    return cfg.replace(**kw)
