"""hymba-1.5b — hybrid: parallel attention + Mamba heads per block
[arXiv:2411.13676]. Sliding-window attention on most layers (full attention
every 8th layer), matching the Hymba design; SSM path gives O(1) state so
long_500k decode is native."""
from .base import ModelConfig, register


@register
def hymba_1_5b() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        num_layers=32,
        d_model=1600,
        num_heads=25,
        num_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab_size=32001,
        ssm_state=16,
        ssm_head_dim=64,
        expand=2,
        sliding_window=1024,
        global_layer_every=8,
        source="arXiv:2411.13676 (Hymba)",
    )
