"""granite-moe-3b-a800m — 40-expert top-8 MoE
[hf:ibm-granite/granite-3.0-1b-a400m-base family]."""
from .base import ModelConfig, register


@register
def granite_moe_3b_a800m() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        num_layers=32,
        d_model=1536,
        num_heads=24,
        num_kv_heads=8,
        head_dim=64,
        d_ff=512,                # per-expert FFN width
        vocab_size=49155,
        num_experts=40,
        experts_per_token=8,
        source="hf:ibm-granite/granite-3.0-1b-a400m-base (Granite MoE family)",
    )
