"""whisper-tiny — encoder-decoder ASR backbone; conv/mel frontend stubbed
per assignment (input_specs() provides precomputed frame embeddings)
[arXiv:2212.04356]."""
from .base import ModelConfig, register


@register
def whisper_tiny() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="audio",
        num_layers=4,            # decoder layers
        enc_layers=4,
        enc_seq=1500,
        d_model=384,
        num_heads=6,
        num_kv_heads=6,
        head_dim=64,
        d_ff=1536,
        vocab_size=51865,
        is_encoder_decoder=True,
        frontend="audio_frames",
        mlp_type="gelu",
        rope_theta=0.0,          # whisper uses learned/sinusoidal positions
        source="arXiv:2212.04356 (Whisper)",
    )
