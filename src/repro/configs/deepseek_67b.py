"""deepseek-67b — llama-architecture dense, 95 layers GQA kv=8 [arXiv:2401.02954]."""
from .base import ModelConfig, register


@register
def deepseek_67b() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b",
        family="dense",
        num_layers=95,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=22016,
        vocab_size=102400,
        rope_theta=10_000.0,
        source="arXiv:2401.02954 (DeepSeek LLM 67B)",
    )
