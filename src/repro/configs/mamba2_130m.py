"""mamba2-130m — SSD (state-space duality), attention-free [arXiv:2405.21060]."""
from .base import ModelConfig, register


@register
def mamba2_130m() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        family="ssm",
        num_layers=24,
        d_model=768,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_head_dim=64,
        expand=2,
        conv_width=4,
        tie_embeddings=True,
        source="arXiv:2405.21060 (Mamba-2, SSD)",
    )
