"""Config registry. Importing this package registers every assigned arch."""
from . import (deepseek_67b, gemma_2b, granite_moe_3b_a800m, hymba_1_5b,
               internvl2_26b, mamba2_130m, qwen3_moe_235b_a22b, tinyllama_1_1b,
               whisper_tiny, yi_6b)  # noqa: F401  (registration side effects)
from .base import REGISTRY, ModelConfig, get_config, smoke_variant  # noqa: F401
from .shapes import (SHAPES, InputShape, adapt_config_for_shape,  # noqa: F401
                     get_shape, pairs)

ALL_ARCHS = sorted(REGISTRY)
