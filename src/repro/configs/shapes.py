"""Assigned input shapes and per-(arch, shape) applicability rules."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from .base import ModelConfig


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def get_shape(name: str) -> InputShape:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; have {sorted(SHAPES)}")
    return SHAPES[name]


# Sliding-window width used to make full-attention archs sub-quadratic for
# long_500k (documented in DESIGN.md §Arch-applicability).
LONG_CONTEXT_WINDOW = 8_192


def adapt_config_for_shape(cfg: ModelConfig, shape: InputShape) -> Tuple[Optional[ModelConfig], str]:
    """Returns (possibly adapted config, note) or (None, skip reason)."""
    if shape.name == "long_500k":
        if cfg.is_encoder_decoder:
            return None, (
                "SKIP: enc-dec audio decoder; 500k-token autoregressive decode "
                "is outside the family scope (full attention, no sub-quadratic "
                "variant in the Whisper family). See DESIGN.md."
            )
        if cfg.family in ("ssm", "hybrid"):
            return cfg, "native sub-quadratic (SSM state / windowed attention)"
        if cfg.sliding_window == 0:
            return (
                cfg.replace(sliding_window=LONG_CONTEXT_WINDOW),
                f"sliding-window({LONG_CONTEXT_WINDOW}) decode variant "
                "(documented sub-quadratic adaptation)",
            )
    return cfg, ""


def pairs(configs: List[ModelConfig]) -> List[Tuple[ModelConfig, InputShape, str]]:
    """All runnable (config, shape) pairs with adaptation notes."""
    out = []
    for cfg in configs:
        for shape in SHAPES.values():
            adapted, note = adapt_config_for_shape(cfg, shape)
            if adapted is not None:
                out.append((adapted, shape, note))
    return out
