"""tinyllama-1.1b — llama2-architecture small dense LM [arXiv:2401.02385]."""
from .base import ModelConfig, register


@register
def tinyllama_1_1b() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b",
        family="dense",
        num_layers=22,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        head_dim=64,
        d_ff=5632,
        vocab_size=32000,
        rope_theta=10_000.0,
        source="arXiv:2401.02385 (TinyLlama)",
    )
