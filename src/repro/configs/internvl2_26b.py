"""internvl2-26b — VLM: InternViT vision encoder (stubbed frontend providing
patch embeddings) + InternLM2-style dense LM backbone [arXiv:2404.16821]."""
from .base import ModelConfig, register


@register
def internvl2_26b() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b",
        family="vlm",
        num_layers=48,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=92553,
        frontend="vision_patches",
        num_frontend_tokens=256,   # one image tile worth of projected patches
        rope_theta=1_000_000.0,
        source="arXiv:2404.16821 (InternVL2; LM=InternLM2-20B-style)",
    )
