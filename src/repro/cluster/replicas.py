"""ReplicaGroup bookkeeping: allocation units -> placed replicas, with
rolling create-then-remove reconfiguration.

``ReplicaFabric`` is the backend-agnostic half of the cluster fabric: it
owns the node inventory, per-variant replica groups, placement, and the
paper's §5 reconfiguration semantics lifted to replica granularity. Both
serving backends (``repro.sim.cluster.SimCluster`` and
``repro.serving.engine.InProcessServingEngine``) delegate to one fabric and
attach their own execution object to each replica via ``Replica.handle``
(a DES ``Backend`` with its own server heap, or a real ``VariantBackend``
with its own slots and admission queue).

Reconfiguration is **staggered create-then-remove**: ``apply`` diffs the
target replica multiset against the live group, creates missing replicas
(ready after rt_m), and schedules surplus replicas to retire only at
``switch_t`` — the moment every newly created replica (cluster-wide) is
ready. Capacity therefore never dips below the old allocation during a
transition; the surge is real (old + new co-resident), so placement charges
retiring replicas against node capacity until they purge.

Fault surface: ``crash_node`` kills every replica on a node immediately
(no drain — it crashed), ``recover_node`` returns capacity,
``slow_replica``/``restore_replica`` scale one replica's service rate.
Re-placement after a fault flows *through the controller*: the next
``apply_allocation`` re-diffs and re-places, and ``capacity_factor`` tells
reactive controllers how much of the target allocation is actually live so
they re-solve without waiting for the interval boundary.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence

from repro.cluster.placement import (Node, Placement, ReplicaSpec,
                                     make_placement_policy, replica_sizes)

__all__ = ["Replica", "Transition", "ReplicaFabric"]


@dataclass
class Replica:
    """One placed replica: spec + lifecycle + the backend execution object."""
    spec: ReplicaSpec
    ready_at: float
    retire_at: float = float("inf")
    slow_factor: float = 1.0     # service-time multiplier (node speed, faults)
    crashed: bool = False
    handle: Any = None           # backend-owned execution state

    @property
    def rid(self) -> str:
        return self.spec.rid

    @property
    def variant(self) -> str:
        return self.spec.variant

    @property
    def units(self) -> int:
        return self.spec.units

    @property
    def node_id(self) -> str:
        return self.spec.node_id

    def ready(self, t: float) -> bool:
        return self.ready_at <= t < self.retire_at

    def live(self, t: float) -> bool:
        return self.retire_at > t


@dataclass
class Transition:
    """What one ``apply`` changed (backends act on created/retired)."""
    created: List[Replica] = field(default_factory=list)
    retired: List[Replica] = field(default_factory=list)
    switch_t: float = 0.0
    shortfall: Dict[str, int] = field(default_factory=dict)


class ReplicaFabric:
    """Node inventory + per-variant replica groups + rolling transitions."""

    def __init__(self, nodes: Sequence[Node], *, policy="first-fit",
                 replica_size: int = 1,
                 rt_fn: Callable[[str], float] = lambda m: 0.0):
        self.nodes: Dict[str, Node] = {n.node_id: n for n in nodes}
        self.policy = make_placement_policy(policy)
        self.replica_size = max(1, int(replica_size))
        self.rt_fn = rt_fn
        self.replicas: Dict[str, Replica] = {}
        self.target_units: Dict[str, int] = {}
        self.shortfall: Dict[str, int] = {}
        self._next_idx: Dict[str, int] = {}

    # ------------------------------------------------------------ inventory
    def group(self, variant: str) -> List[Replica]:
        return [r for r in self.replicas.values() if r.variant == variant]

    def ready_replicas(self, variant: str, t: float) -> List[Replica]:
        return sorted((r for r in self.group(variant) if r.ready(t)),
                      key=lambda r: r.rid)

    def variants_ready(self, t: float) -> List[str]:
        return sorted({r.variant for r in self.replicas.values() if r.ready(t)})

    def used_units(self) -> Dict[str, int]:
        """Units occupied per node by every non-purged replica — retiring
        replicas still hold their slot (surge semantics)."""
        used: Dict[str, int] = {}
        for r in self.replicas.values():
            used[r.node_id] = used.get(r.node_id, 0) + r.units
        return used

    def purge(self, t: float) -> List[Replica]:
        """Drop replicas whose retirement time has passed; returns them so
        the backend can free execution state."""
        gone = [r for r in self.replicas.values() if r.retire_at <= t]
        for r in gone:
            del self.replicas[r.rid]
        return gone

    # ----------------------------------------------------------- transitions
    def apply(self, t: float, units: Mapping[str, int]) -> Transition:
        """Rolling reconfiguration to ``units`` (variant -> total units).

        Target replica sizes come from ``replica_sizes``; existing replicas
        matching a target size are kept in place (no churn; a scheduled
        retirement is cancelled), missing ones are created and placed,
        surplus ones retire at ``switch_t`` = max readiness of all creates.
        """
        target = {m: n for m, n in units.items() if n > 0}
        self.target_units = dict(target)
        tr = Transition()
        to_place: List[ReplicaSpec] = []
        kept: List[Replica] = []
        surplus: List[Replica] = []
        for m, n in target.items():
            pool = [r for r in self.group(m) if not r.crashed]
            # ready replicas match first so a transition never trades a warm
            # replica for a cold one of the same size
            pool.sort(key=lambda r: (r.ready_at, r.rid))
            for size in replica_sizes(n, self.replica_size):
                hit = next((r for r in pool if r.units == size), None)
                if hit is not None:
                    pool.remove(hit)
                    kept.append(hit)
                else:
                    idx = self._next_idx.get(m, 0)
                    self._next_idx[m] = idx + 1
                    to_place.append(ReplicaSpec(m, idx, size))
            surplus.extend(pool)
        for m in {r.variant for r in self.replicas.values()}:
            if m not in target:
                surplus.extend(r for r in self.group(m) if not r.crashed)

        placement = self.policy.place(list(self.nodes.values()), to_place,
                                      self.used_units())
        self.shortfall = dict(placement.shortfall)
        for spec in placement.placed:
            node = self.nodes[spec.node_id]
            rep = Replica(spec, ready_at=t + self.rt_fn(spec.variant),
                          slow_factor=1.0 / max(node.speed, 1e-9))
            self.replicas[rep.rid] = rep
            tr.created.append(rep)

        tr.switch_t = max([t] + [r.ready_at for r in tr.created])
        for r in kept:
            r.retire_at = float("inf")       # re-selected: cancel retirement
        for r in surplus:
            r.retire_at = min(r.retire_at, tr.switch_t)
            tr.retired.append(r)
        tr.shortfall = dict(placement.shortfall)
        return tr

    def mark_ready(self, t: float = 0.0,
                   variants: Optional[Sequence[str]] = None) -> None:
        """Force readiness (warm-start support in the experiment harness)."""
        for r in self.replicas.values():
            if variants is None or r.variant in variants:
                r.ready_at = min(r.ready_at, t)

    # ------------------------------------------------------------ capacity
    def live_units(self, t: float) -> int:
        return sum(r.units for r in self.replicas.values()
                   if r.live(t) and not r.crashed
                   and self.nodes[r.node_id].alive)

    def provisioned_units(self) -> int:
        """Cost accounting parity with the non-replicated backends: units of
        replicas not scheduled for retirement."""
        return sum(r.units for r in self.replicas.values()
                   if r.retire_at == float("inf"))

    def capacity_factor(self, t: float) -> float:
        """Fraction of the target allocation actually live (placed on an
        alive node, not crashed/retired; warming counts — it is coming).
        Reactive controllers multiply provisioned capacity by this, so a
        node crash or placement shortfall triggers an immediate re-solve."""
        target = sum(self.target_units.values())
        if target <= 0:
            return 1.0
        return min(1.0, self.live_units(t) / target)

    # -------------------------------------------------------------- faults
    def crash_node(self, t: float, node_id: str) -> List[Replica]:
        """Node failure: every replica on it dies NOW (no drain). Returns
        the killed replicas so the backend can recover their requests."""
        node = self.nodes[node_id]
        node.alive = False
        killed = [r for r in self.replicas.values()
                  if r.node_id == node_id and r.live(t)]
        for r in killed:
            r.crashed = True
            r.retire_at = t
        return killed

    def recover_node(self, t: float, node_id: str) -> None:
        """Node back: capacity is available again; replicas return only via
        the next placement (controller-driven re-placement)."""
        self.nodes[node_id].alive = True

    def slow_replica(self, t: float, rid: str, factor: float) -> bool:
        """Degrade one replica's service rate by ``factor`` (≥1). Returns
        False when the rid no longer exists (retired/crashed before the
        event fired — stale fault events are no-ops, not crashes)."""
        r = self.replicas.get(rid)
        if r is None:
            return False
        node = self.nodes[r.node_id]
        r.slow_factor = max(factor, 1.0) / max(node.speed, 1e-9)
        return True

    def restore_replica(self, t: float, rid: str) -> bool:
        return self.slow_replica(t, rid, 1.0)
