"""Failure scenarios: a time-ordered schedule of fault events injected into
a fabric-backed cluster, so the SLO impact of failures is measurable
end-to-end (controller re-placement included).

Event kinds (targets name fabric objects):
  * ``node_crash``       — node dies; its replicas are killed immediately
                           (the engine re-submits their in-flight and queued
                           requests to survivors; the DES loses capacity
                           from the crash instant forward);
  * ``node_recover``     — node capacity returns (replicas come back only
                           via the next controller placement);
  * ``replica_slowdown`` — one replica serves ``factor``× slower (straggler
                           / noisy neighbour);
  * ``replica_restore``  — the straggler recovers.

Clusters expose ``inject_fault(t, event)`` (see ``SimCluster`` and
``InProcessServingEngine``); ``FaultSchedule`` feeds due events to it as
time advances — ``repro.sim.runner.run_experiment`` does this automatically
when given ``faults=``, interleaved in time order with controller steps.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

__all__ = ["FaultEvent", "FaultSchedule", "node_crash", "node_recover",
           "replica_slowdown", "replica_restore", "FAULT_KINDS"]

FAULT_KINDS = ("node_crash", "node_recover", "replica_slowdown",
               "replica_restore")


@dataclass(frozen=True, order=True)
class FaultEvent:
    t: float
    kind: str
    target: str                  # node_id or replica rid
    factor: float = 1.0          # slowdown multiplier (replica_slowdown)

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(available: {FAULT_KINDS})")


def node_crash(t: float, node_id: str) -> FaultEvent:
    return FaultEvent(t, "node_crash", node_id)


def node_recover(t: float, node_id: str) -> FaultEvent:
    return FaultEvent(t, "node_recover", node_id)


def replica_slowdown(t: float, rid: str, factor: float) -> FaultEvent:
    return FaultEvent(t, "replica_slowdown", rid, factor)


def replica_restore(t: float, rid: str) -> FaultEvent:
    return FaultEvent(t, "replica_restore", rid)


class FaultSchedule:
    """Time-ordered fault events with pop-due semantics."""

    def __init__(self, events: Sequence[FaultEvent] = ()):
        self._events: List[FaultEvent] = sorted(events)
        self.injected: List[FaultEvent] = []

    def add(self, event: FaultEvent) -> "FaultSchedule":
        self._events.append(event)
        self._events.sort()
        return self

    def next_t(self) -> float:
        """Time of the next pending event (inf when exhausted)."""
        return self._events[0].t if self._events else float("inf")

    def pop_due(self, t: float) -> List[FaultEvent]:
        due = [e for e in self._events if e.t <= t]
        self._events = self._events[len(due):]
        self.injected.extend(due)
        return due

    def apply_due(self, t: float, cluster) -> List[FaultEvent]:
        """Inject every event due by ``t`` into ``cluster`` (which must
        expose ``inject_fault``); returns the injected events."""
        due = self.pop_due(t)
        for e in due:
            cluster.inject_fault(e.t, e)
        return due

    def __len__(self) -> int:
        return len(self._events)
