"""Replica-level routing: the second level of two-level routing.

Level 1 is the paper's dispatcher — smooth weighted round-robin over
*variants*, proportional to the solver's quotas λ_m
(``repro.core.dispatcher.WeightedRoundRobinDispatcher``). This module is
level 2: once the variant is chosen, a ``RoutingAPI`` implementation picks
the *replica*. Both serving backends route through the same interface, so
routing policy is a constructor argument, not backend code.

The default is **power-of-two-choices least-outstanding** (``p2c``): sample
two distinct replicas, send to the one with fewer outstanding requests per
unit (normalizing by units keeps heterogeneous replica sizes fair). The
classic balls-into-bins result — two choices collapse the max/mean load
ratio from Θ(log n / log log n) to Θ(log log n) — holds under queueing too
(Mitzenmacher '01), and unlike full least-outstanding (``least``) it needs
O(1) state reads per request. ``rr``/``random`` are the WRR-only baselines
``benchmarks/bench_cluster.py`` compares against: replica choice blind to
load, which is exactly what a quota-weighted WRR alone gives you.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Protocol, Sequence, runtime_checkable

import numpy as np

__all__ = ["ReplicaView", "RoutingAPI", "PowerOfTwoChoicesRouter",
           "LeastOutstandingRouter", "RoundRobinReplicaRouter",
           "RandomReplicaRouter", "InstrumentedRouter", "ROUTERS",
           "make_router"]


@dataclass
class ReplicaView:
    """What a router may see about one candidate replica."""
    rid: str
    outstanding: float          # queued + in-service requests on the replica
    units: int = 1              # per-replica allocation (capacity weight)

    @property
    def load(self) -> float:
        """Outstanding per unit — the least-loaded comparison key."""
        return self.outstanding / max(self.units, 1)


@runtime_checkable
class RoutingAPI(Protocol):
    """Replica picker: candidates are the chosen variant's ready replicas."""

    def pick(self, replicas: Sequence[ReplicaView]) -> Optional[str]:
        """Return the rid to route to, or None when no candidate exists."""
        ...


class PowerOfTwoChoicesRouter:
    """Sample two distinct replicas, pick the less-loaded (ties: lower rid)."""

    def __init__(self, seed: int = 0):
        self._rng = np.random.default_rng(seed)

    def pick(self, replicas: Sequence[ReplicaView]) -> Optional[str]:
        if not replicas:
            return None
        if len(replicas) == 1:
            return replicas[0].rid
        i, j = self._rng.choice(len(replicas), size=2, replace=False)
        a, b = replicas[int(i)], replicas[int(j)]
        return min((a, b), key=lambda r: (r.load, r.rid)).rid


class LeastOutstandingRouter:
    """Full scan join-the-shortest-queue (upper bound on p2c's benefit)."""

    def pick(self, replicas: Sequence[ReplicaView]) -> Optional[str]:
        if not replicas:
            return None
        return min(replicas, key=lambda r: (r.load, r.rid)).rid


class RoundRobinReplicaRouter:
    """Load-blind cycling — the deterministic WRR-only baseline. Cycles
    per variant (rid prefix before ``#``): interleaved traffic to other
    variants must not break a variant's own rotation."""

    def __init__(self):
        self._i: dict = {}

    def pick(self, replicas: Sequence[ReplicaView]) -> Optional[str]:
        if not replicas:
            return None
        ordered = sorted(replicas, key=lambda r: r.rid)
        key = ordered[0].rid.rsplit("#", 1)[0]
        i = self._i.get(key, 0)
        self._i[key] = i + 1
        return ordered[i % len(ordered)].rid


class RandomReplicaRouter:
    """Load-blind uniform choice — the stateless WRR-only baseline."""

    def __init__(self, seed: int = 0):
        self._rng = np.random.default_rng(seed)

    def pick(self, replicas: Sequence[ReplicaView]) -> Optional[str]:
        if not replicas:
            return None
        return replicas[int(self._rng.integers(len(replicas)))].rid


class InstrumentedRouter:
    """Delegating wrapper that publishes routing decisions into a metrics
    registry (``repro.obs``): total picks, picks with no candidate, and a
    histogram of the chosen replica's load — enough to see whether level-2
    routing is actually balancing without threading counters by hand."""

    def __init__(self, inner: RoutingAPI, metrics):
        self.inner = inner
        self.metrics = metrics

    def pick(self, replicas: Sequence[ReplicaView]) -> Optional[str]:
        rid = self.inner.pick(replicas)
        m = self.metrics
        if rid is None:
            m.inc("router.no_candidate")
            return None
        m.inc("router.picks")
        for r in replicas:
            if r.rid == rid:
                m.observe("router.picked_load", r.load)
                break
        return rid


ROUTERS = {"p2c": PowerOfTwoChoicesRouter, "least": LeastOutstandingRouter,
           "rr": RoundRobinReplicaRouter, "random": RandomReplicaRouter}


def make_router(router, metrics=None) -> RoutingAPI:
    """Accept a router name or an instance (pluggable routing). With a
    ``metrics`` registry, the router is wrapped in ``InstrumentedRouter``
    so every pick lands in the engine-wide registry."""
    if isinstance(router, str):
        try:
            router = ROUTERS[router]()
        except KeyError:
            raise ValueError(f"unknown router {router!r} "
                             f"(available: {sorted(ROUTERS)})")
    if metrics is not None and getattr(metrics, "enabled", False):
        return InstrumentedRouter(router, metrics)
    return router
