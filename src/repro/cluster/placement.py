"""Nodes and placement policies: allocation units -> node->replica map.

The paper's Eq. 1 solver outputs a *vertical* allocation (variant -> n
resource units); production clusters (INFaaS, arXiv 1905.13348; Cocktail)
realise that allocation *horizontally* as replicas spread over nodes. This
module owns the horizontal step:

  * ``Node`` — one machine with ``capacity_units`` and an optional
    heterogeneity ``speed`` factor (a 0.5-speed node runs every replica
    placed on it at half rate — the fabric turns this into a per-replica
    ``slow_factor``);
  * ``replica_sizes`` — split n units into per-replica allocations of at
    most ``replica_size`` units (the per-replica concurrency the profiler's
    units->slots mapping assumes);
  * placement policies — ``FirstFitPlacement`` (bin-packing: fewest nodes)
    and ``SpreadPlacement`` (most free capacity first: failure-domain
    spreading), both behind ``PlacementPolicy``.

Infeasible placements are **rejected or repaired**: with ``strict=True`` a
replica that fits on no alive node raises ``PlacementError``; otherwise the
policy repairs by shrinking the replica to the largest free slot (recorded
as ``Placement.shortfall`` units so callers — and ``capacity_factor`` — see
exactly what was not provisioned).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Protocol, Sequence

__all__ = ["Node", "ReplicaSpec", "Placement", "PlacementError",
           "PlacementPolicy", "FirstFitPlacement", "SpreadPlacement",
           "PLACEMENT_POLICIES", "make_placement_policy", "replica_sizes",
           "make_nodes"]


class PlacementError(RuntimeError):
    """A replica fits on no alive node (strict placement only)."""


@dataclass
class Node:
    """One machine in the cluster: a bin of resource units.

    ``speed`` is the heterogeneity factor (1.0 = reference hardware); the
    fabric assigns replicas on this node ``slow_factor = 1/speed``."""
    node_id: str
    capacity_units: int
    speed: float = 1.0
    alive: bool = True

    def free_units(self, used: Mapping[str, int]) -> int:
        return self.capacity_units - used.get(self.node_id, 0)


@dataclass
class ReplicaSpec:
    """One replica-to-be: (variant, index) identity + size + node."""
    variant: str
    index: int
    units: int
    node_id: str = ""

    @property
    def rid(self) -> str:
        return f"{self.variant}#{self.index}"


@dataclass
class Placement:
    """Result of placing a batch of replica specs onto nodes."""
    placed: List[ReplicaSpec] = field(default_factory=list)
    shortfall: Dict[str, int] = field(default_factory=dict)  # variant -> units

    @property
    def feasible(self) -> bool:
        return not self.shortfall


def replica_sizes(units: int, replica_size: int) -> List[int]:
    """Split an allocation of ``units`` into per-replica sizes ≤
    ``replica_size``, as evenly as possible (largest first) — e.g.
    ``replica_sizes(5, 2) == [2, 2, 1]``. The solver's "n units" becomes
    "len(sizes) replicas" with per-replica concurrency ``sizes[i]``."""
    if units <= 0:
        return []
    r = max(1, int(replica_size))
    k = -(-units // r)                       # ceil
    base, extra = divmod(units, k)
    return [base + 1] * extra + [base] * (k - extra)


def make_nodes(n: int, capacity_units: int, speeds: Sequence[float] = (),
               ) -> List[Node]:
    """Convenience constructor: ``n`` nodes named node0..node{n-1}."""
    return [Node(f"node{i}", capacity_units,
                 speed=(speeds[i] if i < len(speeds) else 1.0))
            for i in range(n)]


class PlacementPolicy(Protocol):
    """Turns replica specs into a node assignment given current usage."""

    def place(self, nodes: Sequence[Node], specs: Sequence[ReplicaSpec],
              used: Mapping[str, int], *, strict: bool = False) -> Placement:
        """Assign ``spec.node_id`` for each spec. ``used`` maps node_id ->
        units already occupied (by live AND retiring replicas — rolling
        create-then-remove needs surge capacity). Repairs by shrinking when
        a spec fits nowhere, unless ``strict``."""
        ...


class _GreedyPlacement:
    """Shared greedy skeleton: subclasses order candidate nodes."""

    def _order(self, nodes: List[Node], free: Dict[str, int]) -> List[Node]:
        raise NotImplementedError

    def place(self, nodes: Sequence[Node], specs: Sequence[ReplicaSpec],
              used: Mapping[str, int], *, strict: bool = False) -> Placement:
        alive = [n for n in nodes if n.alive]
        free = {n.node_id: n.free_units(used) for n in alive}
        out = Placement()
        for spec in sorted(specs, key=lambda s: (-s.units, s.variant, s.index)):
            cands = [n for n in self._order(alive, free)
                     if free[n.node_id] >= spec.units]
            if cands:
                spec.node_id = cands[0].node_id
                free[spec.node_id] -= spec.units
                out.placed.append(spec)
                continue
            # reject or repair: shrink to the largest free slot (≥1 unit)
            best = max(alive, key=lambda n: free[n.node_id], default=None)
            avail = free[best.node_id] if best is not None else 0
            if avail <= 0:
                if strict:
                    raise PlacementError(
                        f"replica {spec.rid} ({spec.units}u) fits on no "
                        f"alive node")
                out.shortfall[spec.variant] = (
                    out.shortfall.get(spec.variant, 0) + spec.units)
                continue
            if strict:
                raise PlacementError(
                    f"replica {spec.rid} needs {spec.units}u, best free "
                    f"slot is {avail}u on {best.node_id}")
            out.shortfall[spec.variant] = (
                out.shortfall.get(spec.variant, 0) + spec.units - avail)
            spec.units = avail
            spec.node_id = best.node_id
            free[best.node_id] -= avail
            out.placed.append(spec)
        return out


class FirstFitPlacement(_GreedyPlacement):
    """First-fit decreasing bin-packing: fill nodes in id order — fewest
    nodes touched (cheap to drain idle nodes)."""

    def _order(self, nodes: List[Node], free: Dict[str, int]) -> List[Node]:
        return sorted(nodes, key=lambda n: n.node_id)


class SpreadPlacement(_GreedyPlacement):
    """Spread-across-nodes: most free capacity first — maximizes failure
    domains (a node crash kills the fewest replicas)."""

    def _order(self, nodes: List[Node], free: Dict[str, int]) -> List[Node]:
        return sorted(nodes, key=lambda n: (-free[n.node_id], n.node_id))


PLACEMENT_POLICIES = {"first-fit": FirstFitPlacement, "spread": SpreadPlacement}


def make_placement_policy(policy) -> PlacementPolicy:
    """Accept a policy name or an instance (pluggable policies)."""
    if isinstance(policy, str):
        try:
            return PLACEMENT_POLICIES[policy]()
        except KeyError:
            raise ValueError(f"unknown placement policy {policy!r} "
                             f"(available: {sorted(PLACEMENT_POLICIES)})")
    return policy
