"""Multi-replica cluster fabric: placement, two-level routing, failures.

The horizontal dimension the paper's InfAdapter leaves implicit: an
allocation of n units materializes as a **placement of replicas across
nodes** (``placement``/``replicas``), requests reach a replica via
**two-level routing** — smooth WRR over variants by solver quota, then a
power-of-two-choices least-outstanding pick over that variant's replicas
(``router``) — and **failure scenarios** (node crashes, stragglers,
recovery) are injected through one schedule (``faults``) so controllers'
re-placement behaviour is measurable end-to-end.

Backend-agnostic by construction: ``repro.sim.cluster.SimCluster`` and
``repro.serving.engine.InProcessServingEngine`` both mount the same
``ReplicaFabric`` (pass ``nodes=`` to either) and stay conformant to the
shared ``ClusterAPI``/``ServingAPI`` (``repro.serving.api``), so every
controller runs on the fabric unchanged. This package is numpy-only — the
simulator path never imports JAX.
"""
from repro.cluster.faults import (FaultEvent, FaultSchedule, node_crash,
                                  node_recover, replica_restore,
                                  replica_slowdown)
from repro.cluster.placement import (PLACEMENT_POLICIES, FirstFitPlacement,
                                     Node, Placement, PlacementError,
                                     PlacementPolicy, ReplicaSpec,
                                     SpreadPlacement, make_nodes,
                                     make_placement_policy, replica_sizes)
from repro.cluster.replicas import Replica, ReplicaFabric, Transition
from repro.cluster.router import (ROUTERS, LeastOutstandingRouter,
                                  PowerOfTwoChoicesRouter,
                                  RandomReplicaRouter, ReplicaView,
                                  RoundRobinReplicaRouter, RoutingAPI,
                                  make_router)

__all__ = [
    "FaultEvent", "FaultSchedule", "node_crash", "node_recover",
    "replica_restore", "replica_slowdown",
    "PLACEMENT_POLICIES", "FirstFitPlacement", "Node", "Placement",
    "PlacementError", "PlacementPolicy", "ReplicaSpec", "SpreadPlacement",
    "make_nodes", "make_placement_policy", "replica_sizes",
    "Replica", "ReplicaFabric", "Transition",
    "ROUTERS", "LeastOutstandingRouter", "PowerOfTwoChoicesRouter",
    "RandomReplicaRouter", "ReplicaView", "RoundRobinReplicaRouter",
    "RoutingAPI", "make_router",
]
