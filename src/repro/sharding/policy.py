"""Divisibility-aware sharding policy (Megatron-style TP + data parallelism).

Given a params pytree (shapes suffice — works on ShapeDtypeStructs) and a
mesh, produce a PartitionSpec tree by path-based rules with per-tensor
divisibility fallbacks:

  * embeddings: vocab-sharded over "model" (vocab is padded to 256 so every
    assigned arch divides a 16-way axis);
  * attention QKV column-parallel over heads, O row-parallel — only when the
    (kv-)head count divides the model axis, else replicated on "model"
    (gemma-2b's 8 heads, hymba's 25, whisper's 6 fall back — recorded);
  * dense FFN up/gate column-parallel, down row-parallel over d_ff;
  * MoE experts expert-parallel when E divides the axis, else d_ff-sharded
    (granite's 40 experts on a 16-way axis fall back to d_ff);
  * SSM mixer params replicated (mamba2-130m is small; documented);
  * norms/scalars replicated.

KV caches are sharded batch→("pod","data") and cache-sequence→"model"
(rope-safe; softmax over a sharded axis is handled by GSPMD partial
reductions). Optimizer state inherits the param specs verbatim.

Every fallback is recorded in ``PolicyReport`` and surfaced by the dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import batch_axes


@dataclass
class PolicyReport:
    sharded: List[str] = field(default_factory=list)
    replicated: List[str] = field(default_factory=list)
    fallbacks: List[str] = field(default_factory=list)


def _path_str(path) -> str:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return "/".join(out)


def param_specs(cfg: ModelConfig, params_tree: Any, mesh,
                fsdp: bool = False) -> Tuple[Any, PolicyReport]:
    """PartitionSpec tree for a params pytree (shapes or arrays).

    ``fsdp=True`` additionally shards one more (divisible, yet-unsharded)
    dimension of each >=2D weight over the "data" axis — ZeRO-3-style fully
    sharded parameters/optimizer state for training and for serving models
    whose TP-sharded weights exceed a single device's HBM (qwen3-moe).
    """
    msize = mesh.shape["model"]
    dsize = mesh.shape.get("data", 1)
    report = PolicyReport()
    heads_ok = cfg.num_heads > 0 and cfg.num_heads % msize == 0
    kv_ok = cfg.num_kv_heads > 0 and cfg.num_kv_heads % msize == 0
    ff_ok = cfg.d_ff > 0 and cfg.d_ff % msize == 0
    experts_ok = cfg.num_experts > 0 and cfg.num_experts % msize == 0
    vocab_ok = cfg.padded_vocab % msize == 0 if cfg.vocab_size else False

    def rule(path, leaf) -> P:
        name = _path_str(path)
        ndim = len(leaf.shape)
        stacked = name.startswith("layers/") or name.startswith("enc_layers/")
        lead = (None,) if stacked else ()

        def spec(*rest):
            return P(*(lead + rest))

        # ---- embeddings ----
        if name.endswith("embed/table"):
            return P("model", None) if vocab_ok else P(None, None)
        if name.endswith("embed/unembed"):
            return P(None, "model") if vocab_ok else P(None, None)
        # ---- attention ----
        if "/attn/" in name or "/xattn/" in name:
            w = name.split("/")[-1]
            if w == "wq" and heads_ok:
                return spec(None, "model")
            if w in ("wk", "wv") and kv_ok:
                return spec(None, "model")
            if w == "wo" and heads_ok:
                return spec("model", None)
            report.fallbacks.append(f"{name}: heads {cfg.num_heads}/kv "
                                    f"{cfg.num_kv_heads} !% model({msize}) -> replicated")
            return spec(*([None] * (ndim - len(lead))))
        # ---- MoE experts ----
        if "/ffn/" in name and cfg.is_moe:
            w = name.split("/")[-1]
            if w == "router":
                return spec(None, None)
            if experts_ok:
                return spec("model", None, None)           # expert-parallel
            if ff_ok:
                report.fallbacks.append(
                    f"{name}: E={cfg.num_experts} !% model({msize}) -> "
                    "d_ff-sharded instead of expert-parallel")
                if w in ("wi", "wg"):
                    return spec(None, None, "model")       # d_ff fallback
                if w == "wo":
                    return spec(None, "model", None)
            report.fallbacks.append(f"{name}: E={cfg.num_experts} and "
                                    f"d_ff={cfg.d_ff} !% model -> replicated")
            return spec(*([None] * (ndim - len(lead))))
        # ---- dense FFN ----
        if "/ffn/" in name:
            w = name.split("/")[-1]
            if ff_ok:
                if w in ("wi", "wg"):
                    return spec(None, "model")
                if w == "wo":
                    return spec("model", None)
            report.fallbacks.append(f"{name}: d_ff={cfg.d_ff} !% model -> replicated")
            return spec(*([None] * (ndim - len(lead))))
        # ---- everything else (norms, ssm mixer, projections, scalars) ----
        return spec(*([None] * max(ndim - len(lead), 0)))

    def with_fsdp(path, leaf, sp):
        name = _path_str(path)
        axes = list(sp) + [None] * (len(leaf.shape) - len(sp))
        if not fsdp or len(leaf.shape) < 2:
            return P(*axes)
        stacked = name.startswith("layers/") or name.startswith("enc_layers/")
        # candidate dims: skip the stacked layer dim; prefer the largest
        cands = [(leaf.shape[i], i) for i in range(len(axes))
                 if axes[i] is None and not (stacked and i == 0)
                 and leaf.shape[i] % dsize == 0 and leaf.shape[i] >= dsize]
        if cands:
            _, i = max(cands)
            axes[i] = "data"
        return P(*axes)

    base = jax.tree_util.tree_map_with_path(rule, params_tree)
    specs = jax.tree_util.tree_map_with_path(with_fsdp, params_tree, base)

    def log(path, leaf, sp):
        name = _path_str(path)
        if any(ax is not None for ax in sp):
            report.sharded.append(f"{name}: {sp}")
        else:
            report.replicated.append(name)
    jax.tree_util.tree_map_with_path(log, params_tree, specs)
    return specs, report


def cache_specs(cfg: ModelConfig, cache_tree: Any, mesh, global_batch: int) -> Any:
    """Specs for a decode cache pytree."""
    baxes = batch_axes(mesh)
    bsize = 1
    for a in baxes:
        bsize *= mesh.shape[a]
    bspec = P(*baxes) if global_batch % bsize == 0 and global_batch >= bsize else P()
    b = bspec if bspec != P() else None
    bats = baxes if b is not None else None
    msize = mesh.shape["model"]

    def rule(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        if name == "pos":
            return P(bats) if bats else P()
        if name in ("k", "v"):
            # (L, B, KV, C, hd): batch -> data axes, cache seq -> model
            c_ok = shape[3] % msize == 0
            return P(None, bats, None, "model" if c_ok else None, None)
        if name == "conv":
            return P(None, bats, None, None)
        if name == "ssd":
            return P(None, bats, None, None, None)
        if name == "enc":
            return P(bats, None, None)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(rule, cache_tree)


def batch_specs(cfg: ModelConfig, batch_tree: Any, mesh, global_batch: int) -> Any:
    baxes = batch_axes(mesh)
    bsize = 1
    for a in baxes:
        bsize *= mesh.shape[a]
    bats = baxes if (global_batch % bsize == 0 and global_batch >= bsize) else None

    def rule(path, leaf):
        nd = len(leaf.shape)
        return P(bats, *([None] * (nd - 1))) if nd else P()

    return jax.tree_util.tree_map_with_path(rule, batch_tree)
