"""Activation-sharding context: lets the (mesh-agnostic) model pin the batch
axis of its activations when compiled under a mesh.

GSPMD generally propagates input shardings, but propagation can drop the
batch sharding through reshapes (e.g. the q-block flash scan) and the loss
pipeline — the deepseek-67b × train_4k hillclimb found full-global-batch
all-reduces (f32[256, 4096, ...]) in the partitioned HLO, i.e. 16× replicated
batch work on those ops. Pinning ``P(batch_axes, None, ...)`` on layer
boundaries and the loss removes them (§Perf hillclimb A).

The context is process-global and set only by launch-time code (dryrun /
train launcher); models behave identically when it is unset.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_STATE = {"mesh": None, "batch_axes": None}


def set_activation_sharding(mesh, batch_axes: Optional[Tuple[str, ...]]):
    _STATE["mesh"] = mesh
    _STATE["batch_axes"] = tuple(batch_axes) if batch_axes else None


def clear_activation_sharding():
    _STATE["mesh"] = None
    _STATE["batch_axes"] = None


@contextmanager
def activation_sharding(mesh, batch_axes: Optional[Tuple[str, ...]]):
    set_activation_sharding(mesh, batch_axes)
    try:
        yield
    finally:
        clear_activation_sharding()


def constrain_batch(x: jax.Array) -> jax.Array:
    """Pin dim 0 to the batch axes (no-op when no context or indivisible)."""
    mesh, bats = _STATE["mesh"], _STATE["batch_axes"]
    if mesh is None or bats is None or x.ndim == 0:
        return x
    size = 1
    for a in bats:
        size *= mesh.shape[a]
    if x.shape[0] % size:
        return x
    spec = P(bats, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_shard_size() -> int:
    """Number of shards the batch axes provide (1 when no context)."""
    mesh, bats = _STATE["mesh"], _STATE["batch_axes"]
    if mesh is None or bats is None:
        return 1
    size = 1
    for a in bats:
        size *= mesh.shape[a]
    return size


def constrain(x: jax.Array, *axes) -> jax.Array:
    """Generic pin: axes entries are None, "batch" (-> the batch mesh axes),
    or a mesh axis name. Silently no-ops on indivisible dims / no context."""
    mesh, bats = _STATE["mesh"], _STATE["batch_axes"]
    if mesh is None:
        return x
    spec = []
    for dim, ax in enumerate(axes):
        if ax is None:
            spec.append(None)
            continue
        if ax == "batch":
            if bats is None:
                spec.append(None)
                continue
            size = 1
            for a in bats:
                size *= mesh.shape[a]
            spec.append(bats if x.shape[dim] % size == 0 else None)
        else:
            spec.append(ax if x.shape[dim] % mesh.shape[ax] == 0 else None)
    spec += [None] * (x.ndim - len(spec))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
