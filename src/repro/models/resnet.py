"""ResNet variant family (the paper's own backends: ResNet-18/34/50/101/152).

Pure-JAX implementation with ``lax.conv_general_dilated``; BatchNorm is folded
into inference-mode scale/shift (serving systems run frozen BN). Used by the
faithful-reproduction serving path and its tests; the InfAdapter control plane
consumes these variants' profiles exactly as the paper does.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# (block type, layers-per-stage, ImageNet top-1 accuracy %, readiness time s)
RESNET_SPECS: Dict[str, Tuple[str, List[int], float, float]] = {
    "resnet18": ("basic", [2, 2, 2, 2], 69.76, 4.0),
    "resnet34": ("basic", [3, 4, 6, 3], 73.31, 6.0),
    "resnet50": ("bottleneck", [3, 4, 6, 3], 76.13, 8.0),
    "resnet101": ("bottleneck", [3, 4, 23, 3], 77.37, 12.0),
    "resnet152": ("bottleneck", [3, 8, 36, 3], 78.31, 15.0),
}
STAGE_WIDTHS = [64, 128, 256, 512]


def _conv_init(key, kh, kw, cin, cout):
    fan = kh * kw * cin
    return jax.random.normal(key, (kh, kw, cin, cout)) * np.sqrt(2.0 / fan)


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _bn(x, scale, shift):
    return x * scale + shift


def init_resnet(key, name: str, num_classes: int = 1000) -> Dict:
    block, stages, _, _ = RESNET_SPECS[name]
    expansion = 1 if block == "basic" else 4
    keys = jax.random.split(key, 200)
    ki = iter(range(200))
    p: Dict = {"stem": _conv_init(keys[next(ki)], 7, 7, 3, 64),
               "stem_scale": jnp.ones((64,)), "stem_shift": jnp.zeros((64,))}
    cin = 64
    for si, (n_blocks, width) in enumerate(zip(stages, STAGE_WIDTHS)):
        for bi in range(n_blocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            cout = width * expansion
            bp: Dict = {}
            if block == "basic":
                bp["c1"] = _conv_init(keys[next(ki)], 3, 3, cin, width)
                bp["c2"] = _conv_init(keys[next(ki)], 3, 3, width, cout)
            else:
                bp["c1"] = _conv_init(keys[next(ki)], 1, 1, cin, width)
                bp["c2"] = _conv_init(keys[next(ki)], 3, 3, width, width)
                bp["c3"] = _conv_init(keys[next(ki)], 1, 1, width, cout)
            for nm in list(bp):
                ch = bp[nm].shape[-1]
                bp[nm + "_scale"] = jnp.ones((ch,))
                bp[nm + "_shift"] = jnp.zeros((ch,))
            if stride != 1 or cin != cout:
                bp["proj"] = _conv_init(keys[next(ki)], 1, 1, cin, cout)
                bp["proj_scale"] = jnp.ones((cout,))
                bp["proj_shift"] = jnp.zeros((cout,))
            p[f"s{si}b{bi}"] = bp
            cin = cout
    p["head"] = jax.random.normal(keys[next(ki)], (cin, num_classes)) * 0.01
    return p


def apply_resnet(p: Dict, name: str, x: jax.Array) -> jax.Array:
    """x: (B, H, W, 3) -> logits (B, num_classes)."""
    block, stages, _, _ = RESNET_SPECS[name]
    h = _conv(x, p["stem"], 2)
    h = jax.nn.relu(_bn(h, p["stem_scale"], p["stem_shift"]))
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                              (1, 2, 2, 1), "SAME")
    for si, n_blocks in enumerate(stages):
        for bi in range(n_blocks):
            bp = p[f"s{si}b{bi}"]
            stride = 2 if (si > 0 and bi == 0) else 1  # static (matches init)
            r = h
            if block == "basic":
                y = jax.nn.relu(_bn(_conv(h, bp["c1"], stride), bp["c1_scale"], bp["c1_shift"]))
                y = _bn(_conv(y, bp["c2"], 1), bp["c2_scale"], bp["c2_shift"])
            else:
                y = jax.nn.relu(_bn(_conv(h, bp["c1"], 1), bp["c1_scale"], bp["c1_shift"]))
                y = jax.nn.relu(_bn(_conv(y, bp["c2"], stride), bp["c2_scale"], bp["c2_shift"]))
                y = _bn(_conv(y, bp["c3"], 1), bp["c3_scale"], bp["c3_shift"])
            if "proj" in bp:
                r = _bn(_conv(r, bp["proj"], stride), bp["proj_scale"], bp["proj_shift"])
            h = jax.nn.relu(y + r)
    h = jnp.mean(h, axis=(1, 2))
    return h @ p["head"]


def resnet_flops(name: str, image: int = 224) -> float:
    """Analytic forward GFLOPs (for profile calibration sanity checks)."""
    known = {"resnet18": 1.82, "resnet34": 3.68, "resnet50": 4.12,
             "resnet101": 7.85, "resnet152": 11.58}
    return known[name] * 1e9 * (image / 224) ** 2
