"""GQA attention: train/prefill (full-sequence causal, optional sliding window)
and single-token decode against a KV cache — dense ring-buffered per-slot
caches or the paged pool (``PagedKVCache`` + ``paged_decode_attention``).

Two execution paths throughout:
  * pure-jnp einsum path (always available; oracle for the kernels)
  * Pallas path (``cfg.use_pallas``) via ``repro.kernels.ops``
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, truncated_normal_init


def init_attention(key, cfg: ModelConfig, cross: bool = False) -> Dict:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "wq": truncated_normal_init(ks[0], (D, H * hd), 1.0, pd),
        "wk": truncated_normal_init(ks[1], (D, KV * hd), 1.0, pd),
        "wv": truncated_normal_init(ks[2], (D, KV * hd), 1.0, pd),
        "wo": truncated_normal_init(ks[3], (H * hd, D), 1.0, pd),
    }


def _split_heads(x: jax.Array, n: int, hd: int) -> jax.Array:
    return x.reshape(x.shape[:-1] + (n, hd))


def causal_mask_bias(q_len: int, kv_len: int, q_offset: int, window) -> jax.Array:
    """(q_len, kv_len) additive bias; window==0 means full causal.

    ``window`` may be a Python int or a traced scalar (per-layer windows in
    hybrid models scanned over layers).
    """
    qi = jnp.arange(q_len)[:, None] + q_offset
    kj = jnp.arange(kv_len)[None, :]
    ok = kj <= qi
    win = jnp.asarray(window)
    ok &= (kj > qi - win) | (win <= 0)
    return jnp.where(ok, 0.0, -1e9).astype(jnp.float32)


def gqa_attend(q: jax.Array, k: jax.Array, v: jax.Array, bias: Optional[jax.Array],
               softcap: float = 0.0) -> jax.Array:
    """q: (B,S,H,hd)  k,v: (B,T,KV,hd)  bias: (S,T) or (B,S,T) additive.

    Operands stay in their native dtype (bf16 in production) with fp32
    accumulation via ``preferred_element_type`` — avoids materializing fp32
    copies of the K/V cache every step (§Perf hillclimb C: −45% decode HBM
    traffic). Softmax runs in fp32; probabilities are cast back to the value
    dtype for the PV matmul (flash-attention convention). For fp32 inputs the
    math is bit-identical to the previous all-fp32 form.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32) / np.sqrt(hd)
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    if bias is not None:
        if bias.ndim == 2:
            scores = scores + bias[None, None, None, :, :]
        else:
            scores = scores + bias[:, None, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, S, H, hd).astype(q.dtype)


# Above this sequence length the jnp path switches to the q-block flash form
# (never materializes the (S, S) score matrix). The Pallas kernel is used when
# cfg.use_pallas regardless.
FLASH_JNP_THRESHOLD = 2048
FLASH_JNP_BQ = 512


def flash_attend_qblocks(q: jax.Array, k: jax.Array, v: jax.Array, window,
                         softcap: float = 0.0, bq: int = FLASH_JNP_BQ,
                         q_offset: int = 0) -> jax.Array:
    """Blockwise causal attention in pure jnp: lax.scan over query blocks,
    each block attending to the full K/V with a mask. Memory is O(bq·S) per
    block instead of O(S²). (The scanned body is cost-corrected analytically
    in the dry-run roofline — see repro.analysis.roofline.)"""
    B, S, H, hd = q.shape
    pad = (-S) % bq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = q.shape[1] // bq
    qb = q.reshape(B, nq, bq, H, hd).transpose(1, 0, 2, 3, 4)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def block(carry, inp):
        # rematerialized in the backward pass: the (bq, S) score/prob blocks
        # are recomputed instead of stored (flash-attention backward)
        qi, idx = inp
        bias = causal_mask_bias(bq, S, idx * bq + q_offset, window)
        out = gqa_attend(qi, k, v, bias, softcap)
        return carry, out

    _, outs = jax.lax.scan(block, None, (qb, jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nq * bq, H, hd)
    from repro.sharding.context import constrain_batch
    return constrain_batch(out[:, :S])


def attention_forward(cfg: ModelConfig, p: Dict, x: jax.Array, positions: jax.Array,
                      window: Optional[int] = None) -> jax.Array:
    """Full-sequence causal self-attention (training / prefill)."""
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    dt = x.dtype
    q = _split_heads(jnp.einsum("bsd,de->bse", x, p["wq"].astype(dt)), H, hd)
    k = _split_heads(jnp.einsum("bsd,de->bse", x, p["wk"].astype(dt)), KV, hd)
    v = _split_heads(jnp.einsum("bsd,de->bse", x, p["wv"].astype(dt)), KV, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    w = cfg.sliding_window if window is None else window
    S = x.shape[1]
    if cfg.use_pallas and isinstance(w, int):
        from repro.kernels import ops as kops
        out = kops.flash_prefill(q, k, v, window=w, softcap=cfg.attn_logit_softcap)
    elif S > FLASH_JNP_THRESHOLD:
        out = flash_attend_qblocks(q, k, v, w, cfg.attn_logit_softcap)
    else:
        bias = causal_mask_bias(S, S, 0, w)
        out = gqa_attend(q, k, v, bias, cfg.attn_logit_softcap)
    out = out.reshape(x.shape[0], x.shape[1], H * hd)
    return jnp.einsum("bse,ed->bsd", out, p["wo"].astype(dt))


def bidirectional_attention(cfg: ModelConfig, p: Dict, x: jax.Array) -> jax.Array:
    """Encoder self-attention (no mask, no rope — whisper-style)."""
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    dt = x.dtype
    q = _split_heads(jnp.einsum("bsd,de->bse", x, p["wq"].astype(dt)), H, hd)
    k = _split_heads(jnp.einsum("bsd,de->bse", x, p["wk"].astype(dt)), KV, hd)
    v = _split_heads(jnp.einsum("bsd,de->bse", x, p["wv"].astype(dt)), KV, hd)
    out = gqa_attend(q, k, v, None)
    return jnp.einsum("bse,ed->bsd", out.reshape(x.shape[0], x.shape[1], H * hd),
                      p["wo"].astype(dt))


def cross_attention(cfg: ModelConfig, p: Dict, x: jax.Array, enc: jax.Array) -> jax.Array:
    """Decoder cross-attention over encoder outputs."""
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    dt = x.dtype
    q = _split_heads(jnp.einsum("bsd,de->bse", x, p["wq"].astype(dt)), H, hd)
    k = _split_heads(jnp.einsum("btd,de->bte", enc, p["wk"].astype(dt)), KV, hd)
    v = _split_heads(jnp.einsum("btd,de->bte", enc, p["wv"].astype(dt)), KV, hd)
    out = gqa_attend(q, k, v, None)
    return jnp.einsum("bse,ed->bsd", out.reshape(x.shape[0], x.shape[1], H * hd),
                      p["wo"].astype(dt))


# ---------------------------------------------------------------------------
# Decode against a KV cache (one new token)
# ---------------------------------------------------------------------------

def decode_attention(cfg: ModelConfig, p: Dict, x: jax.Array, k_cache: jax.Array,
                     v_cache: jax.Array, pos: jax.Array, window: Optional[int] = None,
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, 1, D); k/v_cache: (B, KV, C, hd) where C = cache capacity.

    Cache layout is (B, KV, C, hd) — the exact operand layout of the decode
    attention dot, so no per-step relayout/transpose copy is paid (§Perf
    hillclimb C iteration 2: the (B, C, KV, hd) layout showed transpose
    buffers in the lowered IR every step).

    ``pos``: (B,) int32 absolute position of the new token. When the cache
    capacity C is smaller than the max position (sliding window) the cache is
    a ring buffer indexed by ``pos % C``.

    Returns (attn_out (B,1,D), new_k_cache, new_v_cache).
    """
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    dt = x.dtype
    B, C = k_cache.shape[0], k_cache.shape[2]
    q = _split_heads(jnp.einsum("bsd,de->bse", x, p["wq"].astype(dt)), H, hd)
    k = _split_heads(jnp.einsum("bsd,de->bse", x, p["wk"].astype(dt)), KV, hd)
    v = _split_heads(jnp.einsum("bsd,de->bse", x, p["wv"].astype(dt)), KV, hd)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)

    slot = (pos % C).astype(jnp.int32)                       # (B,)
    batch_idx = jnp.arange(B)
    k_cache = k_cache.astype(dt).at[batch_idx, :, slot].set(k[:, 0])
    v_cache = v_cache.astype(dt).at[batch_idx, :, slot].set(v[:, 0])

    w = cfg.sliding_window if window is None else window
    # validity of each cache slot: the absolute position stored in slot j is
    # the largest value p <= pos with p % C == j; valid iff pos - p < min(C, pos+1)
    j = jnp.arange(C)[None, :]
    stored_pos = pos[:, None] - ((pos[:, None] - j) % C)     # (B, C) abs positions
    ok = stored_pos >= 0
    ok &= stored_pos >= jnp.maximum(pos[:, None] - C + 1, 0)
    if w is not None and not (isinstance(w, int) and w == 0):
        win = jnp.asarray(w)
        ok &= (stored_pos > pos[:, None] - win) | (win <= 0)
    bias = jnp.where(ok, 0.0, -1e9).astype(jnp.float32)      # (B, C)

    qg = q.reshape(B, KV, H // KV, hd)                       # (B,KV,G,hd)
    if cfg.use_pallas:
        from repro.kernels import ops as kops
        out = kops.flash_decode_bkchd(qg, k_cache, v_cache, bias,
                                      softcap=cfg.attn_logit_softcap)
    else:
        scores = jnp.einsum("bkgh,bkth->bkgt", qg, k_cache,
                            preferred_element_type=jnp.float32) / np.sqrt(hd)
        if cfg.attn_logit_softcap:
            c = cfg.attn_logit_softcap
            scores = jnp.tanh(scores / c) * c
        scores = scores + bias[:, None, None, :]
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgt,bkth->bkgh", probs.astype(v_cache.dtype),
                         v_cache, preferred_element_type=jnp.float32)
        out = out.astype(dt)
    out = out.reshape(B, 1, H * hd)
    attn = jnp.einsum("bse,ed->bsd", out, p["wo"].astype(dt))
    return attn, k_cache, v_cache


# ---------------------------------------------------------------------------
# Paged KV cache: pool bookkeeping + decode against block-table pages
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PrefixPlan:
    """How one admission maps onto the prefix index (``PagedKVCache.
    prefix_plan``): ``shared`` pages are mapped read-only by reference
    (refcount bumped at ``alloc``); ``cow_src`` is the page to copy into the
    admission's first fresh page when the boundary block fully matched but
    the request will write into it (the copy-on-write resolved at admission
    — see DESIGN.md §Prefix sharing); ``tail_start`` is the first sequence
    position the request must still prefill itself."""
    shared: Tuple[int, ...]
    cow_src: Optional[int]
    tail_start: int


class PagedKVCache:
    """Host-side bookkeeping for one replica's shared KV page pool.

    The device arrays (the ``(L, KV, P, page_size, hd)`` pool leaves and the
    per-slot block table) live in the engine's cache pytree; this object
    tracks which pool pages are free and which slot maps which pages, so
    admission can be gated on *memory-true* capacity and retirement returns
    pages for reuse.

    Page 0 is reserved as the **trash page**: block-table rows of free slots
    point at it, so decode-step writes from dead batch rows land somewhere
    harmless instead of corrupting a live sequence's pages. ``alloc`` never
    hands it out and ``usable_pages`` excludes it.

    **Prefix sharing** (DESIGN.md §Prefix sharing): pages carry refcounts,
    and a prefix index maps the rolling hash of each ``page_size``-token
    prompt block chain to the live page holding that block's K/V. A new
    request's admission asks ``prefix_plan`` which existing pages cover its
    prompt: fully-covered blocks below every position the request will write
    are mapped read-only (``alloc(..., shared=...)`` bumps their refcount);
    a fully-matched boundary block that the request *will* write into is
    copied into a fresh page (copy-on-write, resolved at admission — after
    admission a request only ever appends at ``pos // page_size``, so shared
    pages are never written). ``free`` decrements refcounts; when the last
    holder lets go a *published* page parks on the LRU **retained tier**
    with its index entry intact (so identical prompts keep hitting across
    quiet gaps) while unpublished pages return to the free list. Retained
    pages are reclaimed — index entries invalidated — only when ``alloc``
    actually needs them, oldest first. Index entries are published by the
    owner once the block's K/V is fully written (``publish_prefix``),
    never before, so a sharer can never gather unwritten pages.

    **Speculative rollback** (DESIGN.md §Speculative decoding):
    ``rollback(slot, new_len)`` validates a position rewind that discards
    rejected draft tokens' KV — no pages move (slots hold their budget
    all-or-nothing), it asserts the rewind stays inside the slot's budget
    and never rejects positions covered by a published prefix block.

    Invariants (property-tested in ``tests/test_kernels_paged.py`` and the
    stateful harness in ``tests/test_paged_prefix.py``): every usable page
    is either free or refcounted ≥ 1 by the slots mapping it; ``alloc`` is
    all-or-nothing; double-``alloc`` on a live slot and ``free`` of a
    never-admitted slot are errors, not silent corruption; index entries
    always point at live pages. See ``assert_invariants``.
    """

    TRASH_PAGE = 0

    def __init__(self, total_pages: int, page_size: int, metrics=None):
        assert total_pages >= 2, "need at least one usable page + trash"
        assert page_size >= 1
        self.total_pages = total_pages
        self.page_size = page_size
        # registry hook (repro.obs): pool telemetry counters are mirrored
        # into the engine-wide registry at the increment site, so
        # kv_pool_stats / benchmarks read them there even after this pool's
        # backend retires. Defaults to the shared no-op registry.
        if metrics is None:
            from repro.obs.registry import NULL_REGISTRY
            metrics = NULL_REGISTRY
        self.metrics = metrics
        # LIFO free list: recently freed pages are reused first (their pool
        # rows are warm in cache)
        self._free: List[int] = list(range(total_pages - 1, 0, -1))
        self._owned: Dict[int, List[int]] = {}     # slot -> mapped page ids
        self._ref: Dict[int, int] = {}             # page -> slots mapping it
        self._index: Dict[bytes, int] = {}         # block-chain digest -> page
        self._page_key: Dict[int, bytes] = {}      # published page -> digest
        # retained-prefix tier (DESIGN.md §Prefix sharing): refcount-0
        # *published* pages park here LRU-ordered (oldest first) with their
        # index entries intact, so a later identical prompt still hits even
        # after every sharer retired. Reclaimed (index invalidated) only
        # when alloc actually needs the pages.
        self._retained: List[int] = []
        # sharing telemetry (surfaced via kv_pool_stats()/summarize and the
        # prefix_sharing bench): lookups/hits at admission, fresh pages
        # actually allocated vs the worst-case budget callers reserved
        self.prefix_lookups = 0
        self.prefix_hits = 0
        self.fresh_pages_allocated = 0
        self.shared_page_maps = 0

    @property
    def usable_pages(self) -> int:
        return self.total_pages - 1                # page 0 is the trash page

    @property
    def free_pages(self) -> int:
        """Pages alloc can satisfy a fresh request from: the free list plus
        the retained tier (retained pages are reclaimed on demand)."""
        return len(self._free) + len(self._retained)

    @property
    def used_pages(self) -> int:
        """Pages mapped by live slots (excludes free and retained)."""
        return self.usable_pages - self.free_pages

    @property
    def retained_pages(self) -> int:
        """Refcount-0 prefix pages kept live for future hits."""
        return len(self._retained)

    @property
    def shared_pages(self) -> int:
        """Pages currently mapped by more than one slot."""
        return sum(1 for c in self._ref.values() if c > 1)

    @property
    def prefix_hit_rate(self) -> float:
        return self.prefix_hits / max(self.prefix_lookups, 1)

    @property
    def occupancy(self) -> float:
        """Fraction of usable pool pages currently mapped by live slots."""
        return self.used_pages / max(self.usable_pages, 1)

    def pages_needed(self, tokens: int) -> int:
        return -(-max(tokens, 0) // self.page_size)

    def can_alloc(self, n: int) -> bool:
        return n <= self.free_pages

    def _reclaim(self, n: int, keep: Sequence[int] = ()) -> int:
        """Evict up to ``n`` retained pages (LRU: oldest first) back to the
        free list, invalidating their index entries. Pages in ``keep`` (about
        to be revived as shared references by the caller) are skipped.
        Returns the number actually reclaimed."""
        got = 0
        survivors = []
        for pg in self._retained:
            if got < n and pg not in keep:
                key = self._page_key.pop(pg, None)
                if key is not None:
                    del self._index[key]
                self._free.append(pg)
                got += 1
            else:
                survivors.append(pg)
        self._retained = survivors
        if got:
            self.metrics.inc("kv.retained_reclaimed", got)
        return got

    def alloc(self, slot: int, n: int, shared: Sequence[int] = (),
              protect: Sequence[int] = ()) -> Optional[List[int]]:
        """Give ``slot`` ``n`` fresh pages plus read-only references to the
        ``shared`` pages (their refcount is bumped; a retained page is
        revived — pulled off the LRU list with its index entry intact);
        None if the free list plus reclaimable retained pages can't satisfy
        the whole fresh request (all-or-nothing — a partial grant would
        admit a sequence the pool cannot finish). ``protect`` pages (the
        admission plan's CoW source, which the caller is about to *read*
        but not map) are exempt from retained-tier reclaim for this call —
        without it a refcount-0 CoW source could be reclaimed into this
        very allocation's fresh set and copied after its contents died.
        Returns the fresh pages only; the slot's full positional mapping
        is ``list(shared) + returned``."""
        if slot in self._owned:
            raise ValueError(f"slot {slot} already owns pages (double alloc)")
        for pg in (*shared, *protect):             # validate before mutating
            if pg == self.TRASH_PAGE or (pg not in self._ref
                                         and pg not in self._retained):
                raise ValueError(f"cannot share dead page {pg}")
        keep = set(shared) | set(protect)
        if n > len(self._free):
            need = n - len(self._free)
            reclaimable = sum(1 for pg in self._retained if pg not in keep)
            if reclaimable < need:                 # check before evicting:
                return None                        # a refused alloc must not
            self._reclaim(need, keep=keep)         # cost any retained entry
        fresh = [self._free.pop() for _ in range(n)]
        for pg in fresh:
            self._ref[pg] = 1
        for pg in shared:
            if pg in self._ref:
                self._ref[pg] += 1
            else:                                  # revive a retained page
                self._retained.remove(pg)
                self._ref[pg] = 1
                self.metrics.inc("kv.retained_revived")
        self.fresh_pages_allocated += n
        self.shared_page_maps += len(shared)
        self.metrics.inc("kv.pages_allocated", n)
        if shared:
            self.metrics.inc("kv.shared_page_maps", len(shared))
        self._owned[slot] = list(shared) + fresh
        return list(fresh)

    def free(self, slot: int) -> List[int]:
        """Drop ``slot``'s page references. When a page's refcount hits
        zero it either parks on the retained tier (published prefix pages:
        index entry kept so future identical prompts still hit) or returns
        to the free list (unpublished pages: index entry never existed);
        pages still shared by other slots stay live. Returns the pages
        whose refcount actually dropped to zero. Freeing a never-admitted
        slot is an error (it means the caller lost track of the slot
        lifecycle — the bug class the poisoned-page tests guard against)."""
        if slot not in self._owned:
            raise ValueError(f"slot {slot} owns no pages "
                             f"(double free or never admitted)")
        released = []
        for pg in self._owned.pop(slot):
            if pg == self.TRASH_PAGE or pg in self._free:
                raise ValueError(f"double free of page {pg}")
            self._ref[pg] -= 1
            if self._ref[pg] == 0:
                del self._ref[pg]
                if pg in self._page_key:           # published: retain (MRU
                    self._retained.append(pg)      # at the tail)
                    self.metrics.inc("kv.pages_retained")
                else:
                    self._free.append(pg)
                released.append(pg)
        return released

    def rollback(self, slot: int, new_len: int) -> None:
        """Discard ``slot``'s KV tail beyond ``new_len`` tokens — the
        speculative-decoding reject path (DESIGN.md §Speculative decoding).

        Pages are slot-granular and all-or-nothing here: a slot keeps its
        full page budget for its whole residency, so rewinding the write
        position never frees a page — in particular a CoW page shared from
        this slot can never be yanked from under a sharer by a rollback.
        The device-side masks (``paged_decode_attention`` lengths,
        ``paged_chunk_prefill_attention`` positions) already ignore slots
        beyond ``pos``, so the host side only has to *validate* the rewind:

        * the slot is live and ``new_len`` fits its page budget;
        * no published prefix-index entry covers a rejected position — the
          index only ever covers fully-written prompt blocks published at
          prefill completion, and drafts append strictly after the prompt,
          so a violation means the engine rolled back into committed state.
        """
        pages = self._owned.get(slot)
        if pages is None:
            raise ValueError(f"rollback of slot {slot} that owns no pages")
        if new_len < 0 or self.pages_needed(new_len) > len(pages):
            raise ValueError(f"rollback of slot {slot} to {new_len} tokens "
                             f"outside its {len(pages)}-page budget")
        for i, pg in enumerate(pages):
            if pg in self._page_key and (i + 1) * self.page_size > new_len:
                raise ValueError(
                    f"rollback of slot {slot} to {new_len} would reject "
                    f"positions covered by published block {i} (page {pg})")
        self.metrics.inc("kv.rollbacks")

    def owned(self, slot: int) -> List[int]:
        return list(self._owned.get(slot, []))

    # -------------------------------------------------- prefix index (sharing)
    def _block_digests(self, tokens) -> List[bytes]:
        """Rolling digest per complete ``page_size``-token block: digest i
        covers tokens ``[0, (i+1)·page_size)``, so a chain match means the
        whole prefix matches, not just one block."""
        import hashlib
        toks = np.asarray(tokens, np.int64)
        h = hashlib.sha256()
        out = []
        for i in range(len(toks) // self.page_size):
            h.update(toks[i * self.page_size:(i + 1) * self.page_size]
                     .tobytes())
            out.append(h.digest())
        return out

    def lookup_prefix(self, tokens, count: bool = True) -> List[int]:
        """Longest chain of fully-matched prompt blocks -> their live page
        ids (index entries are invalidated at release, so every returned
        page is live). ``count=False`` re-checks a plan without skewing the
        hit-rate telemetry."""
        pages = []
        for d in self._block_digests(tokens):
            pg = self._index.get(d)
            if pg is None:
                break
            pages.append(pg)
        if count:
            self.prefix_lookups += 1
            self.prefix_hits += bool(pages)
            self.metrics.inc("kv.prefix_lookups")
            if pages:
                self.metrics.inc("kv.prefix_hits")
        return pages

    def prefix_plan(self, tokens, count: bool = True) -> PrefixPlan:
        """Resolve how a sequence maps onto the index. All writes a request
        performs after admission sit at positions ``>= len(tokens) - 1``
        (the tail prefill re-feeds at least the final token to regenerate
        its logits; decode appends after it), so matched blocks strictly
        below that position are shared read-only. A fully-matched *boundary*
        block containing position ``len(tokens) - 1`` cannot be shared — the
        re-fed final token writes into it — so it is CoW-copied into the
        admission's first fresh page and only that one token is re-fed."""
        pages = self.lookup_prefix(tokens, count=count)
        last_write = max(len(tokens) - 1, 0)
        ro = min(len(pages), last_write // self.page_size)
        cow = pages[ro] if len(pages) > ro else None
        tail = last_write if cow is not None else ro * self.page_size
        return PrefixPlan(shared=tuple(pages[:ro]), cow_src=cow,
                          tail_start=tail)

    def publish_prefix(self, slot: int, tokens) -> int:
        """Register ``slot``'s fully-written prompt blocks in the index
        (called by the owner once prefill completes — never earlier, so a
        sharer cannot map pages whose K/V is still being written). Blocks
        whose chain is already indexed (the shared prefix itself, or a CoW
        copy whose source is published) are skipped. Returns #entries
        added."""
        pages = self._owned.get(slot)
        if pages is None:
            raise ValueError(f"slot {slot} owns no pages to publish")
        added = 0
        for i, d in enumerate(self._block_digests(tokens)):
            if i >= len(pages):
                break
            pg = pages[i]
            if d in self._index or pg in self._page_key:
                continue
            self._index[d] = pg
            self._page_key[pg] = d
            added += 1
        return added

    def assert_invariants(self) -> None:
        """Pool-wide consistency (the stateful harness calls this after
        every step): refcount conservation, free/live partition, no
        double-grants, index liveness."""
        mapped = [p for pages in self._owned.values() for p in pages]
        # refcount conservation: total refcounts == total slot->page maps,
        # and each page's refcount equals the number of slots mapping it
        assert sum(self._ref.values()) == len(mapped)
        counts: Dict[int, int] = {}
        for p in mapped:
            counts[p] = counts.get(p, 0) + 1
        assert counts == self._ref
        # free list, live pages, and the retained tier partition the usable
        # pool; no duplicates anywhere
        assert len(self._free) == len(set(self._free))
        assert len(self._retained) == len(set(self._retained))
        assert self.TRASH_PAGE not in self._free
        assert self.TRASH_PAGE not in self._ref
        assert self.TRASH_PAGE not in self._retained
        live = set(self._ref)
        retained = set(self._retained)
        assert not (live & set(self._free))
        assert not (retained & set(self._free))
        assert not (retained & live)
        assert len(live) + len(self._free) + len(self._retained) \
            == self.usable_pages
        # every retained page is published (that's why it was retained)
        for pg in self._retained:
            assert pg in self._page_key
        # the prefix index only ever points at live or retained pages,
        # bidirectionally
        for key, pg in self._index.items():
            assert pg in live or pg in retained
            assert self._page_key.get(pg) == key
        assert len(self._page_key) == len(self._index)


def paged_decode_attention(cfg: ModelConfig, p: Dict, x: jax.Array,
                           k_pages: jax.Array, v_pages: jax.Array,
                           page_table: jax.Array, pos: jax.Array, *,
                           n_pages: int,
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode against the paged pool (one layer's pool leaves).

    x: (B, 1, D); k/v_pages: (KV, P, page_size, hd) — the shared pool;
    page_table: (B, max_pages) int32 page ids per slot; pos: (B,) int32
    absolute position of the new token. ``n_pages`` is the static live-page
    bound the caller bucketed the batch to: attention reads only the first
    ``n_pages`` table columns, so per-step cost is proportional to the live
    context of the batch, not the pool/slot capacity.

    The new token's K/V is written to page ``page_table[b, pos // ps]`` at
    offset ``pos % ps`` — free slots' table rows point at the reserved trash
    page, so their (garbage) writes are harmless. No sliding-window/ring
    support: the paged discipline allocates capacity for the whole sequence
    (the engine asserts this at cache init).

    Returns (attn_out (B,1,D), new_k_pages, new_v_pages).
    """
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    dt = x.dtype
    B = x.shape[0]
    ps = k_pages.shape[2]
    max_pages = page_table.shape[1]
    q = _split_heads(jnp.einsum("bsd,de->bse", x, p["wq"].astype(dt)), H, hd)
    k = _split_heads(jnp.einsum("bsd,de->bse", x, p["wk"].astype(dt)), KV, hd)
    v = _split_heads(jnp.einsum("bsd,de->bse", x, p["wv"].astype(dt)), KV, hd)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)

    # scatter the new token into its page (clip keeps long-dead rows inside
    # the table; their row is all trash-page anyway)
    page_col = jnp.minimum(pos // ps, max_pages - 1)
    page = page_table[jnp.arange(B), page_col]               # (B,)
    off = pos % ps
    k_pages = k_pages.astype(dt).at[:, page, off].set(
        k[:, 0].transpose(1, 0, 2))                          # value (KV,B,hd)
    v_pages = v_pages.astype(dt).at[:, page, off].set(
        v[:, 0].transpose(1, 0, 2))

    lengths = pos + 1
    tables = page_table[:, :n_pages]
    qg = q.reshape(B, KV, H // KV, hd)                       # (B,KV,G,hd)
    if cfg.use_pallas:
        from repro.kernels import ops as kops
        out = kops.paged_flash_decode(qg, k_pages, v_pages, tables, lengths,
                                      softcap=cfg.attn_logit_softcap)
    else:
        kg = jnp.moveaxis(k_pages[:, tables], 1, 0)      # (B,KV,n_pages,ps,hd)
        vg = jnp.moveaxis(v_pages[:, tables], 1, 0)
        kg = kg.reshape(B, KV, n_pages * ps, hd)
        vg = vg.reshape(B, KV, n_pages * ps, hd)
        scores = jnp.einsum("bkgh,bkth->bkgt", qg, kg,
                            preferred_element_type=jnp.float32) / np.sqrt(hd)
        if cfg.attn_logit_softcap:
            c = cfg.attn_logit_softcap
            scores = jnp.tanh(scores / c) * c
        valid = jnp.arange(n_pages * ps)[None, :] < lengths[:, None]
        scores = jnp.where(valid[:, None, None], scores, -1e9)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgt,bkth->bkgh", probs.astype(vg.dtype), vg,
                         preferred_element_type=jnp.float32)
        out = out.astype(dt)
    out = out.reshape(B, 1, H * hd)
    attn = jnp.einsum("bse,ed->bsd", out, p["wo"].astype(dt))
    return attn, k_pages, v_pages


# ---------------------------------------------------------------------------
# Prefill continuation: one chunk of prompt tokens against the cached prefix
# ---------------------------------------------------------------------------

def _chunk_qkv(cfg: ModelConfig, p: Dict, x: jax.Array, positions: jax.Array):
    """Shared chunk front half: project q/k/v for a (B, ck) chunk and rope
    them at per-row absolute ``positions`` (B, ck)."""
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    dt = x.dtype
    q = _split_heads(jnp.einsum("bsd,de->bse", x, p["wq"].astype(dt)), H, hd)
    k = _split_heads(jnp.einsum("bsd,de->bse", x, p["wk"].astype(dt)), KV, hd)
    v = _split_heads(jnp.einsum("bsd,de->bse", x, p["wv"].astype(dt)), KV, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _chunk_attend(cfg: ModelConfig, q: jax.Array, kg: jax.Array, vg: jax.Array,
                  bias: jax.Array) -> jax.Array:
    """Chunk queries over a gathered/stored cache: q (B,ck,H,hd),
    kg/vg (B,KV,T,hd), bias (B,ck,T) additive -> (B,ck,H,hd). Pure-jnp
    oracle for the per-token Pallas route (fp32 accumulation, softmax in
    fp32 — the ``gqa_attend`` conventions)."""
    B, ck, H, hd = q.shape
    KV = kg.shape[1]
    qg = q.reshape(B, ck, KV, H // KV, hd)
    scores = jnp.einsum("bjkgh,bkth->bkgjt", qg, kg,
                        preferred_element_type=jnp.float32) / np.sqrt(hd)
    if cfg.attn_logit_softcap:
        c = cfg.attn_logit_softcap
        scores = jnp.tanh(scores / c) * c
    scores = scores + bias[:, None, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgjt,bkth->bjkgh", probs.astype(vg.dtype), vg,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, ck, H, hd).astype(q.dtype)


def chunk_prefill_attention(cfg: ModelConfig, p: Dict, x: jax.Array,
                            k_cache: jax.Array, v_cache: jax.Array,
                            start: jax.Array, n_valid: jax.Array,
                            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Prefill-continuation attention for the dense discipline.

    x: (B, ck, D) — the next ``ck`` prompt tokens of each row (right-padded;
    ``n_valid`` (B,) counts the real ones, 0 = row not prefilling);
    k/v_cache: (B, KV, C, hd); start: (B,) absolute position of x[:, 0].
    The chunk's K/V is scattered at positions ``start..start+n_valid`` (the
    non-ring dense cache: slot index == absolute position — the engine
    asserts no sliding window before enabling chunked prefill), then every
    chunk query attends causally over the cache: key slot ``t`` is valid iff
    ``t <= start + j`` — exactly the already-written prefix plus the chunk
    itself, the same stale-entry masking the decode step relies on.

    With ``cfg.use_pallas`` attention routes through the flash decode kernel
    once per chunk token (the chunk is small and static), reusing its
    cached-prefix bias masking; otherwise a blockwise jnp einsum.

    Returns (attn_out (B, ck, D), new_k_cache, new_v_cache).
    """
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    dt = x.dtype
    B, ck = x.shape[0], x.shape[1]
    C = k_cache.shape[2]
    offs = jnp.arange(ck)
    positions = start[:, None] + offs[None, :]               # (B, ck)
    q, k, v = _chunk_qkv(cfg, p, x, positions)
    dest = jnp.where(offs[None, :] < n_valid[:, None], positions, C)
    batch_idx = jnp.arange(B)[:, None]
    k_cache = k_cache.astype(dt).at[batch_idx, :, dest].set(k, mode="drop")
    v_cache = v_cache.astype(dt).at[batch_idx, :, dest].set(v, mode="drop")

    # causal over absolute positions == cache slots; padded queries (j >=
    # n_valid) read stale-but-finite entries and their output is discarded
    valid = jnp.arange(C)[None, None, :] <= positions[:, :, None]
    bias = jnp.where(valid, 0.0, -1e9).astype(jnp.float32)   # (B, ck, C)
    if cfg.use_pallas:
        from repro.kernels import ops as kops
        KV = k_cache.shape[1]
        qg = q.reshape(B, ck, KV, H // KV, hd)
        outs = [kops.flash_decode_bkchd(qg[:, j], k_cache, v_cache, bias[:, j],
                                        softcap=cfg.attn_logit_softcap)
                for j in range(ck)]
        out = jnp.stack(outs, axis=1).reshape(B, ck, H, hd)
    else:
        out = _chunk_attend(cfg, q, k_cache, v_cache, bias)
    out = out.reshape(B, ck, H * hd)
    attn = jnp.einsum("bse,ed->bsd", out, p["wo"].astype(dt))
    return attn, k_cache, v_cache


def paged_chunk_prefill_attention(cfg: ModelConfig, p: Dict, x: jax.Array,
                                  k_pages: jax.Array, v_pages: jax.Array,
                                  page_table: jax.Array, start: jax.Array,
                                  n_valid: jax.Array,
                                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Prefill-continuation attention for the paged discipline (one layer's
    pool leaves — the ``paged_decode_attention`` counterpart of
    ``chunk_prefill_attention``).

    The chunk's K/V lands at page ``page_table[b, pos // ps]`` offset
    ``pos % ps`` for each valid position (invalid rows/tail are pointed out
    of bounds and dropped); attention runs over the row's full block table
    (chunks are rare next to decode ticks, so no live-page bucketing) with
    per-query length masking ``t <= start + j``. Pallas path: the paged
    flash decode kernel per chunk token with per-token lengths.

    Returns (attn_out (B, ck, D), new_k_pages, new_v_pages).
    """
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    dt = x.dtype
    B, ck = x.shape[0], x.shape[1]
    ps = k_pages.shape[2]
    max_pages = page_table.shape[1]
    T = max_pages * ps
    offs = jnp.arange(ck)
    positions = start[:, None] + offs[None, :]               # (B, ck)
    q, k, v = _chunk_qkv(cfg, p, x, positions)

    page_col = jnp.minimum(positions // ps, max_pages - 1)
    page = page_table[jnp.arange(B)[:, None], page_col]      # (B, ck)
    page = jnp.where(offs[None, :] < n_valid[:, None], page,
                     k_pages.shape[1])                       # OOB: dropped
    off = positions % ps
    k_pages = k_pages.astype(dt).at[:, page, off].set(
        k.transpose(2, 0, 1, 3), mode="drop")                # (KV, B, ck, hd)
    v_pages = v_pages.astype(dt).at[:, page, off].set(
        v.transpose(2, 0, 1, 3), mode="drop")

    qg = q.reshape(B, ck, KV, H // KV, hd)
    if cfg.use_pallas:
        from repro.kernels import ops as kops
        lengths = jnp.clip(positions + 1, 1, T)              # (B, ck)
        outs = [kops.paged_flash_decode(qg[:, j], k_pages, v_pages,
                                        page_table, lengths[:, j],
                                        softcap=cfg.attn_logit_softcap)
                for j in range(ck)]
        out = jnp.stack(outs, axis=1).reshape(B, ck, H, hd)
    else:
        kg = jnp.moveaxis(k_pages[:, page_table], 1, 0)      # (B,KV,mp,ps,hd)
        vg = jnp.moveaxis(v_pages[:, page_table], 1, 0)
        kg = kg.reshape(B, KV, T, hd)
        vg = vg.reshape(B, KV, T, hd)
        valid = jnp.arange(T)[None, None, :] <= positions[:, :, None]
        bias = jnp.where(valid, 0.0, -1e9).astype(jnp.float32)
        out = _chunk_attend(cfg, q, kg, vg, bias)
    out = out.reshape(B, ck, H * hd)
    attn = jnp.einsum("bse,ed->bsd", out, p["wo"].astype(dt))
    return attn, k_pages, v_pages
