"""Language-model assembly for all assigned architecture families.

One ``LM`` class covers dense / moe / ssm / hybrid / vlm / audio configs:
layers are parameter-stacked and executed with ``lax.scan`` (95-layer models
compile fast), caches are stacked alongside. Whisper-style encoder-decoder is
handled with a separate encoder stack + cross-attention in the decoder blocks.

Public (pure, jittable) methods:
  init(rng)                       -> params
  apply(params, batch)            -> logits (teacher forcing)
  loss(params, batch)             -> (scalar, metrics)
  init_cache(batch_size, max_len) -> cache pytree
  prefill(params, batch)          -> (last-token logits, cache)
  decode_step(params, cache, tok) -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.sharding.context import constrain_batch
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssd as ssd_mod
from repro.models.layers import (apply_mlp, embed, init_embed, init_mlp,
                                 rms_norm, sinusoidal_positions,
                                 truncated_normal_init, unembed, vocab_mask)

AUDIO_FRAME_DIM = 80     # stub frontend: mel-frame embedding width
VISION_EMBED_DIM = 1024  # stub frontend: ViT patch embedding width


def _layer_windows(cfg: ModelConfig) -> np.ndarray:
    """Per-layer attention window (0 = full attention)."""
    L = cfg.num_layers
    w = np.full((L,), cfg.sliding_window, np.int32)
    if cfg.sliding_window and cfg.global_layer_every:
        w[::cfg.global_layer_every] = 0
    return w


def _uniform_window(cfg: ModelConfig):
    """Static per-layer window if all layers share one, else None."""
    w = _layer_windows(cfg)
    return int(w[0]) if (w == w[0]).all() else None


def _layer_slice(tree, i: int):
    return jax.tree_util.tree_map(lambda x: x[i], tree)


def _stack_layers(dicts):
    if not dicts or not dicts[0]:
        return {}
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), *dicts)


class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.compute_dtype = jnp.dtype(cfg.dtype)
        self._vmask = vocab_mask(cfg)

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------
    def _init_layer(self, key) -> Dict:
        cfg = self.cfg
        pd = jnp.dtype(cfg.param_dtype)
        ks = jax.random.split(key, 8)
        p: Dict = {"ln1": jnp.zeros((cfg.d_model,), pd)}
        if cfg.family in ("dense", "moe", "vlm", "audio", "hybrid"):
            p["attn"] = attn.init_attention(ks[0], cfg)
        if cfg.family in ("ssm", "hybrid"):
            p["ssm"] = ssd_mod.init_ssm(ks[1], cfg)
        if cfg.family == "hybrid":
            p["mix_scale"] = jnp.zeros((2,), pd)  # learned attn/ssm fusion
        if cfg.family == "moe":
            p["ffn"] = moe_mod.init_moe(ks[2], cfg)
            p["ln2"] = jnp.zeros((cfg.d_model,), pd)
        elif cfg.family in ("dense", "vlm", "audio", "hybrid"):
            p["ffn"] = init_mlp(ks[3], cfg)
            p["ln2"] = jnp.zeros((cfg.d_model,), pd)
        if cfg.is_encoder_decoder:
            p["xattn"] = attn.init_attention(ks[4], cfg, cross=True)
            p["lnx"] = jnp.zeros((cfg.d_model,), pd)
        return p

    def _init_encoder_layer(self, key) -> Dict:
        cfg = self.cfg
        pd = jnp.dtype(cfg.param_dtype)
        k1, k2 = jax.random.split(key)
        return {
            "ln1": jnp.zeros((cfg.d_model,), pd),
            "attn": attn.init_attention(k1, cfg),
            "ln2": jnp.zeros((cfg.d_model,), pd),
            "ffn": init_mlp(k2, cfg),
        }

    def init(self, rng) -> Dict:
        cfg = self.cfg
        pd = jnp.dtype(cfg.param_dtype)
        keys = jax.random.split(rng, 8)
        params: Dict = {"embed": init_embed(keys[0], cfg),
                        "final_norm": jnp.zeros((cfg.d_model,), pd)}
        lkeys = jax.random.split(keys[1], cfg.num_layers)
        params["layers"] = jax.vmap(self._init_layer)(lkeys)
        if cfg.is_encoder_decoder:
            ekeys = jax.random.split(keys[2], cfg.enc_layers)
            params["enc_layers"] = jax.vmap(self._init_encoder_layer)(ekeys)
            params["enc_in"] = truncated_normal_init(
                keys[3], (AUDIO_FRAME_DIM, cfg.d_model), 1.0, pd)
            params["enc_norm"] = jnp.zeros((cfg.d_model,), pd)
        if cfg.frontend == "vision_patches":
            params["vis_proj"] = truncated_normal_init(
                keys[4], (VISION_EMBED_DIM, cfg.d_model), 1.0, pd)
        return params

    # ------------------------------------------------------------------
    # decoder block (full-sequence path: train / prefill)
    # ------------------------------------------------------------------
    def _block(self, lp: Dict, x: jax.Array, positions: jax.Array,
               window, enc: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        x = constrain_batch(x)   # keep batch sharded across layer boundaries
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        if cfg.family == "ssm":
            x = x + ssd_mod.ssm_forward(cfg, lp["ssm"], h)
        elif cfg.family == "hybrid":
            a = attn.attention_forward(cfg, lp["attn"], h, positions, window)
            s = ssd_mod.ssm_forward(cfg, lp["ssm"], h)
            sc = jax.nn.sigmoid(lp["mix_scale"].astype(jnp.float32))
            x = x + (sc[0] * a.astype(jnp.float32)
                     + sc[1] * s.astype(jnp.float32)).astype(x.dtype)
        else:
            x = x + attn.attention_forward(cfg, lp["attn"], h, positions, window)
        if cfg.is_encoder_decoder and enc is not None:
            hx = rms_norm(x, lp["lnx"], cfg.norm_eps)
            x = x + attn.cross_attention(cfg, lp["xattn"], hx, enc)
        if "ffn" in lp:
            h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                y, metrics = moe_mod.apply_moe(cfg, lp["ffn"], h2)
                aux = metrics["aux_loss"]
            else:
                y = apply_mlp(cfg, lp["ffn"], h2)
            x = x + y
        return x, aux

    def _run_layers(self, params: Dict, x: jax.Array, positions: jax.Array,
                    enc: Optional[jax.Array], train: bool) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        if not cfg.scan_layers:
            windows = _layer_windows(cfg)
            block = self._block
            if cfg.remat and train:
                block = jax.checkpoint(block, prevent_cse=False,
                                       static_argnums=(3,))
            aux_total = jnp.zeros((), jnp.float32)
            for i in range(cfg.num_layers):
                lp = _layer_slice(params["layers"], i)
                x, aux = block(lp, x, positions, int(windows[i]), enc)
                aux_total = aux_total + aux
            return x, aux_total / cfg.num_layers
        uw = _uniform_window(cfg)
        if uw is not None:
            # uniform window -> keep it static (enables the Pallas path)
            def body(carry, lp):
                y, aux = self._block(lp, carry, positions, uw, enc)
                return y, aux
            xs = params["layers"]
        else:
            def body(carry, inp):
                lp, w = inp
                y, aux = self._block(lp, carry, positions, w, enc)
                return y, aux
            xs = (params["layers"], jnp.asarray(_layer_windows(cfg)))

        if cfg.remat and train:
            body = jax.checkpoint(body, prevent_cse=False)
        x, auxes = jax.lax.scan(body, x, xs)
        return x, jnp.mean(auxes)

    # ------------------------------------------------------------------
    # encoder (whisper)
    # ------------------------------------------------------------------
    def encode(self, params: Dict, frames: jax.Array) -> jax.Array:
        cfg = self.cfg
        dt = self.compute_dtype
        x = jnp.einsum("btf,fd->btd", frames.astype(dt), params["enc_in"].astype(dt))
        pos = jnp.asarray(sinusoidal_positions(frames.shape[1], cfg.d_model))
        x = x + pos[None].astype(dt)

        def body(carry, lp):
            h = rms_norm(carry, lp["ln1"], cfg.norm_eps)
            carry = carry + attn.bidirectional_attention(cfg, lp["attn"], h)
            h2 = rms_norm(carry, lp["ln2"], cfg.norm_eps)
            carry = carry + apply_mlp(cfg, lp["ffn"], h2)
            return carry, None

        if not cfg.scan_layers:
            for i in range(cfg.enc_layers):
                x, _ = body(x, _layer_slice(params["enc_layers"], i))
        else:
            x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    # ------------------------------------------------------------------
    # embed input sequence (handles multimodal prefixes)
    # ------------------------------------------------------------------
    def _embed_inputs(self, params: Dict, batch: Dict) -> jax.Array:
        cfg = self.cfg
        dt = self.compute_dtype
        x = embed(cfg, params["embed"], batch["tokens"], dt)
        if cfg.frontend == "vision_patches" and "patch_embeds" in batch:
            vis = jnp.einsum("bpe,ed->bpd", batch["patch_embeds"].astype(dt),
                             params["vis_proj"].astype(dt))
            x = jnp.concatenate([vis, x], axis=1)
        if cfg.rope_theta <= 0 and not cfg.is_encoder_decoder:
            pos = jnp.asarray(sinusoidal_positions(x.shape[1], cfg.d_model))
            x = x + pos[None].astype(dt)
        if cfg.is_encoder_decoder:
            pos = jnp.asarray(sinusoidal_positions(x.shape[1], cfg.d_model))
            x = x + pos[None].astype(dt)
        return x

    # ------------------------------------------------------------------
    # full-sequence forward (train)
    # ------------------------------------------------------------------
    def apply(self, params: Dict, batch: Dict, train: bool = True
              ) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        enc = None
        if cfg.is_encoder_decoder:
            enc = self.encode(params, batch["frames"])
        x = constrain_batch(self._embed_inputs(params, batch))
        B, S = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x, aux = self._run_layers(params, x, positions, enc, train)
        x = rms_norm(constrain_batch(x), params["final_norm"], cfg.norm_eps)
        logits = unembed(cfg, params["embed"], x)
        logits = logits + jnp.asarray(self._vmask, logits.dtype)
        return logits, aux

    def loss(self, params: Dict, batch: Dict) -> Tuple[jax.Array, Dict]:
        cfg = self.cfg
        logits, aux = self.apply(params, batch, train=True)
        labels = batch["labels"]
        n_prefix = logits.shape[1] - labels.shape[1]  # multimodal prefix tokens
        logits = logits[:, n_prefix:]
        mask = (labels >= 0).astype(jnp.float32)
        labels = jnp.maximum(labels, 0)
        logp = jax.nn.log_softmax(
            constrain_batch(logits).astype(jnp.float32), axis=-1)
        nll = constrain_batch(
            -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0])
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        total = loss + cfg.aux_loss_coef * aux
        return total, {"ce_loss": loss, "aux_loss": aux}

    # ------------------------------------------------------------------
    # caches
    # ------------------------------------------------------------------
    def cache_capacity(self, max_len: int) -> int:
        cfg = self.cfg
        if cfg.sliding_window and cfg.family != "hybrid":
            return min(max_len, cfg.sliding_window)
        if cfg.family == "hybrid" and cfg.sliding_window:
            return min(max_len, cfg.sliding_window)
        return max_len

    def init_cache(self, batch_size: int, max_len: int) -> Dict:
        cfg = self.cfg
        dt = self.compute_dtype
        L, KV, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
        cache: Dict = {"pos": jnp.zeros((batch_size,), jnp.int32)}
        if cfg.family != "ssm":
            C = self.cache_capacity(max_len)
            # (B, KV, C, hd): the decode dot's native operand layout (§Perf C)
            cache["k"] = jnp.zeros((L, batch_size, KV, C, hd), dt)
            cache["v"] = jnp.zeros((L, batch_size, KV, C, hd), dt)
        if cfg.family in ("ssm", "hybrid"):
            ch = cfg.d_inner + 2 * cfg.ssm_state
            cache["conv"] = jnp.zeros((L, batch_size, cfg.conv_width - 1, ch), dt)
            cache["ssd"] = jnp.zeros(
                (L, batch_size, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                jnp.float32)
        if cfg.is_encoder_decoder:
            cache["enc"] = jnp.zeros((batch_size, cfg.enc_seq, cfg.d_model), dt)
        return cache

    # ------------------------------------------------------------------
    # paged KV cache (shared page pool + per-slot block tables)
    # ------------------------------------------------------------------
    def supports_paged_cache(self) -> bool:
        """Paged decode covers the pure-attention KV families. SSM/hybrid
        carry non-positional state, encoder-decoder adds a cross cache, and
        sliding windows imply the ring discipline — all stay dense."""
        cfg = self.cfg
        return (cfg.family in ("dense", "moe", "vlm")
                and not cfg.is_encoder_decoder and not cfg.sliding_window)

    def supports_chunked_prefill(self) -> bool:
        """Prefill continuation needs positional KV state and the non-ring
        slot==position cache discipline — the same predicate as the paged
        cache (SSM/hybrid recurrent state can't be rebuilt chunk-at-offset;
        sliding windows make cache slots ambiguous mid-prompt)."""
        return self.supports_paged_cache()

    def init_paged_cache(self, batch_size: int, pool_pages: int,
                         page_size: int, max_pages_per_seq: int) -> Dict:
        """Pool-shaped cache pytree: ``kp``/``vp`` are the shared page pool
        ``(L, KV, pool_pages, page_size, hd)``; ``pt`` is the per-slot block
        table (all rows initially the reserved trash page 0); ``pos`` the
        per-slot next position. Pool bookkeeping (which pages are free/owned)
        lives host-side in ``repro.models.attention.PagedKVCache``."""
        cfg = self.cfg
        assert self.supports_paged_cache(), \
            f"paged KV cache unsupported for config {cfg.name!r}"
        dt = self.compute_dtype
        L, KV, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
        return {
            "pos": jnp.zeros((batch_size,), jnp.int32),
            "kp": jnp.zeros((L, KV, pool_pages, page_size, hd), dt),
            "vp": jnp.zeros((L, KV, pool_pages, page_size, hd), dt),
            "pt": jnp.zeros((batch_size, max_pages_per_seq), jnp.int32),
        }

    def paged_admit(self, cache: Dict, prefill_cache: Dict,
                    cur_tok: jax.Array, first_tok: jax.Array,
                    page_ids: jax.Array, dest_slots: jax.Array
                    ) -> Tuple[Dict, jax.Array]:
        """Scatter ``b`` right-sized prefilled rows into the page pool.

        ``prefill_cache`` comes from ``prefill(..., max_len=prompt_len)`` —
        sized to the actual arriving batch and the prompt alone, never padded
        to slot capacity. ``page_ids`` (b, max_pages_per_seq) are the full
        block-table rows the pool manager allocated to each joiner;
        ``dest_slots`` (b,) the receiving batch slots. Rows of a partially
        filled admission bucket are dropped by pointing ``dest_slots`` (and
        their ``page_ids``) out of bounds — jnp scatter ``mode="drop"`` makes
        the masking free, so one compiled executable serves any joiner count
        within the bucket. Returns (new cache, new cur_tok)."""
        kp, vp, pt, pos = cache["kp"], cache["vp"], cache["pt"], cache["pos"]
        ps = kp.shape[3]
        k_new, v_new = prefill_cache["k"], prefill_cache["v"]  # (L,b,KV,S,hd)
        L, b, KV, S, hd = k_new.shape
        pp = -(-S // ps)                       # pages holding the prompt
        pad = pp * ps - S
        if pad:
            widths = ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))
            k_new = jnp.pad(k_new, widths)
            v_new = jnp.pad(v_new, widths)
        # (L, KV, b, pp, ps, hd): match the pool gather shape of the scatter
        k_new = k_new.reshape(L, b, KV, pp, ps, hd).transpose(0, 2, 1, 3, 4, 5)
        v_new = v_new.reshape(L, b, KV, pp, ps, hd).transpose(0, 2, 1, 3, 4, 5)
        prompt_pages = page_ids[:, :pp]                       # (b, pp)
        kp = kp.at[:, :, prompt_pages].set(k_new, mode="drop")
        vp = vp.at[:, :, prompt_pages].set(v_new, mode="drop")
        pt = pt.at[dest_slots].set(page_ids, mode="drop")
        pos = pos.at[dest_slots].set(prefill_cache["pos"], mode="drop")
        tok = cur_tok.at[dest_slots].set(first_tok, mode="drop")
        out = dict(cache)
        out.update(kp=kp, vp=vp, pt=pt, pos=pos)
        return out, tok

    def paged_cow_copy(self, cache: Dict, src, dst) -> Dict:
        """Copy one pool page's K/V across every layer: the device half of
        admission-time copy-on-write (DESIGN.md §Prefix sharing). A new
        request whose prompt fully matches a published boundary block gets
        that block's K/V duplicated into its own fresh page ``dst`` instead
        of re-prefilling it, because its tail prefill / decode will write
        into the block and the shared source must stay immutable. ``src``/
        ``dst`` are traced scalars — one executable serves every copy."""
        out = dict(cache)
        out["kp"] = cache["kp"].at[:, :, dst].set(cache["kp"][:, :, src])
        out["vp"] = cache["vp"].at[:, :, dst].set(cache["vp"][:, :, src])
        return out

    def paged_retire(self, cache: Dict, slot: int) -> Dict:
        """Point a retiring slot's block-table row back at the trash page and
        reset its position, so the batch row decodes harmlessly until the
        next admission (its freed pages may be re-owned immediately)."""
        out = dict(cache)
        out["pt"] = cache["pt"].at[slot].set(0)
        out["pos"] = cache["pos"].at[slot].set(0)
        return out

    # ------------------------------------------------------------------
    # prefill continuation: one chunk of prompt tokens at an offset
    # ------------------------------------------------------------------
    def _sinusoid_pe(self, positions: jax.Array) -> jax.Array:
        """Sinusoidal rows for integer ``positions`` of any shape ->
        ``positions.shape + (d_model,)`` fp32."""
        cfg = self.cfg
        half = cfg.d_model // 2
        inv = 1.0 / (10_000.0 ** (jnp.arange(half) / half))
        ang = positions[..., None].astype(jnp.float32) * inv
        pe = jnp.zeros(positions.shape + (cfg.d_model,), jnp.float32)
        return pe.at[..., 0::2].set(jnp.sin(ang)).at[..., 1::2].set(jnp.cos(ang))

    def _finish_chunk(self, x: jax.Array, params: Dict, n_valid: jax.Array
                      ) -> jax.Array:
        """Final norm + unembed at each row's last valid chunk position ->
        logits (B, V) (garbage rows where n_valid == 0)."""
        cfg = self.cfg
        B, ck = x.shape[0], x.shape[1]
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        last = jnp.clip(n_valid - 1, 0, ck - 1)
        logits = unembed(cfg, params["embed"], x[jnp.arange(B), last][:, None])
        return (logits + jnp.asarray(self._vmask, logits.dtype))[:, 0]

    def _chunk_trunk(self, params: Dict, cache: Dict, tokens: jax.Array,
                     start: jax.Array, n_valid: jax.Array, *, paged: bool
                     ) -> Tuple[jax.Array, Dict]:
        """Shared transformer trunk for chunked prefill continuation and
        multi-token verification: embed the (B, ck) chunk at per-row
        absolute ``start`` offsets, run every layer writing chunk K/V into
        the dense cache (``paged=False``) or the row's block-table pages
        (``paged=True``), and return (pre-final-norm activations (B, ck, D),
        new cache with ``pos`` advanced to ``start + n_valid`` on active
        rows). Rows with ``n_valid == 0`` are inert: no writes, no advance.
        """
        cfg = self.cfg
        assert self.supports_chunked_prefill(), \
            f"chunked prefill unsupported for config {cfg.name!r}"
        dt = self.compute_dtype
        x = embed(cfg, params["embed"], tokens, dt)
        if cfg.rope_theta <= 0:
            positions = start[:, None] + jnp.arange(tokens.shape[1])[None, :]
            x = x + self._sinusoid_pe(positions).astype(dt)

        if paged:
            pt = cache["pt"]

            def body(carry, inp):
                lp, kp_l, vp_l = inp
                x_in = carry
                h = rms_norm(x_in, lp["ln1"], cfg.norm_eps)
                a, kp_l, vp_l = attn.paged_chunk_prefill_attention(
                    cfg, lp["attn"], h, kp_l, vp_l, pt, start, n_valid)
                x_new = x_in + a
                h2 = rms_norm(x_new, lp["ln2"], cfg.norm_eps)
                if cfg.family == "moe":
                    y, _ = moe_mod.apply_moe(cfg, lp["ffn"], h2)
                else:
                    y = apply_mlp(cfg, lp["ffn"], h2)
                return x_new + y, {"kp": kp_l, "vp": vp_l}

            if not cfg.scan_layers:
                outs = []
                for i in range(cfg.num_layers):
                    x, out = body(x, (_layer_slice(params["layers"], i),
                                      cache["kp"][i], cache["vp"][i]))
                    outs.append(out)
                new_caches = _stack_layers(outs)
            else:
                x, new_caches = jax.lax.scan(
                    body, x, (params["layers"], cache["kp"], cache["vp"]))
        else:
            def body(carry, inp):
                lp, lc = inp
                x_in = carry
                h = rms_norm(x_in, lp["ln1"], cfg.norm_eps)
                a, kc, vc = attn.chunk_prefill_attention(
                    cfg, lp["attn"], h, lc["k"], lc["v"], start, n_valid)
                x_new = x_in + a
                h2 = rms_norm(x_new, lp["ln2"], cfg.norm_eps)
                if cfg.family == "moe":
                    y, _ = moe_mod.apply_moe(cfg, lp["ffn"], h2)
                else:
                    y = apply_mlp(cfg, lp["ffn"], h2)
                return x_new + y, {"k": kc, "v": vc}

            layer_caches = {k: cache[k] for k in ("k", "v")}
            if not cfg.scan_layers:
                outs = []
                for i in range(cfg.num_layers):
                    x, out = body(x, (_layer_slice(params["layers"], i),
                                      _layer_slice(layer_caches, i)))
                    outs.append(out)
                new_caches = _stack_layers(outs)
            else:
                x, new_caches = jax.lax.scan(body, x,
                                             (params["layers"], layer_caches))
        new_cache = dict(cache)
        new_cache.update(new_caches)
        new_cache["pos"] = jnp.where(n_valid > 0, start + n_valid,
                                     cache["pos"])
        return x, new_cache

    def prefill_chunk(self, params: Dict, cache: Dict, tokens: jax.Array,
                      start: jax.Array, n_valid: jax.Array
                      ) -> Tuple[jax.Array, Dict]:
        """Continue prompt prefill by one chunk against the dense cache.

        tokens: (B, ck) int32 — each prefilling row's next chunk of prompt
        tokens, right-padded; start: (B,) absolute position of tokens[:, 0];
        n_valid: (B,) count of real tokens this chunk (0 = row inert: no
        writes, no position advance). Chunk K/V is written at cache slots
        ``start..start+n_valid`` and every chunk query attends over the
        already-cached prefix plus the chunk itself — run over the whole
        prompt in chunks this reproduces ``prefill`` exactly (greedy-parity
        tested), but interleaves with decode ticks instead of blocking them.

        Returns (logits at each row's last valid token (B, V), new cache);
        ``cache["pos"]`` advances to ``start + n_valid`` on active rows.
        """
        x, new_cache = self._chunk_trunk(params, cache, tokens, start,
                                         n_valid, paged=False)
        return self._finish_chunk(x, params, n_valid), new_cache

    def prefill_chunk_paged(self, params: Dict, cache: Dict,
                            tokens: jax.Array, start: jax.Array,
                            n_valid: jax.Array) -> Tuple[jax.Array, Dict]:
        """``prefill_chunk`` against the paged pool: chunk K/V lands in each
        row's block-table pages (the pages were allocated at admission);
        attention masks to the written prefix per query. Same contract and
        return shape as the dense form."""
        x, new_cache = self._chunk_trunk(params, cache, tokens, start,
                                         n_valid, paged=True)
        return self._finish_chunk(x, params, n_valid), new_cache

    def _verify_finish(self, x: jax.Array, params: Dict) -> jax.Array:
        """Final norm + unembed at EVERY chunk position -> greedy argmax
        (B, ck) int32. Verification needs the target model's prediction at
        each proposed position, not just the row's last valid one."""
        cfg = self.cfg
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed(cfg, params["embed"], x)
        logits = logits + jnp.asarray(self._vmask, logits.dtype)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def verify_chunk(self, params: Dict, cache: Dict, tokens: jax.Array,
                     start: jax.Array, n_valid: jax.Array
                     ) -> Tuple[jax.Array, Dict]:
        """Score a (B, k+1) proposed-token slice at per-row offsets in one
        call (speculative-decoding verify; DESIGN.md §Speculative decoding).

        ``tokens[:, 0]`` is each row's last committed token and the rest are
        draft proposals; position j's argmax is what target-only greedy
        decoding would emit after consuming ``tokens[:, :j+1]``. The chunk's
        K/V is written into the cache exactly like a prefill continuation —
        rejected positions are discarded afterwards by position rewind
        (``rollback``), which the causal validity masks make safe: stale
        slots beyond ``pos`` are never attended.

        Returns (per-position greedy argmax (B, ck) int32, new cache)."""
        x, new_cache = self._chunk_trunk(params, cache, tokens, start,
                                         n_valid, paged=False)
        return self._verify_finish(x, params), new_cache

    def verify_chunk_paged(self, params: Dict, cache: Dict,
                           tokens: jax.Array, start: jax.Array,
                           n_valid: jax.Array) -> Tuple[jax.Array, Dict]:
        """``verify_chunk`` against the paged pool; same contract."""
        x, new_cache = self._chunk_trunk(params, cache, tokens, start,
                                         n_valid, paged=True)
        return self._verify_finish(x, params), new_cache

    # ------------------------------------------------------------------
    # prefill: run the full prompt, build the cache
    # ------------------------------------------------------------------
    def prefill(self, params: Dict, batch: Dict, max_len: Optional[int] = None
                ) -> Tuple[jax.Array, Dict]:
        cfg = self.cfg
        enc = None
        if cfg.is_encoder_decoder:
            enc = self.encode(params, batch["frames"])
        x = self._embed_inputs(params, batch)
        B, S = x.shape[0], x.shape[1]
        C = self.cache_capacity(max_len or S)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        uw = _uniform_window(cfg)
        dt = self.compute_dtype
        KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim

        def body(carry, inp):
            if uw is not None:
                lp, w = inp, uw
            else:
                lp, w = inp
            x_in = carry
            out = {}
            h = rms_norm(x_in, lp["ln1"], cfg.norm_eps)
            if cfg.family == "ssm":
                y, (conv_st, ssd_st) = ssd_mod.ssm_forward(cfg, lp["ssm"], h,
                                                           return_cache=True)
                x_new = x_in + y
                out.update(conv=conv_st, ssd=ssd_st)
            elif cfg.family == "hybrid":
                a = attn.attention_forward(cfg, lp["attn"], h, positions, w)
                s, (conv_st, ssd_st) = ssd_mod.ssm_forward(cfg, lp["ssm"], h,
                                                           return_cache=True)
                sc = jax.nn.sigmoid(lp["mix_scale"].astype(jnp.float32))
                x_new = x_in + (sc[0] * a.astype(jnp.float32)
                                + sc[1] * s.astype(jnp.float32)).astype(x_in.dtype)
                out.update(conv=conv_st, ssd=ssd_st)
            else:
                x_new = x_in + attn.attention_forward(cfg, lp["attn"], h,
                                                      positions, w)
            if cfg.family != "ssm":
                # recompute K/V once for the cache (cheap relative to attn)
                hh = rms_norm(x_in, lp["ln1"], cfg.norm_eps)
                k = jnp.einsum("bsd,de->bse", hh, lp["attn"]["wk"].astype(dt))
                v = jnp.einsum("bsd,de->bse", hh, lp["attn"]["wv"].astype(dt))
                k = k.reshape(B, S, KV, hd)
                v = v.reshape(B, S, KV, hd)
                k = attn.apply_rope(k, positions, cfg.rope_theta)
                k = k.transpose(0, 2, 1, 3)        # (B, KV, S, hd)
                v = v.transpose(0, 2, 1, 3)
                kc = jnp.zeros((B, KV, C, hd), dt)
                vc = jnp.zeros((B, KV, C, hd), dt)
                if S >= C:
                    # keep last C positions, ring-aligned: slot = pos % C
                    tail_k, tail_v = k[:, :, S - C:], v[:, :, S - C:]
                    roll = (S - C) % C
                    slots = (jnp.arange(C) + roll) % C
                    kc = kc.at[:, :, slots].set(tail_k)
                    vc = vc.at[:, :, slots].set(tail_v)
                else:
                    kc = kc.at[:, :, :S].set(k)
                    vc = vc.at[:, :, :S].set(v)
                out.update(k=kc, v=vc)
            if cfg.is_encoder_decoder and enc is not None:
                hx = rms_norm(x_new, lp["lnx"], cfg.norm_eps)
                x_new = x_new + attn.cross_attention(cfg, lp["xattn"], hx, enc)
            if "ffn" in lp:
                h2 = rms_norm(x_new, lp["ln2"], cfg.norm_eps)
                if cfg.family == "moe":
                    y, _ = moe_mod.apply_moe(cfg, lp["ffn"], h2)
                else:
                    y = apply_mlp(cfg, lp["ffn"], h2)
                x_new = x_new + y
            return x_new, out

        if not cfg.scan_layers:
            windows = _layer_windows(cfg)
            outs = []
            for i in range(cfg.num_layers):
                lp = _layer_slice(params["layers"], i)
                inp = lp if uw is not None else (lp, jnp.asarray(windows[i]))
                x, out = body(x, inp)
                outs.append(out)
            layer_caches = _stack_layers(outs)
        else:
            xs = params["layers"] if uw is not None else (
                params["layers"], jnp.asarray(_layer_windows(cfg)))
            x, layer_caches = jax.lax.scan(body, x, xs)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed(cfg, params["embed"], x[:, -1:])
        logits = logits + jnp.asarray(self._vmask, logits.dtype)

        cache: Dict = {"pos": jnp.full((B,), S, jnp.int32)}
        cache.update(layer_caches)
        if cfg.is_encoder_decoder:
            cache["enc"] = enc
        return logits[:, 0], cache

    # ------------------------------------------------------------------
    # one-token decode against the cache
    # ------------------------------------------------------------------
    def decode_step(self, params: Dict, cache: Dict, tokens: jax.Array
                    ) -> Tuple[jax.Array, Dict]:
        """tokens: (B,) int32 -> (logits (B, V), updated cache)."""
        cfg = self.cfg
        dt = self.compute_dtype
        pos = cache["pos"]
        x = embed(cfg, params["embed"], tokens[:, None], dt)
        if cfg.rope_theta <= 0 or cfg.is_encoder_decoder:
            # gather the true sinusoidal row for each position
            x = x + self._sinusoid_pe(pos)[:, None, :].astype(dt)
        enc = cache.get("enc")
        windows = jnp.asarray(_layer_windows(cfg))

        def body(carry, inp):
            lp, w, lc = inp
            x_in = carry
            new_lc = {}
            h = rms_norm(x_in, lp["ln1"], cfg.norm_eps)
            if cfg.family == "ssm":
                y, conv_st, ssd_st = ssd_mod.ssm_decode(cfg, lp["ssm"], h,
                                                        lc["conv"], lc["ssd"])
                x_new = x_in + y
                new_lc.update(conv=conv_st, ssd=ssd_st)
            elif cfg.family == "hybrid":
                a, kc, vc = attn.decode_attention(cfg, lp["attn"], h, lc["k"],
                                                  lc["v"], pos, w)
                s, conv_st, ssd_st = ssd_mod.ssm_decode(cfg, lp["ssm"], h,
                                                        lc["conv"], lc["ssd"])
                sc = jax.nn.sigmoid(lp["mix_scale"].astype(jnp.float32))
                x_new = x_in + (sc[0] * a.astype(jnp.float32)
                                + sc[1] * s.astype(jnp.float32)).astype(x_in.dtype)
                new_lc.update(k=kc, v=vc, conv=conv_st, ssd=ssd_st)
            else:
                a, kc, vc = attn.decode_attention(cfg, lp["attn"], h, lc["k"],
                                                  lc["v"], pos, w)
                x_new = x_in + a
                new_lc.update(k=kc, v=vc)
            if cfg.is_encoder_decoder and enc is not None:
                hx = rms_norm(x_new, lp["lnx"], cfg.norm_eps)
                x_new = x_new + attn.cross_attention(cfg, lp["xattn"], hx, enc)
            if "ffn" in lp:
                h2 = rms_norm(x_new, lp["ln2"], cfg.norm_eps)
                if cfg.family == "moe":
                    y, _ = moe_mod.apply_moe(cfg, lp["ffn"], h2)
                else:
                    y = apply_mlp(cfg, lp["ffn"], h2)
                x_new = x_new + y
            return x_new, new_lc

        layer_caches = {k: cache[k] for k in ("k", "v", "conv", "ssd")
                        if k in cache}
        if not cfg.scan_layers:
            wnp = _layer_windows(cfg)
            outs = []
            for i in range(cfg.num_layers):
                inp = (_layer_slice(params["layers"], i), jnp.asarray(wnp[i]),
                       _layer_slice(layer_caches, i))
                x, out = body(x, inp)
                outs.append(out)
            new_caches = _stack_layers(outs)
        else:
            x, new_caches = jax.lax.scan(body, x, (params["layers"], windows,
                                                   layer_caches))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed(cfg, params["embed"], x)
        logits = logits + jnp.asarray(self._vmask, logits.dtype)
        new_cache = dict(cache)
        new_cache.update(new_caches)
        new_cache["pos"] = pos + 1
        return logits[:, 0], new_cache

    # ------------------------------------------------------------------
    # one-token decode against the paged pool
    # ------------------------------------------------------------------
    def decode_step_paged(self, params: Dict, cache: Dict, tokens: jax.Array,
                          *, n_pages: int) -> Tuple[jax.Array, Dict]:
        """tokens: (B,) int32 -> (logits (B, V), updated cache).

        Paged counterpart of ``decode_step``: per-layer attention runs
        against the shared page pool through each slot's block table, bounded
        by the static ``n_pages`` (the caller's live-page bucket). Per-layer
        pool leaves ride through the layer scan exactly like the dense k/v
        leaves; ``pt``/``pos`` are shared across layers (a token lands at the
        same page offset in every layer's pool)."""
        cfg = self.cfg
        assert self.supports_paged_cache(), cfg.name
        dt = self.compute_dtype
        pos = cache["pos"]
        x = embed(cfg, params["embed"], tokens[:, None], dt)
        if cfg.rope_theta <= 0:
            x = x + self._sinusoid_pe(pos)[:, None, :].astype(dt)
        pt = cache["pt"]

        def body(carry, inp):
            lp, kp_l, vp_l = inp
            x_in = carry
            h = rms_norm(x_in, lp["ln1"], cfg.norm_eps)
            a, kp_l, vp_l = attn.paged_decode_attention(
                cfg, lp["attn"], h, kp_l, vp_l, pt, pos, n_pages=n_pages)
            x_new = x_in + a
            h2 = rms_norm(x_new, lp["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                y, _ = moe_mod.apply_moe(cfg, lp["ffn"], h2)
            else:
                y = apply_mlp(cfg, lp["ffn"], h2)
            x_new = x_new + y
            return x_new, {"kp": kp_l, "vp": vp_l}

        if not cfg.scan_layers:
            outs = []
            for i in range(cfg.num_layers):
                inp = (_layer_slice(params["layers"], i),
                       cache["kp"][i], cache["vp"][i])
                x, out = body(x, inp)
                outs.append(out)
            new_caches = _stack_layers(outs)
        else:
            x, new_caches = jax.lax.scan(
                body, x, (params["layers"], cache["kp"], cache["vp"]))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed(cfg, params["embed"], x)
        logits = logits + jnp.asarray(self._vmask, logits.dtype)
        new_cache = dict(cache)
        new_cache.update(new_caches)
        new_cache["pos"] = pos + 1
        return logits[:, 0], new_cache


@functools.lru_cache(maxsize=64)
def _cached_lm(cfg: ModelConfig) -> LM:
    return LM(cfg)


def build_model(cfg: ModelConfig) -> LM:
    return _cached_lm(cfg)
