"""Top-k MoE FFN with sort-based capacity dispatch.

Tokens are routed to ``experts_per_token`` experts, grouped per expert into a
capacity-bounded (E, C, D) buffer via sort + scatter, run through per-expert
SwiGLU matmuls (a single batched einsum over the expert dimension — this is the
tensor that expert-parallelism shards), and combined back gate-weighted.
Overflowing tokens are dropped (standard capacity-factor semantics); the
pure-dense oracle in tests uses capacity_factor large enough to be dropless.

FLOP profile matches the *active* parameter count (tokens × k × 3DF), unlike a
dense all-experts einsum — this keeps the roofline honest.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import truncated_normal_init
from repro.sharding.context import batch_shard_size, constrain


def init_moe(key, cfg: ModelConfig) -> Dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "router": truncated_normal_init(ks[0], (D, E), 1.0, pd),
        "wi": truncated_normal_init(ks[1], (E, D, F), 1.0, pd),
        "wg": truncated_normal_init(ks[2], (E, D, F), 1.0, pd),
        "wo": truncated_normal_init(ks[3], (E, F, D), 1.0, pd),
    }


def moe_capacity(num_tokens: int, cfg: ModelConfig, capacity_factor: float) -> int:
    E, k = cfg.num_experts, cfg.experts_per_token
    cap = int(num_tokens * k * capacity_factor / E) + 1
    return max(8, ((cap + 7) // 8) * 8)  # pad to 8 for TPU-friendly shapes


def apply_moe(cfg: ModelConfig, p: Dict, x: jax.Array,
              capacity_factor: Optional[float] = None) -> Tuple[jax.Array, Dict]:
    """x: (B, S, D) -> (out (B, S, D), metrics incl. aux load-balance loss)."""
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    dt = x.dtype
    T = B * S
    # ---- grouped local dispatch (§Perf hillclimb B) ----
    # Tokens are split into G groups aligned with the data shards; each group
    # sorts/capacity-buckets its own tokens (exactly how expert-parallel
    # systems dispatch per data shard). The scatter then has a leading group
    # dim that GSPMD shards over "data", while experts shard over "model" —
    # a flat dispatch is unshardable through its scatter and gets replicated
    # (16× flops + 2·T·k·D all-reduces per layer, measured).
    G = batch_shard_size()
    if T % G or G <= 0:
        G = 1
    Tg = T // G
    flat = constrain(x.reshape(G, Tg, D), "batch", None, None)

    logits = jnp.einsum("gtd,de->gte", flat.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                       # (G, Tg, E)
    gate_vals, topk_idx = jax.lax.top_k(probs, k)                 # (G, Tg, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- load-balance auxiliary loss (Switch-style, global) ----
    pe = jnp.mean(probs, axis=(0, 1))                             # (E,)
    fe = jnp.zeros((E,), jnp.float32).at[topk_idx.reshape(-1)].add(1.0) / (T * k)
    aux_loss = E * jnp.sum(fe * pe)

    # ---- per-group sort + capacity bucketing ----
    C = moe_capacity(Tg, cfg, capacity_factor)                    # per group
    a = topk_idx.reshape(G, Tg * k)                               # expert ids
    src = jnp.broadcast_to(jnp.repeat(jnp.arange(Tg), k)[None], (G, Tg * k))
    gates = gate_vals.reshape(G, Tg * k)
    order = jnp.argsort(a, axis=-1, stable=True)
    take = jnp.take_along_axis
    a_s = take(a, order, axis=-1)
    src_s = take(src, order, axis=-1)
    gate_s = take(gates, order, axis=-1)
    g_idx = jnp.arange(G)[:, None]
    counts = jnp.zeros((G, E), jnp.int32).at[g_idx, a_s].add(1)
    starts = jnp.cumsum(counts, axis=-1) - counts                 # exclusive
    pos = jnp.arange(Tg * k)[None] - take(starts, a_s, axis=-1)
    keep = pos < C

    gathered = constrain(take(flat, src_s[..., None], axis=1),
                         "batch", None, None)                     # (G,Tg*k,D)
    buf = jnp.zeros((G, E, C, D), dt).at[
        g_idx, a_s, jnp.where(keep, pos, 0)].set(
        jnp.where(keep[..., None], gathered, 0), mode="drop")
    buf = constrain(buf, "batch", None, None, None)  # E replicated:
    # the scatter stays shard-local; the expert einsum slices E via its
    # model-sharded weights (no resharding collectives)

    # ---- per-expert SwiGLU (expert x group parallel einsum) ----
    h = jnp.einsum("gecd,edf->gecf", buf, p["wi"].astype(dt))
    g_ = jnp.einsum("gecd,edf->gecf", buf, p["wg"].astype(dt))
    h = jax.nn.silu(g_) * h
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(dt))
    out_buf = constrain(out_buf, "batch", None, None, None)

    # ---- combine back, gate-weighted ----
    rows = out_buf[g_idx, a_s, jnp.where(keep, pos, 0)]           # (G,Tg*k,D)
    rows = jnp.where(keep[..., None], rows, 0) * gate_s[..., None].astype(dt)
    y = jnp.zeros((G, Tg, D), dt).at[g_idx, src_s].add(rows)
    y = constrain(y, "batch", None, None)

    metrics = {
        "aux_loss": aux_loss,
        "router_entropy": -jnp.mean(jnp.sum(probs * jnp.log(probs + 1e-9), -1)),
        "drop_fraction": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return y.reshape(B, S, D), metrics


def apply_moe_dense_oracle(cfg: ModelConfig, p: Dict, x: jax.Array) -> jax.Array:
    """Dropless oracle: every token through every expert, gate-combined.
    O(T·E·D·F) — test-scale only."""
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    dt = x.dtype
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, topk_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    gate_full = jnp.zeros_like(probs).at[
        jnp.arange(B)[:, None, None], jnp.arange(S)[None, :, None], topk_idx
    ].set(gate_vals)                                              # (B,S,E)
    h = jnp.einsum("bsd,edf->bsef", x, p["wi"].astype(dt))
    g = jnp.einsum("bsd,edf->bsef", x, p["wg"].astype(dt))
    h = jax.nn.silu(g) * h
    y = jnp.einsum("bsef,efd->bsed", h, p["wo"].astype(dt))
    return jnp.einsum("bsed,bse->bsd", y, gate_full.astype(dt))
