"""Shared neural building blocks (pure JAX, functional, no framework deps)."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def truncated_normal_init(key, shape, scale: float, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale / np.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    if theta <= 0:
        return x
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(max_len: int, d_model: int) -> np.ndarray:
    pos = np.arange(max_len)[:, None]
    dim = np.arange(d_model // 2)[None, :]
    angle = pos / np.power(10_000.0, 2 * dim / d_model)
    out = np.zeros((max_len, d_model), np.float32)
    out[:, 0::2] = np.sin(angle)
    out[:, 1::2] = np.cos(angle)
    return out


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    pd = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "wi": truncated_normal_init(k1, (D, F), 1.0, pd),
        "wo": truncated_normal_init(k3, (F, D), 1.0, pd),
    }
    if cfg.mlp_type in ("swiglu", "geglu"):
        p["wg"] = truncated_normal_init(k2, (D, F), 1.0, pd)
    return p


def apply_mlp(cfg: ModelConfig, p: Dict, x: jax.Array) -> jax.Array:
    dt = x.dtype
    h = jnp.einsum("...d,df->...f", x, p["wi"].astype(dt))
    if cfg.mlp_type == "swiglu":
        g = jnp.einsum("...d,df->...f", x, p["wg"].astype(dt))
        h = jax.nn.silu(g) * h
    elif cfg.mlp_type == "geglu":
        g = jnp.einsum("...d,df->...f", x, p["wg"].astype(dt))
        h = jax.nn.gelu(g, approximate=True) * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    return jnp.einsum("...f,fd->...d", h, p["wo"].astype(dt))


# ---------------------------------------------------------------------------
# Embedding / unembedding with vocab padding
# ---------------------------------------------------------------------------

def init_embed(key, cfg: ModelConfig) -> Dict:
    pd = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    p = {"table": truncated_normal_init(k1, (cfg.padded_vocab, cfg.d_model), 1.0, pd)}
    if not cfg.tie_embeddings:
        p["unembed"] = truncated_normal_init(k2, (cfg.d_model, cfg.padded_vocab), 1.0, pd)
    return p


def embed(cfg: ModelConfig, p: Dict, tokens: jax.Array, dtype) -> jax.Array:
    x = jnp.take(p["table"].astype(dtype), tokens, axis=0)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dtype)
    return x


def unembed(cfg: ModelConfig, p: Dict, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, p["table"].astype(x.dtype))
    else:
        logits = jnp.einsum("...d,dv->...v", x, p["unembed"].astype(x.dtype))
    if cfg.attn_logit_softcap:
        c = cfg.attn_logit_softcap
        logits = jnp.tanh(logits / c) * c
    return logits


def vocab_mask(cfg: ModelConfig) -> np.ndarray:
    """Additive mask: 0 for real vocab entries, -1e9 for padding."""
    m = np.zeros((cfg.padded_vocab,), np.float32)
    m[cfg.vocab_size:] = -1e9
    return m
