"""Mamba-2 SSD (state-space duality) layer — pure-jnp implementation.

The chunked algorithm follows arXiv:2405.21060: intra-chunk outputs are dense
matmuls (MXU-friendly quadratic-in-chunk blocks), inter-chunk states follow the
linear recurrence. This module is also the oracle for ``kernels/ssd_scan.py``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import rms_norm, truncated_normal_init


def segsum(x: jax.Array) -> jax.Array:
    """x: (..., T) -> (..., T, T) with out[..., i, j] = sum_{k=j+1..i} x_k  (j<=i)."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, chunk: int,
                initial_state: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x:  (b, s, h, p)   per-head inputs
    dt: (b, s, h)      discretization steps (post-softplus)
    A:  (h,)           negative decay rates
    B:  (b, s, n)      input projections (ngroups=1, shared across heads)
    C:  (b, s, n)      output projections
    Returns y: (b, s, h, p) and final state (b, h, p, n).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    c, q = s // chunk, chunk

    xdt = (x * dt[..., None]).astype(jnp.float32)          # dt-weighted input
    dA = (dt.astype(jnp.float32) * A.astype(jnp.float32))  # (b, s, h)

    xdt = xdt.reshape(b, c, q, h, p)
    Bc = B.reshape(b, c, q, n).astype(jnp.float32)
    Cc = C.reshape(b, c, q, n).astype(jnp.float32)
    dA = dA.reshape(b, c, q, h).transpose(0, 3, 1, 2)      # (b, h, c, q)
    dA_cs = jnp.cumsum(dA, axis=-1)                        # (b, h, c, q)

    # 1) intra-chunk (dense quadratic block)
    L = jnp.exp(segsum(dA))                                # (b, h, c, q, q)
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", Cc, Bc, L, xdt)

    # 2) per-chunk end states
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)        # (b, h, c, q)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", Bc, decay_states, xdt)

    # 3) inter-chunk recurrence over chunk dimension
    chunk_decay = jnp.exp(dA_cs[..., -1])                  # (b, h, c)
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), jnp.float32)

    def step(carry, inp):
        st, dec = inp                                      # (b,h,p,n), (b,h)
        new = carry * dec[..., None, None] + st
        return new, carry                                  # emit state BEFORE chunk

    final_state, prev_states = jax.lax.scan(
        step, initial_state.astype(jnp.float32),
        (states.transpose(1, 0, 2, 3, 4),       # (c, b, h, p, n)
         chunk_decay.transpose(2, 0, 1)))       # (c, b, h)
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)     # (b, c, h, p, n)

    # 4) contribution of the carried-in state to each position
    state_decay_out = jnp.exp(dA_cs)                       # (b, h, c, q)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cc, prev_states, state_decay_out)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y.astype(x.dtype), final_state


def ssd_decode_step(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                    C: jax.Array, state: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One-token recurrence. x: (b,h,p), dt: (b,h), B,C: (b,n), state: (b,h,p,n)."""
    dA = jnp.exp(dt.astype(jnp.float32) * A.astype(jnp.float32))   # (b, h)
    dBx = jnp.einsum("bn,bhp->bhpn", B.astype(jnp.float32),
                     (x * dt[..., None]).astype(jnp.float32))
    new_state = state * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", new_state, C.astype(jnp.float32))
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Causal depthwise conv (width-w) over (x, B, C) channels, as in Mamba-2
# ---------------------------------------------------------------------------

def causal_conv1d(u: jax.Array, w: jax.Array, bias: jax.Array) -> jax.Array:
    """u: (b, s, ch); w: (cw, ch); bias: (ch,). Causal depthwise conv + silu."""
    cw = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (cw - 1, 0), (0, 0)))
    out = jnp.zeros_like(u, dtype=jnp.float32)
    for i in range(cw):  # cw is tiny (4) — unrolled taps
        out = out + pad[:, i:i + u.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out + bias.astype(jnp.float32)).astype(u.dtype)


def conv_decode_step(u_t: jax.Array, conv_state: jax.Array, w: jax.Array,
                     bias: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """u_t: (b, ch); conv_state: (b, cw-1, ch) past inputs. Returns (out, new_state)."""
    window = jnp.concatenate([conv_state, u_t[:, None, :]], axis=1)   # (b, cw, ch)
    out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
    out = jax.nn.silu(out + bias.astype(jnp.float32)).astype(u_t.dtype)
    return out, window[:, 1:, :]


# ---------------------------------------------------------------------------
# Full SSM mixer (in_proj -> conv -> SSD -> gated norm -> out_proj)
# ---------------------------------------------------------------------------

def init_ssm(key, cfg: ModelConfig) -> Dict:
    D, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    conv_ch = di + 2 * N
    return {
        "in_proj": truncated_normal_init(ks[0], (D, 2 * di + 2 * N + H), 1.0, pd),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, conv_ch)) * 0.1).astype(pd),
        "conv_b": jnp.zeros((conv_ch,), pd),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(pd),
        "D_skip": jnp.ones((H,), pd),
        "dt_bias": jnp.zeros((H,), pd),
        "norm_w": jnp.zeros((di,), pd),
        "out_proj": truncated_normal_init(ks[4], (di, D), 1.0, pd),
    }


def _split_in_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xc = zxbcdt[..., di:di + di + 2 * N]        # x,B,C go through the conv
    dt = zxbcdt[..., di + di + 2 * N:]
    return z, xc, dt


def ssm_forward(cfg: ModelConfig, p: Dict, x: jax.Array,
                initial_state: Optional[jax.Array] = None,
                return_cache: bool = False):
    """Full-sequence SSM mixer. x: (B,S,D) -> (B,S,D) [+ (conv_state, ssd_state)]."""
    B_, S, D = x.shape
    di, N, H, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    dt_ = x.dtype
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dt_))
    z, xc_raw, dtr = _split_in_proj(cfg, zxbcdt)
    xc = causal_conv1d(xc_raw, p["conv_w"], p["conv_b"])
    xs = xc[..., :di].reshape(B_, S, H, hp)
    Bm = xc[..., di:di + N]
    Cm = xc[..., di + N:]
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    chunk = min(cfg.ssd_chunk, S)
    pad = (-S) % chunk
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    if cfg.use_pallas:
        from repro.kernels import ops as kops
        y, ssd_state = kops.ssd_scan(xs, dt, A, Bm, Cm, chunk=chunk,
                                     initial_state=initial_state)
    else:
        y, ssd_state = ssd_chunked(xs, dt, A, Bm, Cm, chunk, initial_state)
    if pad:
        y = y[:, :S]
    y = y + xs[:, :S] * p["D_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B_, S, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(dt_), p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dt_))
    if return_cache:
        # conv state: last (cw-1) *pre-conv* channel inputs
        cw = cfg.conv_width
        if S >= cw - 1:
            conv_state = xc_raw[:, S - (cw - 1):S, :]
        else:
            conv_state = jnp.pad(xc_raw, ((0, 0), (cw - 1 - S, 0), (0, 0)))
        return out, (conv_state, ssd_state)
    return out


def ssm_decode(cfg: ModelConfig, p: Dict, x: jax.Array, conv_state: jax.Array,
               ssd_state: jax.Array):
    """One-token SSM step. x: (B,1,D). Returns (out (B,1,D), conv_state, ssd_state)."""
    B_, _, D = x.shape
    di, N, H, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    dt_ = x.dtype
    zxbcdt = jnp.einsum("bd,de->be", x[:, 0], p["in_proj"].astype(dt_))
    di2 = di + 2 * N
    z = zxbcdt[..., :di]
    xc_raw = zxbcdt[..., di:di + di2]
    dtr = zxbcdt[..., di + di2:]
    xc, conv_state = conv_decode_step(xc_raw, conv_state, p["conv_w"], p["conv_b"])
    xs = xc[..., :di].reshape(B_, H, hp)
    Bm = xc[..., di:di + N]
    Cm = xc[..., di + N:]
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, ssd_state = ssd_decode_step(xs, dt, A, Bm, Cm, ssd_state)
    y = y + xs * p["D_skip"].astype(y.dtype)[None, :, None]
    y = y.reshape(B_, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(dt_), p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"].astype(dt_))[:, None, :]
    return out, conv_state, ssd_state
