"""Experiment runner: replay a workload trace against a controller + cluster.

Reproduces the paper's evaluation harness (§6): Poisson arrivals from a
per-second rate trace (the Twitter-trace methodology of Fig. 5/8), the
controller stepping every 30 s, the dispatcher load-balancing by the solver's
quotas λ_m, and the cluster measuring windowed P99 / accuracy / cost.

The cluster is any ``ServingAPI`` implementation (``repro.serving.api``) —
pass ``cluster=`` to replay against something other than a fresh
``SimCluster``. Asynchronous backends (the real engine) are ticked after
each submission and drained at the end; note their latencies are wall-clock
while arrival stamps are simulated, so absolute latency numbers are only
meaningful on the simulator — the real engine is normally driven in
wall-clock time by ``examples/serve_autoscale.py`` instead. Ensemble
(fanout) controllers additionally need the DES's ``dispatch_fanout`` and
are rejected with a clear error on other backends.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Mapping, Optional

import numpy as np

from repro.core.profiles import VariantProfile
from repro.data.traces import arrivals_from_rate
from repro.obs.audit import attach_from_requests
from repro.serving.api import Request
from repro.sim.cluster import SimCluster

_NO_TOKENS = np.zeros((0,), np.int64)   # sim requests carry no prompt


@dataclass
class ExperimentResult:
    name: str
    summary: Dict
    decisions: list

    def __repr__(self):
        s = self.summary
        return (f"<{self.name}: viol={s['violation_rate']:.3%} "
                f"p99={s['p99_ms']:.0f}ms acc_loss={s['accuracy_loss']:.2f}% "
                f"cost={s['avg_cost_units']:.1f}>")


def run_experiment(name: str, controller, profiles: Mapping[str, VariantProfile],
                   rate_trace: np.ndarray, *, slo_ms: float = 750.0,
                   interval_s: float = 30.0, seed: int = 0,
                   warm_start: Optional[Mapping[str, int]] = None,
                   reference_accuracy: Optional[float] = None,
                   cluster=None, faults=None, slo_monitor=None,
                   ) -> ExperimentResult:
    """Replay ``rate_trace`` (requests/s per second) and score the controller.

    Faithful to the paper's setup: ``interval_s=30`` s control period,
    ``slo_ms=750`` ms latency SLO, accuracy loss reported against the most
    accurate variant (Table 1). ``warm_start`` pre-loads variants as the
    paper's experiments do so t=0 isn't an artificial cold start.

    ``faults`` (a ``repro.cluster.faults.FaultSchedule``) injects failure
    events into fabric-backed clusters as simulated time passes, interleaved
    in time order with controller steps — the end-to-end failure-scenario
    harness.

    ``slo_monitor`` (an ``repro.obs.slo.SLOMonitor`` over the cluster's
    windowed metrics) is checked at every reactive checkpoint, in virtual
    time, before ``maybe_react`` — a controller wired with ``burn_alerts=``
    re-solves on burn-rate breach with the same semantics as the wall-clock
    driver (parity-tested).
    """
    cluster = cluster if cluster is not None else SimCluster(profiles)
    best_acc = reference_accuracy if reference_accuracy is not None \
        else max(p.accuracy for p in profiles.values())
    arrivals = arrivals_from_rate(rate_trace, seed=seed)

    # realized_shares must reflect THIS replay only — a reused controller's
    # dispatcher carries counts (and WRR phase) from previous runs
    dispatcher = getattr(controller, "dispatcher", None)
    if dispatcher is not None:
        dispatcher.reset()

    # Seed the monitor with one flushed pre-trace second of the initial rate so
    # the first decision sees a real load estimate (not the min-load floor).
    controller.monitor.record(-1.0, max(int(rate_trace[0]), 1))
    controller.monitor.advance_to(0.0)
    if warm_start:
        cluster.apply_allocation(-max(profiles[m].rt for m in warm_start),
                                 warm_start)
        # mark as instantly ready (replica-fabric clusters expose mark_warm;
        # plain backends keep the legacy direct poke)
        if hasattr(cluster, "mark_warm"):
            cluster.mark_warm(list(warm_start))
        else:
            for m in warm_start:
                cluster.backends[m].ready_at = 0.0
    controller.step(0.0, cluster)

    react_s = getattr(getattr(controller, "cfg", None), "reactive_check_s", 5.0)
    next_ctrl = interval_s
    next_react = react_s
    for rid, a in enumerate(arrivals):
        while faults is not None and faults.next_t() <= min(a, next_ctrl):
            faults.apply_due(faults.next_t(), cluster)
        while a >= next_ctrl:
            controller.monitor.advance_to(next_ctrl)
            controller.step(next_ctrl, cluster)
            next_ctrl += interval_s
            next_react = next_ctrl - interval_s + react_s
            if faults is not None and faults.next_t() <= min(a, next_ctrl):
                faults.apply_due(faults.next_t(), cluster)
        if a >= next_react and hasattr(controller, "maybe_react"):
            controller.monitor.advance_to(next_react)
            if slo_monitor is not None:
                slo_monitor.check(next_react)
            controller.maybe_react(next_react, cluster)
            next_react += react_s
        controller.monitor.record(a, 1)
        if hasattr(controller, "fanout_backends"):
            # Cocktail-style ensembling: every member serves every request.
            # Fanout needs the DES's dispatch_fanout (latency = slowest
            # member) — not part of the ServingAPI protocol, so fail clearly
            # rather than mid-replay on an arbitrary AttributeError.
            if not hasattr(cluster, "dispatch_fanout"):
                raise TypeError(
                    f"controller {type(controller).__name__} requires fanout "
                    f"dispatch, which {type(cluster).__name__} does not "
                    "support; use SimCluster for ensemble controllers")
            members = controller.fanout_backends()
            acc = controller.decisions[-1].allocation.aa \
                if controller.decisions else 0.0
            cluster.dispatch_fanout(a, members, acc)
        else:
            backend = controller.dispatcher.next_backend()
            # Rejected submissions (backpressure on the real engine) are
            # counted by that backend's summary ("rejected"); they are not
            # scored as served requests. SimCluster never rejects. Each
            # request carries the experiment SLO as its deadline so
            # deadline-aware schedulers (scheduler="edf"/"chunked" on the
            # cluster) and the goodput metric see per-request deadlines.
            cluster.submit(Request(rid=rid, tokens=_NO_TOKENS, max_new=1,
                                   arrival=a, slo_ms=slo_ms), backend)
            cluster.step(a)       # no-op on synchronous backends

    cluster.drain(arrivals[-1] if len(arrivals) else 0.0)
    # Close the audit loop: bucket realized latencies/goodput back onto the
    # controller decisions that governed them (predicted vs measured).
    attach_from_requests(getattr(controller, "audit", None),
                         getattr(cluster, "requests", ()),
                         default_slo_ms=slo_ms)
    summary = cluster.summarize(slo_ms, best_acc)
    return ExperimentResult(name=name, summary=summary,
                            decisions=list(getattr(controller, "decisions", [])))
