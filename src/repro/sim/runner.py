"""Experiment runner: replay a workload trace against a controller + cluster.

Reproduces the paper's evaluation harness: Poisson arrivals from a per-second
rate trace, the controller stepping every 30 s, the dispatcher load-balancing
by quota, and the simulator measuring windowed P99 / accuracy / cost.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Mapping, Optional

import numpy as np

from repro.core.profiles import VariantProfile
from repro.data.traces import arrivals_from_rate
from repro.sim.cluster import SimCluster


@dataclass
class ExperimentResult:
    name: str
    summary: Dict
    decisions: list

    def __repr__(self):
        s = self.summary
        return (f"<{self.name}: viol={s['violation_rate']:.3%} "
                f"p99={s['p99_ms']:.0f}ms acc_loss={s['accuracy_loss']:.2f}% "
                f"cost={s['avg_cost_units']:.1f}>")


def run_experiment(name: str, controller, profiles: Mapping[str, VariantProfile],
                   rate_trace: np.ndarray, *, slo_ms: float = 750.0,
                   interval_s: float = 30.0, seed: int = 0,
                   warm_start: Optional[Mapping[str, int]] = None,
                   reference_accuracy: Optional[float] = None,
                   ) -> ExperimentResult:
    cluster = SimCluster(profiles)
    best_acc = reference_accuracy if reference_accuracy is not None \
        else max(p.accuracy for p in profiles.values())
    arrivals = arrivals_from_rate(rate_trace, seed=seed)

    # Seed the monitor with one flushed pre-trace second of the initial rate so
    # the first decision sees a real load estimate (not the min-load floor).
    controller.monitor.record(-1.0, max(int(rate_trace[0]), 1))
    controller.monitor.advance_to(0.0)
    if warm_start:
        cluster.apply_allocation(-max(profiles[m].rt for m in warm_start),
                                 warm_start)
        # mark as instantly ready
        for m in warm_start:
            cluster.backends[m].ready_at = 0.0
    controller.step(0.0, cluster)

    react_s = getattr(getattr(controller, "cfg", None), "reactive_check_s", 5.0)
    next_ctrl = interval_s
    next_react = react_s
    for a in arrivals:
        while a >= next_ctrl:
            controller.monitor.advance_to(next_ctrl)
            controller.step(next_ctrl, cluster)
            next_ctrl += interval_s
            next_react = next_ctrl - interval_s + react_s
        if a >= next_react and hasattr(controller, "maybe_react"):
            controller.monitor.advance_to(next_react)
            controller.maybe_react(next_react, cluster)
            next_react += react_s
        controller.monitor.record(a, 1)
        if hasattr(controller, "fanout_backends"):
            # Cocktail-style ensembling: every member serves every request
            members = controller.fanout_backends()
            acc = controller.decisions[-1].allocation.aa \
                if controller.decisions else 0.0
            cluster.dispatch_fanout(a, members, acc)
        else:
            backend = controller.dispatcher.next_backend()
            cluster.dispatch(a, backend)

    summary = cluster.summarize(slo_ms, best_acc)
    return ExperimentResult(name=name, summary=summary,
                            decisions=list(getattr(controller, "decisions", [])))
