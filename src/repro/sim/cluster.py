"""Discrete-event simulation of the serving cluster.

Each backend (variant, n units) is a c-server FIFO queue whose capacity
matches the profile exactly (Little's law):

    servers c   = max(1, round(th(n) · p(n)))        # concurrency in flight
    service s   = c / th(n)                          # per-request seconds
    => capacity = c / s = th(n), loaded latency ≈ p(n)

mirroring the paper's TF-Serving setup (inter-op parallelism = #cores,
batching off ⇒ concurrency ≈ cores).

Reconfiguration semantics (paper §5, incl. their zero-downtime VPA patch):
  * resizing a *running* variant applies after RESIZE_DELAY_S;
  * a *new* variant warms up until t + rt_m; while warming it receives no
    traffic — its quota spills onto the ready backends (overloading them,
    which is exactly the transient-SLO-violation dynamic the paper reports);
  * an old variant retires only once every newly created backend is ready
    (create-then-remove).

Replica fabric mode (``nodes=``): instead of one monolithic backend per
variant, the allocation materializes as a **placement of replicas across
nodes** via ``repro.cluster.ReplicaFabric`` — each replica is its own
c-server queue (true per-replica queues/servers), requests are routed
two-level (the dispatcher's variant choice, then a ``RoutingAPI`` replica
pick — power-of-two-choices least-outstanding by default), reconfiguration
is rolling create-then-remove at replica granularity, and faults
(``inject_fault``) kill nodes or degrade replicas. A node crash affects
dispatches from the crash instant forward; requests the DES already
scheduled keep their computed completions (synchronous-serve limitation,
noted in DESIGN.md §Cluster fabric).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Set

import numpy as np

from repro.cluster.faults import FaultEvent
from repro.cluster.placement import Node
from repro.cluster.replicas import Replica, ReplicaFabric
from repro.cluster.router import ReplicaView, RoutingAPI, make_router
from repro.core.profiles import VariantProfile
from repro.serving.api import Request, summarize_requests

RESIZE_DELAY_S = 1.0
# Profiled th(n) is the *SLO-sustained* rate (the paper measures throughput at
# the point where P99 reaches the SLO). The raw service rate at saturation is
# slightly higher; the gap is what lets a backlog drain after a burst.
SERVICE_HEADROOM = 1.35


@dataclass
class Backend:
    profile: VariantProfile
    units: int
    ready_at: float
    retire_at: float = float("inf")
    slow_factor: float = 1.0     # heterogeneity / straggler multiplier
    server_free: List[float] = field(default_factory=list)   # heap

    def __post_init__(self):
        th = self.profile.throughput(self.units)
        p_s = self.profile.p99_ms(self.units) / 1000.0
        c = max(1, int(round(th * p_s)))
        self.capacity = th
        self.service_s = c / max(th * SERVICE_HEADROOM, 1e-9)
        if not self.server_free:
            self.server_free = [self.ready_at] * c
            heapq.heapify(self.server_free)

    def resized(self, n: int, t: float) -> "Backend":
        """Live resize: inherit the in-flight server queue; extra servers come
        online after RESIZE_DELAY_S; shrink keeps the earliest-free servers."""
        nb = Backend(self.profile, n, ready_at=self.ready_at,
                     slow_factor=self.slow_factor)  # resize never un-warms a
        # loading backend nor stalls a ready one
        c_new = len(nb.server_free)
        inherited = sorted(self.server_free)[:c_new]
        while len(inherited) < c_new:
            inherited.append(t + RESIZE_DELAY_S)
        nb.server_free = inherited
        heapq.heapify(nb.server_free)
        return nb

    def ready(self, t: float) -> bool:
        return self.ready_at <= t

    def queue_delay(self, t: float) -> float:
        return max(self.server_free[0] - t, 0.0)

    @property
    def effective_service_s(self) -> float:
        return self.service_s * self.slow_factor

    def outstanding(self, t: float) -> float:
        """Outstanding requests (queued + in service, fractional) — the
        router's least-outstanding signal."""
        s = max(self.effective_service_s, 1e-9)
        return sum(max(f - t, 0.0) for f in self.server_free) / s

    def queued(self, t: float) -> float:
        """Queued-not-in-service requests (the ``ClusterAPI.backlog``
        semantics): per server, whole service times of work beyond the
        request currently in service."""
        s = max(self.effective_service_s, 1e-9)
        return float(sum(int((f - t) / s - 1e-9)
                         for f in self.server_free if f - t > s))

    def serve_timed(self, arrival: float) -> tuple:
        """Grab a server; returns (service_start, completion)."""
        free = heapq.heappop(self.server_free)
        start = max(arrival, free, self.ready_at)
        done = start + self.effective_service_s
        heapq.heappush(self.server_free, done)
        return start, done

    def serve(self, arrival: float) -> float:
        return self.serve_timed(arrival)[1]


@dataclass
class ServedRequest:
    arrival: float
    completion: float
    backend: str
    accuracy: float
    service_start: float = 0.0   # 0.0 = dropped/never served

    @property
    def latency_ms(self) -> float:
        return (self.completion - self.arrival) * 1000.0

    @property
    def queue_wait_ms(self) -> float:
        if self.service_start <= 0.0:
            return 0.0
        return max(self.service_start - self.arrival, 0.0) * 1000.0

    @property
    def service_ms(self) -> float:
        if self.service_start <= 0.0:
            return self.latency_ms
        return max(self.completion - self.service_start, 0.0) * 1000.0


class SimCluster:
    """Discrete-event implementation of the shared ``ClusterAPI``/
    ``ServingAPI`` (``repro.serving.api``) — the same contract the real
    ``InProcessServingEngine`` implements, so controllers and the experiment
    harness drive either interchangeably.

    Without ``nodes`` the cluster is the paper's setup: one backend per
    variant. With ``nodes`` the replica fabric activates (see module
    docstring): ``placement`` picks the policy (``"first-fit"``/``"spread"``
    or a ``PlacementPolicy``), ``router`` the replica-level routing
    (``"p2c"``/``"least"``/``"rr"``/``"random"`` or a ``RoutingAPI``), and
    ``replica_size`` the max units per replica.
    """

    def __init__(self, profiles: Mapping[str, VariantProfile],
                 nodes: Optional[Sequence[Node]] = None,
                 placement="first-fit", router="p2c",
                 replica_size: int = 4):
        self.profiles = dict(profiles)
        self.backends: Dict[str, Backend] = {}
        self.requests: List[ServedRequest] = []
        self.cost_samples: List[tuple] = []    # (t, provisioned units)
        self.fabric: Optional[ReplicaFabric] = None
        self.router: Optional[RoutingAPI] = None
        if nodes is not None:
            self.fabric = ReplicaFabric(
                nodes, policy=placement, replica_size=replica_size,
                rt_fn=lambda m: self.profiles[m].rt)
            self.router = make_router(router)

    # ------------------------------------------------------------- ClusterAPI
    def apply_allocation(self, t: float, units: Mapping[str, int]) -> None:
        if self.fabric is not None:
            self._apply_fabric(t, units)
            return
        target = {m: n for m, n in units.items() if n > 0}
        new_ready = [t]
        for m, n in target.items():
            b = self.backends.get(m)
            if b is not None:
                b.retire_at = float("inf")   # re-selected: cancel retirement
                if b.units != n:
                    self.backends[m] = b.resized(n, t)
                new_ready.append(self.backends[m].ready_at)
            else:
                nb = Backend(self.profiles[m], n, ready_at=t + self.profiles[m].rt)
                self.backends[m] = nb
                new_ready.append(nb.ready_at)
        switch_t = max(new_ready)
        for m, b in self.backends.items():
            if m not in target:
                b.retire_at = min(b.retire_at, switch_t)
        self.cost_samples.append(
            (t, sum(b.units for b in self.backends.values()
                    if b.retire_at == float("inf"))))

    def _apply_fabric(self, t: float, units: Mapping[str, int]) -> None:
        self.fabric.purge(t)
        tr = self.fabric.apply(t, units)
        for rep in tr.created:
            self._attach_handle(rep)
        for rep in tr.retired:
            rep.handle.retire_at = rep.retire_at
        self.cost_samples.append((t, self.fabric.provisioned_units()))

    def _attach_handle(self, rep: Replica) -> None:
        b = Backend(self.profiles[rep.variant], rep.units,
                    ready_at=rep.ready_at, slow_factor=rep.slow_factor)
        rep.handle = b

    def loaded_variants(self, t: float) -> Set[str]:
        if self.fabric is not None:
            return set(self.fabric.variants_ready(t))
        return {m for m, b in self.backends.items() if b.ready(t)}

    def backlog(self, t: float) -> float:
        """Queued-not-in-service requests (shared ``ClusterAPI`` semantics:
        admitted work not yet being processed — see ``serving/api.py``)."""
        if self.fabric is not None:
            return sum(r.handle.queued(t) for r in self.fabric.replicas.values()
                       if r.live(t))
        return sum(b.queued(t) for b in self.backends.values()
                   if b.retire_at > t)

    def capacity_factor(self, t: float) -> float:
        """Fraction of the target allocation actually live (1.0 without a
        fabric — monolithic backends don't fail)."""
        return self.fabric.capacity_factor(t) if self.fabric is not None else 1.0

    def mark_warm(self, variants: Optional[Sequence[str]] = None,
                  t: float = 0.0) -> None:
        """Force readiness at ``t`` (experiment-harness warm start; call
        before traffic — it also clears the warm-up hold on each server)."""
        def warm(b: Backend) -> None:
            b.ready_at = min(b.ready_at, t)
            b.server_free = [min(f, t) for f in b.server_free]
            heapq.heapify(b.server_free)
        if self.fabric is not None:
            self.fabric.mark_ready(t, variants)
            for r in self.fabric.replicas.values():
                if variants is None or r.variant in variants:
                    warm(r.handle)
            return
        for m, b in self.backends.items():
            if variants is None or m in variants:
                warm(b)

    # ----------------------------------------------------------------- faults
    def inject_fault(self, t: float, event: FaultEvent) -> None:
        """Apply one ``repro.cluster.faults`` event (fabric mode only)."""
        if self.fabric is None:
            raise RuntimeError("fault injection requires the replica fabric "
                               "(construct SimCluster with nodes=)")
        if event.kind == "node_crash":
            self.fabric.crash_node(t, event.target)
        elif event.kind == "node_recover":
            self.fabric.recover_node(t, event.target)
        elif event.kind in ("replica_slowdown", "replica_restore"):
            factor = event.factor if event.kind == "replica_slowdown" else 1.0
            if self.fabric.slow_replica(t, event.target, factor):
                rep = self.fabric.replicas[event.target]
                rep.handle.slow_factor = rep.slow_factor

    # ---------------------------------------------------------------- serving
    def submit(self, req: Request, backend: Optional[str]) -> bool:
        """ServingAPI parity with the real engine: a simulated request needs
        only its arrival time — prompt tokens don't affect queueing."""
        self.dispatch(req.arrival, backend or None)
        return True

    def step(self, now: float) -> int:
        """No-op: the DES serves synchronously at submit time."""
        return 0

    def drain(self, now: float) -> int:
        """No-op: nothing is ever left in flight between submits."""
        return 0

    def _purge(self, t: float) -> None:
        for m in [m for m, b in self.backends.items() if b.retire_at <= t]:
            del self.backends[m]

    def dispatch(self, arrival: float, backend_name: Optional[str]) -> None:
        if self.fabric is not None:
            self._dispatch_fabric(arrival, backend_name)
            return
        self._purge(arrival)
        candidates = {m: b for m, b in self.backends.items()
                      if b.retire_at > arrival}
        if not candidates:
            self.requests.append(ServedRequest(arrival, arrival + 10.0,
                                               "none", 0.0))
            return
        b = candidates.get(backend_name) if backend_name else None
        if b is None or not b.ready(arrival):
            ready = {m: bb for m, bb in candidates.items() if bb.ready(arrival)}
            pool = ready or candidates
            name = min(pool, key=lambda m: pool[m].queue_delay(arrival))
            b = pool[name]
            backend_name = name
        start, done = b.serve_timed(arrival)
        self.requests.append(ServedRequest(arrival, done, backend_name,
                                           b.profile.accuracy,
                                           service_start=start))

    # ----------------------------------------------------- two-level routing
    def _pick_replica(self, variant: str, arrival: float) -> Optional[Replica]:
        """Level 2 of two-level routing: the ``RoutingAPI`` picks among the
        variant's ready replicas (fall back to warming ones — service then
        waits for readiness, the same spill the monolithic sim models)."""
        reps = self.fabric.ready_replicas(variant, arrival) or \
            [r for r in self.fabric.group(variant) if r.live(arrival)]
        if not reps:
            return None
        views = [ReplicaView(r.rid, r.handle.outstanding(arrival), r.units)
                 for r in reps]
        rid = self.router.pick(views)
        return self.fabric.replicas[rid]

    def _dispatch_fabric(self, arrival: float,
                         backend_name: Optional[str]) -> None:
        self.fabric.purge(arrival)
        live = [r for r in self.fabric.replicas.values() if r.live(arrival)]
        if not live:
            self.requests.append(ServedRequest(arrival, arrival + 10.0,
                                               "none", 0.0))
            return
        variant = backend_name
        ready = [r for r in live if r.ready(arrival)]
        if variant is None or not any(r.variant == variant for r in ready):
            # dispatcher quota points at a warming/retired/unknown variant:
            # spill to the ready variant whose best replica frees first
            # (legacy fallback — the transient-overload dynamic of §5)
            pool = ready or live
            variant = min(pool,
                          key=lambda r: r.handle.queue_delay(arrival)).variant
        rep = self._pick_replica(variant, arrival)
        start, done = rep.handle.serve_timed(arrival)
        self.requests.append(ServedRequest(
            arrival, done, rep.rid, self.profiles[rep.variant].accuracy,
            service_start=start))

    def dispatch_fanout(self, arrival: float, backend_names, accuracy: float
                        ) -> None:
        """Cocktail-style ensembling: the request runs on EVERY member;
        latency is the slowest member (majority vote needs all of them)."""
        if self.fabric is not None:
            self._dispatch_fanout_fabric(arrival, backend_names, accuracy)
            return
        self._purge(arrival)
        done = arrival + 10.0
        served = False
        start = 0.0
        for name in backend_names:
            b = self.backends.get(name)
            if b is None or b.retire_at <= arrival:
                continue
            s, d = b.serve_timed(arrival)
            done = max(done if served else arrival, d)
            start = min(start, s) if served else s   # earliest member start
            served = True
        if not served:
            self.dispatch(arrival, None)
            return
        self.requests.append(ServedRequest(arrival, done, "+".join(backend_names),
                                           accuracy, service_start=start))

    def _dispatch_fanout_fabric(self, arrival: float, backend_names,
                                accuracy: float) -> None:
        self.fabric.purge(arrival)
        done = arrival + 10.0
        served = False
        start = 0.0
        members = []
        for name in backend_names:
            rep = self._pick_replica(name, arrival)
            if rep is None:
                continue
            s, d = rep.handle.serve_timed(arrival)
            done = max(done if served else arrival, d)
            start = min(start, s) if served else s
            served = True
            members.append(rep.rid)
        if not served:
            self.dispatch(arrival, None)
            return
        self.requests.append(ServedRequest(arrival, done, "+".join(members),
                                           accuracy, service_start=start))

    # ---------------------------------------------------------------- metrics
    def summarize(self, slo_ms: float, best_accuracy: float,
                  window_s: float = 10.0) -> Dict:
        """Paper evaluation summary (§6) via the shared metric helper."""
        return summarize_requests(
            [r.arrival for r in self.requests],
            [r.latency_ms for r in self.requests],
            [r.accuracy for r in self.requests],
            slo_ms=slo_ms, best_accuracy=best_accuracy,
            cost_samples=self.cost_samples, window_s=window_s,
            queue_ms=[r.queue_wait_ms for r in self.requests],
            service_ms=[r.service_ms for r in self.requests])
