"""Discrete-event simulation of the serving cluster.

Each backend (variant, n units) is a c-server FIFO queue whose capacity
matches the profile exactly (Little's law):

    servers c   = max(1, round(th(n) · p(n)))        # concurrency in flight
    service s   = c / th(n)                          # per-request seconds
    => capacity = c / s = th(n), loaded latency ≈ p(n)

mirroring the paper's TF-Serving setup (inter-op parallelism = #cores,
batching off ⇒ concurrency ≈ cores).

Reconfiguration semantics (paper §5, incl. their zero-downtime VPA patch):
  * resizing a *running* variant applies after RESIZE_DELAY_S;
  * a *new* variant warms up until t + rt_m; while warming it receives no
    traffic — its quota spills onto the ready backends (overloading them,
    which is exactly the transient-SLO-violation dynamic the paper reports);
  * an old variant retires only once every newly created backend is ready
    (create-then-remove).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Set

import numpy as np

from repro.core.profiles import VariantProfile
from repro.serving.api import Request, summarize_requests

RESIZE_DELAY_S = 1.0
# Profiled th(n) is the *SLO-sustained* rate (the paper measures throughput at
# the point where P99 reaches the SLO). The raw service rate at saturation is
# slightly higher; the gap is what lets a backlog drain after a burst.
SERVICE_HEADROOM = 1.35


@dataclass
class Backend:
    profile: VariantProfile
    units: int
    ready_at: float
    retire_at: float = float("inf")
    server_free: List[float] = field(default_factory=list)   # heap

    def __post_init__(self):
        th = self.profile.throughput(self.units)
        p_s = self.profile.p99_ms(self.units) / 1000.0
        c = max(1, int(round(th * p_s)))
        self.capacity = th
        self.service_s = c / max(th * SERVICE_HEADROOM, 1e-9)
        if not self.server_free:
            self.server_free = [self.ready_at] * c
            heapq.heapify(self.server_free)

    def resized(self, n: int, t: float) -> "Backend":
        """Live resize: inherit the in-flight server queue; extra servers come
        online after RESIZE_DELAY_S; shrink keeps the earliest-free servers."""
        nb = Backend(self.profile, n, ready_at=self.ready_at)  # resize never
        # un-warms a loading backend nor stalls a ready one
        c_new = len(nb.server_free)
        inherited = sorted(self.server_free)[:c_new]
        while len(inherited) < c_new:
            inherited.append(t + RESIZE_DELAY_S)
        nb.server_free = inherited
        heapq.heapify(nb.server_free)
        return nb

    def ready(self, t: float) -> bool:
        return self.ready_at <= t

    def queue_delay(self, t: float) -> float:
        return max(self.server_free[0] - t, 0.0)

    def serve_timed(self, arrival: float) -> tuple:
        """Grab a server; returns (service_start, completion)."""
        free = heapq.heappop(self.server_free)
        start = max(arrival, free, self.ready_at)
        done = start + self.service_s
        heapq.heappush(self.server_free, done)
        return start, done

    def serve(self, arrival: float) -> float:
        return self.serve_timed(arrival)[1]


@dataclass
class ServedRequest:
    arrival: float
    completion: float
    backend: str
    accuracy: float
    service_start: float = 0.0   # 0.0 = dropped/never served

    @property
    def latency_ms(self) -> float:
        return (self.completion - self.arrival) * 1000.0

    @property
    def queue_wait_ms(self) -> float:
        if self.service_start <= 0.0:
            return 0.0
        return max(self.service_start - self.arrival, 0.0) * 1000.0

    @property
    def service_ms(self) -> float:
        if self.service_start <= 0.0:
            return self.latency_ms
        return max(self.completion - self.service_start, 0.0) * 1000.0


class SimCluster:
    """Discrete-event implementation of the shared ``ClusterAPI``/
    ``ServingAPI`` (``repro.serving.api``) — the same contract the real
    ``InProcessServingEngine`` implements, so controllers and the experiment
    harness drive either interchangeably."""

    def __init__(self, profiles: Mapping[str, VariantProfile]):
        self.profiles = dict(profiles)
        self.backends: Dict[str, Backend] = {}
        self.requests: List[ServedRequest] = []
        self.cost_samples: List[tuple] = []    # (t, provisioned units)

    # ------------------------------------------------------------- ClusterAPI
    def apply_allocation(self, t: float, units: Mapping[str, int]) -> None:
        target = {m: n for m, n in units.items() if n > 0}
        new_ready = [t]
        for m, n in target.items():
            b = self.backends.get(m)
            if b is not None:
                b.retire_at = float("inf")   # re-selected: cancel retirement
                if b.units != n:
                    self.backends[m] = b.resized(n, t)
                new_ready.append(self.backends[m].ready_at)
            else:
                nb = Backend(self.profiles[m], n, ready_at=t + self.profiles[m].rt)
                self.backends[m] = nb
                new_ready.append(nb.ready_at)
        switch_t = max(new_ready)
        for m, b in self.backends.items():
            if m not in target:
                b.retire_at = min(b.retire_at, switch_t)
        self.cost_samples.append(
            (t, sum(b.units for b in self.backends.values()
                    if b.retire_at == float("inf"))))

    def loaded_variants(self, t: float) -> Set[str]:
        return {m for m, b in self.backends.items() if b.ready(t)}

    def backlog(self, t: float) -> float:
        """Requests queued beyond the in-service set (for queue-aware mode)."""
        total = 0.0
        for b in self.backends.values():
            if b.retire_at <= t:
                continue
            waiting = sum(max(f - t, 0.0) for f in b.server_free)
            total += waiting / max(b.service_s, 1e-9)
        return total

    # ---------------------------------------------------------------- serving
    def submit(self, req: Request, backend: Optional[str]) -> bool:
        """ServingAPI parity with the real engine: a simulated request needs
        only its arrival time — prompt tokens don't affect queueing."""
        self.dispatch(req.arrival, backend or None)
        return True

    def step(self, now: float) -> int:
        """No-op: the DES serves synchronously at submit time."""
        return 0

    def drain(self, now: float) -> int:
        """No-op: nothing is ever left in flight between submits."""
        return 0

    def _purge(self, t: float) -> None:
        for m in [m for m, b in self.backends.items() if b.retire_at <= t]:
            del self.backends[m]

    def dispatch(self, arrival: float, backend_name: Optional[str]) -> None:
        self._purge(arrival)
        candidates = {m: b for m, b in self.backends.items()
                      if b.retire_at > arrival}
        if not candidates:
            self.requests.append(ServedRequest(arrival, arrival + 10.0,
                                               "none", 0.0))
            return
        b = candidates.get(backend_name) if backend_name else None
        if b is None or not b.ready(arrival):
            ready = {m: bb for m, bb in candidates.items() if bb.ready(arrival)}
            pool = ready or candidates
            name = min(pool, key=lambda m: pool[m].queue_delay(arrival))
            b = pool[name]
            backend_name = name
        start, done = b.serve_timed(arrival)
        self.requests.append(ServedRequest(arrival, done, backend_name,
                                           b.profile.accuracy,
                                           service_start=start))

    def dispatch_fanout(self, arrival: float, backend_names, accuracy: float
                        ) -> None:
        """Cocktail-style ensembling: the request runs on EVERY member;
        latency is the slowest member (majority vote needs all of them)."""
        self._purge(arrival)
        done = arrival + 10.0
        served = False
        start = 0.0
        for name in backend_names:
            b = self.backends.get(name)
            if b is None or b.retire_at <= arrival:
                continue
            s, d = b.serve_timed(arrival)
            done = max(done if served else arrival, d)
            start = min(start, s) if served else s   # earliest member start
            served = True
        if not served:
            self.dispatch(arrival, None)
            return
        self.requests.append(ServedRequest(arrival, done, "+".join(backend_names),
                                           accuracy, service_start=start))

    # ---------------------------------------------------------------- metrics
    def summarize(self, slo_ms: float, best_accuracy: float,
                  window_s: float = 10.0) -> Dict:
        """Paper evaluation summary (§6) via the shared metric helper."""
        return summarize_requests(
            [r.arrival for r in self.requests],
            [r.latency_ms for r in self.requests],
            [r.accuracy for r in self.requests],
            slo_ms=slo_ms, best_accuracy=best_accuracy,
            cost_samples=self.cost_samples, window_s=window_s,
            queue_ms=[r.queue_wait_ms for r in self.requests],
            service_ms=[r.service_ms for r in self.requests])
