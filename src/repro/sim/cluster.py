"""Discrete-event simulation of the serving cluster.

Each backend (variant, n units) is a c-server FIFO queue whose capacity
matches the profile exactly (Little's law):

    servers c   = max(1, round(th(n) · p(n)))        # concurrency in flight
    service s   = c / th(n)                          # per-request seconds
    => capacity = c / s = th(n), loaded latency ≈ p(n)

mirroring the paper's TF-Serving setup (inter-op parallelism = #cores,
batching off ⇒ concurrency ≈ cores).

Reconfiguration semantics (paper §5, incl. their zero-downtime VPA patch):
  * resizing a *running* variant applies after RESIZE_DELAY_S;
  * a *new* variant warms up until t + rt_m; while warming it receives no
    traffic — its quota spills onto the ready backends (overloading them,
    which is exactly the transient-SLO-violation dynamic the paper reports);
  * an old variant retires only once every newly created backend is ready
    (create-then-remove).

Replica fabric mode (``nodes=``): instead of one monolithic backend per
variant, the allocation materializes as a **placement of replicas across
nodes** via ``repro.cluster.ReplicaFabric`` — each replica is its own
c-server queue (true per-replica queues/servers), requests are routed
two-level (the dispatcher's variant choice, then a ``RoutingAPI`` replica
pick — power-of-two-choices least-outstanding by default), reconfiguration
is rolling create-then-remove at replica granularity, and faults
(``inject_fault``) kill nodes or degrade replicas. A node crash affects
dispatches from the crash instant forward; requests the DES already
scheduled keep their computed completions (synchronous-serve limitation,
noted in DESIGN.md §Cluster fabric).

Scheduling (``scheduler=``): the queue discipline mirrors the real engine's
scheduler layer (DESIGN.md §Scheduling) so controller experiments see the
same queueing semantics in DES and real execution. ``"fifo"`` (default)
serves at submit time in arrival order — the original behavior,
byte-for-byte. ``"edf"``/``"chunked"`` hold arrivals in per-backend
pending heaps and assign them to servers in **earliest-deadline-first**
order at each server-free instant — already-expired deadlines after every
still-feasible one (the engine's expired-last EDF), and only requests
already arrived by that instant are eligible (no lookahead). Chunked
prefill itself is a real-execution concern (DES service times are scalar),
so ``"chunked"`` maps to EDF ordering here; preemption is likewise
engine-only.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Set

import numpy as np

from repro.cluster.faults import FaultEvent
from repro.cluster.placement import Node
from repro.cluster.replicas import Replica, ReplicaFabric
from repro.cluster.router import ReplicaView, RoutingAPI, make_router
from repro.core.profiles import VariantProfile
from repro.obs import Observability
from repro.obs import trace as ev
from repro.obs.slo import slo_class_key
from repro.serving.api import Request, summarize_requests
from repro.serving.sched import make_scheduler

RESIZE_DELAY_S = 1.0
# Profiled th(n) is the *SLO-sustained* rate (the paper measures throughput at
# the point where P99 reaches the SLO). The raw service rate at saturation is
# slightly higher; the gap is what lets a backlog drain after a burst.
SERVICE_HEADROOM = 1.35


@dataclass
class Backend:
    profile: VariantProfile
    units: int
    ready_at: float
    retire_at: float = float("inf")
    slow_factor: float = 1.0     # heterogeneity / straggler multiplier
    server_free: List[float] = field(default_factory=list)   # heap

    def __post_init__(self):
        th = self.profile.throughput(self.units)
        p_s = self.profile.p99_ms(self.units) / 1000.0
        c = max(1, int(round(th * p_s)))
        self.capacity = th
        self.service_s = c / max(th * SERVICE_HEADROOM, 1e-9)
        if not self.server_free:
            self.server_free = [self.ready_at] * c
            heapq.heapify(self.server_free)

    def resized(self, n: int, t: float) -> "Backend":
        """Live resize: inherit the in-flight server queue; extra servers come
        online after RESIZE_DELAY_S; shrink keeps the earliest-free servers."""
        nb = Backend(self.profile, n, ready_at=self.ready_at,
                     slow_factor=self.slow_factor)  # resize never un-warms a
        # loading backend nor stalls a ready one
        c_new = len(nb.server_free)
        inherited = sorted(self.server_free)[:c_new]
        while len(inherited) < c_new:
            inherited.append(t + RESIZE_DELAY_S)
        nb.server_free = inherited
        heapq.heapify(nb.server_free)
        return nb

    def ready(self, t: float) -> bool:
        return self.ready_at <= t

    def queue_delay(self, t: float) -> float:
        return max(self.server_free[0] - t, 0.0)

    @property
    def effective_service_s(self) -> float:
        return self.service_s * self.slow_factor

    def outstanding(self, t: float) -> float:
        """Outstanding requests (queued + in service, fractional) — the
        router's least-outstanding signal."""
        s = max(self.effective_service_s, 1e-9)
        return sum(max(f - t, 0.0) for f in self.server_free) / s

    def queued(self, t: float) -> float:
        """Queued-not-in-service requests (the ``ClusterAPI.backlog``
        semantics): per server, whole service times of work beyond the
        request currently in service."""
        s = max(self.effective_service_s, 1e-9)
        return float(sum(int((f - t) / s - 1e-9)
                         for f in self.server_free if f - t > s))

    def serve_timed(self, arrival: float) -> tuple:
        """Grab a server; returns (service_start, completion)."""
        free = heapq.heappop(self.server_free)
        start = max(arrival, free, self.ready_at)
        done = start + self.effective_service_s
        heapq.heappush(self.server_free, done)
        return start, done

    def serve(self, arrival: float) -> float:
        return self.serve_timed(arrival)[1]


@dataclass
class ServedRequest:
    arrival: float
    completion: float
    backend: str
    accuracy: float
    service_start: float = 0.0   # 0.0 = dropped/never served
    slo_ms: float = 0.0          # per-request SLO (goodput metric); <=0=none

    @property
    def latency_ms(self) -> float:
        return (self.completion - self.arrival) * 1000.0

    @property
    def queue_wait_ms(self) -> float:
        if self.service_start <= 0.0:
            return 0.0
        return max(self.service_start - self.arrival, 0.0) * 1000.0

    @property
    def service_ms(self) -> float:
        if self.service_start <= 0.0:
            return self.latency_ms
        return max(self.completion - self.service_start, 0.0) * 1000.0


class SimCluster:
    """Discrete-event implementation of the shared ``ClusterAPI``/
    ``ServingAPI`` (``repro.serving.api``) — the same contract the real
    ``InProcessServingEngine`` implements, so controllers and the experiment
    harness drive either interchangeably.

    Without ``nodes`` the cluster is the paper's setup: one backend per
    variant. With ``nodes`` the replica fabric activates (see module
    docstring): ``placement`` picks the policy (``"first-fit"``/``"spread"``
    or a ``PlacementPolicy``), ``router`` the replica-level routing
    (``"p2c"``/``"least"``/``"rr"``/``"random"`` or a ``RoutingAPI``), and
    ``replica_size`` the max units per replica.
    """

    def __init__(self, profiles: Mapping[str, VariantProfile],
                 nodes: Optional[Sequence[Node]] = None,
                 placement="first-fit", router="p2c",
                 replica_size: int = 4, scheduler="fifo",
                 trace: bool = False, obs: Optional[Observability] = None):
        self.profiles = dict(profiles)
        self.backends: Dict[str, Backend] = {}
        self.requests: List[ServedRequest] = []
        self.cost_samples: List[tuple] = []    # (t, provisioned units)
        # observability parity with the engine (DESIGN.md §Observability):
        # the DES publishes the SAME metric names (requests.*, request.*,
        # router.*) into its registry, and with trace=True stamps lifecycle
        # span events in simulated time — so controller experiments read one
        # metric surface regardless of backend. Simulated requests have no
        # ticks, so the DES emits no TickRecords.
        self.obs = obs if obs is not None else Observability(trace=trace)
        self.metrics = self.obs.metrics
        self.tracer = self.obs.tracer
        # rolling windows (obs.windows): fed at completion in _record with
        # the SAME names as the engine's _obs_complete, keyed by virtual
        # time — burn-rate monitors read either backend identically
        self.windows = self.obs.windows
        # queue discipline mirroring the engine's scheduler layer (module
        # docstring): "fifo" serves at submit; "edf"/"chunked" hold arrivals
        # in per-backend pending heaps assigned deadline-first
        self.sched = make_scheduler(scheduler)
        self._edf = self.sched.name != "fifo"
        # per backend key: two heaps of (deadline, seq, arrival, slo_ms,
        # rid) — still-feasible vs already-expired entries (the engine's EDF
        # serves expired requests LAST; see _flush_pending) — plus an
        # arrival heap and a live-seq set for lazy deletion (seq is unique,
        # so heap comparison never reaches the trailing rid)
        self._pending: Dict[str, Dict[str, object]] = {}
        self._pseq = itertools.count()
        self.fabric: Optional[ReplicaFabric] = None
        self.router: Optional[RoutingAPI] = None
        if nodes is not None:
            self.fabric = ReplicaFabric(
                nodes, policy=placement, replica_size=replica_size,
                rt_fn=lambda m: self.profiles[m].rt)
            self.router = make_router(router, metrics=self.metrics)

    # ------------------------------------------------------------- ClusterAPI
    def apply_allocation(self, t: float, units: Mapping[str, int]) -> None:
        if self.fabric is not None:
            self._apply_fabric(t, units)
            return
        target = {m: n for m, n in units.items() if n > 0}
        new_ready = [t]
        for m, n in target.items():
            b = self.backends.get(m)
            if b is not None:
                b.retire_at = float("inf")   # re-selected: cancel retirement
                if b.units != n:
                    self.backends[m] = b.resized(n, t)
                new_ready.append(self.backends[m].ready_at)
            else:
                nb = Backend(self.profiles[m], n, ready_at=t + self.profiles[m].rt)
                self.backends[m] = nb
                new_ready.append(nb.ready_at)
        switch_t = max(new_ready)
        for m, b in self.backends.items():
            if m not in target:
                b.retire_at = min(b.retire_at, switch_t)
        self.cost_samples.append(
            (t, sum(b.units for b in self.backends.values()
                    if b.retire_at == float("inf"))))

    def _apply_fabric(self, t: float, units: Mapping[str, int]) -> None:
        self.fabric.purge(t)
        tr = self.fabric.apply(t, units)
        for rep in tr.created:
            self._attach_handle(rep)
        for rep in tr.retired:
            rep.handle.retire_at = rep.retire_at
        self.cost_samples.append((t, self.fabric.provisioned_units()))

    def _attach_handle(self, rep: Replica) -> None:
        b = Backend(self.profiles[rep.variant], rep.units,
                    ready_at=rep.ready_at, slow_factor=rep.slow_factor)
        rep.handle = b

    def loaded_variants(self, t: float) -> Set[str]:
        if self.fabric is not None:
            return set(self.fabric.variants_ready(t))
        return {m for m, b in self.backends.items() if b.ready(t)}

    def backlog(self, t: float) -> float:
        """Queued-not-in-service requests (shared ``ClusterAPI`` semantics:
        admitted work not yet being processed — see ``serving/api.py``).
        Under deadline-aware scheduling, still-pending (unassigned) requests
        count too — they are admitted work waiting for a server."""
        if self.fabric is not None:
            return sum(r.handle.queued(t) for r in self.fabric.replicas.values()
                       if r.live(t)) + self._pending_depth()
        return sum(b.queued(t) for b in self.backends.values()
                   if b.retire_at > t) + self._pending_depth()

    def capacity_factor(self, t: float) -> float:
        """Fraction of the target allocation actually live (1.0 without a
        fabric — monolithic backends don't fail)."""
        return self.fabric.capacity_factor(t) if self.fabric is not None else 1.0

    def mark_warm(self, variants: Optional[Sequence[str]] = None,
                  t: float = 0.0) -> None:
        """Force readiness at ``t`` (experiment-harness warm start; call
        before traffic — it also clears the warm-up hold on each server)."""
        def warm(b: Backend) -> None:
            b.ready_at = min(b.ready_at, t)
            b.server_free = [min(f, t) for f in b.server_free]
            heapq.heapify(b.server_free)
        if self.fabric is not None:
            self.fabric.mark_ready(t, variants)
            for r in self.fabric.replicas.values():
                if variants is None or r.variant in variants:
                    warm(r.handle)
            return
        for m, b in self.backends.items():
            if variants is None or m in variants:
                warm(b)

    # ----------------------------------------------------------------- faults
    def inject_fault(self, t: float, event: FaultEvent) -> None:
        """Apply one ``repro.cluster.faults`` event (fabric mode only)."""
        if self.fabric is None:
            raise RuntimeError("fault injection requires the replica fabric "
                               "(construct SimCluster with nodes=)")
        if event.kind == "node_crash":
            self.fabric.crash_node(t, event.target)
        elif event.kind == "node_recover":
            self.fabric.recover_node(t, event.target)
        elif event.kind in ("replica_slowdown", "replica_restore"):
            factor = event.factor if event.kind == "replica_slowdown" else 1.0
            if self.fabric.slow_replica(t, event.target, factor):
                rep = self.fabric.replicas[event.target]
                rep.handle.slow_factor = rep.slow_factor
        if self.obs.flight is not None:
            self.obs.flight.trigger(f"fault_{event.kind}", t,
                                    extra={"target": event.target,
                                           "factor": event.factor})

    # ---------------------------------------------------------------- serving
    def submit(self, req: Request, backend: Optional[str]) -> bool:
        """ServingAPI parity with the real engine: a simulated request needs
        only its arrival time (and SLO, for deadline-aware scheduling) —
        prompt tokens don't affect queueing."""
        self.dispatch(req.arrival, backend or None, slo_ms=req.slo_ms,
                      rid=req.rid)
        return True

    def _record(self, sr: ServedRequest, rid: Optional[int] = None) -> None:
        """The ONE sink for served requests: append + publish the same
        registry metrics the engine's ``_obs_complete`` emits, and (tracing
        on, rid known) the queued/admitted/complete span events in simulated
        time. ``service_start == 0`` marks a request the DES never served
        (no live backend) — counted as dropped, mirroring engine drops."""
        self.requests.append(sr)
        m = self.metrics
        m.inc("requests.completed")
        lat = sr.latency_ms
        m.observe("request.latency_ms", lat)
        m.observe("request.queue_wait_ms", sr.queue_wait_ms)
        m.observe("request.service_ms", sr.service_ms)
        dropped = sr.service_start <= 0.0
        good = not dropped and (sr.slo_ms <= 0 or lat <= sr.slo_ms)
        if dropped:
            m.inc("requests.dropped")
        elif good:
            m.inc("requests.goodput_ok")
        w = self.windows
        if w.on:     # windowed mirror of the above, keyed at virtual time
            tc = sr.completion
            w.inc("requests.completed", tc)
            w.observe("request.latency_ms", tc, lat)
            cls = slo_class_key(sr.slo_ms)
            if dropped:
                w.inc("requests.dropped", tc)
            elif good:
                w.inc("requests.goodput_ok", tc)
            w.inc(f"slo.class.{cls}.{'good' if good else 'bad'}", tc)
        if self.tracer.on and rid is not None:
            self.tracer.event(rid, ev.QUEUED, sr.arrival, backend=sr.backend)
            if sr.service_start > 0.0:
                self.tracer.event(rid, ev.ADMITTED, sr.service_start,
                                  backend=sr.backend)
            self.tracer.event(rid, ev.COMPLETE, sr.completion,
                              backend=sr.backend, latency_ms=lat)

    def step(self, now: float) -> int:
        """No-op: the DES serves synchronously at submit time."""
        return 0

    def drain(self, now: float) -> int:
        """FIFO: no-op (nothing is left in flight between submits). EDF:
        assign every still-pending request to its backend's servers."""
        if not self._edf:
            return 0
        n0 = len(self.requests)
        self._flush_all()
        return len(self.requests) - n0

    # ----------------------------------------- deadline-aware pending queues
    @staticmethod
    def _pop_eligible(heap: List[tuple], live: set, t: float):
        """Earliest-deadline entry with ``arrival <= t``, removed from the
        heap; None if no such entry. Dead (already-assigned) tops are
        dropped lazily. A top that arrived after ``t`` falls back to a
        linear scan — rare, because flushes run at every dispatch so pending
        arrivals almost always precede the assignment instant."""
        while heap and heap[0][1] not in live:
            heapq.heappop(heap)
        if not heap:
            return None
        if heap[0][2] <= t:
            return heapq.heappop(heap)
        elig = [e for e in heap if e[1] in live and e[2] <= t]
        if not elig:
            return None
        e = min(elig)
        heap.remove(e)
        heapq.heapify(heap)
        return e

    def _flush_pending(self, key: str, b: Backend, upto: float,
                       accuracy: float) -> None:
        """Assign pending requests to ``b``'s servers up to time ``upto``.
        At each assignment instant — the later of the earliest-free server
        and the earliest pending arrival — the earliest-deadline request
        *already arrived by that instant* is served, with already-expired
        deadlines served after every still-feasible one (the engine's
        ``_edf_key`` semantics: spending a server on a hopeless request
        before a feasible one converts one violation into two). No
        lookahead: later arrivals were not in the queue when the server
        came free, whatever their deadline."""
        pend = self._pending.get(key)
        if not pend:
            return
        feas, exp, arr, live = (pend["feas"], pend["exp"], pend["arr"],
                                pend["live"])
        while live:
            t_free = max(b.server_free[0], b.ready_at)
            while arr and arr[0][1] not in live:
                heapq.heappop(arr)
            t_assign = max(t_free, arr[0][0])
            if t_assign > upto:
                break
            # deadlines that have passed by the assignment instant migrate
            # to the expired heap (one-way: t_assign is non-decreasing)
            while feas:
                if feas[0][1] not in live:
                    heapq.heappop(feas)
                elif feas[0][0] <= t_assign:
                    heapq.heappush(exp, heapq.heappop(feas))
                else:
                    break
            e = self._pop_eligible(feas, live, t_assign)
            if e is None:
                e = self._pop_eligible(exp, live, t_assign)
            assert e is not None   # the min-arrival live entry is eligible
            live.discard(e[1])
            start, done = b.serve_timed(e[2])
            self._record(ServedRequest(e[2], done, key, accuracy,
                                       service_start=start, slo_ms=e[3]),
                         rid=e[4])

    def _enqueue_pending(self, key: str, arrival: float, slo_ms: float,
                         rid: Optional[int] = None) -> None:
        dl = arrival + slo_ms / 1000.0 if slo_ms > 0 else float("inf")
        pend = self._pending.setdefault(
            key, {"feas": [], "exp": [], "arr": [], "live": set()})
        seq = next(self._pseq)
        heapq.heappush(pend["feas"], (dl, seq, arrival, slo_ms, rid))
        heapq.heappush(pend["arr"], (arrival, seq))
        pend["live"].add(seq)

    def _flush_all(self) -> None:
        for key, pend in self._pending.items():
            if not pend["live"]:
                continue
            if self.fabric is not None:
                rep = self.fabric.replicas.get(key)
                if rep is not None and rep.handle is not None:
                    self._flush_pending(key, rep.handle, float("inf"),
                                        self.profiles[rep.variant].accuracy)
                    continue
            elif key in self.backends:
                b = self.backends[key]
                self._flush_pending(key, b, float("inf"), b.profile.accuracy)
                continue
            live = pend["live"]          # backend gone: orphaned pendings
            for e in list(pend["feas"]) + list(pend["exp"]):
                if e[1] in live:
                    self._record(ServedRequest(e[2], e[2] + 10.0,
                                               "none", 0.0, slo_ms=e[3]),
                                 rid=e[4])
            pend["feas"].clear()
            pend["exp"].clear()
            pend["arr"].clear()
            live.clear()

    def _pending_depth(self) -> float:
        return float(sum(len(p["live"]) for p in self._pending.values()))

    def _purge(self, t: float) -> None:
        for m in [m for m, b in self.backends.items() if b.retire_at <= t]:
            b = self.backends[m]
            # a retiring backend first serves what was assigned to it —
            # accepted work is never dropped by a switch (engine parity)
            self._flush_pending(m, b, float("inf"), b.profile.accuracy)
            del self.backends[m]

    def dispatch(self, arrival: float, backend_name: Optional[str],
                 slo_ms: float = 0.0, rid: Optional[int] = None) -> None:
        self.metrics.inc("requests.submitted")
        if self.windows.on:
            self.windows.inc("requests.submitted", arrival)
        if self.fabric is not None:
            self._dispatch_fabric(arrival, backend_name, slo_ms, rid=rid)
            return
        self._purge(arrival)
        candidates = {m: b for m, b in self.backends.items()
                      if b.retire_at > arrival}
        if not candidates:
            self._record(ServedRequest(arrival, arrival + 10.0,
                                       "none", 0.0, slo_ms=slo_ms), rid=rid)
            return
        b = candidates.get(backend_name) if backend_name else None
        if b is None or not b.ready(arrival):
            ready = {m: bb for m, bb in candidates.items() if bb.ready(arrival)}
            pool = ready or candidates
            name = min(pool, key=lambda m: pool[m].queue_delay(arrival))
            b = pool[name]
            backend_name = name
        if self._edf:
            self._enqueue_pending(backend_name, arrival, slo_ms, rid=rid)
            self._flush_pending(backend_name, b, arrival, b.profile.accuracy)
            return
        start, done = b.serve_timed(arrival)
        self._record(ServedRequest(arrival, done, backend_name,
                                   b.profile.accuracy, service_start=start,
                                   slo_ms=slo_ms), rid=rid)

    # ----------------------------------------------------- two-level routing
    def _pick_replica(self, variant: str, arrival: float) -> Optional[Replica]:
        """Level 2 of two-level routing: the ``RoutingAPI`` picks among the
        variant's ready replicas (fall back to warming ones — service then
        waits for readiness, the same spill the monolithic sim models)."""
        reps = self.fabric.ready_replicas(variant, arrival) or \
            [r for r in self.fabric.group(variant) if r.live(arrival)]
        if not reps:
            return None
        views = [ReplicaView(r.rid, r.handle.outstanding(arrival), r.units)
                 for r in reps]
        rid = self.router.pick(views)
        return self.fabric.replicas[rid]

    def _dispatch_fabric(self, arrival: float, backend_name: Optional[str],
                         slo_ms: float = 0.0,
                         rid: Optional[int] = None) -> None:
        self.fabric.purge(arrival)
        live = [r for r in self.fabric.replicas.values() if r.live(arrival)]
        if not live:
            self._record(ServedRequest(arrival, arrival + 10.0,
                                       "none", 0.0, slo_ms=slo_ms), rid=rid)
            return
        variant = backend_name
        ready = [r for r in live if r.ready(arrival)]
        if variant is None or not any(r.variant == variant for r in ready):
            # dispatcher quota points at a warming/retired/unknown variant:
            # spill to the ready variant whose best replica frees first
            # (legacy fallback — the transient-overload dynamic of §5)
            pool = ready or live
            variant = min(pool,
                          key=lambda r: r.handle.queue_delay(arrival)).variant
        rep = self._pick_replica(variant, arrival)
        if self._edf:
            self._enqueue_pending(rep.rid, arrival, slo_ms, rid=rid)
            self._flush_pending(rep.rid, rep.handle, arrival,
                                self.profiles[rep.variant].accuracy)
            return
        start, done = rep.handle.serve_timed(arrival)
        self._record(ServedRequest(
            arrival, done, rep.rid, self.profiles[rep.variant].accuracy,
            service_start=start, slo_ms=slo_ms), rid=rid)

    def dispatch_fanout(self, arrival: float, backend_names, accuracy: float
                        ) -> None:
        """Cocktail-style ensembling: the request runs on EVERY member;
        latency is the slowest member (majority vote needs all of them)."""
        if self.fabric is not None:
            self._dispatch_fanout_fabric(arrival, backend_names, accuracy)
            return
        self._purge(arrival)
        done = arrival + 10.0
        served = False
        start = 0.0
        for name in backend_names:
            b = self.backends.get(name)
            if b is None or b.retire_at <= arrival:
                continue
            s, d = b.serve_timed(arrival)
            done = max(done if served else arrival, d)
            start = min(start, s) if served else s   # earliest member start
            served = True
        if not served:
            self.dispatch(arrival, None)
            return
        self.metrics.inc("requests.submitted")
        if self.windows.on:
            self.windows.inc("requests.submitted", arrival)
        self._record(ServedRequest(arrival, done, "+".join(backend_names),
                                   accuracy, service_start=start))

    def _dispatch_fanout_fabric(self, arrival: float, backend_names,
                                accuracy: float) -> None:
        self.fabric.purge(arrival)
        done = arrival + 10.0
        served = False
        start = 0.0
        members = []
        for name in backend_names:
            rep = self._pick_replica(name, arrival)
            if rep is None:
                continue
            s, d = rep.handle.serve_timed(arrival)
            done = max(done if served else arrival, d)
            start = min(start, s) if served else s
            served = True
            members.append(rep.rid)
        if not served:
            self.dispatch(arrival, None)
            return
        self.metrics.inc("requests.submitted")
        if self.windows.on:
            self.windows.inc("requests.submitted", arrival)
        self._record(ServedRequest(arrival, done, "+".join(members),
                                   accuracy, service_start=start))

    # ---------------------------------------------------------------- metrics
    def summarize(self, slo_ms: float, best_accuracy: float,
                  window_s: float = 10.0) -> Dict:
        """Paper evaluation summary (§6) via the shared metric helper."""
        if self._edf:
            self._flush_all()            # score still-pending work too
        return summarize_requests(
            [r.arrival for r in self.requests],
            [r.latency_ms for r in self.requests],
            [r.accuracy for r in self.requests],
            slo_ms=slo_ms, best_accuracy=best_accuracy,
            cost_samples=self.cost_samples, window_s=window_s,
            queue_ms=[r.queue_wait_ms for r in self.requests],
            service_ms=[r.service_ms for r in self.requests],
            slo_list_ms=[r.slo_ms for r in self.requests])
