"""Serving layer: the shared ClusterAPI contract and its real-execution
implementation (see DESIGN.md §Continuous batching).

Only the light-weight protocol module is imported eagerly — the real engine
(``repro.serving.engine``) pulls in JAX and the model stack, which the
numpy-only simulator path must not pay for.
"""
from repro.serving.api import (ClusterAPI, Request,  # noqa: F401
                               SchedulerAPI, ServingAPI, summarize_requests)
from repro.serving.sched import make_scheduler  # noqa: F401
