"""Shared wall-clock serving loop for the real-execution drivers.

``examples/serve_autoscale.py`` and ``repro.launch.serve`` both replay a
synthetic load curve against an ``InProcessServingEngine`` behind the
InfAdapter control loop; this module holds the one copy of that loop so the
two drivers can't drift. Poisson arrivals are scaled by the *measured* tick
duration, so offered load tracks λ(t) regardless of how fast the engine
ticks.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import numpy as np

from repro.serving.api import Request, ServingAPI


def run_serving_loop(engine: ServingAPI, ctrl, *, seconds: float,
                     interval: float, load_fn: Callable[[float], float],
                     seed: int = 0, prompt_len: int = 16, max_new: int = 8,
                     vocab: int = 256, tick_sleep: float = 0.05,
                     faults=None,
                     log: Optional[Callable[[str], None]] = print) -> int:
    """Drive ``engine`` under ``ctrl`` for ``seconds`` of wall-clock time.

    ``load_fn(now)`` gives the offered rate λ (req/s) at elapsed time
    ``now``. The controller steps every ``interval`` seconds; the engine is
    ticked (admission + one decode chunk) every ``tick_sleep``, and drained
    before returning. ``faults`` (a ``repro.cluster.faults.FaultSchedule``
    with event times in elapsed seconds) is injected into fabric-backed
    engines as wall-clock time passes. Returns the number of requests
    submitted.
    """
    rng = np.random.default_rng(seed)
    t_start = time.time()
    rid = 0
    next_ctrl = 0.0
    last = 0.0
    while True:
        now = time.time() - t_start
        if now > seconds:
            break
        if faults is not None and faults.next_t() <= now:
            for ev in faults.apply_due(now, engine):
                if log is not None:
                    log(f"  t={now:5.1f}s FAULT {ev.kind} {ev.target}")
        if now >= next_ctrl:
            ctrl.monitor.advance_to(now)
            d = ctrl.step(now, engine)
            if log is not None:
                active = {k: v for k, v in d.allocation.units.items() if v}
                log(f"  t={now:5.1f}s predicted={d.predicted_load:5.1f} rps "
                    f"backlog={engine.backlog(now):3.0f} -> {active}")
            next_ctrl += interval
        lam = load_fn(now)
        for _ in range(rng.poisson(lam * max(now - last, 1e-3))):
            ctrl.monitor.record(now, 1)
            engine.submit(
                Request(rid=rid,
                        tokens=rng.integers(0, vocab, prompt_len).astype(np.int64),
                        max_new=max_new, arrival=time.time()),
                ctrl.dispatcher.next_backend())
            rid += 1
        last = now
        engine.step(now)   # one engine tick: admit into free slots + decode
        time.sleep(tick_sleep)
    engine.drain(seconds)  # finish whatever is still queued/in flight
    return rid


def rise_fall_load(seconds: float, lo: float = 4.0, hi: float = 32.0,
                   ) -> Callable[[float], float]:
    """The drivers' synthetic λ(t): a sin²-shaped ramp up then down."""
    def load(now: float) -> float:
        return lo + (hi - lo) * float(np.sin(np.pi * now / seconds) ** 2)
    return load
