"""Shared wall-clock serving loop for the real-execution drivers.

``examples/serve_autoscale.py`` and ``repro.launch.serve`` both replay a
load curve against an ``InProcessServingEngine`` behind the InfAdapter
control loop; this module holds the one copy of that loop so the two
drivers can't drift. Poisson arrivals are scaled by the *measured* tick
duration, so offered load tracks λ(t) regardless of how fast the engine
ticks.

Clock domains: every latency-bearing stamp — ``Request.arrival`` here,
``service_start``/``completion`` inside the engine — is taken from the
**engine's own clock** (``engine.clock``, ``time.time`` by default), so
queue waits and latencies always subtract same-domain values. Construct the
engine with ``clock=ElapsedClock()`` to put those stamps on the loop's
elapsed-seconds timeline (the domain control steps, fault schedules, and
the monitor already use); the loop resets that clock at t=0.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import numpy as np

from repro.obs.audit import attach_from_requests
from repro.serving.api import Request, ServingAPI


class ElapsedClock:
    """Callable clock returning seconds since construction (or the latest
    ``reset``). Hand one to ``InProcessServingEngine(clock=...)`` so every
    request stamp shares the serving loop's elapsed-time domain instead of
    absolute epoch seconds."""

    def __init__(self):
        self.t0 = time.time()

    def reset(self) -> None:
        self.t0 = time.time()

    def __call__(self) -> float:
        return time.time() - self.t0


def trace_load(rate: np.ndarray, scale: float = 1.0,
               repeat: bool = False) -> Callable[[float], float]:
    """λ(t) from a recorded per-second rate trace (``repro.data.traces``):
    second ``int(now)`` of the trace, scaled by ``scale`` (smoke-size a
    Twitter-shaped trace down to what a CPU engine sustains). ``repeat``
    wraps around instead of holding the last second."""
    arr = np.asarray(rate, float)
    assert len(arr) > 0

    def load(now: float) -> float:
        i = int(max(now, 0.0))
        i = i % len(arr) if repeat else min(i, len(arr) - 1)
        return float(arr[i]) * scale
    return load


def run_serving_loop(engine: ServingAPI, ctrl, *, seconds: float,
                     interval: float, load_fn: Callable[[float], float],
                     seed: int = 0, prompt_len: int = 16, max_new: int = 8,
                     vocab: int = 256, tick_sleep: float = 0.05,
                     faults=None, slo_ms: float = 0.0,
                     slo_monitor=None,
                     log: Optional[Callable[[str], None]] = print) -> int:
    """Drive ``engine`` under ``ctrl`` for ``seconds`` of wall-clock time.

    ``load_fn(now)`` gives the offered rate λ (req/s) at elapsed time
    ``now`` (see ``trace_load`` to replay a recorded trace). The controller
    steps every ``interval`` seconds; the engine is ticked (admission + one
    decode chunk) every ``tick_sleep``, and drained before returning.
    ``faults`` (a ``repro.cluster.faults.FaultSchedule`` with event times in
    elapsed seconds) is injected into fabric-backed engines as wall-clock
    time passes. ``slo_ms`` stamps each request's deadline (deadline-aware
    schedulers and the goodput metric read it). Returns the number of
    requests submitted.

    ``slo_monitor`` (a ``repro.obs.slo.SLOMonitor`` over the engine's
    windowed metrics) turns on the online reaction path: every iteration
    the monitor's burn-rate rules are checked and ``ctrl.maybe_react`` is
    called, so a controller wired with ``burn_alerts=`` re-solves on a
    burn-rate breach *between* interval steps. Without it the loop is
    purely interval-driven (unchanged legacy behavior).

    Arrivals are stamped from the engine's clock — the same clock the
    engine stamps ``service_start``/``completion`` from — so latencies and
    queue waits never mix clock domains (regression-tested).
    """
    rng = np.random.default_rng(seed)
    clk = getattr(engine, "clock", time.time)
    if isinstance(clk, ElapsedClock):
        clk.reset()          # elapsed stamps align with the loop's t=0
    t_start = time.time()
    rid = 0
    next_ctrl = 0.0
    last = 0.0
    while True:
        now = time.time() - t_start
        if now > seconds:
            break
        if faults is not None and faults.next_t() <= now:
            # commit the in-flight async tick before the fleet mutates:
            # fault handling (crash re-submission, drain) must see fully
            # committed slot state, not one tick of lagged bookkeeping
            flush = getattr(engine, "flush_pending", None)
            if flush is not None:
                flush(now)
            for ev in faults.apply_due(now, engine):
                if log is not None:
                    log(f"  t={now:5.1f}s FAULT {ev.kind} {ev.target}")
        if now >= next_ctrl:
            ctrl.monitor.advance_to(now)
            d = ctrl.step(now, engine)
            if log is not None:
                active = {k: v for k, v in d.allocation.units.items() if v}
                log(f"  t={now:5.1f}s predicted={d.predicted_load:5.1f} rps "
                    f"backlog={engine.backlog(now):3.0f} -> {active}")
            next_ctrl += interval
        lam = load_fn(now)
        for _ in range(rng.poisson(lam * max(now - last, 1e-3))):
            ctrl.monitor.record(now, 1)
            engine.submit(
                Request(rid=rid,
                        tokens=rng.integers(0, vocab, prompt_len).astype(np.int64),
                        max_new=max_new, arrival=clk(), slo_ms=slo_ms),
                ctrl.dispatcher.next_backend())
            rid += 1
        last = now
        engine.step(now)   # one engine tick: admit into free slots + decode
        # the burn-rate check runs AFTER the tick's commit phase (with
        # async_tick, step() commits the previous tick's completions before
        # returning), so mid-interval alerts only ever see fully-committed
        # windows — never a tick of half-applied completions
        if slo_monitor is not None:
            fired = slo_monitor.check(now)
            if fired:
                if log is not None:
                    for a in fired:
                        log(f"  t={now:5.1f}s BURN slo_class={a.slo_class} "
                            f"fast={a.burn_fast:.1f}x slow={a.burn_slow:.1f}x")
                ctrl.monitor.advance_to(now)
                d = ctrl.maybe_react(now, engine)
                if d is not None and log is not None:
                    active = {k: v for k, v in d.allocation.units.items() if v}
                    log(f"  t={now:5.1f}s re-solve (burn_rate) -> {active}")
            flight = getattr(engine, "obs", None)
            flight = flight.flight if flight is not None else None
            if flight is not None:
                flight.snap_metrics(now, engine.obs.metrics)
        time.sleep(tick_sleep)
    engine.drain(seconds)  # finish whatever is still queued/in flight
    # Close the audit loop: bucket realized latencies/goodput back onto the
    # controller decisions that governed them (predicted vs measured).
    attach_from_requests(getattr(ctrl, "audit", None),
                         getattr(engine, "done", ()),
                         default_slo_ms=slo_ms)
    return rid


def rise_fall_load(seconds: float, lo: float = 4.0, hi: float = 32.0,
                   ) -> Callable[[float], float]:
    """The drivers' synthetic λ(t): a sin²-shaped ramp up then down."""
    def load(now: float) -> float:
        return lo + (hi - lo) * float(np.sin(np.pi * now / seconds) ** 2)
    return load
