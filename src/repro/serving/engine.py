"""In-process JAX serving engine — the real-execution counterpart of the
discrete-event simulator. Implements the shared ``ClusterAPI``/``ServingAPI``
(see ``repro.serving.api``) so the same InfAdapter controller drives either.

Two execution modes per ``VariantBackend``:

  * ``"continuous"`` (default) — continuous batching over a persistent
    slot-based batch: the KV cache is allocated once at ``(max_batch, C)``
    and lives across requests; new requests join free slots at any decode
    step and finished sequences retire immediately, so a long generation
    never head-of-line-blocks a short one. The decode loop is jitted ONCE as
    a ``jax.lax.scan`` over ``decode_chunk`` steps — no per-token Python
    dispatch. Slot admission scatters a freshly prefilled cache into the
    resident batch cache with a single jitted masked-gather (no recompiles:
    every shape is fixed at warm-up).
  * ``"pump"`` — the legacy micro-batching path (per-chunk Python decode
    loop), kept as the baseline that ``benchmarks/bench_engine.py`` measures
    continuous batching against.

Two KV disciplines (``kv_cache=``): ``"dense"`` materializes the per-slot
``(max_batch, prompt_len + max_new)`` ring cache; ``"paged"`` replaces it
with a shared per-replica page pool (``PagedVariantBackend``): prefill is
right-sized to the actual arriving batch, decode attention is bounded by the
live context's page count instead of capacity, and pages are allocated at
admission / freed at retirement so admission respects memory-true capacity
(DESIGN.md §Paged KV cache).

Admission control: the engine keeps a bounded queue *per variant*
(backpressure — ``submit`` returns False and counts a rejection when the
queue is full), so ``backlog(t)`` reports true queue depth to the
queue-aware controller mode.

Scheduling (``scheduler=``, DESIGN.md §Scheduling): the order in which
queued requests claim slots — and how prefill interleaves with decode — is a
pluggable ``SchedulerAPI`` policy (``repro.serving.sched``): ``"fifo"``
(default, the legacy tick byte-for-byte), ``"edf"`` (earliest-deadline-first
admission over ``Request.deadline``), and ``"chunked"`` (EDF + chunked
prefill: prompts prefill in ``prefill_chunk``-token chunks interleaved with
decode chunks, so no resident decode step waits longer than one chunk —
no head-of-line blocking behind long prompts). ``preemption=`` optionally
retires deadline-hopeless in-service requests so feasible waiters run:
``"requeue"`` resumes them later via prefill continuation with every
generated token preserved; ``"drop"`` completes them early as ``dropped``.

Variant loading (init + jit warm-up of prefill, the decode chunk, and the
slot-admission scatter) happens on first use — that IS the readiness time
rt_m on this backend, measured rather than assumed.

Replica sharding (``nodes=``): the engine mounts the shared
``repro.cluster.ReplicaFabric`` and an allocation of n units materializes as
multiple ``VariantBackend`` *instances* per variant ("variant#i" replicas),
each with its own slots, KV cache, and bounded admission queue, placed on
nodes by the configured policy. ``submit`` routes two-level: the caller's
dispatcher picks the variant (solver-quota WRR), the engine's ``RoutingAPI``
picks the replica (power-of-two-choices least-outstanding by default).
``inject_fault`` supports node crashes (in-flight and queued requests of
killed replicas are re-submitted to survivors — retry semantics, latency
keeps the original arrival) and replica slow-downs (decode stretched by the
slow factor). The legacy single-backend-per-variant layout is untouched when
``nodes`` is omitted.

This engine is CPU-sized (smoke-scale variants) — it exists to run the
end-to-end example and integration tests with actual model execution; the
TPU-scale path is exercised by the dry-run. Set ``use_pallas=True`` to route
decode attention through the ``flash_decode`` Pallas kernel (interpret mode
off-TPU; see DESIGN.md).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import (Callable, Deque, Dict, List, Mapping, Optional, Sequence,
                    Set, Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.faults import FaultEvent
from repro.cluster.placement import Node
from repro.cluster.replicas import ReplicaFabric
from repro.cluster.router import ReplicaView, make_router
from repro.configs.base import ModelConfig
from repro.models.attention import PagedKVCache
from repro.models.model import build_model
from repro.obs import Observability, TickRecord
from repro.obs import trace as ev
from repro.obs.slo import slo_class_key
from repro.serving.api import Request, summarize_requests
from repro.serving.sched import make_scheduler, migration_target

__all__ = ["Request", "VariantBackend", "PagedVariantBackend",
           "DraftPair", "InProcessServingEngine"]

# Batch axis of each cache leaf (k/v/conv/ssd carry a leading layer axis).
_CACHE_BATCH_AXIS = {"pos": 0, "k": 1, "v": 1, "conv": 1, "ssd": 1, "enc": 0}


@dataclass
class _PrefillJob:
    """Host-side progress of one slot's chunked prefill (DESIGN.md
    §Scheduling): ``seq`` is everything that must be in the cache before
    decode resumes — the prompt for a fresh request, prompt + all-but-last
    generated token for a preempted one (``resume_tok`` is that last token,
    fed to decode instead of the prefill argmax; ``gen`` seeds
    ``slot_tokens`` so no generated token is lost or duplicated)."""
    req: Request
    seq: np.ndarray               # tokens to prefill (int64)
    pos: int = 0                  # next seq index to feed
    resume_tok: Optional[int] = None
    gen: List[int] = field(default_factory=list)


@dataclass
class _PendingExec:
    """One dispatched-but-uncommitted exec phase (DESIGN.md §Async tick
    loop). ``toks`` is the un-synced device output — the decode chunk's
    ``(chunk, B)`` token matrix or the fused tick's ``(B,)`` ``cur_tok``
    snapshot; neither is in any jit's donation set, so holding the
    reference across the next dispatch is safe while the donated KV cache
    is updated in place underneath it. Everything value-*independent*
    (slot_remaining, positions, prefill progress) was already applied at
    dispatch time; ``commit_exec`` applies the value-*dependent* remainder
    (token appends, ``_finish``, slot retirement) one tick later, guarded
    by the ``(request identity, slot_gen)`` pair so a slot preempted or
    rebound inside the gap never absorbs stale tokens."""
    kind: str                                  # "decode" | "fused" | "spec"
    toks: object                               # un-synced device array
    dispatched_at: float                       # perf_counter at dispatch start
    t_dispatch: float                          # timeline clock at dispatch
    # (slot, req, slot_gen, take, finishing) — decode rows to append
    decode_items: List[Tuple] = field(default_factory=list)
    # (slot, req, slot_gen, resume_tok, gen_before, finishing) — rows whose
    # chunked prefill completed at dispatch; their first token is the fused
    # argmax (or the preserved resume token) read at commit
    fused_completions: List[Tuple] = field(default_factory=list)
    # (slot, req, slot_gen, base, round_no) — speculative rounds; ``toks``
    # is the packed (B, 2k+1) [drafts | verifier argmax] matrix and the
    # commit replays the device's acceptance rule on it (DraftPair.commit)
    spec_items: List[Tuple] = field(default_factory=list)


class VariantBackend:
    """One loaded model variant: params + jitted prefill/decode + slot state.

    The KV discipline is pluggable: this base class materializes the dense
    per-slot ring cache at ``(max_batch, prompt_len + max_new)``;
    ``PagedVariantBackend`` replaces it with the shared page pool (see
    DESIGN.md §Paged KV cache). The slot lifecycle, queueing, and retirement
    logic are shared — subclasses override ``_build_state`` (cache + jit
    warm-up, measured as readiness), ``_run_decode_chunk``, admission, and
    the ``_retire_slot`` hook."""

    def __init__(self, name: str, cfg: ModelConfig, accuracy: float,
                 max_batch: int = 8, prompt_len: int = 32, max_new: int = 16,
                 seed: int = 0, decode_chunk: int = 4,
                 use_pallas: bool = False, chunked: bool = False,
                 prefill_chunk_tokens: int = 16, preemption: str = "none",
                 prefix_sharing: bool = False,
                 cache_headroom: int = 0, build_chunked: bool = False,
                 clock: Callable[[], float] = time.time,
                 obs: Optional[Observability] = None):
        self.name = name
        # observability bundle (metrics registry + tracer) — the engine hands
        # its own down so all backends publish into one registry; hot paths
        # use the cached instrument refs, never the bundle
        self.obs = obs if obs is not None else Observability.disabled()
        self.metrics = self.obs.metrics
        self.tracer = self.obs.tracer
        self.windows = self.obs.windows
        # dispatch profiler (obs.profiler): the engine arms _fence_exec on
        # sampled ticks; _jit_exec then fences the exec-phase jit call and
        # leaves (dispatch_ms, device_ms) on exec_split for the TickRecord
        self._fence_exec = False
        self.exec_split: Optional[Tuple[float, float]] = None
        if use_pallas and not cfg.use_pallas:
            cfg = cfg.replace(use_pallas=True)
        self.cfg = cfg
        self.accuracy = accuracy
        self.max_batch = max_batch
        self.prompt_len = prompt_len
        self.max_new = max_new
        self.decode_chunk = max(1, min(decode_chunk, max_new))
        self.clock = clock       # every service/completion stamp uses this
        # chunked-prefill machinery is built when the scheduler interleaves
        # prefill chunks with decode, when preemption is on (resume = a
        # prefill continuation over prompt + preserved tokens), or when
        # prefix sharing is on (a shared-prefix admission prefills only the
        # novel tail — a continuation starting mid-sequence); right-sized
        # admission (true prompt length, not padded) only under the chunked
        # scheduler itself — resume under monolithic admission must rebuild
        # the padded cache it preempted (see admit_chunked)
        self.preemption = preemption
        self.prefix_sharing = prefix_sharing   # honored by paged backends
        self.right_sized = chunked
        self.chunked = chunked or preemption != "none" or prefix_sharing
        self.prefill_chunk_tokens = max(1, prefill_chunk_tokens)
        # cache_headroom: extra token capacity past prompt_len + max_new.
        # Speculative drafters need it — a draft scan writes up to k
        # positions past the last committed token, and on the dense ring a
        # write past capacity would wrap onto the row's own prompt. The
        # request budget (``_budget``) is NOT widened: headroom is
        # scratch space, never servable tokens.
        self.cache_headroom = max(0, cache_headroom)
        self.model = build_model(cfg)
        if self.chunked:
            assert self.model.supports_chunked_prefill(), \
                (f"scheduler needs prefill continuation, unsupported for "
                 f"config {cfg.name!r} (needs a pure-attention family "
                 f"without sliding window)")
        elif build_chunked and self.model.supports_chunked_prefill():
            # opportunistic: the engine wants the continuation machinery
            # (async-tick admission pipelining) but nothing *requires* it —
            # right_sized stays False, so admission still prefills the
            # zero-padded prompt and outputs bit-match the monolithic path
            self.chunked = True
        # speculative decoding: the engine attaches a DraftPair here when
        # this backend is the verifier of a drafter:verifier binding
        self._spec_pair: Optional["DraftPair"] = None
        self.units = 1
        self.slot_cap: Optional[int] = None   # units -> concurrency (enforced
        # only when the engine runs with enforce_units; see free_slots)
        self.slow_factor = 1.0   # straggler fault: decode stretched by this
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.slot_remaining = np.zeros((max_batch,), np.int64)
        self.slot_tokens: List[List[int]] = [[] for _ in range(max_batch)]
        # async tick loop (DESIGN.md §Async tick loop): the engine parks the
        # dispatched-but-uncommitted exec here between ticks; slot_gen is a
        # per-slot bind counter so a commit can detect preempt/rebind inside
        # the gap; _uncommitted_done marks slots finished by count at
        # dispatch whose tokens have not been read back yet (excluded from
        # further dispatch and from preemption, still occupying their slot
        # so admission headroom lags exactly one tick)
        self._pending: Optional[_PendingExec] = None
        self.slot_gen = [0] * max_batch
        self._uncommitted_done: Set[int] = set()
        self.commit_wait_ms = float("nan")   # blocked in the commit D2H read
        self.commit_gap_ms = float("nan")    # dispatch -> commit-read gap
        # host mirror of each bound row's device position (the paged backend
        # buckets on it; chunked fused ticks feed it as the continuation
        # offset) — maintained through admit/chunk/decode for bound rows
        self.slot_pos = np.zeros((max_batch,), np.int64)
        self._prefilling: Dict[int, _PrefillJob] = {}   # slot -> progress
        # prompt tokens this backend actually prefilled (monolithic admits
        # + continuation chunks) — the prefix_sharing bench's reduction
        # metric compares this between sharing on/off on the same workload
        self.prefill_tokens_total = 0
        t0 = time.time()
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self._build_state()                  # cache + jit warm-up = readiness
        if self.chunked:
            self._build_chunk_state()        # prefill-continuation jits too
        self.readiness_s = time.time() - t0

    def _build_state(self) -> None:
        """Dense KV discipline: one resident ``(max_batch, C)`` cache.

        The resident cache is **donated** to every jitted consumer (decode,
        decode chunk, admission merge): the engine always replaces
        ``self.cache`` with the call's result, so XLA may update the KV
        buffers in place instead of copying the whole capacity-sized cache
        every step (§Paged KV cache perf notes — the copy, not the math, was
        the dominant per-step cost at large C on CPU)."""
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(
                p, b, max_len=(self.prompt_len + self.max_new
                               + self.cache_headroom)))
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))
        self._decode_chunk = jax.jit(self._decode_chunk_fn,
                                     donate_argnums=(1,))
        self._admit_merge = jax.jit(self._admit_merge_fn, donate_argnums=(0,))

        # --- persistent slot state (continuous batching) ---
        # Warm-up compiles every jitted entry point (part of readiness).
        # Donated caches are chained call-to-call — a donated buffer is dead
        # after the call, so each step feeds the previous step's output.
        toks = jnp.zeros((self.max_batch, self.prompt_len), jnp.int32)
        zeros_tok = jnp.zeros((self.max_batch,), jnp.int32)
        logits, cache = self._prefill(self.params, {"tokens": toks})
        self.cur_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        _, cache = self._decode(self.params, cache, zeros_tok)
        _, cache, _ = self._decode_chunk(self.params, cache, self.cur_tok)
        _, fresh = self._prefill(self.params, {"tokens": toks})
        self.cache, self.cur_tok = self._admit_merge(
            cache, fresh, self.cur_tok, self.cur_tok,
            jnp.zeros((self.max_batch,), jnp.int32),
            jnp.zeros((self.max_batch,), bool))
        self.slot_req = [None] * self.max_batch          # warm-up left no state

    def _build_chunk_state(self) -> None:
        """Chunked-prefill machinery (built only when the scheduler or
        preemption needs it): ONE prefill-continuation jit, donated and
        warmed as part of readiness. It serves fused ticks — mid-prefill
        rows consume a chunk of prompt tokens while decoding rows consume
        their single current token (decode IS a 1-token continuation), so a
        tick never pays a prefill call *and* a decode call."""
        self._prefill_chunk = jax.jit(self._prefill_chunk_fn,
                                      donate_argnums=(1,))
        B, ck = self.max_batch, self.prefill_chunk_tokens
        zeros = jnp.zeros((B,), jnp.int32)
        self.cur_tok, self.cache = self._prefill_chunk(
            self.params, self.cache, self.cur_tok,
            jnp.zeros((B, ck), jnp.int32), zeros, zeros,
            jnp.zeros((B,), bool), jnp.zeros((B,), bool))

    # ------------------------------------------------------------- jitted fns
    def _chunk_scan(self, cache, tok, step_fn):
        """``decode_chunk`` greedy steps of ``step_fn(cache, tok)`` as one
        traced scan. Returns (next feed token (B,), cache, emitted tokens
        (chunk, B)). A chunk of 1 skips the scan: the scan carry
        double-buffers the whole cache per iteration, which donation cannot
        elide."""
        def body(carry, _):
            t, c = carry
            logits, c = step_fn(c, t)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return (nxt, c), nxt

        if self.decode_chunk == 1:
            (tok, cache), toks = body((tok, cache), None)
            return tok, cache, toks[None]
        (tok, cache), toks = jax.lax.scan(
            body, (tok, cache), None, length=self.decode_chunk)
        return tok, cache, toks

    def _decode_chunk_fn(self, params, cache, tok):
        return self._chunk_scan(
            cache, tok, lambda c, t: self.model.decode_step(params, c, t))

    def _model_prefill_chunk(self, params, cache, tokens, start, n_valid):
        """KV-discipline hook: the paged backend swaps in the pool form."""
        return self.model.prefill_chunk(params, cache, tokens, start, n_valid)

    def _prefill_chunk_fn(self, params, cache, cur_tok, tokens, start,
                          n_valid, set_mask, feed_mask):
        """One prefill-continuation chunk for every mid-prefill row, plus the
        first greedy token for rows whose prompt completes here
        (``set_mask``) — one executable regardless of which rows are
        prefilling. ``feed_mask`` rows (plain decodes riding the fused
        tick) take their input token from the device-side ``cur_tok``
        instead of the host matrix: bitwise the same value as the host's
        ``slot_tokens[s][-1]`` feed, but available without a D2H sync —
        what lets the async tick dispatch a fused step before the previous
        tick's tokens have been read back."""
        tokens = tokens.at[:, 0].set(
            jnp.where(feed_mask, cur_tok.astype(tokens.dtype), tokens[:, 0]))
        logits, cache = self._model_prefill_chunk(params, cache, tokens,
                                                  start, n_valid)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jnp.where(set_mask, tok, cur_tok), cache

    @staticmethod
    def _admit_merge_fn(cache, new_cache, cur_tok, new_tok, src, mask):
        """Scatter prefilled rows into the resident batch cache.

        ``src[i]`` is the row of ``new_cache`` destined for slot ``i``;
        ``mask[i]`` selects which slots actually receive it. Fixed shapes —
        compiles once regardless of how many requests join."""
        out = {}
        for key, old in cache.items():
            ax = _CACHE_BATCH_AXIS[key]
            nv = jnp.take(new_cache[key], src, axis=ax)
            m = mask.reshape((1,) * ax + (-1,) + (1,) * (old.ndim - ax - 1))
            out[key] = jnp.where(m, nv, old)
        tok = jnp.where(mask, jnp.take(new_tok, src), cur_tok)
        return out, tok

    # -------------------------------------------------------- pump-mode path
    def generate(self, prompts: np.ndarray, max_new: int) -> np.ndarray:
        """Legacy pump path: per-token Python decode loop over a micro-batch.

        prompts: (b, prompt_len), padded to max_batch internally."""
        b = prompts.shape[0]
        pad = self.max_batch - b
        # one H2D copy of the unpadded prompts, padded on device — the old
        # np.pad-then-asarray form materialized the padded matrix on host
        # first (a second full copy per admission)
        toks = jnp.pad(jnp.asarray(prompts), ((0, pad), (0, 0)))
        logits, cache = self._prefill(self.params, {"tokens": toks})
        outs = []
        tok = jnp.argmax(logits, axis=-1)
        for _ in range(max_new):
            outs.append(tok)
            logits, cache = self._decode(self.params, cache, tok)
            tok = jnp.argmax(logits, axis=-1)
        out = jnp.stack(outs, axis=1)
        return np.asarray(out[:b])

    # ------------------------------------------------- continuous-batch path
    @property
    def free_slots(self) -> List[int]:
        """Slots open for admission. With ``slot_cap`` set (the engine's
        ``enforce_units`` mode), allocation units bound live concurrency the
        same way the profiler's allocation sweep does — so measured th(n)
        describes the serving behaviour at allocation n, not just the
        profiling run."""
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        if self.slot_cap is not None:
            allow = min(self.slot_cap, self.max_batch) - self.active_slots
            return free[:max(allow, 0)]
        return free

    @property
    def active_slots(self) -> int:
        return sum(1 for r in self.slot_req if r is not None)

    def _admit_prefill(self, reqs: List[Request], rows: int):
        """Shared admission front half: stamp service start (everything
        before is queue wait), build the (rows, prompt_len) prompt matrix,
        prefill, take the first greedy token. Returns (first tokens (rows,)
        device, same as np, prefill cache)."""
        t_service = self.clock()
        for r in reqs:                   # service (= prefill + decode) begins
            r.service_start = t_service
            self.tracer.request_event(r, ev.ADMITTED, t_service,
                                      backend=self.name, mode="monolithic")
        prompts = np.zeros((rows, self.prompt_len), np.int64)
        for j, r in enumerate(reqs):
            prompts[j, :len(r.tokens)] = r.tokens[:self.prompt_len]
        self._count_prefill_tokens(len(reqs) * self.prompt_len)
        logits, new_cache = self._prefill(self.params,
                                          {"tokens": jnp.asarray(prompts)})
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return first, np.asarray(first), new_cache

    def _count_prefill_tokens(self, n: int) -> None:
        """The ONE increment site for prompt tokens this backend prefilled
        (monolithic admits + continuation chunks): the legacy attribute and
        the registry counter move together and can never drift apart."""
        self.prefill_tokens_total += n
        self.metrics.inc("engine.prefill_tokens_total", n)

    def _budget(self, r: Request) -> int:
        """A request's token budget is ``min(r.max_new, self.max_new)`` —
        the cache is provisioned for prompt_len + max_new tokens, so longer
        asks are truncated (``r.output`` carries the served length; the
        request object itself is never mutated)."""
        return min(r.max_new, self.max_new)

    def _bind_slot(self, r: Request, slot: int, tok0: int) -> None:
        self.slot_gen[slot] += 1
        self.slot_req[slot] = r
        self.slot_remaining[slot] = self._budget(r) - 1
        self.slot_tokens[slot] = [tok0]
        self.slot_pos[slot] = self.prompt_len     # device pos after prefill
        if self._spec_pair is not None:
            # monolithic admission prefilled the zero-padded prompt, so the
            # drafter must mirror exactly that sequence
            self._spec_pair.on_fresh(slot, self._effective_seq(r))

    def admit(self, reqs: List[Request], now: float) -> List[Request]:
        """Prefill ``reqs`` (≤ free slots) and join them to the batch.
        Requests whose budget is 1 complete at admission (their token is
        the prefill argmax). Returns requests finished here."""
        free = self.free_slots
        assert len(reqs) <= len(free)
        if not reqs:
            return []
        first, first_np, new_cache = self._admit_prefill(reqs, self.max_batch)
        src = np.zeros((self.max_batch,), np.int32)
        mask = np.zeros((self.max_batch,), bool)
        finished = []
        for j, r in enumerate(reqs):
            slot = free[j]
            src[slot], mask[slot] = j, True
            tok0 = int(first_np[j])
            if self._budget(r) <= 1:
                self._finish(r, [tok0], now)
                finished.append(r)
                continue
            self._bind_slot(r, slot, tok0)
        self.cache, self.cur_tok = self._admit_merge(
            self.cache, new_cache, self.cur_tok, first,
            jnp.asarray(src), jnp.asarray(mask))
        if self.tracer.on:    # monolithic prefill finishes inside the admit
            for r in reqs:
                if r not in finished:
                    self.tracer.event(r.rid, ev.PREFILL_COMPLETE, now,
                                      backend=self.name)
        return finished

    # ----------------------------------------------- chunked-prefill path
    def admit_chunked(self, reqs: List[Request], now: float) -> List[Request]:
        """Chunked admission: bind a slot and queue the prompt for prefill
        continuation — no device work here; the prefill advances one chunk
        per fused tick, interleaved with decode. A preempted request's
        preserved tokens extend the prefill sequence (see ``_PrefillJob``).
        Returns [] — nothing finishes at bind time.

        Prefill is **right-sized to the actual prompt** when the scheduler
        is chunked: a 16-token prompt costs one chunk, not a padded
        ``prompt_len`` prefill (the monolithic path always pads). When this
        machinery serves only preemption resume under monolithic admission,
        the sequence IS zero-padded to ``prompt_len`` so the rebuilt cache
        bit-matches the original padded prefill and resumed greedy tokens
        cannot diverge."""
        free = self.free_slots
        assert len(reqs) <= len(free)
        t_service = self.clock()
        for j, r in enumerate(reqs):
            slot = free[j]
            if r.service_start <= 0.0:   # resume keeps the original stamp
                r.service_start = t_service
            seq = self._effective_seq(r)
            resume_tok: Optional[int] = None
            gen: List[int] = []
            if r.resume_tokens:
                gen = [int(t) for t in r.resume_tokens[:-1]]
                resume_tok = int(r.resume_tokens[-1])
                seq = np.concatenate([seq, np.asarray(gen, np.int64)])
            self.slot_gen[slot] += 1
            self.slot_req[slot] = r
            self.slot_remaining[slot] = 0      # set when prefill completes
            self.slot_tokens[slot] = []
            self.slot_pos[slot] = 0
            self._prefilling[slot] = _PrefillJob(req=r, seq=seq,
                                                 resume_tok=resume_tok,
                                                 gen=gen)
            self.tracer.request_event(
                r, ev.RESUME if resume_tok is not None else ev.ADMITTED,
                t_service, backend=self.name, slot=slot, seq_len=len(seq))
            self._bind_chunked_slot(slot)      # paged: allocate pages now
        return []

    def _effective_seq(self, r: Request) -> np.ndarray:
        """The sequence chunked admission must put in the cache for ``r``'s
        prompt: right-sized to the true prompt under the chunked scheduler,
        zero-padded to ``prompt_len`` otherwise (monolithic parity — see
        ``admit_chunked``). Prefix-index hashes are computed over exactly
        this sequence, so sharing matches whatever discipline admits."""
        toks = np.asarray(r.tokens[:self.prompt_len], np.int64)
        if self.right_sized:
            return toks if len(toks) else np.zeros((1,), np.int64)
        seq = np.zeros((self.prompt_len,), np.int64)
        seq[:len(toks)] = toks
        return seq

    def _bind_chunked_slot(self, slot: int) -> None:
        """KV-discipline hook at chunked bind time (dense: nothing to do —
        the resident cache rows are permanent)."""

    def _prefill_complete(self, slot: int, job: _PrefillJob) -> None:
        """KV-discipline hook when a slot's chunked prefill finishes (paged
        backends with prefix sharing publish the slot's fully-written prompt
        blocks to the prefix index here — never earlier, so a sharer cannot
        map pages whose K/V is still being written)."""

    def fused_chunk_step(self, now: float) -> List[Request]:
        """One fused tick, sync form: dispatch then commit back-to-back —
        exactly the legacy fused tick. The async engine calls the two
        halves a tick apart instead (``dispatch_exec``/``commit_exec``)."""
        return self.commit_exec(self.dispatch_fused(now), now)

    def dispatch_fused(self, now: float) -> _PendingExec:
        """Dispatch one fused tick (Sarathi-style stall-free batching):
        every mid-prefill row advances by one prompt chunk while every
        decoding row advances by exactly one token — a decode step IS a
        one-token prefill continuation (feed the current token at the
        current position, take the argmax of its logits) — all in a single
        jitted call. A resident decode therefore never waits on more than
        one chunk of someone else's prompt, and a tick never pays a prefill
        call *and* a decode call.

        Only value-independent bookkeeping happens here: prefill progress,
        position mirrors, remaining-budget counts, the prefill-complete
        transition (including the prefix-index publish — device-stream
        ordering guarantees the published pages are written before any
        later-dispatched sharer reads them). Token *values* — which never
        influence any of the above under greedy decoding — are applied by
        ``commit_exec`` from the returned pending record."""
        B, ck = self.max_batch, self.prefill_chunk_tokens
        tokens = np.zeros((B, ck), np.int64)
        start = np.zeros((B,), np.int32)
        n_valid = np.zeros((B,), np.int32)
        set_mask = np.zeros((B,), bool)
        feed_mask = np.zeros((B,), bool)
        for slot, job in self._prefilling.items():
            nv = min(len(job.seq) - job.pos, ck)
            tokens[slot, :nv] = job.seq[job.pos:job.pos + nv]
            start[slot] = job.pos
            n_valid[slot] = nv
            # fresh rows completing here take the chunk's argmax as their
            # first generated token; resumed rows already know theirs
            set_mask[slot] = (job.pos + nv >= len(job.seq)
                              and job.resume_tok is None)
        # speculative rows are advanced only by DraftPair rounds — a fused
        # tick (someone else's prefill) must not single-step them, so they
        # stall for the tick exactly like zombies (their spec state stays
        # consistent; the pair resumes them on the next spec dispatch)
        spec_rows = (self._spec_pair.owned()
                     if self._spec_pair is not None else ())
        decode_rows = [s for s, r in enumerate(self.slot_req)
                       if r is not None and s not in self._prefilling
                       and s not in self._uncommitted_done
                       and s not in spec_rows]
        for s in decode_rows:
            feed_mask[s] = True          # device-side cur_tok feed (see
            start[s] = self.slot_pos[s]  # _prefill_chunk_fn) — no D2H dep
            n_valid[s] = 1
            set_mask[s] = True                       # argmax = next token
        t_disp = time.perf_counter()
        self.cur_tok, self.cache = self._jit_exec(
            self._prefill_chunk,
            self.params, self.cache, self.cur_tok, jnp.asarray(tokens),
            jnp.asarray(start), jnp.asarray(n_valid), jnp.asarray(set_mask),
            jnp.asarray(feed_mask))
        # cur_tok is NOT donated: this snapshot stays valid across the next
        # dispatch even though the donated cache is updated in place
        pend = _PendingExec(kind="fused", toks=self.cur_tok,
                            dispatched_at=t_disp, t_dispatch=now)
        resume_sets: List[Tuple[int, int]] = []
        tron = self.tracer.on
        for slot, job in list(self._prefilling.items()):
            nv = int(n_valid[slot])
            job.pos += nv
            self._count_prefill_tokens(nv)
            self.slot_pos[slot] = job.pos
            if tron:
                self.tracer.event(job.req.rid, ev.PREFILL_CHUNK, now,
                                  backend=self.name, pos=job.pos, n=nv)
            if job.pos < len(job.seq):
                continue
            del self._prefilling[slot]
            self._prefill_complete(slot, job)
            r = job.req
            if tron:
                self.tracer.event(r.rid, ev.PREFILL_COMPLETE, now,
                                  backend=self.name)
            if job.resume_tok is not None:
                resume_sets.append((slot, job.resume_tok))
            gen_n = len(job.gen) + 1     # count-based: known at dispatch
            fin = gen_n >= self._budget(r)
            if fin:
                self.slot_remaining[slot] = 0
                self._uncommitted_done.add(slot)
            else:
                self.slot_remaining[slot] = self._budget(r) - gen_n
                if self._spec_pair is not None:
                    # the row starts decoding next tick — hand it to the
                    # drafter pair (job.seq is exactly what this backend
                    # prefilled, so the drafter mirrors it bit-for-bit)
                    self._spec_pair.on_fresh(slot, job.seq)
            pend.fused_completions.append(
                (slot, r, self.slot_gen[slot], job.resume_tok,
                 list(job.gen), fin))
        for s in decode_rows:
            self.slot_pos[s] += 1
            self.slot_remaining[s] -= 1
            fin = self.slot_remaining[s] <= 0
            if fin:
                self._uncommitted_done.add(s)
            pend.decode_items.append(
                (s, self.slot_req[s], self.slot_gen[s], 1, fin))
        if resume_sets:    # resumed rows decode from their preserved token
            self.cur_tok = self.cur_tok.at[
                jnp.asarray([s for s, _ in resume_sets])].set(
                jnp.asarray([t for _, t in resume_sets], jnp.int32))
        return pend

    def preempt(self, r: Request, now: float) -> str:
        """Retire ``r`` early (scheduler-selected victim): its slot — and
        pages, for paged backends — is freed, the tokens it generated are
        preserved on ``r.resume_tokens``. Returns "requeued" (caller puts it
        back on the queue; it later resumes exactly where it stopped) or
        "dropped" (completed now with partial output, ``dropped=True``)."""
        slot = next(s for s, q in enumerate(self.slot_req) if q is r)
        job = self._prefilling.pop(slot, None)
        if job is not None:              # mid-prefill: preserved tokens are
            gen = job.gen + ([] if job.resume_tok is None
                             else [job.resume_tok])   # whatever it resumed with
        else:
            gen = list(self.slot_tokens[slot])
        self.slot_req[slot] = None
        self.slot_tokens[slot] = []
        self.slot_remaining[slot] = 0
        self._uncommitted_done.discard(slot)
        self._retire_slot(slot)
        if self._spec_pair is not None:
            self._spec_pair.on_release(slot)
        r.preemptions += 1
        r.resume_tokens = gen
        self.metrics.inc("requests.preempted")
        self.tracer.request_event(r, ev.PREEMPT, now, backend=self.name,
                                  slot=slot, generated=len(gen),
                                  action=self.preemption)
        if self.preemption == "drop":
            r.output = np.asarray(gen, np.int64)
            r.completion = self.clock()
            r.accuracy = self.accuracy
            r.dropped = True
            self._obs_complete(r, dropped=True)
            return "dropped"
        return "requeued"

    def decode_step_batch(self, now: float) -> List[Request]:
        """One jitted chunk of decode steps, sync form: dispatch then commit
        back-to-back (the async engine splits them a tick apart). Never
        called with rows mid-prefill — those ticks are fused
        (``fused_chunk_step``); the plain decode path stays the fast,
        bucket-aware one."""
        if self._spec_pair is not None and self._spec_pair.has_work():
            return self.commit_exec(self._spec_pair.dispatch(now), now)
        if self.active_slots == 0:
            return []
        return self.commit_exec(self.dispatch_decode(now), now)

    def dispatch_decode(self, now: float) -> Optional[_PendingExec]:
        """Dispatch one decode chunk without waiting for its tokens.
        Value-independent bookkeeping (positions, remaining counts,
        count-based completion detection) happens here; the returned
        pending record carries the un-synced ``(chunk, B)`` token array for
        ``commit_exec``. Returns None when every bound slot is a
        finished-but-uncommitted zombie — nothing left to run."""
        assert not self._prefilling, "mid-prefill rows need the fused tick"
        items = []
        for slot, r in enumerate(self.slot_req):
            if r is None or slot in self._uncommitted_done:
                continue
            take = min(int(self.slot_remaining[slot]), self.decode_chunk)
            items.append([slot, r, self.slot_gen[slot], take, False])
        if not items:
            return None
        t_disp = time.perf_counter()
        toks = self._dispatch_chunk()        # un-synced device (chunk, B)
        for it in items:
            slot, take = it[0], it[3]
            self.slot_remaining[slot] -= take
            if self.slot_remaining[slot] <= 0:
                it[4] = True
                self._uncommitted_done.add(slot)
        return _PendingExec(kind="decode", toks=toks, dispatched_at=t_disp,
                            t_dispatch=now,
                            decode_items=[tuple(it) for it in items])

    def dispatch_exec(self, now: float) -> Tuple[str, Optional[_PendingExec]]:
        """Async exec phase: enqueue this tick's jitted work and return
        (tick kind, pending record) — the record is committed on the NEXT
        tick, after that tick's own dispatch, so the D2H read and
        bookkeeping hide behind in-flight device compute."""
        if self._prefilling:
            return "fused", self.dispatch_fused(now)
        if self._spec_pair is not None and self._spec_pair.has_work():
            return "spec", self._spec_pair.dispatch(now)
        pend = self.dispatch_decode(now) if self.active_slots else None
        return ("decode" if pend is not None else "idle"), pend

    def commit_exec(self, pending: Optional[_PendingExec],
                    now: float) -> List[Request]:
        """Apply a dispatched exec's value-dependent bookkeeping: ONE
        batched D2H read for the whole tick (tokens of every slot arrive in
        a single ``np.asarray`` — commit lag never multiplies small
        per-slot transfers), then token appends, completion stamping, and
        slot retirement. A ``(request identity, slot_gen)`` mismatch means
        the slot was preempted or rebound inside the dispatch→commit gap;
        its stale tokens are discarded — greedy decoding regenerates the
        identical values on resume. Returns requests finished here."""
        if pending is None:
            return []
        if pending.kind == "spec":
            return self._spec_pair.commit(pending, now)
        if self.tracer.on:
            t0 = time.perf_counter()
            toks = np.asarray(pending.toks)
            t1 = time.perf_counter()
            self.commit_wait_ms = (t1 - t0) * 1e3
            self.commit_gap_ms = (t0 - pending.dispatched_at) * 1e3
        else:
            toks = np.asarray(pending.toks)
        if self.slow_factor > 1.0 and pending.kind == "decode":
            # injected straggler: effective chunk time scales by slow_factor
            time.sleep((time.perf_counter() - pending.dispatched_at)
                       * (self.slow_factor - 1.0))
        finished: List[Request] = []
        for slot, r, gen_id, resume_tok, gen_before, fin \
                in pending.fused_completions:
            if self.slot_req[slot] is not r or self.slot_gen[slot] != gen_id:
                continue
            tok0 = resume_tok if resume_tok is not None else int(toks[slot])
            gen = gen_before + [tok0]
            if fin:
                self._finish(r, gen, now)
                finished.append(r)
                self._release_slot(slot)
            else:
                self.slot_tokens[slot] = gen
        for slot, r, gen_id, take, fin in pending.decode_items:
            if self.slot_req[slot] is not r or self.slot_gen[slot] != gen_id:
                continue
            if pending.kind == "fused":
                self.slot_tokens[slot].append(int(toks[slot]))
            else:
                self.slot_tokens[slot].extend(
                    int(t) for t in toks[:take, slot])
            if fin:
                self._finish(r, self.slot_tokens[slot], now)
                finished.append(r)
                self._release_slot(slot)
        return finished

    def _release_slot(self, slot: int) -> None:
        self.slot_req[slot] = None
        self.slot_tokens[slot] = []
        self._uncommitted_done.discard(slot)
        self._retire_slot(slot)
        if self._spec_pair is not None:
            self._spec_pair.on_release(slot)

    def flush_pending(self, now: float) -> List[Request]:
        """Commit the in-flight tick, if any (async shutdown/fault path)."""
        pend, self._pending = self._pending, None
        return self.commit_exec(pend, now)

    def _jit_exec(self, call, *args):
        """Run one exec-phase jitted call. On dispatch-sampled ticks
        (``_fence_exec``) the call's outputs are fenced with
        ``block_until_ready``, splitting the async-dispatch cost (the jit
        call returning) from device compute; ``exec_split`` carries
        (dispatch_ms, device_ms) for the tick's ``TickRecord`` — the
        remainder of the engine-measured exec phase is the host-sync tail
        (``np.asarray`` D2H copy + per-slot bookkeeping)."""
        if not self._fence_exec:
            return call(*args)
        t0 = time.perf_counter()
        out = call(*args)
        t1 = time.perf_counter()
        jax.block_until_ready(out)
        t2 = time.perf_counter()
        self.exec_split = ((t1 - t0) * 1e3, (t2 - t1) * 1e3)
        return out

    def _dispatch_chunk(self):
        """Enqueue one decode chunk; returns the UN-SYNCED device token
        array (chunk, B) — the chunk outputs are not in the donation set,
        so the caller may hold them across the next dispatch."""
        self.cur_tok, self.cache, toks = self._jit_exec(
            self._decode_chunk, self.params, self.cache, self.cur_tok)
        self.slot_pos += self.decode_chunk   # device advanced every row
        return toks

    def _retire_slot(self, slot: int) -> None:
        """Hook called when a slot's request completes (paged backends free
        the slot's pool pages here); the dense cache needs no cleanup —
        stale entries are masked by the validity bias."""

    def _finish(self, r: Request, tokens: List[int], now: float) -> None:
        r.output = np.asarray(tokens[:min(r.max_new, self.max_new)], np.int64)
        r.completion = self.clock()
        r.accuracy = self.accuracy
        self._obs_complete(r)

    def _obs_complete(self, r: Request, dropped: bool = False) -> None:
        """Completion-side metrics + terminal span event — one site for
        normal finishes, preemption drops, and the legacy pump path, so the
        registry's request totals always agree with ``self.done``.

        Goodput counts a request when it wasn't dropped and met its own
        ``slo_ms`` (requests without a per-request SLO count as good — the
        registry can't know the summary-time global SLO).

        With rolling windows on (``Observability(windows=True)``) the same
        outcomes also land in the windowed instruments under the SAME
        names, keyed at ``r.completion`` (the backend's one clock), plus
        the per-SLO-class ``slo.class.<key>.good|bad`` counters the
        burn-rate monitor reads — the DES ``_record`` sink mirrors this
        exactly (parity-tested)."""
        m = self.metrics
        lat = r.latency_ms
        good = not dropped and (r.slo_ms <= 0 or lat <= r.slo_ms)
        m.inc("requests.completed")
        m.observe("request.latency_ms", lat)
        m.observe("request.queue_wait_ms", r.queue_wait_ms)
        m.observe("request.service_ms", r.service_ms)
        if dropped:
            m.inc("requests.dropped")
        elif good:
            m.inc("requests.goodput_ok")
        w = self.windows
        if w.on:
            tc = r.completion
            w.inc("requests.completed", tc)
            w.observe("request.latency_ms", tc, lat)
            cls = slo_class_key(r.slo_ms)
            if dropped:
                w.inc("requests.dropped", tc)
            elif good:
                w.inc("requests.goodput_ok", tc)
            w.inc(f"slo.class.{cls}.{'good' if good else 'bad'}", tc)
        self.tracer.request_event(r, ev.DROP if dropped else ev.COMPLETE,
                                  r.completion, backend=self.name,
                                  latency_ms=lat)

    def drain_slots(self, now: float) -> List[Request]:
        """Run prefill/decode until every in-flight sequence completes
        (connection draining before retirement — create-then-remove).
        Commits any in-flight async tick first, then loops synchronously."""
        done: List[Request] = list(self.flush_pending(now))
        steps = 0
        max_steps = self.max_new // self.decode_chunk + 2
        if self.chunked:   # fused ticks: 1 decode token while chunks finish
            max_steps += -(-(self.prompt_len + self.max_new)
                           // self.prefill_chunk_tokens) + self.max_new + 2
        if self._spec_pair is not None:
            max_steps += self.max_new + 2   # worst case: 1 accepted/round
        while self.active_slots and steps < max_steps:
            if self._prefilling:
                done.extend(self.fused_chunk_step(now))
            else:
                done.extend(self.decode_step_batch(now))
            steps += 1
        return done


def _bucket_ladder(lo: int, hi: int) -> List[int]:
    """Doubling ladder of static sizes in [lo, hi], always ending at hi —
    the compile-once buckets for right-sized prefill batches and live-page
    bounds (log₂ many executables instead of one per dynamic size)."""
    sizes = []
    n = max(1, lo)
    while n < hi:
        sizes.append(n)
        n *= 2
    sizes.append(hi)
    return sizes


class PagedVariantBackend(VariantBackend):
    """``VariantBackend`` with a paged KV pool instead of the dense ring.

    Three cost levers over the dense discipline (DESIGN.md §Paged KV cache):

      * **Right-sized prefill** — admission prefills a batch bucketed to the
        actual number of joiners (1, 2, 4, …), never padded to ``max_batch``,
        and only to ``prompt_len`` capacity (decode tokens live in pages, so
        the prefill cache never over-allocates for them).
      * **Length-aware decode** — each decode chunk runs at the smallest
        live-page bucket covering the longest live sequence; attention cost
        is proportional to live context, not ``prompt_len + max_new``
        capacity. With ``use_pallas`` the ``paged_flash_decode`` kernel
        additionally skips pages per row.
      * **Memory-true capacity** — pages are allocated at admission (whole
        sequence budget, all-or-nothing) and freed at retirement;
        ``free_slots`` admits only what the pool can hold, so
        ``enforce_units`` and the profiler observe real memory capacity.
    """

    def __init__(self, name: str, cfg: ModelConfig, accuracy: float,
                 page_size: int = 16, pool_pages: Optional[int] = None,
                 **kw):
        self.page_size = page_size
        self._pool_pages_arg = pool_pages
        super().__init__(name, cfg, accuracy, **kw)

    def _build_state(self) -> None:
        model, ps = self.model, self.page_size
        # pages covering one slot's whole budget (prompt + decode tokens,
        # plus any scratch headroom — speculative drafters write drafts
        # past the last committed position before they are accepted)
        self.pages_per_slot = -(-(self.prompt_len + self.max_new
                                  + self.cache_headroom) // ps)
        pool_pages = self._pool_pages_arg or (
            self.max_batch * self.pages_per_slot + 1)   # +1: trash page 0
        self.pool = PagedKVCache(pool_pages, ps, metrics=self.metrics)
        self.cache = model.init_paged_cache(
            self.max_batch, pool_pages, ps, self.pages_per_slot)
        self.cur_tok = jnp.zeros((self.max_batch,), jnp.int32)
        self.batch_buckets = _bucket_ladder(1, self.max_batch)
        first_pages = self.pool.pages_needed(self.prompt_len + self.decode_chunk)
        self.page_buckets = _bucket_ladder(first_pages, self.pages_per_slot)

        # The pool is donated to the admission scatter and the decode chunk
        # (the engine always replaces ``self.cache`` with the result), so
        # page writes happen in place — a paged cache that copied the whole
        # pool per touch would scale with capacity again
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_len=self.prompt_len))
        self._paged_admit = jax.jit(model.paged_admit, donate_argnums=(0,))
        self._decode_chunk_p = jax.jit(self._paged_chunk_fn,
                                       static_argnums=(3,),
                                       donate_argnums=(1,))

        # warm-up every (batch bucket, page bucket) executable — all are
        # part of this backend's measured readiness rt_m (donated caches are
        # chained call-to-call; see the dense warm-up)
        for bb in self.batch_buckets:
            toks = jnp.zeros((bb, self.prompt_len), jnp.int32)
            logits, pref = self._prefill(self.params, {"tokens": toks})
            first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            self.cache, self.cur_tok = self._paged_admit(
                self.cache, pref, self.cur_tok, first,
                jnp.full((bb, self.pages_per_slot), self.pool.total_pages,
                         jnp.int32),                     # OOB page ids: drop
                jnp.full((bb,), self.max_batch, jnp.int32))  # OOB slots: drop
        for nb in self.page_buckets:
            self.cur_tok, self.cache, _ = self._decode_chunk_p(
                self.params, self.cache, self.cur_tok, nb)
        # prefix sharing: the admission-time CoW page copy (one executable —
        # src/dst are traced scalars) and the per-request plans stashed
        # between the admit-time lookup and the slot bind (same tick)
        self._admit_plans: Dict[int, "object"] = {}
        if self.prefix_sharing:
            self._cow_copy = jax.jit(self.model.paged_cow_copy,
                                     donate_argnums=(0,))
            self.cache = self._cow_copy(self.cache, 0, 0)   # warm: trash->trash

    # chunked machinery: the base ``_build_chunk_state`` works unchanged —
    # ``_model_prefill_chunk`` below is the only paged-specific piece (the
    # pool-form continuation attends the row's whole block table: one
    # executable; fused ticks are already bounded by the chunk size)

    # ------------------------------------------------------------- jitted fns
    def _paged_chunk_fn(self, params, cache, tok, n_pages: int):
        """``decode_chunk`` paged decode steps as one traced scan at the
        static live-page bucket ``n_pages`` (shares ``_chunk_scan`` with the
        dense path)."""
        return self._chunk_scan(
            cache, tok,
            lambda c, t: self.model.decode_step_paged(params, c, t,
                                                      n_pages=n_pages))

    def _model_prefill_chunk(self, params, cache, tokens, start, n_valid):
        return self.model.prefill_chunk_paged(params, cache, tokens, start,
                                              n_valid)

    # ------------------------------------------------- continuous-batch path
    @property
    def free_slots(self) -> List[int]:
        """Slots open for admission = free batch rows ∩ slot_cap (see base)
        ∩ what the page pool can actually hold — memory-true capacity."""
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        if self.slot_cap is not None:
            allow = min(self.slot_cap, self.max_batch) - self.active_slots
            free = free[:max(allow, 0)]
        return free[:self.pool.free_pages // self.pages_per_slot]

    @property
    def kv_pool_occupancy(self) -> float:
        return self.pool.occupancy

    def admit(self, reqs: List[Request], now: float) -> List[Request]:
        """Right-sized admission: prefill only the actual joiners (bucketed),
        allocate each a full page budget, scatter the prefilled KV into its
        pages. With prefix sharing on, joiners whose prompt hits the prefix
        index are peeled off onto the continuation path instead — their
        indexed prefix is mapped by reference at bind and only the novel
        tail is prefilled (the monolithic batch prefill would recompute the
        whole prompt)."""
        if not self.prefix_sharing:
            return self._admit_monolithic(reqs, now)
        hits, misses = [], []
        for r in reqs:
            plan = self.pool.prefix_plan(self._effective_seq(r)) \
                if self._budget(r) > 1 else None   # budget-1: no pages at all
            if plan is not None and (plan.shared or plan.cow_src is not None):
                self._admit_plans[id(r)] = plan
                hits.append(r)
            else:
                misses.append(r)
        finished = self._admit_monolithic(misses, now)
        if hits:                     # binds slots; nothing finishes at bind
            self.admit_chunked(hits, now)
        return finished

    def _admit_monolithic(self, reqs: List[Request],
                          now: float) -> List[Request]:
        free = self.free_slots
        assert len(reqs) <= len(free)
        if not reqs:
            return []
        bb = next(b for b in self.batch_buckets if b >= len(reqs))
        first, first_np, pref = self._admit_prefill(reqs, bb)
        # OOB defaults: rows not joining a slot are dropped by the scatter
        page_ids = np.full((bb, self.pages_per_slot), self.pool.total_pages,
                           np.int32)
        dest = np.full((bb,), self.max_batch, np.int32)
        finished = []
        for j, r in enumerate(reqs):
            slot = free[j]
            tok0 = int(first_np[j])
            if self._budget(r) <= 1:     # completes at admission: no pages
                self._finish(r, [tok0], now)
                finished.append(r)
                continue
            pages = self.pool.alloc(slot, self.pages_per_slot)
            assert pages is not None     # free_slots gated on the pool
            page_ids[j] = pages
            dest[j] = slot
            self._bind_slot(r, slot, tok0)   # slot_pos mirror set there
        self.cache, self.cur_tok = self._paged_admit(
            self.cache, pref, self.cur_tok, first,
            jnp.asarray(page_ids), jnp.asarray(dest))
        if self.prefix_sharing:
            # the scatter above wrote every bound row's full prompt K/V, so
            # those blocks are publishable to the prefix index immediately
            for j, r in enumerate(reqs):
                if int(dest[j]) < self.max_batch:
                    self.pool.publish_prefix(int(dest[j]),
                                             self._effective_seq(r))
        return finished

    def _bind_chunked_slot(self, slot: int) -> None:
        """Chunked admission owns the slot's full page budget up front (the
        all-or-nothing discipline of ``admit``; ``free_slots`` already gated
        the bind on pool capacity — worst-case, so sharing savings are
        realized here, never promised in advance).

        With prefix sharing, the plan's matched blocks are mapped by
        reference (refcount bump) and only the remainder is allocated
        fresh; a fully-matched boundary block is copied on write into the
        first fresh page so the re-fed final prompt token's K/V write
        cannot touch the shared original. The prefill job then starts at
        ``plan.tail_start`` instead of 0 — shared tokens are never
        recomputed."""
        job = self._prefilling[slot]
        stored = self._admit_plans.pop(id(job.req), None)
        plan = None
        if self.prefix_sharing:
            # plan against the *current* index: with the retained tier, a
            # plan computed at admit() peel time can go stale within the
            # same tick (an earlier bind or monolithic alloc may reclaim a
            # planned refcount-0 page). Lookups are cheap; the hit-rate
            # telemetry was already counted once at plan time (resume
            # lookups stay out of it).
            plan = self.pool.prefix_plan(
                self._effective_seq(job.req),
                count=stored is None and job.resume_tok is None)
        shared = tuple(plan.shared) if plan is not None else ()
        cow = plan.cow_src if plan is not None else None
        # protect the CoW source from retained-tier reclaim within this very
        # alloc — the device copy below reads it after the pages are granted
        fresh = self.pool.alloc(slot, self.pages_per_slot - len(shared),
                                shared=shared,
                                protect=() if cow is None else (cow,))
        if fresh is None:
            # retained-tier squeeze: the plan's keep-set blocked reclaim of
            # the last pages. Drop the plan and take the full budget fresh —
            # free_slots gated the bind on free_pages, which is sufficient
            # once nothing is protected.
            plan, shared, cow = None, (), None
            fresh = self.pool.alloc(slot, self.pages_per_slot)
        assert fresh is not None
        self.cache["pt"] = self.cache["pt"].at[slot].set(
            jnp.asarray(list(shared) + list(fresh), jnp.int32))
        if plan is not None and plan.tail_start > 0:
            if plan.cow_src is not None:
                self.cache = self._cow_copy(self.cache, plan.cow_src,
                                            fresh[0])
                self.metrics.inc("kv.cow_copies")
            job.pos = plan.tail_start
            self.slot_pos[slot] = plan.tail_start
            self.tracer.request_event(job.req, ev.COW_BIND, self.clock(),
                                      backend=self.name, slot=slot,
                                      shared_pages=len(shared),
                                      tail_start=plan.tail_start,
                                      cow=plan.cow_src is not None)

    def _prefill_complete(self, slot: int, job: "_PrefillJob") -> None:
        """Publish the slot's fully-written prompt blocks to the prefix
        index — only now, so a sharer can never map pages whose K/V is
        still being written by an in-flight continuation. Resume jobs
        publish just the prompt portion of the rebuilt sequence (generated
        tokens live past the prompt and their final page keeps being
        appended to)."""
        if not self.prefix_sharing:
            return
        prompt = job.seq[:len(job.seq) - len(job.gen)]
        self.pool.publish_prefix(slot, prompt)

    def _dispatch_chunk(self):
        # bucket on rows that still generate: finished-but-uncommitted
        # zombies keep decoding harmlessly (their writes clamp into the
        # slot's own last page, as sync tail chunks always have) but must
        # not inflate the live-page bound
        live = [self.slot_pos[s] for s, r in enumerate(self.slot_req)
                if r is not None and s not in self._uncommitted_done]
        need = self.pool.pages_needed(int(max(live)) + self.decode_chunk)
        need = min(need, self.pages_per_slot)
        nb = next(b for b in self.page_buckets if b >= need)
        self.cur_tok, self.cache, toks = self._jit_exec(
            self._decode_chunk_p, self.params, self.cache, self.cur_tok, nb)
        self.slot_pos += self.decode_chunk   # device advanced every row
        return toks

    def _retire_slot(self, slot: int) -> None:
        """Free the slot's pages and point its table row back at the trash
        page so the dead batch row keeps decoding harmlessly."""
        self.pool.free(slot)
        self.cache = self.model.paged_retire(self.cache, slot)
        self.slot_pos[slot] = 0

    # -------------------------------------------------------- pump-mode path
    def generate(self, prompts: np.ndarray, max_new: int) -> np.ndarray:
        raise NotImplementedError(
            "paged KV backends serve in continuous mode only")


class DraftPair:
    """Speculative decoding binding (DESIGN.md §Speculative decoding): a
    cheap *drafter* backend proposes ``k`` tokens per round for every
    decoding slot of its *verifier* backend; the verifier scores all k+1
    positions (the pending token + the k drafts) in ONE prefill-continuation
    call (``model.verify_chunk``), the longest agreeing draft prefix plus
    the verifier's own bonus token commits, and the rest rolls back by pure
    position rewind.

    **Greedy parity.** The bonus token is always the verifier's own argmax
    given the committed prefix, and a draft commits only where it equals
    that argmax — inductively the committed stream is bitwise identical to
    target-only greedy decoding, whatever the drafter proposes.

    **Overlap.** Acceptance of round t is computed on DEVICE at round
    t+1's dispatch (``_accept_fn`` over the previous round's un-synced
    draft/argmax arrays), so under ``async_tick=True`` the draft+verify of
    round t+1 dispatches before round t's tokens are read back — draft of
    chunk t+1 overlaps verify of chunk t. The commit replays the same
    integer acceptance rule on the packed ``(B, 2k+1)`` matrix host-side
    one tick later; exact equality keeps both sides identical.

    **Rollback.** Both caches rewind ``pos`` to the committed length.
    Chunk and decode attention mask every slot past the query position and
    overwrite a slot before attending it, so rejected-draft K/V is
    unreachable the moment the position retreats — no page is freed
    (budgets are all-or-nothing), CoW pages keep their sharers, and
    ``PagedKVCache.rollback`` audits that published prefix entries never
    cover rejected positions.

    **Per-slot host state** (``_mode``): ``"fresh"`` — drafter mirror
    prefilled this dispatch, the pending token lives in the verifier's
    device ``cur_tok``; ``"device"`` — a dispatched round's acceptance has
    not been committed yet, the device derives base/pending itself;
    ``"host"`` — the round committed before the next spec dispatch (sync
    ticks, or async ticks interleaved with fused prefill ticks), so the
    host feeds base/pending/resync explicitly. ``base[slot]`` always holds
    the round-start base of the arrays in ``_prev`` until a dispatch
    consumes their acceptance, then catches up at commit."""

    def __init__(self, verifier: VariantBackend, drafter: VariantBackend,
                 k: int):
        assert k >= 1
        assert drafter.max_batch == verifier.max_batch
        assert drafter.prompt_len == verifier.prompt_len
        assert drafter.max_new == verifier.max_new
        assert drafter.decode_chunk == k, \
            "the drafter's warmed decode scan IS the k-token draft"
        assert drafter.chunked, "drafter needs the continuation machinery " \
            "(mirror prefill + the full-accept resync)"
        self.v, self.d, self.k = verifier, drafter, k
        self.paged = isinstance(verifier, PagedVariantBackend)
        assert self.paged == isinstance(drafter, PagedVariantBackend)
        self.metrics = verifier.metrics
        self.windows = verifier.windows
        B = verifier.max_batch
        self.base = np.zeros((B,), np.int64)       # round-start verifier pos
        self.end = np.zeros((B,), np.int64)        # base at completion
        self.pend_tok = np.zeros((B,), np.int64)   # host-fed pending token
        self.resync_host = np.zeros((B,), bool)    # host-fed full-accept flag
        self._slot_round = np.zeros((B,), np.int64)
        self._round_no = 0
        self._mode: Dict[int, str] = {}
        self.fresh: Dict[int, np.ndarray] = {}     # slot -> mirror sequence
        self._d_bound: Set[int] = set()
        self._prev = None            # (drafts (B,k), argmax (B,k+1)) device
        # per-slot acceptance telemetry (k-adaptation reads these)
        self.slot_rounds = np.zeros((B,), np.int64)
        self.slot_accepted = np.zeros((B,), np.int64)
        self.slot_proposed = np.zeros((B,), np.int64)
        self._accept = jax.jit(self._accept_fn)
        vfn = (verifier.model.verify_chunk_paged if self.paged
               else verifier.model.verify_chunk)
        self._verify = jax.jit(lambda p, c, t, s, nv: vfn(p, c, t, s, nv),
                               donate_argnums=(1,))
        # warm-up: the verify executable (n_valid=0 writes nothing) and the
        # drafter's width-1 continuation (the full-accept resync shape)
        zi = jnp.zeros((B,), jnp.int32)
        fz = jnp.zeros((B,), bool)
        _, self.v.cache = self._verify(
            verifier.params, verifier.cache,
            jnp.zeros((B, k + 1), jnp.int32), zi, zi)
        self.d.cur_tok, self.d.cache = drafter._prefill_chunk(
            drafter.params, drafter.cache, drafter.cur_tok,
            jnp.zeros((B, 1), jnp.int32), zi, zi, fz, fz)
        verifier._spec_pair = self

    # ------------------------------------------------------------ slot hooks
    def on_fresh(self, slot: int, seq: np.ndarray) -> None:
        """The verifier bound ``slot`` to a decoding request whose cache
        holds exactly ``seq`` (+ the pending first token in ``cur_tok``)."""
        self.fresh[slot] = np.asarray(seq, np.int64)
        self._mode.pop(slot, None)

    def on_release(self, slot: int) -> None:
        """The verifier released ``slot`` (finish or preemption): drop the
        spec state and free the drafter's mirror resources. Any in-flight
        round's stale items are discarded by the commit guard."""
        self._mode.pop(slot, None)
        self.fresh.pop(slot, None)
        if slot in self._d_bound:
            self._d_bound.discard(slot)
            self.d._retire_slot(slot)

    def owned(self):
        return self._mode.keys() | self.fresh.keys()

    def has_work(self) -> bool:
        return bool(self._mode or self.fresh)

    # ------------------------------------------------------------- jitted fns
    def _accept_fn(self, drafts, pred, base_in, end, dev_m, fresh_m,
                   host_tok, host_resync, cur_v):
        """Acceptance of the previous round + inputs of the next, one
        traced call. ``dev_m`` rows derive base/pending from the previous
        round's arrays; ``fresh_m`` rows take the verifier's device
        ``cur_tok`` as pending at their bootstrap base; remaining live rows
        are host-fed (their round already committed). ``n_valid`` is capped
        by the tokens still owed (``end - base``), so a finished row's
        in-flight zombie round verifies nothing and writes nothing."""
        k = self.k
        nv_prev = jnp.clip(end - base_in, 0, k + 1)
        agree = ((drafts == pred[:, :k])
                 & (jnp.arange(k)[None, :] < (nv_prev - 1)[:, None]))
        a = jnp.sum(jnp.cumprod(agree.astype(jnp.int32), axis=1), axis=1)
        bonus = jnp.take_along_axis(pred, a[:, None], axis=1)[:, 0]
        base_new = jnp.where(dev_m, base_in + a + 1, base_in) \
            .astype(jnp.int32)
        pending = jnp.where(dev_m, bonus,
                            jnp.where(fresh_m, cur_v, host_tok)) \
            .astype(jnp.int32)
        resync = (dev_m & (a == k)) | (~dev_m & ~fresh_m & host_resync)
        nv_next = jnp.clip(end - base_new, 0, k + 1).astype(jnp.int32)
        return base_new, pending, nv_next, resync

    # ---------------------------------------------------------- round halves
    def _bootstrap_fresh(self) -> None:
        """Mirror-prefill every newly bound slot's committed sequence into
        the drafter's cache (batched continuation chunks — handles resumed
        rows whose sequence exceeds ``prompt_len``), and seed the host
        state. The pending token itself is copied device-side from the
        verifier's ``cur_tok`` at dispatch — it may only exist on device
        (a chunked completion whose commit has not run yet)."""
        v, d = self.v, self.d
        B, ck = v.max_batch, d.prefill_chunk_tokens
        maxlen = 0
        for slot, seq in sorted(self.fresh.items()):
            base0 = int(v.slot_pos[slot])
            assert base0 == len(seq), (base0, len(seq))
            self.base[slot] = base0
            self.end[slot] = base0 + int(v.slot_remaining[slot])
            self.pend_tok[slot] = 0
            self.resync_host[slot] = False
            self._mode[slot] = "fresh"
            maxlen = max(maxlen, len(seq))
            if self.paged and slot not in self._d_bound:
                pages = d.pool.alloc(slot, d.pages_per_slot)
                assert pages is not None, "drafter pool covers max_batch"
                d.cache["pt"] = d.cache["pt"].at[slot].set(
                    jnp.asarray(pages, jnp.int32))
            self._d_bound.add(slot)
        fz = jnp.zeros((B,), bool)
        for off in range(0, maxlen, ck):
            tokens = np.zeros((B, ck), np.int64)
            st = np.zeros((B,), np.int32)
            nv = np.zeros((B,), np.int32)
            for slot, seq in self.fresh.items():
                n = min(len(seq) - off, ck)
                if n <= 0:
                    continue
                tokens[slot, :n] = seq[off:off + n]
                st[slot] = off
                nv[slot] = n
            d.cur_tok, d.cache = d._prefill_chunk(
                d.params, d.cache, d.cur_tok, jnp.asarray(tokens),
                jnp.asarray(st), jnp.asarray(nv), fz, fz)
        self.fresh.clear()

    def dispatch(self, now: float) -> Optional[_PendingExec]:
        """One speculative round for every owned slot: consume the previous
        round's acceptance (device), rewind both caches, resync the drafter
        on full accepts, draft k tokens on the cheap model, verify all k+1
        positions on the target — five device calls, no D2H."""
        v, d, k = self.v, self.d, self.k
        B = v.max_batch
        if self.fresh:
            self._bootstrap_fresh()
        live = sorted(self._mode)
        if not live:
            return None
        t_disp = time.perf_counter()
        self._round_no += 1
        rnd = self._round_no
        live_np = np.zeros((B,), bool)
        dev_np = np.zeros((B,), bool)
        fresh_np = np.zeros((B,), bool)
        items = []
        for s in live:
            live_np[s] = True
            dev_np[s] = self._mode[s] == "device"
            fresh_np[s] = self._mode[s] == "fresh"
            items.append((s, v.slot_req[s], v.slot_gen[s],
                          int(self.base[s]), rnd))
            self._slot_round[s] = rnd
            self._mode[s] = "device"
        if self._prev is None:
            pd = jnp.zeros((B, k), jnp.int32)
            pp = jnp.zeros((B, k + 1), jnp.int32)
        else:
            pd, pp = self._prev
        base_new, pending, nv_next, resync = self._accept(
            pd, pp, jnp.asarray(self.base), jnp.asarray(self.end),
            jnp.asarray(dev_np), jnp.asarray(fresh_np),
            jnp.asarray(self.pend_tok), jnp.asarray(self.resync_host),
            v.cur_tok)
        live_j = jnp.asarray(live_np)
        # rollback + advance: pure position rewind on both caches — chunk
        # and decode attention mask every slot past the query position and
        # overwrite before attending, so rejected-draft K/V is dead
        v.cache["pos"] = jnp.where(live_j, base_new, v.cache["pos"])
        d.cache["pos"] = jnp.where(live_j, base_new, d.cache["pos"])
        if self.paged:
            for s in live:     # pool-side audit: rewind never uncovers a
                v.pool.rollback(s, int(self.base[s]) + 1)   # published page
        if self._prev is not None:
            # full-accept resync: the k-th draft committed but its K/V was
            # never written (the scan emits it as output only) — feed it
            # through a width-1 continuation at base_new - 1
            fz = jnp.zeros((B,), bool)
            d.cur_tok, d.cache = d._prefill_chunk(
                d.params, d.cache, d.cur_tok, pd[:, -1:], base_new - 1,
                resync.astype(jnp.int32), fz, fz)
        d.cur_tok = jnp.where(live_j, pending, d.cur_tok)
        if self.paged:
            mx = max(int(self.base[s]) for s in live)
            cap = d.prompt_len + d.max_new + d.cache_headroom
            need = d.pool.pages_needed(min(mx + 2 * k + 2, cap))
            nb = next(b for b in d.page_buckets
                      if b >= min(need, d.pages_per_slot))
            d.cur_tok, d.cache, dtoks = d._decode_chunk_p(
                d.params, d.cache, d.cur_tok, nb)
        else:
            d.cur_tok, d.cache, dtoks = d._decode_chunk(
                d.params, d.cache, d.cur_tok)
        drafts = jnp.transpose(dtoks).astype(jnp.int32)      # (B, k)
        vt = jnp.concatenate([pending[:, None], drafts], axis=1)
        pred, v.cache = v._jit_exec(self._verify, v.params, v.cache, vt,
                                    base_new, nv_next)
        self._prev = (drafts, pred)
        self.metrics.inc("spec.batch_rounds")
        return _PendingExec(kind="spec",
                            toks=jnp.concatenate([drafts, pred], axis=1),
                            dispatched_at=t_disp, t_dispatch=now,
                            spec_items=items)

    def commit(self, pending: _PendingExec, now: float) -> List[Request]:
        """Replay the round's acceptance host-side from the packed
        ``(B, 2k+1)`` matrix — ONE D2H read — and apply the value-dependent
        bookkeeping: token appends, acceptance telemetry, completion. A
        ``(request identity, slot_gen)`` mismatch means the slot was
        preempted or rebound inside the dispatch→commit gap; its stale
        tokens are discarded and regenerated identically on resume."""
        v, k = self.v, self.k
        m, w = self.metrics, self.windows
        pack = np.asarray(pending.toks)
        drafts, pred = pack[:, :k], pack[:, k:]
        finished: List[Request] = []
        for slot, r, gen_id, _base_disp, rnd in pending.spec_items:
            if v.slot_req[slot] is not r or v.slot_gen[slot] != gen_id:
                continue
            # The round-start base is read LIVE from ``self.base``, not
            # from the dispatch-time snapshot: under async overlap the
            # dispatch of round r+1 runs before the commit of round r has
            # advanced the host base, so the snapshot can be one round
            # stale. Commits drain strictly FIFO and each advances
            # ``self.base`` by exactly a+1, so at commit(r) the host base
            # is always round r's true start offset.
            base_t = int(self.base[slot])
            nv = int(min(self.end[slot] - base_t, k + 1))
            if nv <= 0:
                continue          # zombie round of an already-finished row
            a = 0
            while a < nv - 1 and int(drafts[slot, a]) == int(pred[slot, a]):
                a += 1
            v.slot_tokens[slot].extend(
                [int(t) for t in drafts[slot, :a]] + [int(pred[slot, a])])
            new_base = base_t + a + 1
            self.base[slot] = new_base
            v.slot_pos[slot] = new_base
            v.slot_remaining[slot] = self.end[slot] - new_base
            self.slot_rounds[slot] += 1
            self.slot_accepted[slot] += a
            self.slot_proposed[slot] += nv - 1
            m.inc("spec.rounds")
            m.inc("spec.committed_tokens", a + 1)
            m.inc("spec.drafts_accepted", a)
            m.inc("spec.drafts_proposed", nv - 1)
            if w.on:
                w.observe("spec.tokens_per_step", now, a + 1)
                if nv > 1:
                    w.observe("spec.accept_rate", now, a / (nv - 1))
            if self._slot_round[slot] == rnd:
                # no newer round in flight (sync ticks, or async ticks
                # interleaved with fused prefill): the next dispatch takes
                # base/pending/resync from the host side
                self._mode[slot] = "host"
                self.pend_tok[slot] = int(pred[slot, a])
                self.resync_host[slot] = a == k
            # else: a newer round already consumed this acceptance on
            # device — self.base just caught up to that round's base
            if new_base >= self.end[slot]:
                v._finish(r, v.slot_tokens[slot], now)
                finished.append(r)
                v._release_slot(slot)     # -> on_release drops spec state
        return finished

    def acceptance_stats(self) -> Dict:
        rounds = int(self.slot_rounds.sum())
        acc = int(self.slot_accepted.sum())
        prop = int(self.slot_proposed.sum())
        return {"rounds": rounds, "drafts_accepted": acc,
                "drafts_proposed": prop,
                "accept_rate": acc / max(prop, 1),
                "tokens_per_step": (acc + rounds) / max(rounds, 1)}


class InProcessServingEngine:
    """``ServingAPI`` on real models (continuous batching or legacy pump).

    Parameters mirror the paper's serving setup: ``variants`` maps name ->
    (ModelConfig, accuracy%); ``apply_allocation`` loads/retires variants
    with measured readiness; per-variant admission queues are bounded at
    ``queue_cap`` requests (backpressure).
    """

    def __init__(self, variants: Mapping[str, Tuple[ModelConfig, float]],
                 max_batch: int = 8, prompt_len: int = 32,
                 mode: str = "continuous", max_new: int = 16,
                 decode_chunk: int = 4, queue_cap: int = 256,
                 use_pallas: bool = False, enforce_units: bool = False,
                 nodes: Optional[Sequence[Node]] = None,
                 placement="first-fit", router="p2c", replica_size: int = 1,
                 kv_cache: str = "dense", kv_page_size: int = 16,
                 kv_pool_pages: Optional[int] = None,
                 kv_prefix_sharing: bool = False,
                 scheduler="fifo", prefill_chunk: int = 16,
                 preemption: str = "none",
                 clock: Callable[[], float] = time.time,
                 trace: bool = False,
                 obs: Optional[Observability] = None,
                 profile_dispatch: int = 0,
                 async_tick: bool = False,
                 speculative: Optional[str] = None,
                 spec_k: int = 4):
        assert mode in ("continuous", "pump"), mode
        assert not async_tick or mode == "continuous", \
            "async_tick needs the continuous engine (the pump path is " \
            "a blocking per-batch loop)"
        assert kv_cache in ("dense", "paged"), kv_cache
        assert kv_cache == "dense" or mode == "continuous", \
            "paged KV backends serve in continuous mode only"
        assert preemption in ("none", "requeue", "drop", "migrate"), \
            preemption
        assert not (kv_prefix_sharing and kv_cache != "paged"), \
            "kv_prefix_sharing requires kv_cache='paged' (the prefix index " \
            "maps shared blocks onto pool pages)"
        # scheduling discipline between each backend's queue and its slots
        # (DESIGN.md §Scheduling): "fifo" = the legacy behavior; "edf" =
        # deadline-order admission; "chunked" = EDF + chunked prefill.
        # preemption= retires deadline-hopeless residents for feasible
        # waiters ("requeue" resumes them later with tokens preserved,
        # "drop" completes them early as dropped).
        self.sched = make_scheduler(scheduler)
        self.prefill_chunk = prefill_chunk
        self.preemption = preemption
        self.clock = clock   # every arrival/service/completion stamp source
        # observability: metrics are on by default (registry bumps cost what
        # the old ad-hoc counters cost); span/tick tracing is opt-in via
        # trace=True. One bundle serves the engine and every backend it
        # creates, so all replicas publish into one registry and one trace
        # timeline (stamped from self.clock — the engine's one clock).
        self.obs = obs if obs is not None else Observability(trace=trace)
        self.metrics = self.obs.metrics
        self.tracer = self.obs.tracer
        self.windows = self.obs.windows
        # dispatch profiler: every Nth tick fences its exec-phase jit call
        # (block_until_ready) and records the dispatch/device/host-sync
        # split on the TickRecord (0 = off; needs tracing for the records)
        self.profile_dispatch = int(profile_dispatch)
        self._tick_no = 0
        # async tick loop (DESIGN.md §Async tick loop): each tick dispatches
        # its exec FIRST, then commits the PREVIOUS tick's — the D2H read
        # and bookkeeping of tick t hide behind tick t+1's device compute.
        # Greedy outputs are bitwise identical to the sync default; only
        # completion/retirement bookkeeping lags by exactly one tick.
        self.async_tick = bool(async_tick)
        assert mode == "continuous" or (
            not self.sched.chunked and preemption == "none"), \
            "chunked scheduling/preemption need the continuous engine"
        # speculative decoding on the variant ladder: "drafter:verifier"
        # names two loaded variants; every backend of the verifier variant
        # gets a dedicated drafter instance bound as a DraftPair
        self.spec_drafter = self.spec_verifier = None
        self.spec_k = int(spec_k)
        if speculative is not None:
            assert mode == "continuous", \
                "speculative decoding needs the continuous engine"
            drafter, _, verifier = speculative.partition(":")
            assert drafter and verifier and drafter != verifier, \
                f"speculative= wants 'drafter:verifier', got {speculative!r}"
            assert drafter in variants and verifier in variants, \
                f"speculative variants must be loaded: {speculative!r}"
            assert 1 <= self.spec_k <= max_new, \
                "spec_k must fit inside the decode budget"
            self.spec_drafter, self.spec_verifier = drafter, verifier
        self.variant_defs = dict(variants)       # name -> (cfg, accuracy)
        self.max_batch = max_batch
        self.prompt_len = prompt_len
        self.mode = mode
        self.max_new = max_new
        self.decode_chunk = decode_chunk
        self.queue_cap = queue_cap
        self.use_pallas = use_pallas
        # KV discipline of every backend this engine creates: "dense" is the
        # per-slot ring cache; "paged" the shared page pool (page_size tokens
        # per page, pool sized kv_pool_pages or full slot parity by default)
        self.kv_cache = kv_cache
        self.kv_page_size = kv_page_size
        self.kv_pool_pages = kv_pool_pages
        self.kv_prefix_sharing = kv_prefix_sharing
        # enforce_units: an allocation of n units caps the variant at n
        # concurrent slots — the same units -> concurrency mapping the
        # profiling subsystem measures th(n) under, so measured profiles
        # describe live capacity exactly (off by default: PR-1 semantics,
        # where units are cost bookkeeping and batching always uses the
        # full slot budget)
        self.enforce_units = enforce_units
        self.backends: Dict[str, VariantBackend] = {}
        self.units: Dict[str, int] = {}
        self.queues: Dict[str, Deque[Request]] = {}
        self.done: List[Request] = []
        self.rejected: int = 0
        self.cost_log: List[Tuple[float, int]] = []
        # replica sharding (cluster fabric): backends keyed by replica rid
        # ("variant#i") instead of variant name; ``nodes=None`` keeps the
        # legacy one-backend-per-variant layout byte-for-byte.
        self.fabric: Optional[ReplicaFabric] = None
        self.router = None
        if nodes is not None:
            # loading is synchronous on this engine (construction blocks for
            # the measured jit warm-up), so fabric readiness is immediate
            self.fabric = ReplicaFabric(nodes, policy=placement,
                                        replica_size=replica_size,
                                        rt_fn=lambda m: 0.0)
            self.router = make_router(router, metrics=self.metrics)

    def _make_backend(self, variant: str) -> VariantBackend:
        cfg, acc = self.variant_defs[variant]
        kw = dict(max_batch=self.max_batch, prompt_len=self.prompt_len,
                  max_new=self.max_new, decode_chunk=self.decode_chunk,
                  use_pallas=self.use_pallas, chunked=self.sched.chunked,
                  prefill_chunk_tokens=self.prefill_chunk,
                  preemption=self.preemption, clock=self.clock,
                  obs=self.obs,
                  # async-tick admission pipelining: build the continuation
                  # machinery so monolithic admission can route through the
                  # dispatch/commit pipeline (chunked admission of the same
                  # zero-padded prompt — bitwise-identical outputs)
                  build_chunked=self.async_tick)
        if self.kv_cache == "paged":
            b = PagedVariantBackend(variant, cfg, acc,
                                    page_size=self.kv_page_size,
                                    pool_pages=self.kv_pool_pages,
                                    prefix_sharing=self.kv_prefix_sharing,
                                    **kw)
        else:
            b = VariantBackend(variant, cfg, acc, **kw)
        if variant == self.spec_verifier:
            self._attach_drafter(b)
        return b

    def _attach_drafter(self, verifier: VariantBackend) -> None:
        """Materialize a dedicated drafter backend for one verifier replica
        and bind them as a ``DraftPair``. The drafter is hidden from
        routing/queues — it exists purely as the verifier's proposal
        engine, with its own KV (pool) sized for scratch headroom: drafts
        are written up to k positions past the last committed token before
        acceptance, plus one in-flight zombie round under async commit."""
        dcfg, dacc = self.variant_defs[self.spec_drafter]
        kw = dict(max_batch=self.max_batch, prompt_len=self.prompt_len,
                  max_new=self.max_new, decode_chunk=self.spec_k,
                  use_pallas=self.use_pallas, chunked=True,
                  prefill_chunk_tokens=self.prefill_chunk,
                  preemption="none", clock=self.clock, obs=self.obs,
                  cache_headroom=self.spec_k + 2)
        if self.kv_cache == "paged":
            d = PagedVariantBackend(self.spec_drafter, dcfg, dacc,
                                    page_size=self.kv_page_size, **kw)
        else:
            d = VariantBackend(self.spec_drafter, dcfg, dacc, **kw)
        DraftPair(verifier, d, self.spec_k)

    # ------------------------------------------------------------ ClusterAPI
    def apply_allocation(self, t: float, units: Mapping[str, int]) -> None:
        target = {m: n for m, n in units.items() if n > 0}
        if self.fabric is not None:
            self._apply_fabric(t, target)
            return
        for m, n in target.items():
            if m not in self.backends:
                self.backends[m] = self._make_backend(m)
                self.queues.setdefault(m, deque())
            self.backends[m].units = n
            self.backends[m].slot_cap = n if self.enforce_units else None
        for m in list(self.backends):
            if m not in target:
                b = self.backends.pop(m)
                # connection draining: finish in-flight work; waiting requests
                # stay queued and are rebalanced onto survivors at the next
                # tick — an accepted request is never dropped by a switch
                self.done.extend(b.drain_slots(t))
        self._rebalance_queues()
        self.units = dict(target)
        self.cost_log.append((t, sum(target.values())))

    def _apply_fabric(self, t: float, target: Mapping[str, int]) -> None:
        """Replica-granular create-then-remove: the fabric diffs the target
        replica multiset, new replicas become whole ``VariantBackend``
        instances (ready on construction — the warm-up blocks here, which IS
        rt_m), surplus replicas drain their slots and requeue waiters."""
        tr = self.fabric.apply(t, target)
        for rep in tr.created:
            b = self._make_backend(rep.variant)
            b.units = rep.units
            b.slot_cap = min(rep.units, self.max_batch) \
                if self.enforce_units else None
            b.slow_factor = rep.slow_factor
            rep.handle = b
            self.backends[rep.rid] = b
            self.queues.setdefault(rep.rid, deque())
        for rep in self.fabric.purge(t):     # switch_t == t: loads blocked
            b = self.backends.pop(rep.rid, None)
            if b is not None and not rep.crashed:
                self.done.extend(b.drain_slots(t))
        self._rebalance_queues()
        self.units = dict(target)
        self.cost_log.append((t, self.fabric.provisioned_units()))

    def _rebalance_queues(self) -> None:
        """Move requests queued on retired backends to the least-loaded live
        ones. Accepted work is never dropped, so a switch may transiently
        push a survivor's queue past ``queue_cap``; only *new* submissions
        are bounded (backpressure). If an allocation empties the cluster,
        orphans stay queued (visible via ``backlog``/``summarize['pending']``)
        and are served once the next allocation loads a variant."""
        if not self.backends:
            return                       # keep orphans until a variant loads
        dead = [m for m in self.queues if m not in self.backends]
        for m in dead:
            for r in self.queues.pop(m):
                tgt = min(self.backends,
                          key=lambda n: len(self.queues.setdefault(n, deque())))
                r.backend = tgt
                self.queues.setdefault(tgt, deque()).append(r)

    def loaded_variants(self, t: float) -> Set[str]:
        if self.fabric is not None:
            return set(self.fabric.variants_ready(t))
        return set(self.backends)

    def backlog(self, t: float) -> float:
        """Queued-but-not-in-service depth (requests waiting for a slot) —
        the shared ``ClusterAPI.backlog`` semantics; in-slot requests are in
        service and excluded."""
        return float(sum(len(q) for q in self.queues.values()))

    def capacity_factor(self, t: float) -> float:
        """Fraction of the target allocation actually live (1.0 without a
        fabric). Lets reactive controllers see crashes immediately."""
        return self.fabric.capacity_factor(t) if self.fabric is not None else 1.0

    def mark_warm(self, variants: Optional[Sequence[str]] = None,
                  t: float = 0.0) -> None:
        """Harness parity with the simulator: engine backends are ready the
        moment construction returns, so warm start is a no-op."""

    def in_flight(self) -> int:
        return sum(b.active_slots for b in self.backends.values())

    def flush_pending(self, now: float) -> int:
        """Commit every backend's in-flight async tick (no-op in sync mode
        or when nothing is pending). ``drain``/``drain_slots`` flush on
        their own; faults and external shutdown paths call this so
        bookkeeping never trails the last dispatch. Returns #completed."""
        n0 = len(self.done)
        for b in self.backends.values():
            self.done.extend(b.flush_pending(now))
        return len(self.done) - n0

    def kv_pool_stats(self) -> Optional[Dict]:
        """Aggregate page-pool usage across paged backends (None when the
        engine runs dense KV caches) — the memory-true capacity gauge that
        admission already enforces per backend via ``free_slots``.

        Occupancy-style levels are read off the live pools and published as
        registry gauges; the cumulative counters (prefix lookups/hits, fresh
        pages) are read from the registry, where the pools themselves
        already increment them — so this surface, the benchmarks, and the
        JSONL dump all report the same numbers from the same source. (When
        the registry is disabled the pools' own attribute counters are the
        fallback — live backends only, retired pools' history is gone.)"""
        pools = [b.pool for b in self.backends.values()
                 if isinstance(b, PagedVariantBackend)]
        if not pools:
            return None
        m = self.metrics
        used = sum(p.used_pages for p in pools)
        usable = sum(p.usable_pages for p in pools)
        shared = sum(p.shared_pages for p in pools)
        retained = sum(p.retained_pages for p in pools)
        occupancy = used / max(usable, 1)
        m.set("kv.used_pages", used)
        m.set("kv.usable_pages", usable)
        m.set("kv.shared_pages", shared)
        m.set("kv.retained_pages", retained)
        m.set("kv.occupancy", occupancy)
        if m.enabled:
            lookups = int(m.value("kv.prefix_lookups"))
            hits = int(m.value("kv.prefix_hits"))
            fresh = int(m.value("kv.pages_allocated"))
        else:
            lookups = sum(p.prefix_lookups for p in pools)
            hits = sum(p.prefix_hits for p in pools)
            fresh = sum(p.fresh_pages_allocated for p in pools)
        return {"used_pages": used, "usable_pages": usable,
                "occupancy": occupancy, "shared_pages": shared,
                "retained_pages": retained,
                "prefix_lookups": lookups, "prefix_hits": hits,
                "prefix_hit_rate": hits / max(lookups, 1),
                "fresh_pages_allocated": fresh}

    # ----------------------------------------------------------------- faults
    def inject_fault(self, now: float, event: FaultEvent) -> None:
        """Apply one ``repro.cluster.faults`` event (fabric mode only)."""
        if self.fabric is None:
            raise RuntimeError("fault injection requires the replica fabric "
                               "(construct the engine with nodes=)")
        if event.kind == "node_crash":
            self._crash_node(now, event.target)
        elif event.kind == "node_recover":
            self.fabric.recover_node(now, event.target)
        elif event.kind in ("replica_slowdown", "replica_restore"):
            factor = event.factor if event.kind == "replica_slowdown" else 1.0
            if self.fabric.slow_replica(now, event.target, factor):
                rep = self.fabric.replicas[event.target]
                if rep.handle is not None:
                    rep.handle.slow_factor = rep.slow_factor
        if self.obs.flight is not None:   # snapshot the run-up to the fault
            self.obs.flight.trigger(f"fault_{event.kind}", now,
                                    extra={"target": event.target,
                                           "factor": event.factor})

    def _crash_node(self, now: float, node_id: str) -> None:
        """Kill every replica on the node NOW (no drain): their in-flight
        and queued requests are re-submitted to survivors — retry semantics;
        latency keeps the original arrival stamp, so the failure's SLO cost
        is measured, not hidden."""
        # commit in-flight async ticks first: a request whose last tokens
        # are already committed on a SURVIVOR must not be re-submitted, and
        # the killed replicas' zombies re-enter the queue as full retries
        self.flush_pending(now)
        killed = self.fabric.crash_node(now, node_id)
        orphans: List[Tuple[str, Request]] = []
        for rep in killed:
            b = self.backends.pop(rep.rid, None)
            orphans.extend((rep.variant, r)
                           for r in self.queues.pop(rep.rid, deque()))
            if b is not None:
                orphans.extend((rep.variant, r)
                               for r in b.slot_req if r is not None)
        self.fabric.purge(now)
        for variant, r in orphans:
            r.service_start = 0.0        # retry starts from the queue again
            # retry keeps the dispatcher's variant choice: surviving replicas
            # of the same variant absorb first; _route_replica spills to the
            # whole cluster only if none are left. Full/empty: counts rejected
            self.submit(r, variant)

    # ---------------------------------------------------------------- serving
    def submit(self, req: Request, backend: Optional[str]) -> bool:
        """Enqueue on an admission queue. Legacy: ``backend`` names the
        variant's single backend. Fabric mode: two-level routing — the
        caller's dispatcher already picked the variant; the ``RoutingAPI``
        picks the replica among it (power-of-two-choices least-outstanding
        by default). Returns False — backpressure — when the queue is full."""
        if not self.backends:
            self.rejected += 1
            self.metrics.inc("requests.rejected")
            if self.windows.on:
                self.windows.inc("requests.rejected", self.clock())
            self.tracer.request_event(req, ev.REJECTED, self.clock(),
                                      reason="no_backend")
            return False
        if self.fabric is not None:
            name = self._route_replica(req, backend)
        else:
            name = backend if backend in self.backends else \
                min(self.queues, key=lambda m: len(self.queues[m])) \
                if self.queues else min(self.backends)
        q = self.queues.setdefault(name, deque())
        if len(q) >= self.queue_cap:
            self.rejected += 1
            self.metrics.inc("requests.rejected")
            if self.windows.on:
                self.windows.inc("requests.rejected", self.clock())
            self.tracer.request_event(req, ev.REJECTED, self.clock(),
                                      backend=name, reason="queue_full")
            return False
        req.backend = name
        q.append(req)
        self.metrics.inc("requests.submitted")
        if self.windows.on:
            self.windows.inc("requests.submitted", self.clock())
        # stamped at clock(), not req.arrival: a crash retry re-queues with
        # its original arrival preserved, and span times must stay monotone
        self.tracer.request_event(req, ev.QUEUED, self.clock(), backend=name,
                                  arrival=req.arrival)
        return True

    def _route_replica(self, req: Request, variant: Optional[str]) -> str:
        """Level 2 of two-level routing: pick the replica rid. Outstanding =
        queued + in-slot requests, normalized by replica units so bigger
        replicas absorb proportionally more."""
        rids = [rid for rid, b in self.backends.items()
                if variant is not None and b.name == variant]
        if not rids:                     # unknown/retired variant: all live
            rids = list(self.backends)
        views = [ReplicaView(
            rid,
            len(self.queues.get(rid, ())) + self.backends[rid].active_slots,
            self.backends[rid].units) for rid in rids]
        return self.router.pick(views)

    def step(self, now: float) -> int:
        """ONE engine tick (continuous mode): each backend admits waiting
        requests into free slots, then runs one jitted decode chunk.
        Non-blocking — the real-time loops in ``examples/`` and
        ``benchmarks/bench_engine.py`` call this between arrival batches."""
        if self.mode != "continuous":
            return self._pump_legacy(now)
        return self._tick(now)

    def pump(self, now: float) -> int:
        """Serve everything currently queued; returns #completed.

        Blocking convenience wrapper: in continuous mode it ticks until the
        queues and slots are empty; in pump mode it drains every queue in
        micro-batches (the legacy path)."""
        if self.mode == "continuous":
            return self.drain(now)
        return self._pump_legacy(now)

    def _tick(self, now: float) -> int:
        """One scheduler-driven engine tick per backend, in four phases:
        preempt (optional) → admit (scheduler-ordered) → prefill chunk
        (chunked only) → decode chunk. With the default FIFO scheduler and
        no preemption this is exactly the legacy admit+decode tick.

        With tracing on, each backend's tick lands one ``TickRecord``:
        wall cost per phase (``perf_counter`` around the phase bodies),
        batch geometry, and pool occupancy. Tracing off costs one branch
        per phase — the bench_engine overhead gate measures this path."""
        self._rebalance_queues()
        done_before = len(self.done)
        tron = self.tracer.on
        self._tick_no += 1
        # dispatch-profiler sampling: fence every Nth tick's exec call; the
        # records only exist with tracing on, so sampling follows tron
        fence = (tron and self.profile_dispatch > 0
                 and self._tick_no % self.profile_dispatch == 0)
        for name, b in self.backends.items():
            q = self.queues.get(name, deque())
            bdone = len(self.done)
            n_preempted = n_admitted = 0
            t0 = time.perf_counter() if tron else 0.0
            if self.preemption != "none" and q:
                # finished-but-uncommitted zombie slots are not preemptable:
                # their request is already complete by count, only its token
                # read-back lags (async commit lag)
                resident = [r for s, r in enumerate(b.slot_req)
                            if r is not None and s not in b._uncommitted_done]
                for v in self.sched.select_victims(resident, list(q), now,
                                                   len(b.free_slots)):
                    n_preempted += 1
                    if b.preempt(v, now) == "dropped":
                        self.done.append(v)
                        continue        # resumes later, tokens preserved
                    tq = q
                    if self.preemption == "migrate":
                        # cross-variant migration: resume on a cheaper
                        # variant via chunked prefill continuation — the
                        # accuracy-for-latency escape hatch under deadline
                        # pressure (stays put when nothing is cheaper)
                        tgt = migration_target(name, self.backends,
                                               self.queues)
                        if tgt is not None:
                            v.backend = tgt
                            tq = self.queues.setdefault(tgt, deque())
                            self.metrics.inc("requests.migrated")
                            if self.windows.on:
                                self.windows.inc("requests.migrated", now)
                    tq.append(v)
            t1 = time.perf_counter() if tron else 0.0
            free_n = len(b.free_slots)
            if q and free_n:
                ordered = self.sched.order(list(q), now)
                joiners, rest = ordered[:free_n], ordered[free_n:]
                q.clear()
                q.extend(rest)
                n_admitted = len(joiners)
                if self.sched.chunked:
                    self.done.extend(b.admit_chunked(joiners, now))
                elif self.async_tick and b.chunked:
                    # async-tick headroom: monolithic admission would
                    # prefill synchronously inside the tick; chunked
                    # admission of the same zero-padded prompt
                    # (right_sized stays off) defers the prefill into the
                    # dispatch/commit pipeline with identical outputs
                    self.done.extend(b.admit_chunked(joiners, now))
                else:
                    # resumed requests need prefill continuation even under
                    # monolithic admission (preemption builds the machinery)
                    fresh = [r for r in joiners if not r.resume_tokens]
                    self.done.extend(b.admit(fresh, now))
                    resumed = [r for r in joiners if r.resume_tokens]
                    if resumed:
                        self.done.extend(b.admit_chunked(resumed, now))
            t2 = time.perf_counter() if tron else 0.0
            if fence:
                b._fence_exec, b.exec_split = True, None
            nan = float("nan")
            commit_ms = gap_ms = wait_ms = hidden_ms = nan
            if self.async_tick:
                # dispatch tick t's exec, THEN commit tick t-1's: the read
                # + bookkeeping of t-1 hide behind t's device compute
                pend_prev, b._pending = b._pending, None
                kind, b._pending = b.dispatch_exec(now)
                t3 = time.perf_counter() if tron else 0.0
                self.done.extend(b.commit_exec(pend_prev, now))
                if tron and pend_prev is not None:
                    commit_ms = (time.perf_counter() - t3) * 1e3
                    gap_ms = b.commit_gap_ms
                    wait_ms = b.commit_wait_ms
                    # host work done this tick while t-1 was still in
                    # flight on the device (preempt + admit + dispatch)
                    hidden_ms = (t3 - t0) * 1e3
            elif b._prefilling:   # fused tick: prefill chunks + 1-tok decodes
                kind = "fused"
                self.done.extend(b.fused_chunk_step(now))
                t3 = time.perf_counter() if tron else 0.0
            else:                 # pure decode: the fast bucket-aware chunk
                kind = "decode" if b.active_slots else "idle"
                self.done.extend(b.decode_step_batch(now))
                t3 = time.perf_counter() if tron else 0.0
            if tron:
                exec_ms = (t3 - t2) * 1e3
                disp_ms = dev_ms = host_ms = nan
                if fence:
                    b._fence_exec = False
                    if b.exec_split is not None:  # idle ticks ran no jit
                        disp_ms, dev_ms = b.exec_split
                        host_ms = max(exec_ms - disp_ms - dev_ms, 0.0)
                occ = (b.kv_pool_occupancy
                       if isinstance(b, PagedVariantBackend) else float("nan"))
                self.tracer.tick(TickRecord(
                    backend=name, t=now, kind=kind,
                    preempt_ms=(t1 - t0) * 1e3, admit_ms=(t2 - t1) * 1e3,
                    exec_ms=exec_ms, active=b.active_slots,
                    prefilling=len(b._prefilling), queued=len(q),
                    admitted=n_admitted, preempted=n_preempted,
                    completed=len(self.done) - bdone, pool_occupancy=occ,
                    dispatch_ms=disp_ms, device_ms=dev_ms,
                    host_sync_ms=host_ms, commit_ms=commit_ms,
                    commit_gap_ms=gap_ms, commit_wait_ms=wait_ms,
                    hidden_host_ms=hidden_ms))
        return len(self.done) - done_before

    def drain(self, now: float, max_ticks: int = 10_000) -> int:
        """Tick until every queue and slot is empty."""
        if self.mode != "continuous":
            return self._pump_legacy(now)
        served = 0
        for _ in range(max_ticks):
            if not self.backends or (self.backlog(now) == 0
                                     and self.in_flight() == 0):
                break
            served += self._tick(now)
        return served

    def _pump_legacy(self, now: float) -> int:
        self._rebalance_queues()
        served = 0
        for name in list(self.queues):
            q = self.queues[name]
            if not q or name not in self.backends:
                continue
            b = self.backends[name]
            reqs = list(q)
            q.clear()
            for i in range(0, len(reqs), b.max_batch):
                chunk = reqs[i:i + b.max_batch]
                t_service = self.clock()
                for r in chunk:
                    r.service_start = t_service
                prompts = np.stack([
                    np.pad(r.tokens[:self.prompt_len],
                           (0, max(0, self.prompt_len - len(r.tokens))))
                    for r in chunk])
                gen = min(max(r.max_new for r in chunk), self.max_new)
                out = b.generate(prompts, max_new=gen)
                tdone = self.clock()
                for j, r in enumerate(chunk):
                    r.output = out[j, :min(r.max_new, self.max_new)]
                    r.completion = tdone
                    r.accuracy = b.accuracy
                    b._obs_complete(r)
                    self.done.append(r)
                    served += 1
        return served

    # ---------------------------------------------------------------- metrics
    def summarize(self, slo_ms: float, best_accuracy: float) -> Dict:
        out = summarize_requests(
            [r.arrival for r in self.done],
            [r.latency_ms for r in self.done],
            [r.accuracy for r in self.done],
            slo_ms=slo_ms, best_accuracy=best_accuracy,
            cost_samples=self.cost_log,
            queue_ms=[r.queue_wait_ms for r in self.done],
            service_ms=[r.service_ms for r in self.done],
            slo_list_ms=[r.slo_ms for r in self.done],
            dropped=[r.dropped for r in self.done])
        if out:
            out["rejected"] = self.rejected
            # accepted but not yet served (queued + in flight) — nonzero when
            # summarizing mid-run or after an allocation emptied the cluster
            out["pending"] = int(sum(len(q) for q in self.queues.values())
                                 + self.in_flight())
            pool = self.kv_pool_stats()
            if pool is not None:
                out["kv_pool_occupancy"] = pool["occupancy"]
                out["kv_shared_pages"] = pool["shared_pages"]
                out["kv_prefix_hit_rate"] = pool["prefix_hit_rate"]
            pairs = [b._spec_pair for b in self.backends.values()
                     if b._spec_pair is not None]
            if pairs:
                rounds = sum(int(p.slot_rounds.sum()) for p in pairs)
                acc = sum(int(p.slot_accepted.sum()) for p in pairs)
                prop = sum(int(p.slot_proposed.sum()) for p in pairs)
                out["spec_accept_rate"] = acc / max(prop, 1)
                out["spec_tokens_per_step"] = \
                    (acc + rounds) / max(rounds, 1)
        return out
