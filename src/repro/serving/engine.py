"""In-process JAX serving engine — the real-execution counterpart of the
discrete-event simulator. Implements the adapter's ClusterAPI so the same
InfAdapter controller drives either.

Each active variant gets a ``VariantBackend``: params + jitted prefill/decode
with slot-based batching (requests are micro-batched up to ``max_batch`` per
pump). Variant loading (init + jit warm-up) happens on first use — that IS
the readiness time rt_m on this backend, measured rather than assumed.

This engine is CPU-sized (smoke-scale variants) — it exists to run the
end-to-end example and integration tests with actual model execution; the
TPU-scale path is exercised by the dry-run.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import build_model


@dataclass
class Request:
    rid: int
    tokens: np.ndarray          # prompt (prompt_len,)
    max_new: int
    arrival: float
    backend: str = ""
    completion: float = 0.0
    output: Optional[np.ndarray] = None
    accuracy: float = 0.0

    @property
    def latency_ms(self) -> float:
        return (self.completion - self.arrival) * 1000.0


class VariantBackend:
    def __init__(self, name: str, cfg: ModelConfig, accuracy: float,
                 max_batch: int = 8, prompt_len: int = 32, max_new: int = 16,
                 seed: int = 0):
        self.name = name
        self.cfg = cfg
        self.accuracy = accuracy
        self.max_batch = max_batch
        self.prompt_len = prompt_len
        self.max_new = max_new
        self.model = build_model(cfg)
        self.units = 1
        t0 = time.time()
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, max_len=prompt_len + max_new))
        self._decode = jax.jit(self.model.decode_step)
        # warm-up compile at the fixed batch shape (part of readiness)
        toks = jnp.zeros((max_batch, prompt_len), jnp.int32)
        lg, cache = self._prefill(self.params, {"tokens": toks})
        self._decode(self.params, cache, jnp.zeros((max_batch,), jnp.int32))
        self.readiness_s = time.time() - t0

    def generate(self, prompts: np.ndarray, max_new: int) -> np.ndarray:
        """prompts: (b, prompt_len) padded to max_batch internally."""
        b = prompts.shape[0]
        pad = self.max_batch - b
        toks = jnp.asarray(np.pad(prompts, ((0, pad), (0, 0))))
        logits, cache = self._prefill(self.params, {"tokens": toks})
        outs = []
        tok = jnp.argmax(logits, axis=-1)
        for _ in range(max_new):
            outs.append(tok)
            logits, cache = self._decode(self.params, cache, tok)
            tok = jnp.argmax(logits, axis=-1)
        out = jnp.stack(outs, axis=1)
        return np.asarray(out[:b])


class InProcessServingEngine:
    """ClusterAPI + request execution on real models."""

    def __init__(self, variants: Mapping[str, Tuple[ModelConfig, float]],
                 max_batch: int = 8, prompt_len: int = 32):
        self.variant_defs = dict(variants)       # name -> (cfg, accuracy)
        self.max_batch = max_batch
        self.prompt_len = prompt_len
        self.backends: Dict[str, VariantBackend] = {}
        self.units: Dict[str, int] = {}
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self.cost_log: List[Tuple[float, int]] = []

    # ---- ClusterAPI ----
    def apply_allocation(self, t: float, units: Mapping[str, int]) -> None:
        target = {m: n for m, n in units.items() if n > 0}
        for m, n in target.items():
            if m not in self.backends:
                cfg, acc = self.variant_defs[m]
                self.backends[m] = VariantBackend(
                    m, cfg, acc, max_batch=self.max_batch,
                    prompt_len=self.prompt_len)
            self.backends[m].units = n
        for m in list(self.backends):
            if m not in target:
                del self.backends[m]
        self.units = dict(target)
        self.cost_log.append((t, sum(target.values())))

    def loaded_variants(self, t: float) -> Set[str]:
        return set(self.backends)

    def backlog(self, t: float) -> float:
        return float(len(self.queue))

    # ---- serving ----
    def submit(self, req: Request, backend: Optional[str]) -> None:
        req.backend = backend or ""
        self.queue.append(req)

    def pump(self, now: float) -> int:
        """Serve queued requests in micro-batches. Returns #served."""
        if not self.queue or not self.backends:
            return 0
        served = 0
        by_backend: Dict[str, List[Request]] = {}
        for r in self.queue:
            name = r.backend if r.backend in self.backends else \
                min(self.backends)
            by_backend.setdefault(name, []).append(r)
        self.queue.clear()
        for name, reqs in by_backend.items():
            b = self.backends[name]
            for i in range(0, len(reqs), b.max_batch):
                chunk = reqs[i:i + b.max_batch]
                prompts = np.stack([r.tokens for r in chunk])
                out = b.generate(prompts, max_new=max(r.max_new for r in chunk))
                tdone = time.time()
                for j, r in enumerate(chunk):
                    r.output = out[j, :r.max_new]
                    r.completion = tdone
                    r.accuracy = b.accuracy
                    self.done.append(r)
                    served += 1
        return served

    def summarize(self, slo_ms: float, best_accuracy: float) -> Dict:
        if not self.done:
            return {}
        lat = np.array([r.latency_ms for r in self.done])
        acc = np.array([r.accuracy for r in self.done])
        return {
            "n_requests": len(self.done),
            "violation_rate": float((lat > slo_ms).mean()),
            "p99_ms": float(np.percentile(lat, 99)),
            "mean_latency_ms": float(lat.mean()),
            "avg_accuracy": float(acc.mean()),
            "accuracy_loss": float(best_accuracy - acc.mean()),
        }
