"""Scheduling policies for the serving backends (``SchedulerAPI``).

The paper's objective is meeting latency SLOs while maximizing
accuracy-minus-cost, but allocation alone can't fix *ordering*: a strict-FIFO
queue with monolithic prefill head-of-line-blocks short interactive requests
behind long prompts, and the controllers then over-provision against the
resulting P99. INFaaS (PAPERS.md) makes the case that SLO-aware selection
needs per-request deadlines visible in the data plane; Loki that SLOs must be
enforced at the scheduling layer. This module is that layer, shared by the
real engine and the DES:

  * ``fifo``    — arrival order, monolithic prefill, no preemption. Exactly
    the pre-scheduler behavior; the default everywhere.
  * ``edf``     — earliest-deadline-first admission (``Request.deadline =
    arrival + slo_ms``). Requests whose deadline has already passed sort
    *after* all still-feasible ones (deadline order within each class):
    serving a hopeless request before a feasible one converts one violation
    into two.
  * ``chunked`` — EDF admission (or FIFO via ``order="fifo"``) plus chunked
    prefill: the backend splits prompt prefill into fixed-size chunks
    interleaved with decode ticks, so no resident decode step ever waits
    longer than one chunk (Sarathi-style stall-free scheduling).

Preemption is orthogonal and opt-in (the engine's ``preemption=`` mode):
``select_victims`` names in-service requests whose deadline has passed while
feasible work waits and no slot is free. Victims keep their generated tokens
(``Request.resume_tokens``) and are requeued (completing later from where
they stopped) or dropped. ``Request.preemptions`` bounds how often one
request may be preempted, so a hopeless request still finishes instead of
thrashing admit/preempt forever.
"""
from __future__ import annotations

from typing import List, Sequence, Union

from repro.serving.api import Request, SchedulerAPI

__all__ = ["FIFOScheduler", "EDFScheduler", "ChunkedScheduler",
           "make_scheduler", "MAX_PREEMPTIONS"]

# a request preempted this many times is never preempted again — bounded
# disruption, so preemption cannot livelock a request (property-tested)
MAX_PREEMPTIONS = 2


class FIFOScheduler:
    """Arrival order, monolithic prefill, no preemption — the pre-scheduler
    engine behavior, byte-for-byte."""

    name = "fifo"
    chunked = False

    def describe(self) -> dict:
        """Policy metadata for traces and audit logs (``repro.obs``)."""
        return {"policy": self.name, "chunked": self.chunked,
                "admission": getattr(self, "_order", self.name)}

    def order(self, queue: Sequence[Request], now: float) -> List[Request]:
        return list(queue)

    def select_victims(self, resident: Sequence[Request],
                       queue: Sequence[Request], now: float,
                       free_slots: int) -> List[Request]:
        return []


def _edf_key(r: Request, now: float):
    """Feasible-first EDF: requests whose deadline already passed sort after
    every still-feasible request (then by deadline, priority, arrival)."""
    return (r.deadline <= now, r.deadline, -r.priority, r.arrival)


class EDFScheduler:
    """Earliest-deadline-first admission over ``Request.deadline``.

    Preemption (only consulted when the engine enables it): while feasible
    requests wait and no slot is free, in-service requests whose deadline
    has passed are retired — latest deadline and lowest priority first —
    freeing slots/pages for work that can still meet its SLO.
    """

    name = "edf"
    chunked = False

    def describe(self) -> dict:
        """Policy metadata for traces and audit logs (``repro.obs``)."""
        return {"policy": self.name, "chunked": self.chunked,
                "admission": getattr(self, "_order", "edf"),
                "max_preemptions": MAX_PREEMPTIONS}

    def order(self, queue: Sequence[Request], now: float) -> List[Request]:
        return sorted(queue, key=lambda r: _edf_key(r, now))

    def select_victims(self, resident: Sequence[Request],
                       queue: Sequence[Request], now: float,
                       free_slots: int) -> List[Request]:
        feasible_waiting = sum(1 for r in queue if r.deadline > now)
        want = feasible_waiting - free_slots
        if want <= 0:
            return []
        hopeless = [r for r in resident
                    if r.deadline <= now and r.preemptions < MAX_PREEMPTIONS]
        hopeless.sort(key=lambda r: (-r.deadline, r.priority))  # latest first
        return hopeless[:want]


class ChunkedScheduler(EDFScheduler):
    """EDF (default) or FIFO admission + chunked prefill.

    The backend splits each prompt's prefill into ``prefill_chunk``-token
    chunks, one per engine tick, interleaved with decode chunks — bounding
    how long any resident decode slot waits on new admissions regardless of
    prompt length. Ordering and preemption are inherited from EDF unless
    constructed with ``order="fifo"``.
    """

    name = "chunked"
    chunked = True

    def __init__(self, order: str = "edf"):
        assert order in ("edf", "fifo"), order
        self._order = order
        if order != "edf":
            self.name = f"chunked-{order}"

    def order(self, queue: Sequence[Request], now: float) -> List[Request]:
        if self._order == "fifo":
            return list(queue)
        return super().order(queue, now)


def make_scheduler(spec: Union[str, SchedulerAPI]) -> SchedulerAPI:
    """Resolve ``"fifo" | "edf" | "chunked" | "chunked-fifo"`` (or pass a
    ``SchedulerAPI`` instance through) — the shared factory both backends
    call from their ``scheduler=`` parameter."""
    if not isinstance(spec, str):
        return spec
    if spec == "fifo":
        return FIFOScheduler()
    if spec == "edf":
        return EDFScheduler()
    if spec == "chunked":
        return ChunkedScheduler()
    if spec == "chunked-fifo":
        return ChunkedScheduler(order="fifo")
    raise ValueError(f"unknown scheduler {spec!r} "
                     "(expected fifo|edf|chunked|chunked-fifo)")


def migration_target(current: str, backends, queues) -> Union[str, None]:
    """Pick where a preempted request should resume under the engine's
    ``preemption="migrate"`` mode: the *cheapest* (lowest-accuracy) loaded
    backend strictly cheaper than the one it was preempted from, breaking
    ties by shortest queue — the accuracy-for-latency escape hatch of
    cross-variant migration (resume is a chunked prefill continuation, so
    any backend with the machinery can pick the request up with every
    generated token preserved). Returns None when nothing cheaper is
    loaded: the request requeues where it was, plain ``"requeue"``
    semantics."""
    cur_acc = backends[current].accuracy
    cheaper = [n for n, b in backends.items()
               if n != current and b.accuracy < cur_acc]
    if not cheaper:
        return None
    return min(cheaper, key=lambda n: (backends[n].accuracy,
                                       len(queues.get(n, ())), n))
