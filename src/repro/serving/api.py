"""Shared cluster/serving interface — the contract between the InfAdapter
control plane and any backend that executes requests.

The paper (arXiv 2304.10892) separates the *Adapter* (forecaster + Eq. 1
solver) from the cluster it reconfigures (§4, Fig. 3). This module pins that
boundary down as two protocols so the discrete-event simulator
(`repro.sim.cluster.SimCluster`) and the real-execution engine
(`repro.serving.engine.InProcessServingEngine`) are interchangeable under the
same controller, dispatcher, and experiment harness:

  * ``ClusterAPI``  — control-plane surface: ``apply_allocation`` (the paper's
    create-then-remove reconfiguration, §5), ``loaded_variants`` (feeds the
    loading-cost LC term of Eq. 1), and ``backlog`` (queued-not-in-service
    depth, used by the beyond-paper queue-aware / reactive controller modes).
  * ``ServingAPI``  — data-plane surface on top of ``ClusterAPI``: request
    submission plus the windowed metric summary both backends report.
  * ``SchedulerAPI`` — the scheduling discipline between a backend's
    admission queue and its execution slots (admission order, chunked
    prefill, preemption); policies live in ``repro.serving.sched`` and both
    backends accept ``scheduler=`` so DES and real execution queue
    identically (INFaaS-style SLO awareness in the data plane).

Both backends also accept ``nodes=`` to mount the replica-level cluster
fabric (``repro.cluster``: placement across nodes, two-level routing via a
``RoutingAPI`` replica picker, fault injection) while staying conformant to
these same protocols — controllers never see replicas, only variants.

``summarize_requests`` is the single implementation of the paper's evaluation
metrics (SLO-violation rate, P99, average accuracy drop vs the best variant,
time-averaged cost — §6); both backends call it so the simulator and the real
engine are scored identically.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import (Dict, List, Mapping, Optional, Protocol, Sequence, Set,
                    Tuple, runtime_checkable)

import numpy as np


@dataclass
class Request:
    """One inference request travelling through a serving backend.

    ``arrival``/``completion`` are seconds on whatever clock the backend uses
    (wall clock for the real engine, simulated time for the DES) — only the
    difference is ever interpreted. ``service_start`` is stamped when the
    request leaves the admission queue and begins execution (prefill on the
    real engine, server grab in the DES), splitting end-to-end latency into
    queue wait and *processing* latency — the quantity the paper's profiler
    fits as p_m(n) (§5) and the profiling subsystem measures.

    ``slo_ms`` is the request's latency SLO; ``deadline`` (arrival + SLO) is
    what deadline-aware schedulers (``repro.serving.sched``) order and
    preempt on. ``slo_ms <= 0`` means no per-request deadline — the summary's
    global ``slo_ms`` applies. ``resume_tokens``/``preemptions``/``dropped``
    are preemption bookkeeping: a preempted request keeps the tokens it
    already generated and either re-enters the queue (requeue) or finishes
    early with ``dropped=True`` (drop).
    """
    rid: int
    tokens: np.ndarray          # prompt (prompt_len,)
    max_new: int
    arrival: float
    backend: str = ""
    service_start: float = 0.0  # 0.0 = never entered service
    completion: float = 0.0
    output: Optional[np.ndarray] = None
    accuracy: float = 0.0
    slo_ms: float = 0.0         # per-request latency SLO; <=0 = none
    priority: float = 0.0       # higher = more important (preemption tiebreak)
    preemptions: int = 0        # times this request was preempted
    resume_tokens: Optional[List[int]] = field(default=None, repr=False)
    dropped: bool = False       # preempted-and-dropped: output is partial
    # lifecycle span events (repro.obs.trace.SpanEvent), mounted by the
    # backend's tracer when tracing is on — the request accumulates its own
    # typed timeline (queued → admitted → prefill chunks → … → complete),
    # every stamp from the backend's one clock. None when tracing is off.
    spans: Optional[List] = field(default=None, repr=False, compare=False)

    @property
    def deadline(self) -> float:
        """Absolute deadline on the backend's clock (inf when no SLO)."""
        if self.slo_ms <= 0.0:
            return float("inf")
        return self.arrival + self.slo_ms / 1000.0

    @property
    def latency_ms(self) -> float:
        return (self.completion - self.arrival) * 1000.0

    @property
    def queue_wait_ms(self) -> float:
        """Admission-queue wait (arrival → service start)."""
        if self.service_start <= 0.0:
            return 0.0
        return max(self.service_start - self.arrival, 0.0) * 1000.0

    @property
    def service_ms(self) -> float:
        """Processing latency p_m(n): service start → completion, excluding
        queue wait. Falls back to end-to-end latency when the backend did
        not stamp ``service_start``."""
        if self.service_start <= 0.0:
            return self.latency_ms
        return max(self.completion - self.service_start, 0.0) * 1000.0


@runtime_checkable
class ClusterAPI(Protocol):
    """Control-plane interface the InfAdapter controller drives (paper §4)."""

    def apply_allocation(self, t: float, units: Mapping[str, int]) -> None:
        """Reconfigure backends to ``units`` (variant -> resource units).

        Semantics follow the paper's zero-downtime patch (§5): new variants
        warm up for their readiness time rt_m before taking traffic, and old
        variants retire only after the replacements are ready
        (create-then-remove)."""
        ...

    def loaded_variants(self, t: float) -> Set[str]:
        """Variants currently loaded & ready — the LC term of Eq. 1 charges
        only for variants *not* in this set."""
        ...

    def backlog(self, t: float) -> float:
        """Requests **queued but not yet in service** — admitted work still
        waiting for a server/slot; requests being processed are excluded.
        Both backends share this definition: the engine reports admission-
        queue depth (in-slot requests are in service), the simulator counts
        whole service times of per-server work beyond the request currently
        occupying each server. Feeds the queue-aware controller extension
        (λ inflated by backlog/interval to drain within one interval)."""
        ...


@runtime_checkable
class SchedulerAPI(Protocol):
    """Per-backend scheduling discipline — the layer between a backend's
    admission queue and its execution slots (implementations in
    ``repro.serving.sched``; DESIGN.md §Scheduling).

    A scheduler makes three decisions each engine tick, all pure functions of
    the visible queue/slot state (no device work):

      * **admission order** — ``order`` ranks the waiting queue; the backend
        admits the prefix that fits its free slots (FIFO = arrival order,
        EDF = earliest ``Request.deadline`` first).
      * **prefill granularity** — ``chunked`` backends split prompt prefill
        into fixed-size chunks interleaved with decode ticks, so a long
        prompt never stalls resident decode slots for a whole prefill.
      * **preemption** — ``select_victims`` names in-service requests to
        retire early (slot + pages freed, generated tokens preserved) so a
        feasible waiter can run; the engine's ``preemption=`` mode decides
        whether victims are requeued or dropped.
    """

    name: str
    chunked: bool        # engine builds the prefill-continuation machinery

    def order(self, queue: Sequence["Request"], now: float) -> List["Request"]:
        """Rank waiting requests; the backend admits a prefix of this."""
        ...

    def select_victims(self, resident: Sequence["Request"],
                       queue: Sequence["Request"], now: float,
                       free_slots: int) -> List["Request"]:
        """In-service requests to preempt this tick (may be empty)."""
        ...


@runtime_checkable
class ServingAPI(ClusterAPI, Protocol):
    """Data-plane surface: what the experiment harness needs beyond control."""

    def submit(self, req: Request, backend: Optional[str]) -> bool:
        """Enqueue a request on a backend's admission queue. Returns False if
        the queue rejected it (backpressure)."""
        ...

    def step(self, now: float) -> int:
        """Advance an asynchronous backend by one scheduling tick (admission
        + one decode chunk on the real engine). Synchronous backends (the
        discrete-event simulator serves at submit time) no-op and return 0.
        Returns the number of requests completed by this call."""
        ...

    def drain(self, now: float) -> int:
        """Serve everything still queued or in flight; no-op on synchronous
        backends. Returns the number of requests completed by this call."""
        ...

    def summarize(self, slo_ms: float, best_accuracy: float) -> Dict:
        ...


def summarize_requests(arrivals: Sequence[float], latencies_ms: Sequence[float],
                       accuracies: Sequence[float], *, slo_ms: float,
                       best_accuracy: float,
                       cost_samples: Optional[Sequence[Tuple[float, int]]] = None,
                       window_s: float = 0.0,
                       queue_ms: Optional[Sequence[float]] = None,
                       service_ms: Optional[Sequence[float]] = None,
                       slo_list_ms: Optional[Sequence[float]] = None,
                       dropped: Optional[Sequence[bool]] = None) -> Dict:
    """The paper's evaluation summary (§6), shared by sim and real engine.

    Returns violation rate / P99 / mean latency / average accuracy and the
    accuracy *loss* vs the most accurate variant; with ``cost_samples`` the
    time-averaged provisioned units (the RC term integrated over time); with
    ``window_s`` also per-window series (the paper's Fig. 5/8 time plots) and
    ``violation_seconds`` (number of wall-clock seconds containing at least
    one violation — the unit the paper reports its 65% reduction in). With
    ``queue_ms``/``service_ms`` (the queue-wait / processing-latency split of
    each request, paper §5) also mean/P99 of each component — the processing
    side is what profile fits p_m(n) are checked against.

    **Goodput** — the fraction of requests that completed in full (not
    ``dropped``) within their deadline — is reported next to P99. Each
    request's effective SLO is its own ``slo_list_ms`` entry when positive,
    else the global ``slo_ms``; without per-request SLOs and drops, goodput
    is exactly ``1 - violation_rate``. This is the paper's objective stated
    per-request (INFaaS/Loki report the same quantity as "SLO attainment").

    Latency, queue wait, and service time each report p50/p95 alongside the
    p99 the paper headlines (tail shape, not just the tail point). When
    ``slo_list_ms`` is heterogeneous — more than one distinct positive SLO —
    ``slo_classes`` breaks n/goodput/p50/p99 out per SLO class, keyed by the
    class's SLO in ms (the multi-tenant view a per-class-aware controller
    consumes).
    """
    if len(arrivals) == 0:
        return {}
    order = np.argsort(np.asarray(arrivals, float))
    arr = np.asarray(arrivals, float)[order]
    lat = np.asarray(latencies_ms, float)[order]
    acc = np.asarray(accuracies, float)[order]
    viol = lat > slo_ms
    eff_slo = np.full(len(arr), slo_ms, float)
    if slo_list_ms is not None and len(slo_list_ms):
        per = np.asarray(slo_list_ms, float)[order]
        eff_slo = np.where(per > 0, per, eff_slo)
    ok = lat <= eff_slo
    if dropped is not None and len(dropped):
        ok &= ~np.asarray(dropped, bool)[order]
    out: Dict = {
        "n_requests": int(len(arr)),
        "violation_rate": float(viol.mean()),
        "goodput": float(ok.mean()),
        "p50_ms": float(np.percentile(lat, 50)),
        "p95_ms": float(np.percentile(lat, 95)),
        "p99_ms": float(np.percentile(lat, 99)),
        "mean_latency_ms": float(lat.mean()),
        "avg_accuracy": float(acc.mean()),
        "accuracy_loss": float(best_accuracy - acc.mean()),
    }
    if queue_ms is not None and len(queue_ms):
        q = np.asarray(queue_ms, float)
        out["mean_queue_ms"] = float(q.mean())
        out["p50_queue_ms"] = float(np.percentile(q, 50))
        out["p95_queue_ms"] = float(np.percentile(q, 95))
        out["p99_queue_ms"] = float(np.percentile(q, 99))
    if service_ms is not None and len(service_ms):
        s = np.asarray(service_ms, float)
        out["mean_service_ms"] = float(s.mean())
        out["p50_service_ms"] = float(np.percentile(s, 50))
        out["p95_service_ms"] = float(np.percentile(s, 95))
        out["p99_service_ms"] = float(np.percentile(s, 99))
    if slo_list_ms is not None and len(slo_list_ms):
        classes = sorted({float(v) for v in np.asarray(slo_list_ms, float)
                          if v > 0})
        if len(classes) > 1:        # heterogeneous SLOs: per-class breakdown
            per = np.asarray(slo_list_ms, float)[order]
            out["slo_classes"] = {}
            for c in classes:
                m = per == c
                out["slo_classes"][f"{c:g}"] = {
                    "n_requests": int(m.sum()),
                    "goodput": float(ok[m].mean()),
                    "p50_ms": float(np.percentile(lat[m], 50)),
                    "p99_ms": float(np.percentile(lat[m], 99)),
                }
    if cost_samples is not None:
        cost_t = np.array([c[0] for c in cost_samples], float)
        cost_v = np.array([c[1] for c in cost_samples], float)
        if len(cost_t) > 1:
            out["avg_cost_units"] = float(
                np.trapezoid(cost_v, cost_t) / max(cost_t[-1] - cost_t[0], 1e-9))
        else:
            out["avg_cost_units"] = float(cost_v.mean()) if len(cost_v) else 0.0
    if window_s > 0:
        out["violation_seconds"] = float(
            len({int(a) for a, v in zip(arr, viol) if v}))
        wins, p99s, accs, vrate = [], [], [], []
        # anchor windows at the first arrival's window boundary — arrivals may
        # be epoch wall-clock stamps, not trace-relative seconds
        t0 = np.floor(arr.min() / window_s) * window_s
        for w0 in np.arange(t0, arr.max(), window_s):
            m = (arr >= w0) & (arr < w0 + window_s)
            if m.sum() > 3:
                wins.append(float(w0))
                p99s.append(float(np.percentile(lat[m], 99)))
                accs.append(float(acc[m].mean()))
                vrate.append(float(viol[m].mean()))
        out["windows"] = {"t": wins, "p99_ms": p99s, "accuracy": accs,
                         "violation_rate": vrate}
    return out
