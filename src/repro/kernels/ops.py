"""Jit'd public wrappers around the Pallas kernels.

Handles layout transposes between the model's (B, S, H, hd) convention and the
kernels' (B, KV, G, S, hd) tiling layout and pads sequences to block
multiples. Interpret mode is auto-detected inside each kernel (compiled on a
real TPU backend, interpret everywhere else — this container validates on
CPU); pass ``interpret=`` explicitly at the kernel level to override.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_decode as fd
from repro.kernels import flash_prefill as fp
from repro.kernels import ssd_scan as ss
from repro.kernels import paged as pk


def _pad_to(x: jax.Array, axis: int, multiple: int) -> Tuple[jax.Array, int]:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array, *, window: int = 0,
                  softcap: float = 0.0) -> jax.Array:
    """q: (B,S,H,hd); k,v: (B,S,KV,hd) -> (B,S,H,hd). Causal (+window)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    bq = bk = min(fp.DEFAULT_BQ, max(8, 1 << (S - 1).bit_length()))
    qk = q.reshape(B, S, KV, G, hd).transpose(0, 2, 3, 1, 4)   # (B,KV,G,S,hd)
    kk = k.transpose(0, 2, 1, 3)                               # (B,KV,S,hd)
    vk = v.transpose(0, 2, 1, 3)
    qk, _ = _pad_to(qk, 3, bq)
    kk, _ = _pad_to(kk, 2, bk)
    vk, _ = _pad_to(vk, 2, bk)
    out = fp.flash_prefill_bkhd(qk, kk, vk, window=window, softcap=softcap,
                                bq=bq, bk=bk)
    out = out[:, :, :, :S]                                     # drop padding
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd)


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, bias: jax.Array, *,
                 softcap: float = 0.0) -> jax.Array:
    """q: (B,1,H,hd); k,v: (B,C,KV,hd); bias: (B,C) -> (B,1,H,hd)."""
    B, _, H, hd = q.shape
    C, KV = k.shape[1], k.shape[2]
    G = H // KV
    qk = q.reshape(B, KV, G, hd)
    kk = k.transpose(0, 2, 1, 3)                               # (B,KV,C,hd)
    vk = v.transpose(0, 2, 1, 3)
    out = flash_decode_bkchd(qk, kk, vk, bias, softcap=softcap)
    return out.reshape(B, 1, H, hd)


def flash_decode_bkchd(q: jax.Array, k: jax.Array, v: jax.Array,
                       bias: jax.Array, *, softcap: float = 0.0) -> jax.Array:
    """Kernel-native layout: q (B,KV,G,hd); k,v (B,KV,C,hd); bias (B,C)
    -> (B,KV,G,hd). No relayout copies (cache is stored in this layout).
    The kernel itself pads and masks a ragged tail block, so any C works."""
    C = k.shape[2]
    bk = min(fd.DEFAULT_BK, max(8, 1 << (C - 1).bit_length()))
    return fd.flash_decode_bkhd(q, k, v, bias, softcap=softcap, bk=bk)


def paged_flash_decode(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                       tables: jax.Array, lengths: jax.Array, *,
                       softcap: float = 0.0) -> jax.Array:
    """Paged decode in kernel-native layout: q (B,KV,G,hd); k/v_pages
    (KV,P,page_size,hd); tables (B,n_pages) page ids; lengths (B,) live
    tokens -> (B,KV,G,hd). The page pool IS the stored cache layout, so no
    gather/relayout copies are paid on the Pallas path."""
    return pk.paged_flash_decode_bkhd(q, k_pages, v_pages, tables, lengths,
                                      softcap=softcap)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, *, chunk: int = ss.DEFAULT_CHUNK,
             initial_state: Optional[jax.Array] = None):
    """x: (b,s,h,p); dt: (b,s,h); A: (h,); B,C: (b,s,n). s % chunk == 0
    (the model pads). Returns (y, final_state)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), jnp.float32)
    return ss.ssd_scan_chunked(x, dt, A, B, C, initial_state, chunk=chunk,
                               interpret=fd.resolve_interpret(None))
