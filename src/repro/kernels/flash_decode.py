"""Flash-decoding GQA attention for a single new token — Pallas TPU kernel.

One query token per sequence attends to a long KV cache. The KV cache is
tiled into BK-sized blocks (the innermost grid axis); running (m, l, acc)
scratch implements the online softmax across blocks — the TPU analogue of
flash-decoding's split-K, realized through the sequential TPU grid instead of
a cross-SM reduction (hardware adaptation noted in DESIGN.md).

An additive bias (B, C) carries slot validity (ring-buffer occupancy and
sliding-window masks are computed by the caller — they depend on the cache
discipline, not on the kernel).

Arbitrary context lengths are accepted: a ragged tail block (C % bk != 0)
is padded up to the block size and masked through the bias (-1e30 on the
padding), so callers need no divisibility discipline.

Grid: (B, KV, ceil(C/BK)). Block shapes keep the whole GQA group resident:
q (G, hd), k/v (BK, hd), bias (BK,) — VMEM ≈ G·hd + 2·BK·hd floats.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BK = 512
NEG_INF = -1e30


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """interpret=None auto-detects: compiled on a real TPU backend,
    interpret mode everywhere else (this container validates on CPU)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _decode_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, bk: int, softcap: float, n_kv_blocks: int):
    jk = pl.program_id(2)
    G, hd = q_ref.shape[2], q_ref.shape[3]

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                    # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)                    # (BK, hd)
    v = v_ref[0, 0].astype(jnp.float32)                    # (BK, hd)
    bias = bias_ref[0].astype(jnp.float32)                 # (BK,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, BK)
    s = s / np.sqrt(hd)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    s = s + bias[None, :]

    m_prev = m_ref[...]                                    # (G, 1)
    m_new = jnp.maximum(m_prev[:, 0], jnp.max(s, axis=-1))  # (G,)
    p = jnp.exp(s - m_new[:, None])
    scale = jnp.exp(m_prev[:, 0] - m_new)
    l_ref[...] = l_ref[...] * scale[:, None] + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))  # (G, hd)
    acc_ref[...] = acc_ref[...] * scale[:, None] + pv
    m_ref[...] = m_new[:, None]

    @pl.when(jk == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("softcap", "bk", "interpret"))
def flash_decode_bkhd(q: jax.Array, k: jax.Array, v: jax.Array,
                      bias: jax.Array, *, softcap: float = 0.0,
                      bk: int = DEFAULT_BK,
                      interpret: Optional[bool] = None) -> jax.Array:
    """q: (B, KV, G, hd); k, v: (B, KV, C, hd); bias: (B, C) -> out like q.

    C need not divide bk: the ragged tail block is padded and masked here."""
    B, KV, G, hd = q.shape
    C = k.shape[2]
    pad = (-C) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        bias = jnp.pad(bias, ((0, 0), (0, pad)), constant_values=NEG_INF)
    n_k = (C + pad) // bk
    kernel = functools.partial(_decode_kernel, bk=bk, softcap=softcap,
                               n_kv_blocks=n_k)
    return pl.pallas_call(
        kernel,
        grid=(B, KV, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, bk), lambda b, h, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(q, k, v, bias)
