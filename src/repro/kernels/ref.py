"""Pure-jnp oracles for every Pallas kernel (naive, obviously-correct forms).

These are intentionally *independent* implementations (no chunking, no online
softmax) so kernel tests compare two different algorithms for the same math.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def ref_flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array,
                      window: int = 0, softcap: float = 0.0) -> jax.Array:
    """Causal GQA attention. q: (B,S,H,hd); k,v: (B,S,KV,hd) -> (B,S,H,hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, S, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qf, k.astype(jnp.float32))
    scores = scores / np.sqrt(hd)
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(S)[None, :]
    ok = kj <= qi
    if window > 0:
        ok &= kj > qi - window
    scores = jnp.where(ok[None, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def ref_flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                     bias: jax.Array, softcap: float = 0.0) -> jax.Array:
    """One-token GQA decode. q: (B,1,H,hd); k,v: (B,C,KV,hd); bias: (B,C)
    additive (-1e9 for invalid slots) -> (B,1,H,hd)."""
    B, _, H, hd = q.shape
    C, KV = k.shape[1], k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, KV, G, hd)
    scores = jnp.einsum("bkgh,btkh->bkgt", qf, k.astype(jnp.float32)) / np.sqrt(hd)
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    scores = scores + bias[:, None, None, :].astype(jnp.float32)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", probs, v.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def ref_paged_decode(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                     tables: jax.Array, lengths: jax.Array,
                     softcap: float = 0.0) -> jax.Array:
    """Paged one-token GQA decode oracle: gather the pages each row owns
    into a dense (B, n_pages*page_size) context, mask positions beyond the
    row's length, and run plain softmax attention.

    q: (B, KV, G, hd); k/v_pages: (KV, P, page_size, hd);
    tables: (B, n_pages) int32 page ids; lengths: (B,) int32
    -> (B, KV, G, hd). Rows with length == 0 return zeros (matching the
    kernel's inert dead-slot semantics)."""
    B, KV, G, hd = q.shape
    ps = k_pages.shape[2]
    n_pages = tables.shape[1]
    kg = jnp.moveaxis(k_pages[:, tables], 1, 0)        # (B,KV,n_pages,ps,hd)
    vg = jnp.moveaxis(v_pages[:, tables], 1, 0)
    kg = kg.reshape(B, KV, n_pages * ps, hd).astype(jnp.float32)
    vg = vg.reshape(B, KV, n_pages * ps, hd).astype(jnp.float32)
    qf = q.astype(jnp.float32)
    scores = jnp.einsum("bkgh,bkth->bkgt", qf, kg) / np.sqrt(hd)
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    valid = jnp.arange(n_pages * ps)[None, :] < lengths[:, None]   # (B, T)
    scores = jnp.where(valid[:, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(valid[:, None, None], probs, 0.0)  # len==0: all-NaN -> 0
    probs = jnp.nan_to_num(probs)
    out = jnp.einsum("bkgt,bkth->bkgh", probs, vg)
    return out.astype(q.dtype)


def ref_ssd(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
            C: jax.Array, initial_state: Optional[jax.Array] = None,
            ) -> Tuple[jax.Array, jax.Array]:
    """Naive sequential SSD recurrence (token by token).

    x: (b,s,h,p); dt: (b,s,h); A: (h,); B,C: (b,s,n).
    h_t = exp(dt_t*A) * h_{t-1} + dt_t * x_t ⊗ B_t ;  y_t = h_t · C_t
    Returns (y (b,s,h,p), final state (b,h,p,n)).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), jnp.float32)

    def step(state, inp):
        x_t, dt_t, B_t, C_t = inp
        dA = jnp.exp(dt_t.astype(jnp.float32) * A.astype(jnp.float32))   # (b,h)
        dBx = jnp.einsum("bn,bhp->bhpn", B_t.astype(jnp.float32),
                         (x_t * dt_t[..., None]).astype(jnp.float32))
        state = state * dA[..., None, None] + dBx
        y = jnp.einsum("bhpn,bn->bhp", state, C_t.astype(jnp.float32))
        return state, y

    final, ys = jax.lax.scan(
        step, initial_state.astype(jnp.float32),
        (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
         B.transpose(1, 0, 2), C.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final
