"""Mamba-2 SSD chunked scan — Pallas TPU kernel.

State-space duality on the MXU: each chunk's intra-block output is a dense
(q×q) masked-decay attention-like matmul; the inter-chunk linear recurrence is
carried in a VMEM scratch state across the sequential chunk grid axis.

Grid: (B, n_chunks) with chunks innermost. Per step, blocks hold one chunk of
x (q, h, p), dt (q, h), B/C (q, n) plus the carried state (h, p, n) in fp32
scratch. All contractions are MXU matmuls; chunk length q=128 aligns the
(q×q) decay matrix and the (q×n)/(q×p) operands to hardware tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, init_ref, y_ref,
                final_ref, state_ref, *, n_chunks: int):
    ci = pl.program_id(1)
    q, h, p = x_ref.shape[2], x_ref.shape[3], x_ref.shape[4]
    n = b_ref.shape[3]

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = init_ref[0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)       # (q, h, p)
    dt = dt_ref[0, 0].astype(jnp.float32)     # (q, h)
    A = a_ref[...].astype(jnp.float32)        # (h,)
    Bm = b_ref[0, 0].astype(jnp.float32)      # (q, n)
    Cm = c_ref[0, 0].astype(jnp.float32)      # (q, n)

    xdt = x * dt[..., None]                   # (q, h, p)
    dA = dt * A[None, :]                      # (q, h)
    dA_cs = jnp.cumsum(dA, axis=0)            # (q, h)

    # ---- intra-chunk: y_diag[l] = sum_{s<=l} C_l·B_s * decay(l,s) * xdt[s]
    # decay(l, s) = exp(cs[l] - cs[s]) for s <= l
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))   # (q, q)
    li = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    causal = li >= si
    y_acc = jnp.zeros((q, h * p), jnp.float32)
    # per-head decay differs -> loop over heads (h is small: <= 48)
    decay_all = dA_cs[:, None, :] - dA_cs[None, :, :]            # (q, q, h)
    decay_all = jnp.where(causal[..., None], jnp.exp(decay_all), 0.0)
    Lfull = cb[..., None] * decay_all                            # (q, q, h)
    # y_diag[l, h, p] = sum_s Lfull[l, s, h] * xdt[s, h, p]
    y_diag = jnp.einsum("lsh,shp->lhp", Lfull, xdt,
                        preferred_element_type=jnp.float32)

    # ---- inter-chunk: contribution of carried state
    state = state_ref[...]                                       # (h, p, n)
    expcs = jnp.exp(dA_cs)                                       # (q, h)
    y_off = jnp.einsum("ln,hpn,lh->lhp", Cm, state, expcs,
                       preferred_element_type=jnp.float32)
    y_ref[0, 0] = (y_diag + y_off).astype(y_ref.dtype)

    # ---- state update: state' = decay_chunk * state + sum_s B_s ⊗ xdt_s decay
    total = dA_cs[-1]                                            # (h,)
    decay_states = jnp.exp(total[None, :] - dA_cs)               # (q, h)
    new_contrib = jnp.einsum("ln,lhp,lh->hpn", Bm, xdt, decay_states,
                             preferred_element_type=jnp.float32)
    state_ref[...] = state * jnp.exp(total)[:, None, None] + new_contrib

    @pl.when(ci == n_chunks - 1)
    def _finalize():
        final_ref[0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                     C: jax.Array, initial_state: jax.Array, *,
                     chunk: int = DEFAULT_CHUNK, interpret: bool = True):
    """x: (b,s,h,p); dt: (b,s,h); A: (h,); B,C: (b,s,n); init: (b,h,p,n).
    Returns (y (b,s,h,p), final_state (b,h,p,n) fp32). s % chunk == 0."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)
    kernel = functools.partial(_ssd_kernel, n_chunks=nc)
    y, final = pl.pallas_call(
        kernel,
        grid=(b, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, h, p), lambda i, c: (i, c, 0, 0, 0)),
            pl.BlockSpec((1, 1, chunk, h), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((h,), lambda i, c: (0,)),
            pl.BlockSpec((1, 1, chunk, n), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((1, h, p, n), lambda i, c: (i, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, h, p), lambda i, c: (i, c, 0, 0, 0)),
            pl.BlockSpec((1, h, p, n), lambda i, c: (i, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, chunk, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((h, p, n), jnp.float32)],
        interpret=interpret,
    )(xc, dtc, A, Bc, Cc, initial_state.astype(jnp.float32))
    return y.reshape(b, s, h, p), final
