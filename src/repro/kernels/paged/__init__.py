"""Paged KV-cache kernels: block-table-indexed attention over a page pool.

The serving engine's paged KV discipline (DESIGN.md §Paged KV cache) stores
each sequence's cache as a list of fixed-size pages drawn from a shared
per-replica pool; these kernels consume that layout directly instead of a
dense per-slot cache.
"""
from repro.kernels.paged.decode import (DEFAULT_PAGE_SIZE,  # noqa: F401
                                        paged_flash_decode_bkhd)
