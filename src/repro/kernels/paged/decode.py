"""Paged flash-decoding GQA attention — Pallas TPU kernel over a page pool.

One query token per sequence attends to a KV cache stored as fixed-size
*pages* in a shared pool ``(KV, P, page_size, hd)``; each sequence owns an
ordered list of page ids in a block table ``(B, n_pages)``. The kernel
gathers K/V through the block table with scalar prefetch: the table and the
per-row live lengths are ``PrefetchScalarGridSpec`` operands, so the
``index_map`` of the K/V BlockSpecs can address ``pages[tables[b, j]]``
before the grid step runs — the DMA engine fetches exactly the pages a
sequence owns, never a dense ``(B, C)`` cache slice.

Two properties make the per-step cost proportional to *live* context rather
than pool capacity (the whole point of the paged discipline):

  * the grid's page axis is bounded by the *caller's* ``n_pages`` — the
    engine buckets it to the max live page count of the current batch, not
    the per-slot capacity;
  * within the grid, rows skip pages beyond their own length with
    ``pl.when(j * page_size < length[b])`` (a row that retired or just
    joined does no attention work for pages it doesn't reach), and the tail
    page is masked per-position with an iota compare — ragged lengths need
    no padding discipline from the caller.

Running ``(m, l, acc)`` VMEM scratch implements the online softmax across
the sequential page axis, exactly like ``flash_decode.py`` (TPU split-K via
the sequential grid; DESIGN.md). A row with ``length == 0`` runs no compute
block at all and finalizes to zeros (``l`` is floored), so dead batch slots
are numerically inert.

Grid: (B, KV, n_pages). VMEM per step ≈ G·hd + 2·page_size·hd floats.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_decode import resolve_interpret

DEFAULT_PAGE_SIZE = 16
NEG_INF = -1e30


def _paged_decode_kernel(tables_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, page_size: int,
                         softcap: float, n_pages: int):
    b = pl.program_id(0)
    jp = pl.program_id(2)
    G, hd = q_ref.shape[2], q_ref.shape[3]

    @pl.when(jp == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lens_ref[b]

    # live-page bound: rows do no work for pages beyond their own length
    @pl.when(jp * page_size < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)                  # (ps, hd)
        v = v_ref[0, 0].astype(jnp.float32)                  # (ps, hd)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, ps)
        s = s / np.sqrt(hd)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        # masked tail: positions of this page beyond the row's length
        pos = jp * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (G, page_size), 1)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev = m_ref[...]                                  # (G, 1)
        m_new = jnp.maximum(m_prev[:, 0], jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        scale = jnp.exp(m_prev[:, 0] - m_new)
        l_ref[...] = (l_ref[...] * scale[:, None]
                      + jnp.sum(p, axis=-1, keepdims=True))
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))  # (G, hd)
        acc_ref[...] = acc_ref[...] * scale[:, None] + pv
        m_ref[...] = m_new[:, None]

    @pl.when(jp == n_pages - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)   # length-0 rows finalize to 0
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret"))
def paged_flash_decode_bkhd(q: jax.Array, k_pages: jax.Array,
                            v_pages: jax.Array, tables: jax.Array,
                            lengths: jax.Array, *, softcap: float = 0.0,
                            interpret: Optional[bool] = None) -> jax.Array:
    """q: (B, KV, G, hd); k/v_pages: (KV, P, page_size, hd);
    tables: (B, n_pages) int32 page ids; lengths: (B,) int32 live tokens
    per row -> out like q.

    ``tables[b, j]`` for ``j * page_size >= lengths[b]`` is never read by
    the compute path but must still be a valid pool index (< P) — the
    BlockSpec fetch happens regardless of the ``pl.when`` skip. The engine
    points unowned table entries at the reserved page 0.
    """
    B, KV, G, hd = q.shape
    ps = k_pages.shape[2]
    n_pages = tables.shape[1]
    assert k_pages.shape[0] == KV and v_pages.shape == k_pages.shape
    assert lengths.shape == (B,)
    kernel = functools.partial(_paged_decode_kernel, page_size=ps,
                               softcap=softcap, n_pages=n_pages)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j, t, n: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, ps, hd),
                         lambda b, h, j, t, n: (h, t[b, j], 0, 0)),
            pl.BlockSpec((1, 1, ps, hd),
                         lambda b, h, j, t, n: (h, t[b, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, j, t, n: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),     # running max m
            pltpu.VMEM((G, 1), jnp.float32),     # running sum l
            pltpu.VMEM((G, hd), jnp.float32),    # output accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=resolve_interpret(interpret),
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32), q, k_pages, v_pages)
