"""Block-tiled causal flash attention (prefill/train) — Pallas TPU kernel.

Online-softmax flash attention with GQA grouping and optional sliding window.
Tiling is MXU-oriented: query/key blocks of 128 along the sequence, the full
GQA group G and head_dim kept resident in VMEM per block.

Grid: (B, KV_heads, S/BQ, S/BK) with the KV-block axis innermost — TPU grids
execute sequentially, so the (m, l, acc) scratch accumulators implement the
online softmax across KV blocks. Fully-masked KV blocks (block start beyond
the causal frontier or behind the sliding window) are skipped with pl.when.

VMEM budget per step (BQ=BK=128, G<=8, hd<=256, fp32 scratch):
  q (G*BQ*hd) + k,v (BK*hd) + acc (G*BQ*hd) + scores (G*BQ*BK)  ≈ 2-3 MB.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_decode import resolve_interpret

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _prefill_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                    bq: int, bk: int, window: int, softcap: float,
                    seq_len: int, n_kv_blocks: int):
    iq = pl.program_id(2)
    jk = pl.program_id(3)
    G, hd = q_ref.shape[2], q_ref.shape[4]

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * bq
    k_start = jk * bk
    # Block-level causal/window reachability (static per grid step).
    reachable = k_start <= q_start + bq - 1
    if window > 0:
        reachable &= k_start + bk - 1 > q_start - window

    @pl.when(reachable)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # (G, BQ, hd)
        k = k_ref[0, 0].astype(jnp.float32)                  # (BK, hd)
        v = v_ref[0, 0].astype(jnp.float32)                  # (BK, hd)
        s = jax.lax.dot_general(q.reshape(G * bq, hd), k,
                                (((1,), (1,)), ((), ())))    # (G*BQ, BK)
        s = s.reshape(G, bq, bk) / np.sqrt(hd)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = kpos <= qpos
        if window > 0:
            ok &= kpos > qpos - window
        s = jnp.where(ok[None], s, NEG_INF)

        m_prev = m_ref[...]                                  # (G, BQ)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * scale + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(p.reshape(G * bq, bk), v,
                                 (((1,), (0,)), ((), ())))   # (G*BQ, hd)
        acc_ref[...] = acc_ref[...] * scale[..., None] + pv.reshape(G, bq, hd)
        m_ref[...] = m_new

    @pl.when(jk == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "bq", "bk",
                                             "interpret"))
def flash_prefill_bkhd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       window: int = 0, softcap: float = 0.0,
                       bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                       interpret: Optional[bool] = None) -> jax.Array:
    """q: (B, KV, G, S, hd); k, v: (B, KV, S, hd) -> out like q.

    S must be divisible by the block sizes (ops.py pads).
    """
    B, KV, G, S, hd = q.shape
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    n_q, n_k = S // bq, S // bk
    kernel = functools.partial(
        _prefill_kernel, bq=bq, bk=bk, window=window, softcap=softcap,
        seq_len=S, n_kv_blocks=n_k)
    return pl.pallas_call(
        kernel,
        grid=(B, KV, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, G, bq, hd), lambda b, h, i, j: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, bq, hd), lambda b, h, i, j: (b, h, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, bq), jnp.float32),        # running max m
            pltpu.VMEM((G, bq), jnp.float32),        # running sum l
            pltpu.VMEM((G, bq, hd), jnp.float32),    # output accumulator
        ],
        interpret=resolve_interpret(interpret),
    )(q, k, v)
