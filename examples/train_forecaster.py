"""Train the paper's LSTM load forecaster (25-unit LSTM + dense, Adam, MSE)
on a synthetic Twitter-like trace, and compare against baselines.

Run:  PYTHONPATH=src python examples/train_forecaster.py [--steps 300]
"""
import argparse

import numpy as np

from repro.core.forecaster import (EnsembleMaxForecaster, MovingMaxForecaster,
                                   forecast_mae, train_lstm_forecaster)
from repro.data.traces import synthetic_twitter_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--hours", type=int, default=4)
    args = ap.parse_args()

    trace = synthetic_twitter_trace(seconds=args.hours * 3600, seed=2)
    split = int(len(trace) * 0.75)
    print(f"trace: {len(trace)}s, train {split}s / test {len(trace)-split}s")

    fc, losses = train_lstm_forecaster(trace[:split], steps=args.steps)
    print(f"LSTM trained: loss {losses[0]:.4f} -> {losses[-1]:.4f}")

    test = trace[split:]
    rows = {
        "LSTM (paper)": fc,
        "MovingMax": MovingMaxForecaster(),
        "Ensemble(max)": EnsembleMaxForecaster(members=(fc, MovingMaxForecaster())),
    }
    print(f"\n{'forecaster':<16} {'MAE':>8} {'under-predict rate':>20}")
    for name, f in rows.items():
        m = forecast_mae(f, test, stride=240)
        print(f"{name:<16} {m['mae']:8.2f} {m['under_rate']:20.2%}")
    print("\n(under-predictions are what cause SLO violations; the ensemble "
          "trades MAE for safety)")


if __name__ == "__main__":
    main()
