"""Quickstart: solve the paper's core problem in 30 lines.

Given profiled ResNet variants, a latency SLO and a CPU budget, InfAdapter
picks a *set* of variants + allocations + traffic quotas maximizing
α·accuracy − (β·cost + γ·loading) — and beats the best single-variant choice.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.profiles import paper_resnet_profiles
from repro.core.solver import solve_exact, solve_single_variant

SLO_MS = 750.0
BUDGET = 14          # CPU cores
LOAD = 75.0          # requests/second (paper Fig. 2 scenario)

profiles = paper_resnet_profiles()

inf = solve_exact(profiles, LOAD, BUDGET, SLO_MS, beta=0.05, gamma=0.01)
ms = solve_single_variant(profiles, LOAD, BUDGET, SLO_MS, beta=0.05, gamma=0.01)

print(f"load={LOAD} RPS, budget={BUDGET} cores, SLO={SLO_MS} ms P99\n")
print("InfAdapter (variant set):")
for m, n in sorted(inf.units.items()):
    if n:
        print(f"  {m:10s} cores={n:2d} quota={inf.quotas.get(m, 0):5.1f} RPS "
              f"(p99={profiles[m].p99_ms(n):.0f} ms)")
print(f"  weighted accuracy = {inf.aa:.2f}%  cost = {inf.rc:.0f} cores")
print("\nModel-Switching+ (best single variant):")
for m, n in sorted(ms.units.items()):
    if n:
        print(f"  {m:10s} cores={n:2d}")
print(f"  accuracy = {ms.aa:.2f}%  cost = {ms.rc:.0f} cores")
print(f"\nInfAdapter accuracy gain: +{inf.aa - ms.aa:.2f}% at equal SLO/budget")
assert inf.aa >= ms.aa
