"""InfAdapter on the assigned LLM architectures (TPU resource model).

The paper's technique applied beyond ResNets: each assigned arch gets a
depth-scaled variant ladder whose throughput profiles come from the TPU v5e
roofline (chips as resource units instead of CPU cores — DESIGN.md §3).
The same exact-DP solver + simulator then runs the 20-minute bursty trace.

Run:  PYTHONPATH=src python examples/llm_autoscale_tpu.py [--arch yi-6b]
"""
import argparse

import numpy as np

from repro.configs import get_config
from repro.core.adapter import (ControllerConfig, InfAdapterController,
                                MSPlusController)
from repro.core.forecaster import MovingMaxForecaster
from repro.core.profiles import variant_ladder_profiles
from repro.data.traces import paper_bursty_trace
from repro.sim.runner import run_experiment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--budget", type=int, default=12, help="TPU chips")
    ap.add_argument("--slo-ms", type=float, default=2000.0)
    args = ap.parse_args()

    base = get_config(args.arch)
    profiles = variant_ladder_profiles(base)
    print(f"variant ladder for {args.arch} (chips as units):")
    for name, p in profiles.items():
        print(f"  {name:24s} acc~{p.accuracy:5.2f} th(4 chips)="
              f"{p.throughput(4):7.1f} rps  load={p.rt:5.1f}s")

    best = max(p.accuracy for p in profiles.values())
    # scale the trace to this ladder's capacity regime
    cap4 = min(p.throughput(4) for p in profiles.values())
    trace = paper_bursty_trace(base=cap4 * 2.0, spike=cap4 * 4.5)
    warm = {max(profiles, key=lambda m: profiles[m].th_slope): 4}

    cfg = ControllerConfig(budget=args.budget, slo_ms=args.slo_ms,
                           beta=0.02, gamma=0.05)
    for name, ctrl in [
        ("InfAdapter", InfAdapterController(profiles, MovingMaxForecaster(), cfg)),
        ("MS+", MSPlusController(profiles, MovingMaxForecaster(), cfg)),
    ]:
        r = run_experiment(name, ctrl, profiles, trace, slo_ms=args.slo_ms,
                           warm_start=warm, reference_accuracy=best)
        s = r.summary
        print(f"{name:12s} viol={s['violation_rate']:6.2%} "
              f"acc_loss={s['accuracy_loss']:5.2f} cost={s['avg_cost_units']:5.1f} chips")


if __name__ == "__main__":
    main()
