"""Replay the paper's 20-minute evaluation (Fig. 5/7/8) in simulation.

Compares InfAdapter vs MS+ vs VPA+{ResNet18,50,152} on the bursty and
non-bursty traces, printing the accuracy-loss / cost / P99 panels the paper
plots, plus the beyond-paper reactive+queue-aware InfAdapter.

Run:  PYTHONPATH=src python examples/replay_twitter_trace.py [--beta 0.05]
"""
import argparse

from repro.core.adapter import (ControllerConfig, InfAdapterController,
                                MSPlusController, VPAPlusController)
from repro.core.forecaster import MovingMaxForecaster
from repro.core.profiles import paper_resnet_profiles
from repro.data.traces import paper_bursty_trace, paper_nonbursty_trace
from repro.sim.runner import run_experiment

REF_ACC = 78.31  # ResNet152 (most accurate variant)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--beta", type=float, default=0.05)
    ap.add_argument("--budget", type=int, default=20)
    args = ap.parse_args()

    profiles = paper_resnet_profiles()
    for tname, trace in [("bursty (Fig.5)", paper_bursty_trace()),
                         ("non-bursty (Fig.8)", paper_nonbursty_trace())]:
        print(f"\n=== {tname}, beta={args.beta} ===")
        print(f"{'method':<22} {'viol%':>7} {'p99 ms':>8} {'acc loss':>9} {'cost':>6}")
        rows = []
        cfg = ControllerConfig(budget=args.budget, beta=args.beta, gamma=0.2)
        c = InfAdapterController(profiles, MovingMaxForecaster(), cfg)
        rows.append(run_experiment("InfAdapter", c, profiles, trace,
                                   warm_start={"resnet18": 8},
                                   reference_accuracy=REF_ACC))
        cfg_r = ControllerConfig(budget=args.budget, beta=args.beta, gamma=0.2,
                                 reactive=True, queue_aware=True)
        c = InfAdapterController(profiles, MovingMaxForecaster(), cfg_r)
        rows.append(run_experiment("InfAdapter-reactive*", c, profiles, trace,
                                   warm_start={"resnet18": 8},
                                   reference_accuracy=REF_ACC))
        c = MSPlusController(profiles, MovingMaxForecaster(), cfg)
        rows.append(run_experiment("MS+", c, profiles, trace,
                                   warm_start={"resnet18": 8},
                                   reference_accuracy=REF_ACC))
        for v in ("resnet18", "resnet50", "resnet152"):
            c = VPAPlusController(profiles[v], cfg)
            rows.append(run_experiment(f"VPA-{v}", c, {v: profiles[v]}, trace,
                                       warm_start={v: 8},
                                       reference_accuracy=REF_ACC))
        for r in rows:
            s = r.summary
            print(f"{r.name:<22} {s['violation_rate']*100:6.2f}% "
                  f"{s['p99_ms']:8.0f} {s['accuracy_loss']:8.2f}% "
                  f"{s['avg_cost_units']:6.1f}")
        print("(* beyond-paper extension; see EXPERIMENTS.md)")


if __name__ == "__main__":
    main()
