"""Replay the paper's 20-minute evaluation (Fig. 5/7/8) in simulation.

Compares InfAdapter vs MS+ vs VPA+{ResNet18,50,152} on the bursty and
non-bursty traces, printing the accuracy-loss / cost / P99 panels the paper
plots, plus the beyond-paper reactive+queue-aware InfAdapter.

``--engine`` additionally replays a smoke-scaled slice of the bursty trace
against the REAL ``InProcessServingEngine`` (continuous batching on actual
models) through the same control loop, using the shared
``run_serving_loop`` + ``trace_load`` helpers — the trace drives real
execution, not just the DES. ``--scheduler`` picks the engine's scheduling
discipline (fifo / edf / chunked; DESIGN.md §Scheduling).

Run:  PYTHONPATH=src python examples/replay_twitter_trace.py [--beta 0.05]
          [--engine --engine-seconds 20 --scheduler chunked]
"""
import argparse

from repro.core.adapter import (ControllerConfig, InfAdapterController,
                                MSPlusController, VPAPlusController)
from repro.core.forecaster import MovingMaxForecaster
from repro.core.profiles import paper_resnet_profiles
from repro.data.traces import paper_bursty_trace, paper_nonbursty_trace
from repro.sim.runner import run_experiment

REF_ACC = 78.31  # ResNet152 (most accurate variant)


def replay_on_engine(seconds: float, scheduler: str, scale: float) -> None:
    """Drive the real engine with the recorded bursty trace: profile a tiny
    variant ladder live, then replay ``trace_load(paper_bursty_trace())``
    (rate scaled to CPU smoke capacity) behind the InfAdapter loop."""
    from repro.configs import get_config, smoke_variant
    from repro.profiling.measure import EngineProfiler
    from repro.serving.driver import (ElapsedClock, run_serving_loop,
                                      trace_load)
    from repro.serving.engine import InProcessServingEngine

    base = smoke_variant(get_config("tinyllama-1.1b")).replace(d_model=128)
    variants = {
        "tiny-2L": (base.replace(num_layers=2, name="tiny-2L"), 70.0),
        "tiny-4L": (base.replace(num_layers=4, name="tiny-4L"), 75.0),
    }
    slo_ms = 2000.0
    engine = InProcessServingEngine(
        variants, max_batch=8, prompt_len=16, max_new=8, decode_chunk=4,
        scheduler=scheduler, clock=ElapsedClock())
    profiler = EngineProfiler(engine, points=(1, 2), requests_per_point=8,
                              warmup=2, max_units=3)
    profiles = {m.profile.name: m.profile
                for m in profiler.profile_all().values()}
    cfg = ControllerConfig(interval_s=5.0, budget=3, slo_ms=slo_ms,
                           beta=0.05, gamma=0.05, reactive=True,
                           queue_aware=True)
    ctrl = InfAdapterController(profiles, MovingMaxForecaster(window=10), cfg)
    # the paper trace peaks near 95 rps; scale it into CPU smoke range
    load_fn = trace_load(paper_bursty_trace(), scale=scale)
    print(f"\nreplaying bursty trace on the REAL engine for {seconds:.0f}s "
          f"(scheduler={scheduler}, rate scale {scale})...")
    n = run_serving_loop(engine, ctrl, seconds=seconds, interval=5.0,
                         load_fn=load_fn, slo_ms=slo_ms)
    s = engine.summarize(slo_ms, best_accuracy=75.0)
    if not s:
        print(f"no requests completed ({engine.rejected} rejected)")
        return
    print(f"engine replay: {s['n_requests']}/{n} served  "
          f"goodput={s['goodput']:.1%} viol={s['violation_rate']:.1%} "
          f"p99={s['p99_ms']:.0f}ms queue_p99={s.get('p99_queue_ms', 0):.0f}ms")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--beta", type=float, default=0.05)
    ap.add_argument("--budget", type=int, default=20)
    ap.add_argument("--engine", action="store_true",
                    help="also replay the bursty trace on the real engine "
                         "via run_serving_loop + trace_load")
    ap.add_argument("--engine-seconds", type=float, default=20.0)
    ap.add_argument("--engine-scale", type=float, default=0.15,
                    help="trace rate multiplier for the CPU-sized engine")
    ap.add_argument("--scheduler", default="chunked",
                    choices=("fifo", "edf", "chunked"),
                    help="engine scheduling discipline (--engine mode)")
    args = ap.parse_args()

    profiles = paper_resnet_profiles()
    for tname, trace in [("bursty (Fig.5)", paper_bursty_trace()),
                         ("non-bursty (Fig.8)", paper_nonbursty_trace())]:
        print(f"\n=== {tname}, beta={args.beta} ===")
        print(f"{'method':<22} {'viol%':>7} {'p99 ms':>8} {'acc loss':>9} {'cost':>6}")
        rows = []
        cfg = ControllerConfig(budget=args.budget, beta=args.beta, gamma=0.2)
        c = InfAdapterController(profiles, MovingMaxForecaster(), cfg)
        rows.append(run_experiment("InfAdapter", c, profiles, trace,
                                   warm_start={"resnet18": 8},
                                   reference_accuracy=REF_ACC))
        cfg_r = ControllerConfig(budget=args.budget, beta=args.beta, gamma=0.2,
                                 reactive=True, queue_aware=True)
        c = InfAdapterController(profiles, MovingMaxForecaster(), cfg_r)
        rows.append(run_experiment("InfAdapter-reactive*", c, profiles, trace,
                                   warm_start={"resnet18": 8},
                                   reference_accuracy=REF_ACC))
        c = MSPlusController(profiles, MovingMaxForecaster(), cfg)
        rows.append(run_experiment("MS+", c, profiles, trace,
                                   warm_start={"resnet18": 8},
                                   reference_accuracy=REF_ACC))
        for v in ("resnet18", "resnet50", "resnet152"):
            c = VPAPlusController(profiles[v], cfg)
            rows.append(run_experiment(f"VPA-{v}", c, {v: profiles[v]}, trace,
                                       warm_start={v: 8},
                                       reference_accuracy=REF_ACC))
        for r in rows:
            s = r.summary
            print(f"{r.name:<22} {s['violation_rate']*100:6.2f}% "
                  f"{s['p99_ms']:8.0f} {s['accuracy_loss']:8.2f}% "
                  f"{s['avg_cost_units']:6.1f}")
        print("(* beyond-paper extension; see EXPERIMENTS.md)")

    if args.engine:
        replay_on_engine(args.engine_seconds, args.scheduler,
                         args.engine_scale)


if __name__ == "__main__":
    main()
